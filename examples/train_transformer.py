"""Train a reduced assigned-architecture transformer end-to-end on CPU:
sharded jit (host mesh), AdamW, cosine schedule, checkpointing, loss curve.

    PYTHONPATH=src python examples/train_transformer.py \
        --arch qwen2-7b --steps 100

(The paper's kind is inference/serving, so the flagship end-to-end driver is
examples/collaborative_serve.py; this driver exercises the training substrate
on a reduced config — the full configs train only in the multi-pod dry-run.)
"""
import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.configs.registry import ARCH_IDS, get_smoke_config
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as tr
from repro.optim import adamw, cosine_warmup
from repro.sharding.specs import batch_specs, param_specs, to_shardings


def synth_batch(cfg, key, B, S):
    """Markov-chain synthetic tokens (learnable bigram structure)."""
    k1, k2 = jax.random.split(key)
    start = jax.random.randint(k1, (B, 1), 0, cfg.vocab_size)
    steps = jax.random.randint(k2, (B, S - 1), 1, 17)
    tok = jnp.concatenate(
        [start, (start + jnp.cumsum(steps, 1)) % cfg.vocab_size], 1)
    batch = {"tokens": tok,
             "labels": jnp.concatenate(
                 [tok[:, 1:], -jnp.ones((B, 1), jnp.int32)], 1)}
    if cfg.vision_tokens:
        batch["vision_embeds"] = jax.random.normal(
            k2, (B, cfg.vision_tokens, cfg.d_model), jnp.dtype(cfg.dtype))
    if cfg.embeds_input:
        batch = {"embeds": jax.random.normal(k1, (B, S, cfg.d_model),
                                             jnp.dtype(cfg.dtype)),
                 "labels": tok}
    return batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-7b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt", default="/tmp/repro_ckpt/train_transformer")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch).replace(dtype="float32")
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    n = tr.param_count(params)
    print(f"{args.arch} (reduced): {n / 1e6:.2f}M params, "
          f"{cfg.num_layers}L d{cfg.d_model}")
    opt = adamw(cosine_warmup(args.lr, warmup=min(10, args.steps // 5),
                          total=args.steps))
    opt_state = opt.init(params)
    mesh = make_host_mesh()

    def step_fn(p, s, b):
        (loss, metrics), grads = jax.value_and_grad(
            tr.loss_fn, has_aux=True)(p, cfg, b)
        p, s = opt.update(grads, s, p)
        return p, s, metrics

    with mesh:
        pshard = to_shardings(param_specs(params, cfg, mesh), mesh)
        b0 = synth_batch(cfg, jax.random.PRNGKey(1), args.batch, args.seq)
        bshard = to_shardings(batch_specs(b0, cfg, mesh), mesh)
        jitted = jax.jit(step_fn, in_shardings=(pshard, None, bshard),
                         donate_argnums=(0, 1))
        losses = []
        t0 = time.time()
        for i in range(args.steps):
            batch = synth_batch(cfg, jax.random.PRNGKey(100 + i),
                                args.batch, args.seq)
            params, opt_state, m = jitted(params, opt_state, batch)
            losses.append(float(m["loss"]))
            if i % 10 == 0 or i == args.steps - 1:
                dt = (time.time() - t0) / (i + 1)
                print(f"step {i:4d}  loss {losses[-1]:.4f}  "
                      f"xent {float(m['xent']):.4f}  {dt * 1e3:.0f} ms/step")
    head = float(np.mean(losses[:5]))
    tail = float(np.mean(losses[-5:]))
    assert tail < head, f"training must reduce the loss ({head} -> {tail})"
    os.makedirs(os.path.dirname(args.ckpt), exist_ok=True)
    store.save(args.ckpt, params, metadata={"arch": args.arch,
                                            "steps": args.steps,
                                            "final_loss": losses[-1]})
    print(f"checkpoint -> {args.ckpt}(.npz/.json)  "
          f"final loss {losses[-1]:.4f} (from {losses[0]:.4f})")


if __name__ == "__main__":
    main()
