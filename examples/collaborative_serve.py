"""End-to-end collaborative serving driver (the paper's deployment, §4.3,
minus the Gradio front end): a cloud server process on a localhost socket, an
edge client that runs the front sub-model, ships intermediate features over a
bandwidth-shaped (~50 Mbps) channel, and receives logits back — for a batch
of requests.

    PYTHONPATH=src python examples/collaborative_serve.py [--requests 16]
    [--bandwidth-mbps 50] [--split N]
"""
import argparse
import threading
import time

import jax
import numpy as np

from repro.core.collab.runtime import EdgeClient, serve_cloud
from repro.core.partition.latency_model import (cnn_input_bytes,
                                                cnn_layer_costs)
from repro.core.partition.profiles import PAPER_PROFILE, LinkProfile
from repro.core.partition.splitter import greedy_split
from repro.core.pruning.masks import cnn_masks_from_ratios
from repro.data.synthetic import PlantVillageSynthetic
from repro.models.cnn import init_cnn_params, tiny_cnn_config


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--bandwidth-mbps", type=float, default=50.0)
    ap.add_argument("--split", type=int, default=None,
                    help="split layer (default: greedy optimum)")
    ap.add_argument("--port", type=int, default=29480)
    ap.add_argument("--prune", type=float, default=0.5,
                    help="preserve ratio for conv layers (1.0 = dense)")
    args = ap.parse_args()

    cfg = tiny_cnn_config(num_classes=38, hw=32)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    data = PlantVillageSynthetic(n_per_class=4, hw=32)
    masks = None
    if args.prune < 1.0:
        ratios = {i: args.prune for i, s in enumerate(cfg.layers)
                  if s.kind == "conv" and i > 0}
        masks = cnn_masks_from_ratios(params, cfg, ratios)

    split = args.split
    if split is None:
        dec = greedy_split(cnn_layer_costs(cfg, masks), PAPER_PROFILE,
                           cnn_input_bytes(cfg))
        split = dec.split_point
        print(f"greedy split point: c={split} "
              f"(analytic T={dec.latency['T'] * 1e3:.2f} ms)")

    link = LinkProfile(f"{args.bandwidth_mbps} Mbps",
                       bandwidth=args.bandwidth_mbps * 1e6 / 8, rtt_s=2e-3)
    ready = threading.Event()
    srv = threading.Thread(
        target=serve_cloud, args=(params, cfg, split, args.port),
        kwargs=dict(masks=masks, link=link, max_requests=args.requests,
                    ready=ready), daemon=True)
    srv.start()
    ready.wait(10)
    client = EdgeClient(params, cfg, split, args.port, masks=masks,
                        link=link)

    print(f"serving {args.requests} requests, split c={split}, "
          f"{args.bandwidth_mbps} Mbps link, prune={args.prune}")
    lat, correct = [], 0
    t0 = time.time()
    for i in range(args.requests):
        c, idx = data.test_ids[i % len(data.test_ids)]
        img = data._batch(np.array([[c, idx]]))["image"]
        res = client.infer(img)
        lat.append(res["t_edge"] + res["t_net_and_cloud"])
        correct += int(np.argmax(res["logits"]) == c)
        print(f"  req {i:2d}: {lat[-1] * 1e3:7.2f} ms "
              f"(edge {res['t_edge'] * 1e3:6.2f} | net+cloud "
              f"{res['t_net_and_cloud'] * 1e3:7.2f}) tx {res['tx_bytes']} B")
    client.close()
    srv.join(5)
    lat = np.array(lat)
    print(f"\nthroughput {args.requests / (time.time() - t0):.1f} req/s | "
          f"latency mean {lat.mean() * 1e3:.2f} ms  p50 "
          f"{np.percentile(lat, 50) * 1e3:.2f}  p95 "
          f"{np.percentile(lat, 95) * 1e3:.2f}")


if __name__ == "__main__":
    main()
