"""End-to-end collaborative serving driver (the paper's deployment, §4.3,
minus the Gradio front end): a cloud server process on a localhost socket, an
edge client that runs the front sub-model, ships intermediate features over a
bandwidth-shaped (~50 Mbps) channel, and receives logits back — for a batch
of requests.

The fast deployment path is on by default: pruning masks are physically
compacted on both peers (--no-compact for masked-but-dense execution), the
split-boundary features cross the wire through the chosen --codec, and
--pipeline streams requests through EdgeClient.submit/collect so edge
compute overlaps the network+cloud time of earlier requests.

    PYTHONPATH=src python examples/collaborative_serve.py [--requests 16]
    [--bandwidth-mbps 50] [--split N] [--codec int8] [--pipeline]
"""
import argparse
import threading
import time

import jax
import numpy as np

from repro.core.collab.protocol import CODEC_TX_SCALE
from repro.core.collab.runtime import EdgeClient, serve_cloud
from repro.core.partition.latency_model import (cnn_input_bytes,
                                                cnn_layer_costs,
                                                compacted_cnn_layer_costs)
from repro.core.partition.profiles import PAPER_PROFILE, LinkProfile
from repro.core.partition.splitter import greedy_split
from repro.core.pruning.masks import cnn_masks_from_ratios
from repro.data.synthetic import PlantVillageSynthetic
from repro.models.cnn import init_cnn_params, tiny_cnn_config


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--bandwidth-mbps", type=float, default=50.0)
    ap.add_argument("--split", type=int, default=None,
                    help="split layer (default: greedy optimum)")
    ap.add_argument("--port", type=int, default=29480)
    ap.add_argument("--prune", type=float, default=0.5,
                    help="preserve ratio for conv layers (1.0 = dense)")
    ap.add_argument("--no-compact", dest="compact", action="store_false",
                    help="run masked-but-dense instead of physically "
                         "compacted submodels")
    ap.add_argument("--codec", choices=list(CODEC_TX_SCALE), default="fp32",
                    help="wire encoding of the split-boundary features")
    ap.add_argument("--pipeline", action="store_true",
                    help="stream requests via submit/collect (overlapped) "
                         "instead of one-at-a-time infer")
    args = ap.parse_args()

    cfg = tiny_cnn_config(num_classes=38, hw=32)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    data = PlantVillageSynthetic(n_per_class=4, hw=32)
    masks = None
    if args.prune < 1.0:
        ratios = {i: args.prune for i, s in enumerate(cfg.layers)
                  if s.kind == "conv" and i > 0}
        masks = cnn_masks_from_ratios(params, cfg, ratios)

    compact = args.compact and masks is not None
    split = args.split
    if split is None:
        costs = (compacted_cnn_layer_costs(cfg, masks) if compact
                 else cnn_layer_costs(cfg, masks))
        dec = greedy_split(costs, PAPER_PROFILE, cnn_input_bytes(cfg),
                           tx_scale=CODEC_TX_SCALE[args.codec])
        split = dec.split_point
        print(f"greedy split point: c={split} "
              f"({'compacted' if compact else 'masked'} shapes, "
              f"codec={args.codec}, analytic "
              f"T={dec.latency['T'] * 1e3:.2f} ms)")

    link = LinkProfile(f"{args.bandwidth_mbps} Mbps",
                       bandwidth=args.bandwidth_mbps * 1e6 / 8, rtt_s=2e-3)
    ready = threading.Event()
    srv = threading.Thread(
        target=serve_cloud, args=(params, cfg, split, args.port),
        kwargs=dict(masks=masks, link=link, max_requests=args.requests,
                    ready=ready, compact=compact), daemon=True)
    srv.start()
    ready.wait(10)
    client = EdgeClient(params, cfg, split, args.port, masks=masks,
                        link=link, compact=compact, codec=args.codec,
                        pack=not compact)

    print(f"serving {args.requests} requests, split c={split}, "
          f"{args.bandwidth_mbps} Mbps link, prune={args.prune}, "
          f"compact={compact}, codec={args.codec}, "
          f"pipeline={args.pipeline}")
    images, labels = [], []
    for i in range(args.requests):
        c, idx = data.test_ids[i % len(data.test_ids)]
        images.append(data._batch(np.array([[c, idx]]))["image"])
        labels.append(c)
    t0 = time.time()
    if args.pipeline:
        for img in images:
            client.submit(img)
        results = client.collect()
    else:
        results = [client.infer(img) for img in images]
    wall = time.time() - t0
    correct, lat = 0, []
    for i, (res, c) in enumerate(zip(results, labels)):
        correct += int(np.argmax(res["logits"]) == c)
        t = res.get("t_edge", 0.0) + res.get("t_net_and_cloud", 0.0)
        lat.append(t)
        print(f"  req {i:2d}: edge {res['t_edge'] * 1e3:6.2f} ms  "
              f"tx {res['tx_bytes']} B")
    client.close()
    srv.join(5)
    lat = np.array(lat)
    print(f"\nthroughput {args.requests / wall:.1f} req/s "
          f"(wall {wall * 1e3:.1f} ms)")
    if not args.pipeline:
        print(f"latency mean {lat.mean() * 1e3:.2f} ms  p50 "
              f"{np.percentile(lat, 50) * 1e3:.2f}  p95 "
              f"{np.percentile(lat, 95) * 1e3:.2f}")


if __name__ == "__main__":
    main()
