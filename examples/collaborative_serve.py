"""End-to-end collaborative serving driver (the paper's deployment, §4.3,
minus the Gradio front end), on the unified ``repro.serving`` API: one
``DeploymentPlan`` (model + masks + split + codec + link) deployed to both
peers — a cloud server process on a localhost socket and an edge client
that runs the front sub-model, ships intermediate features over a
bandwidth-shaped (~50 Mbps) channel, and receives logits back. The
connection opens with the HELLO handshake, so a peer loading a different
plan is rejected instead of decoding garbage.

The fast deployment path is on by default: pruning masks are physically
compacted on both peers (--no-compact for masked-but-dense execution), the
split-boundary features cross the wire through the chosen --codec, and
--pipeline streams requests through the session's pipelined infer_many so
edge compute overlaps the network+cloud time of earlier requests.

Time-varying links: --trace NAME (wifi_degrading, lte_handover, ...)
replays a canned bandwidth trace on both peers' shapers, and --adaptive
arms the plan's adaptive section so the edge session re-splits live
(RESPLIT frame, same connection) as the measured link drifts.

    PYTHONPATH=src python examples/collaborative_serve.py [--requests 16]
    [--bandwidth-mbps 50] [--split N] [--codec int8] [--pipeline]
    [--trace wifi_degrading] [--adaptive]
    [--save-plan DIR | --load-plan DIR]
"""
import argparse
import time

import jax
import numpy as np

from repro import serving
from repro.core.collab.protocol import CODEC_TX_SCALE
from repro.core.partition.profiles import (LinkProfile, PAPER_PROFILE,
                                           TRACES, TwoTierProfile)
from repro.core.pruning.masks import cnn_masks_from_ratios
from repro.data.synthetic import PlantVillageSynthetic
from repro.models.cnn import init_cnn_params, tiny_cnn_config


def build_plan(args) -> serving.DeploymentPlan:
    cfg = tiny_cnn_config(num_classes=38, hw=32)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    masks = None
    if args.prune < 1.0:
        ratios = {i: args.prune for i, s in enumerate(cfg.layers)
                  if s.kind == "conv" and i > 0}
        masks = cnn_masks_from_ratios(params, cfg, ratios)
    compact = args.compact and masks is not None
    link = LinkProfile(f"{args.bandwidth_mbps} Mbps",
                       bandwidth=args.bandwidth_mbps * 1e6 / 8, rtt_s=2e-3)
    profile = TwoTierProfile(PAPER_PROFILE.device, PAPER_PROFILE.server,
                             link)
    adaptive = None
    if args.adaptive:
        # every interior split plus the endpoints is a legal landing spot
        adaptive = serving.AdaptivePolicy(
            candidates=tuple(range(len(cfg.layers) + 1)))
    # split=None -> greedy optimum on the deployed (compacted/masked)
    # shapes with the codec's wire discount priced in
    return serving.DeploymentPlan.from_args(
        params, cfg, args.split, masks=masks, compact=compact,
        codec=args.codec, pack=not compact and masks is not None,
        profile=profile, port=args.port, adaptive=adaptive)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--bandwidth-mbps", type=float, default=50.0)
    ap.add_argument("--split", type=int, default=None,
                    help="split layer (default: greedy optimum)")
    ap.add_argument("--port", type=int, default=29480)
    ap.add_argument("--prune", type=float, default=0.5,
                    help="preserve ratio for conv layers (1.0 = dense)")
    ap.add_argument("--no-compact", dest="compact", action="store_false",
                    help="run masked-but-dense instead of physically "
                         "compacted submodels")
    ap.add_argument("--codec", choices=list(CODEC_TX_SCALE), default="fp32",
                    help="wire encoding of the split-boundary features")
    ap.add_argument("--pipeline", action="store_true",
                    help="stream requests via the session's pipelined "
                         "infer_many instead of one-at-a-time infer")
    ap.add_argument("--trace", choices=sorted(TRACES), default=None,
                    help="replay a canned time-varying link trace on the "
                         "socket shapers instead of the fixed bandwidth")
    ap.add_argument("--adaptive", action="store_true",
                    help="arm the plan's adaptive section: the session "
                         "re-splits live as the measured link drifts")
    ap.add_argument("--save-plan", default=None, metavar="DIR",
                    help="export the DeploymentPlan artifact and exit")
    ap.add_argument("--load-plan", default=None, metavar="DIR",
                    help="serve a previously exported plan instead of "
                         "building one")
    args = ap.parse_args()

    if args.load_plan:
        plan = serving.DeploymentPlan.load(args.load_plan)
        plan.port = args.port        # transport is not part of the contract
        if (args.split is not None or args.codec != "fp32"
                or not args.compact or args.prune != 0.5
                or args.bandwidth_mbps != 50.0):
            print("note: --load-plan serves the saved contract; "
                  "--split/--codec/--no-compact/--prune/--bandwidth-mbps "
                  "are ignored")
    else:
        plan = build_plan(args)
    print(plan.describe())
    bw_mbps = plan.profile.link.bandwidth * 8 / 1e6
    if args.save_plan:
        plan.save(args.save_plan)
        print(f"plan exported to {args.save_plan}/ "
              f"(serve it with --load-plan)")
        return

    data = PlantVillageSynthetic(n_per_class=4, hw=32)
    images, labels = [], []
    for i in range(args.requests):
        c, idx = data.test_ids[i % len(data.test_ids)]
        images.append(data._batch(np.array([[c, idx]]))["image"])
        labels.append(c)

    trace = TRACES[args.trace] if args.trace else None
    print(f"serving {args.requests} requests, split c={plan.split}, "
          f"{(trace.name if trace else f'{bw_mbps:g} Mbps')} link, "
          f"masked_layers={len(plan.masks) if plan.masks else 0}, "
          f"compact={plan.compact}, codec={plan.codec}, "
          f"pipeline={args.pipeline}, adaptive={bool(plan.adaptive)}")
    with serving.CloudServer(plan, max_requests=args.requests,
                             trace=trace) as cloud:
        with serving.connect(plan, backend="socket",
                             trace=trace) as sess:
            t0 = time.time()
            if args.pipeline:
                results = sess.infer_many(images)
            else:
                results = [sess.infer(img) for img in images]
            wall = time.time() - t0
            switches = list(sess.switches)
    for sw in switches:
        print("  " + sw.describe())
    correct, lat = 0, []
    for i, (res, c) in enumerate(zip(results, labels)):
        correct += int(np.argmax(res["logits"]) == c)
        lat.append(res["t_total"] or 0.0)
        print(f"  req {i:2d}: edge {res['t_edge'] * 1e3:6.2f} ms  "
              f"tx {res['tx_bytes']} B")
    lat = np.array(lat)
    print(f"\nthroughput {args.requests / wall:.1f} req/s "
          f"(wall {wall * 1e3:.1f} ms)")
    if not args.pipeline:
        print(f"latency mean {lat.mean() * 1e3:.2f} ms  p50 "
              f"{np.percentile(lat, 50) * 1e3:.2f}  p95 "
              f"{np.percentile(lat, 95) * 1e3:.2f}")


if __name__ == "__main__":
    main()
