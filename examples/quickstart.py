"""Quickstart: the paper's two-stage optimization in ~60 seconds on CPU.

    PYTHONPATH=src python examples/quickstart.py

1. builds a reduced AlexNet-family CNN + synthetic PlantVillage-38,
2. trains it briefly,
3. runs a short DDPG pruning search (AMC, paper §3.2),
4. greedy split-point selection (Algorithm 1) under the paper's
   i7-edge / 3090-server / 50 Mbps-Wi-Fi profile,
5. deploys the resulting DeploymentPlan through repro.serving and prints
   the Eq. 5 breakdown.
"""
import numpy as np

from repro import serving
from repro.core.pipeline import run_paper_pipeline
from repro.data.synthetic import PlantVillageSynthetic
from repro.models.cnn import tiny_cnn_config


def main():
    print("== quickstart: prune + split a plant-disease CNN ==")
    cfg = tiny_cnn_config(num_classes=38, width=0.25, hw=32)
    data = PlantVillageSynthetic(n_per_class=10, hw=32)
    res = run_paper_pipeline(cfg, data, train_epochs=5, finetune_epochs=3,
                             episodes=24, warmup=6, flops_budget=0.7,
                             optimizer_name="adamw", lr=3e-3,
                             log=lambda s: print("  ", s))
    print(f"\noriginal  acc: {res.acc_original}")
    print(f"pruned    acc: {res.acc_pruned}")
    print(f"fine-tuned acc: {res.acc_finetuned}")
    print(f"pruning ratios: { {k: round(v, 2) for k, v in res.ratios.items()} }")
    print(f"optimal split: c={res.split.split_point} "
          f"T={res.split.latency['T'] * 1e3:.2f} ms "
          f"(T_D {res.split.latency['T_D'] * 1e3:.2f} + "
          f"T_TX {res.split.latency['T_TX'] * 1e3:.2f} + "
          f"T_S {res.split.latency['T_S'] * 1e3:.2f})")

    print("\n== deploy the plan and serve one image ==")
    print(res.plan.describe())
    with serving.connect(res.plan, backend="local") as sess:
        img = data._batch(data.test_ids[:1])["image"]
        out = sess.infer(img)
    print(f"predicted class: {int(np.argmax(out['logits']))} "
          f"(true {int(data.test_ids[0][0])})")
    print(f"T = {out['t_total'] * 1e3:.2f} ms  "
          f"[edge {out['t_edge'] * 1e3:.2f} | net+cloud "
          f"{out['t_upstream'] * 1e3:.2f} ({out['tx_bytes']} B)]")


if __name__ == "__main__":
    main()
