"""The paper's technique on an ASSIGNED TRANSFORMER: DDPG structured pruning
(heads / FFN channels / experts / SSD heads) + greedy layer-split for
two-tier deployment — the generalization DESIGN.md §2 Tier B describes —
plus the unified deployment artifact: the chosen prune+split contract
packaged as a ``repro.serving.DeploymentPlan`` (--export-plan DIR saves
it; the demo reloads and serves it without the pipeline objects).

    PYTHONPATH=src python examples/prune_and_split.py --arch mixtral-8x7b
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import serving

from repro.configs.registry import ARCH_IDS, get_config, get_smoke_config
from repro.core.partition.latency_model import transformer_layer_costs
from repro.core.partition.profiles import PROFILES
from repro.core.partition.splitter import balanced_split, greedy_split
from repro.core.pruning.amc_env import PruningEnv, transformer_layer_descs
from repro.core.pruning.masks import (mask_sparsity,
                                      transformer_masks_from_ratios,
                                      transformer_prunable_units)
from repro.core.pruning.policy import search_pruning_policy
from repro.models import transformer as tr


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="mixtral-8x7b")
    ap.add_argument("--episodes", type=int, default=8)
    ap.add_argument("--budget", type=float, default=0.6)
    ap.add_argument("--profile", choices=list(PROFILES),
                    default="tpu_edge_cloud")
    ap.add_argument("--export-plan", default=None, metavar="DIR",
                    help="directory for the CNN DeploymentPlan artifact "
                         "demo (default: a temp dir)")
    args = ap.parse_args()

    # 1) DDPG pruning search on the smoke-scale model (policy + env are
    #    size-agnostic; CPU can't fine-tune the full model — DESIGN.md §7)
    cfg = get_smoke_config(args.arch).replace(dtype="float32")
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    units = transformer_prunable_units(cfg)
    descs = transformer_layer_descs(cfg, seq_len=64)
    tok = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                             cfg.vocab_size)
    batch = {"tokens": tok, "labels": tok}
    if cfg.vision_tokens:
        batch["vision_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (4, cfg.vision_tokens, cfg.d_model))
    if cfg.embeds_input:
        batch = {"embeds": jax.random.normal(
            jax.random.PRNGKey(2), (4, 64, cfg.d_model)),
            "labels": tok}
    base_loss = float(tr.loss_fn(params, cfg, batch)[0])

    def evaluate(ratios):
        masks = transformer_masks_from_ratios(params, cfg, list(ratios))
        loss = float(tr.loss_fn(params, cfg, batch, masks=masks)[0])
        return float(np.exp(base_loss - loss))     # >1 if better than dense

    env = PruningEnv(descs, evaluate, flops_budget=args.budget)
    res = search_pruning_policy(env, episodes=args.episodes, warmup=2,
                                log=lambda s: print("  ", s))
    print(f"\nbest reward {res.best_reward:.4f} "
          f"flops kept {res.best_flops_kept:.2f}")
    masks = transformer_masks_from_ratios(params, cfg, res.best_ratios)
    print(f"mask sparsity: {mask_sparsity(masks):.2%} of structured units "
          f"removed across {len(units)} (layer, axis) groups")

    # 2) greedy split of the FULL config under a two-tier TPU profile
    full = get_config(args.arch)
    profile = PROFILES[args.profile]
    costs = transformer_layer_costs(full, seq_len=4096)
    inp_bytes = 4096 * full.d_model * 2
    g = greedy_split(costs, profile, inp_bytes)
    b = balanced_split(costs, profile, inp_bytes)
    print(f"\nfull {args.arch}: {full.num_layers} layers, "
          f"profile={args.profile}")
    print(f"  greedy   split c={g.split_point:3d}  "
          f"T={g.latency['T'] * 1e3:.3f} ms "
          f"(TD {g.latency['T_D'] * 1e3:.3f} TX {g.latency['T_TX'] * 1e3:.3f} "
          f"TS {g.latency['T_S'] * 1e3:.3f})")
    print(f"  balanced split c={b.split_point:3d}  "
          f"bottleneck={max(b.latency['T_D'], b.latency['T_TX'], b.latency['T_S']) * 1e3:.3f} ms"
          f" (steady-state pipelined serving, beyond-paper)")

    # 3) the unified deployment artifact (paper CNN path): the whole
    #    contract — model, masks, split, codec, link — saved as one
    #    DeploymentPlan and re-served with no pipeline objects in scope
    from repro.core.pruning.masks import cnn_masks_from_ratios
    from repro.models.cnn import (init_cnn_params, prunable_layers,
                                  tiny_cnn_config)
    ccfg = tiny_cnn_config(num_classes=38, hw=32)
    cparams = init_cnn_params(jax.random.PRNGKey(0), ccfg)
    cmasks = cnn_masks_from_ratios(cparams, ccfg,
                                   {i: 0.5 for i in prunable_layers(ccfg)})
    plan = serving.DeploymentPlan.from_args(cparams, ccfg, None,
                                            masks=cmasks, compact=True,
                                            codec="int8")
    out_dir = args.export_plan or tempfile.mkdtemp(prefix="deploy_plan_")
    plan.save(out_dir)
    reloaded = serving.DeploymentPlan.load(out_dir)
    with serving.connect(reloaded, backend="local") as sess:
        res = sess.infer(np.zeros((1, 32, 32, 3), np.float32))
    print(f"\ndeployment artifact: {plan.describe()}")
    print(f"  exported to {out_dir}/, reloaded (digest match: "
          f"{reloaded.digest == plan.digest}), served one request "
          f"T={res['t_total'] * 1e3:.2f} ms, tx {res['tx_bytes']} B")


if __name__ == "__main__":
    main()
