"""Paper Fig. 5 — latency comparison of deployment strategies:

    device-only / server-only / co-inference, each dense and pruned.

Analytic on full AlexNet under the paper's hardware profile, plus an
executed comparison on the reduced CNN through the unified serving API
(one DeploymentPlan per strategy, local backend: real compute on this
CPU, byte-accurate simulated channel). Claims validated:
co-inference never loses to either endpoint (they are candidates), pruning
accelerates every strategy, and the server-only path is dominated by
transmission (the paper's 80.78 ms story).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import save_result, table
from benchmarks.table2_split_latency import _paper_masks
from repro import serving
from repro.core.partition.latency_model import (cnn_input_bytes,
                                                cnn_layer_costs,
                                                split_latency)
from repro.core.partition.profiles import PAPER_PROFILE
from repro.core.partition.splitter import greedy_split
from repro.core.pruning.masks import cnn_masks_from_ratios
from repro.models.cnn import (alexnet_config, init_cnn_params,
                              tiny_cnn_config)

PAPER_MS = {"device_only": 31.36, "server_only": 80.78,
            "pruned_co_infer": 18.55}


def run(fast: bool = False) -> dict:
    cfg = alexnet_config()
    rows = []
    analytic = {}
    for tag, masks in [("dense", None), ("pruned", _paper_masks(cfg))]:
        costs = cnn_layer_costs(cfg, masks)
        n = len(costs)
        dev = split_latency(costs, n, PAPER_PROFILE, cnn_input_bytes(cfg))
        srv = split_latency(costs, 0, PAPER_PROFILE, cnn_input_bytes(cfg))
        co = greedy_split(costs, PAPER_PROFILE, cnn_input_bytes(cfg))
        rows += [
            {"method": f"device_only_{tag}", "T_ms": dev["T"] * 1e3},
            {"method": f"server_only_{tag}", "T_ms": srv["T"] * 1e3},
            {"method": f"co_infer_{tag}", "T_ms": co.latency["T"] * 1e3,
             "split": co.split_point},
        ]
        analytic[tag] = {"device_only": dev["T"] * 1e3,
                         "server_only": srv["T"] * 1e3,
                         "co_infer": co.latency["T"] * 1e3,
                         "split": co.split_point}
        # invariants
        assert co.latency["T"] <= dev["T"] + 1e-9
        assert co.latency["T"] <= srv["T"] + 1e-9
    assert analytic["pruned"]["co_infer"] <= analytic["dense"]["co_infer"]
    print(table(rows, ["method", "T_ms", "split"],
                "Fig. 5 (analytic, AlexNet, paper profile) — paper: "
                f"{PAPER_MS}"))
    speedup_vs_dev = (analytic["dense"]["device_only"]
                      / analytic["pruned"]["co_infer"])
    speedup_vs_srv = (analytic["dense"]["server_only"]
                      / analytic["pruned"]["co_infer"])
    print(f"   pruned co-infer speedup: {speedup_vs_dev:.2f}x vs "
          f"device-only, {speedup_vs_srv:.2f}x vs server-only "
          f"(paper: 1.69x / 4.35x)")

    # executed comparison on the reduced CNN
    tcfg = tiny_cnn_config(hw=32)
    params = init_cnn_params(jax.random.PRNGKey(0), tcfg)
    x = np.random.RandomState(0).rand(1, 32, 32, 3).astype(np.float32)
    ratios = {i: 0.4 for i, s in enumerate(tcfg.layers)
              if s.kind == "conv" and i > 0}
    masks = cnn_masks_from_ratios(params, tcfg, ratios)
    n = len(tcfg.layers)
    costs = cnn_layer_costs(tcfg, masks)
    best = greedy_split(costs, PAPER_PROFILE, cnn_input_bytes(tcfg))
    execd = {}
    for method, split, mk, kw in [
            ("device_only", n, None, {}),
            ("server_only", 0, None, {}),
            ("co_infer", best.split_point, None, {}),
            ("pruned_co_infer", best.split_point, masks, {}),
            # fast deployment path: masks physically removed + int8 codec
            ("compact_co_infer", best.split_point, masks,
             dict(compact=True, codec="int8"))]:
        plan = serving.DeploymentPlan.from_args(params, tcfg, split,
                                                masks=mk,
                                                profile=PAPER_PROFILE, **kw)
        with serving.connect(plan, backend="local") as sess:
            r = sess.infer(x)
        execd[method] = {"T_ms": r["t_total"] * 1e3,
                         "tx_KB": r["tx_bytes"] / 1024}
    assert execd["compact_co_infer"]["tx_KB"] <= \
        execd["pruned_co_infer"]["tx_KB"] + 1e-9
    erows = [{"method": k, **v} for k, v in execd.items()]
    print(table(erows, ["method", "T_ms", "tx_KB"],
                "Fig. 5 (executed, reduced CNN via serving local backend)"))
    print("   (tx_KB is the uplink feature payload; T charges the "
          "uplink + one RTT per Eq. 5 — the logits downlink is not "
          "modelled)")
    out = {"analytic": analytic, "executed": execd,
           "speedups": {"vs_device_only": speedup_vs_dev,
                        "vs_server_only": speedup_vs_srv},
           "paper_ms": PAPER_MS}
    save_result("fig5_methods", out)
    return out


if __name__ == "__main__":
    run()
