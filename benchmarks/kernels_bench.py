"""Kernel benchmark: correctness sweeps at benchmark shapes + CPU wall-time
of the XLA reference paths (interpret-mode Pallas timings are meaningless —
the TPU numbers come from the dry-run roofline instead), + the static VMEM
working-set accounting per kernel tiling (what the BlockSpecs claim).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result, table
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.masked_matmul.ops import masked_matmul
from repro.kernels.masked_matmul.ref import masked_matmul_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref


def _time(fn, *args, repeats=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats


def vmem_bytes_flash(block_q, block_k, D):
    f32 = 4
    return (block_q * D + 2 * block_k * D + block_q * D
            + 2 * block_q) * f32 + block_q * block_k * f32


def vmem_bytes_ssd(chunk, P, N):
    f32 = 4
    return (chunk * P + 2 * chunk * N + chunk * chunk + P * N * 2) * f32


def run(fast: bool = False) -> dict:
    rows = []
    key = jax.random.PRNGKey(0)

    # flash attention @ a serving-ish shape
    B, S, H, Hkv, D = 1, 256 if fast else 1024, 8, 2, 64
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    got = flash_attention(q, k, v, block_q=128, block_k=128, interpret=True)
    ref = attention_ref(q, k, v)
    err = float(jnp.max(jnp.abs(got - ref)))
    rows.append({"kernel": "flash_attention", "shape": f"B{B} S{S} H{H}/{Hkv} D{D}",
                 "max_err": err, "xla_ref_ms": _time(
                     jax.jit(lambda a, b, c: attention_ref(a, b, c)),
                     q, k, v) * 1e3,
                 "vmem_KB": vmem_bytes_flash(512, 512, 128) / 1024})

    # ssd @ mamba2-ish shape (reduced)
    Bs, Ss, Hh, G, P, N = 1, 256 if fast else 512, 8, 1, 32, 64
    ks = jax.random.split(key, 5)
    xh = jax.random.normal(ks[0], (Bs, Ss, Hh, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bs, Ss, Hh)))
    A = -jnp.exp(jax.random.normal(ks[2], (Hh,)) * 0.3)
    Bm = jax.random.normal(ks[3], (Bs, Ss, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (Bs, Ss, G, N)) * 0.5
    y, fs = ssd_scan(xh, dt, A, Bm, Cm, chunk=64, interpret=True)
    yr, fsr = ssd_ref(xh, dt, A, Bm, Cm, 64)
    rows.append({"kernel": "ssd_scan", "shape": f"B{Bs} S{Ss} H{Hh} P{P} N{N}",
                 "max_err": float(jnp.max(jnp.abs(y - yr))),
                 "xla_ref_ms": _time(
                     jax.jit(lambda *a: ssd_ref(*a, 64)),
                     xh, dt, A, Bm, Cm) * 1e3,
                 "vmem_KB": vmem_bytes_ssd(256, 64, 128) / 1024})

    # masked matmul @ pruned-FFN shape
    M, K, Nn = 256, 512, 1024
    a = jax.random.normal(ks[0], (M, K), jnp.float32)
    b = jax.random.normal(ks[1], (K, Nn), jnp.float32)
    m = (jax.random.uniform(ks[2], (Nn,)) > 0.5).astype(jnp.float32)
    got = masked_matmul(a, b, m, interpret=True)
    rows.append({"kernel": "masked_matmul", "shape": f"{M}x{K}x{Nn}",
                 "max_err": float(jnp.max(jnp.abs(
                     got - masked_matmul_ref(a, b, m)))),
                 "xla_ref_ms": _time(
                     jax.jit(masked_matmul_ref), a, b, m) * 1e3,
                 "vmem_KB": (128 * 128 * 3 * 4 + 128 * 4) / 1024})

    # rmsnorm @ layer shape
    x = jax.random.normal(ks[0], (4096, 1024), jnp.float32)
    sc = jax.random.normal(ks[1], (1024,))
    rows.append({"kernel": "rmsnorm", "shape": "4096x1024",
                 "max_err": float(jnp.max(jnp.abs(
                     rmsnorm(x, sc, interpret=True) - rmsnorm_ref(x, sc)))),
                 "xla_ref_ms": _time(jax.jit(rmsnorm_ref), x, sc) * 1e3,
                 "vmem_KB": (256 * 1024 * 2 + 1024) * 4 / 1024})

    print(table(rows, ["kernel", "shape", "max_err", "xla_ref_ms",
                       "vmem_KB"],
                "Pallas kernels: correctness @ bench shapes, XLA-ref CPU "
                "time, BlockSpec VMEM claim"))
    assert all(r["max_err"] < 1e-2 for r in rows)
    out = {"rows": rows}
    save_result("kernels_micro", out)
    return out


if __name__ == "__main__":
    run()
