"""Adaptive split control vs every fixed split on a degrading link (BENCH).

The claim behind the paper's *wireless* premise: the greedy split is only
optimal for the bandwidth it was measured at. This benchmark replays a
piecewise bandwidth trace (Wi-Fi that degrades mid-run) through the
simulated channel and serves the same request stream three ways:

  1. *fixed* — one session per candidate split, the paper's static
     deployment, each replaying the full trace;
  2. *adaptive* — one session with ``plan.adaptive`` set: it estimates
     the live uplink from each request's (tx_bytes, t_tx), re-runs the
     Eq. 5 sweep on the measured link, and re-splits itself mid-run;
  3. *oracle* — per-request best fixed split in hindsight (lower bound).

Checks (the PR's acceptance criteria):
  * the adaptive session switches at least once, without reconnecting;
  * its end-to-end latency beats the best fixed split on the same trace;
  * its logits are bit-identical to the fixed-split reference at every
    request (fp32 codec: moving the partition never changes the math).

``--smoke`` additionally exercises the live-socket RESPLIT path: a real
edge/cloud TCP pair switches split on the open connection and the served
logits stay bit-identical across the switch.

The edge is priced as an MCU-class device (a profile knob, not a code
path): on paper hardware the tiny 32px CNN is device-dominant at every
bandwidth, which would make adaptation trivially "run everything on the
device". The weak edge reproduces the paper's AlexNet@224-vs-i7 regime —
a split optimum that genuinely moves with the link — at benchmark scale.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from benchmarks.common import save_result, table
from repro import serving
from repro.core.partition.profiles import (LinkTrace, MCU_EDGE,
                                           PAPER_PROFILE, TwoTierProfile)
from repro.core.pruning.masks import cnn_masks_from_ratios
from repro.models.cnn import (cnn_apply, init_cnn_params, prunable_layers,
                              tiny_cnn_config)
#: Wi-Fi walking out of range: 50 -> 20 -> 2 Mbps over the run
DEGRADE_TRACE = LinkTrace.from_mbps(
    "bench_wifi_degrade",
    [(0.12, 50.0), (0.10, 20.0), (float("inf"), 2.0)], rtt_ms=1.0)
CANDIDATES = (0, 3, 6, 13)


def build_plan(adaptive: bool) -> serving.DeploymentPlan:
    cfg = tiny_cnn_config(num_classes=38, hw=32)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    masks = cnn_masks_from_ratios(params, cfg,
                                  {i: 0.5 for i in prunable_layers(cfg)})
    profile = TwoTierProfile(MCU_EDGE, PAPER_PROFILE.server,
                             DEGRADE_TRACE.link_at(0.0))
    policy = (serving.AdaptivePolicy(candidates=CANDIDATES, ewma_alpha=0.5,
                                     min_samples=2, hysteresis=0.05,
                                     dwell=2) if adaptive else None)
    # split=None: greedy optimum at the trace's t=0 bandwidth — the static
    # deployment decision the adaptive controller then revises live
    return serving.DeploymentPlan.from_args(
        params, cfg, None, masks=masks, compact=True, codec="fp32",
        profile=profile, adaptive=policy, shape_link=False, port=29520)


def replay(plan, imgs, trace):
    """Serve ``imgs`` through a local session replaying ``trace``; returns
    (per-request T seconds, logits list, session)."""
    sess = serving.connect(plan, backend="local", trace=trace)
    ts, logits = [], []
    for img in imgs:
        res = sess.infer(img)
        ts.append(res["t_total"])
        logits.append(res["logits"])
    return np.asarray(ts), logits, sess


def socket_resplit_smoke(plan, img) -> None:
    """Exercise the RESPLIT protocol on a real TCP pair: one connection,
    split moved live, logits bit-identical across the switch."""
    with serving.CloudServer(plan, max_clients=1, max_requests=6):
        with serving.connect(plan, backend="socket") as sess:
            before = sess.infer(img)["logits"]
            for c in (3, 13, 6):           # walk the candidate set live
                sess.resplit(c)
                got = sess.infer(img)["logits"]
                np.testing.assert_array_equal(got, before,
                                              err_msg=f"resplit c={c}")
    print("socket RESPLIT: 4 splits served bit-identically on one "
          "connection")


def run(fast: bool = False) -> dict:
    n_requests = 40 if fast else 80
    plan = build_plan(adaptive=True)
    print(plan.describe())
    print(f"trace: {DEGRADE_TRACE.name} "
          + " -> ".join(f"{s.bandwidth * 8 / 1e6:g} Mbps"
                        for s in DEGRADE_TRACE.segments))

    rng = np.random.RandomState(0)
    imgs = [rng.rand(1, 32, 32, 3).astype(np.float32)
            for _ in range(n_requests)]
    # numerical reference: masked dense execution (compaction reorders
    # float ops, so this is an allclose check, not bit-equality)
    masked = [np.asarray(cnn_apply(plan.params, plan.cfg, img,
                                   masks=plan.masks)) for img in imgs]

    # --- fixed splits: the paper's static deployment, per candidate -----
    rows, fixed_totals = [], {}
    fixed_ts = {}
    want = None          # fixed-split reference logits (bit-equality)
    for c in CANDIDATES:
        fplan = build_plan(adaptive=False)
        fplan = serving.DeploymentPlan(
            cfg=fplan.cfg, params=fplan.params, split=c, masks=fplan.masks,
            compact=True, codec="fp32", profile=fplan.profile,
            shape_link=False)
        ts, logits, _ = replay(fplan, imgs, DEGRADE_TRACE)
        if want is None:
            want = logits
            for got, m in zip(logits, masked):
                np.testing.assert_allclose(got, m, rtol=1e-4, atol=1e-4)
        else:
            # moving the partition never changes the math (fp32 codec)
            for got, w in zip(logits, want):
                np.testing.assert_array_equal(got, w)
        fixed_totals[c] = ts.sum()
        fixed_ts[c] = ts
        rows.append({"policy": f"fixed c={c}", "total_ms": ts.sum() * 1e3,
                     "mean_ms": ts.mean() * 1e3, "switches": 0})

    # --- adaptive ------------------------------------------------------
    ats, alogits, sess = replay(plan, imgs, DEGRADE_TRACE)
    for i, (got, w) in enumerate(zip(alogits, want)):
        np.testing.assert_array_equal(got, w,
                                      err_msg=f"adaptive request {i}")
    for sw in sess.switches:
        print("  " + sw.describe())
    rows.append({"policy": "adaptive", "total_ms": ats.sum() * 1e3,
                 "mean_ms": ats.mean() * 1e3,
                 "switches": len(sess.switches)})

    # --- oracle: per-request argmin over the fixed replays --------------
    oracle = np.min(np.stack([fixed_ts[c] for c in CANDIDATES]), axis=0)
    rows.append({"policy": "oracle (hindsight)",
                 "total_ms": oracle.sum() * 1e3,
                 "mean_ms": oracle.mean() * 1e3, "switches": None})

    best_fixed = min(fixed_totals, key=fixed_totals.get)
    print(table(rows, ["policy", "total_ms", "mean_ms", "switches"],
                f"{n_requests} requests over a degrading link "
                f"(candidates {list(CANDIDATES)})"))
    print(f"   best fixed: c={best_fixed} "
          f"({fixed_totals[best_fixed] * 1e3:.1f} ms); adaptive "
          f"{ats.sum() * 1e3:.1f} ms "
          f"({fixed_totals[best_fixed] / ats.sum():.2f}x)")

    assert len(sess.switches) >= 1, "adaptive session never re-split"
    assert ats.sum() < fixed_totals[best_fixed], (
        "adaptive did not beat the best fixed split",
        ats.sum(), fixed_totals)

    out = {"n_requests": n_requests, "candidates": list(CANDIDATES),
           "fixed_total_s": {str(c): float(t)
                             for c, t in fixed_totals.items()},
           "adaptive_total_s": float(ats.sum()),
           "oracle_total_s": float(oracle.sum()),
           "best_fixed": best_fixed,
           "speedup_vs_best_fixed": float(fixed_totals[best_fixed]
                                          / ats.sum()),
           "switches": [{"request": sw.request_index, "from": sw.old_split,
                         "to": sw.new_split,
                         "est_mbps": sw.est_bandwidth * 8 / 1e6}
                        for sw in sess.switches]}
    save_result("adaptive_split", out)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: short trace replay + live-socket "
                         "RESPLIT exercise")
    args = ap.parse_args()
    out = run(fast=args.smoke)
    plan = build_plan(adaptive=True)
    img = np.random.RandomState(1).rand(1, 32, 32, 3).astype(np.float32)
    socket_resplit_smoke(plan, img)
    print(f"adaptive beat best fixed split c={out['best_fixed']} by "
          f"{(out['speedup_vs_best_fixed'] - 1) * 100:.1f}%")


if __name__ == "__main__":
    main()
