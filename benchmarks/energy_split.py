"""Energy-aware split optimization vs the latency-only sweep (BENCH).

The paper motivates collaborative inference with *both* latency and the
"high energy consumption" of resource-limited embedded devices, but its
Eq. 5 objective prices latency only. This benchmark prices every
candidate split into a ``(T_total, E_edge)`` pair
(``repro.core.partition.energy_model``) and shows three things:

  1. **Pareto section** — for each (device power class x canned link
     trace) pair, the latency/energy Pareto front over all splits: the
     latency optimum and the joules optimum are different operating
     points, and the front between them is the menu.
  2. **Objective flip (acceptance)** — on at least one (profile, trace)
     pair the weighted latency·energy objective picks a *different*
     split than the latency-only sweep; both plans are then actually
     served over the trace and their logits are **bit-identical**
     (fp32 codec: moving the partition never changes the math) while
     the energy-aware plan measurably spends fewer joules per request.
  3. **Battery replay** — an adaptive plan with a ``battery_j`` budget
     re-splits itself toward the low-energy end of the front as the
     budget drains (MCU class: the radio is the expensive part, so a
     dying battery stops transmitting and computes locally).

``--smoke`` runs the CI-sized version; the tracked perf record
``experiments/bench/BENCH_energy.json`` is written by ``--json`` (or by
``benchmarks.run --json``), next to ``BENCH_collab.json``.
"""
from __future__ import annotations

import argparse
from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import save_result, table, write_energy_record
from repro import serving
from repro.core.partition.energy_model import (ENERGY_PROFILES, EnergyPolicy,
                                               pareto_front)
from repro.core.partition.latency_model import (cnn_input_bytes,
                                                compacted_cnn_layer_costs,
                                                wire_tx_scale)
from repro.core.partition.profiles import (LinkProfile, LinkTrace, MCU_EDGE,
                                           PAPER_PROFILE, PI_EDGE, TRACES,
                                           TwoTierProfile)
from repro.core.partition.splitter import sweep_splits
from repro.core.pruning.masks import cnn_masks_from_ratios
from repro.models.cnn import init_cnn_params, prunable_layers, tiny_cnn_config

#: device classes under study: (compute profile, energy profile name,
#: static energy weight s/J for the flip demo)
DEVICES = {
    "mcu": (MCU_EDGE, "mcu", 0.5),
    "pi": (PI_EDGE, "pi", 2.0),
}
#: steady bench link for the deterministic serving/battery demos (1 ms
#: RTT — the regime where offloading is latency-competitive, so the
#: joules are what tips the decision)
STEADY_50 = LinkTrace.from_mbps("bench_wifi_50", [(float("inf"), 50.0)],
                                rtt_ms=1.0)
CANDIDATES = (0, 3, 6, 13)


def _setup():
    cfg = tiny_cnn_config(num_classes=38, hw=32)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    masks = cnn_masks_from_ratios(params, cfg,
                                  {i: 0.5 for i in prunable_layers(cfg)})
    return cfg, params, masks


def _sweep(cfg, masks, device, energy, link: LinkProfile):
    """The energy-priced Eq. 5 sweep on the deployed (compacted) shapes."""
    costs = compacted_cnn_layer_costs(cfg, masks)
    prof = TwoTierProfile(device, PAPER_PROFILE.server, link)
    return sweep_splits(
        costs, prof, cnn_input_bytes(cfg), energy=energy,
        tx_scale=lambda c: wire_tx_scale(cfg, masks, c, codec="fp32",
                                         compact=True))


def pareto_section(cfg, masks, traces: Dict[str, LinkTrace]) -> List[Dict]:
    """Latency/energy Pareto fronts per (device, trace at t=0); returns
    the rows of the tracked record, including the flip scan."""
    rows = []
    for dev_name, (device, en_name, weight) in DEVICES.items():
        energy = ENERGY_PROFILES[en_name]
        policy = EnergyPolicy(profile=energy, energy_weight_s_per_j=weight)
        for tr_name, trace in traces.items():
            tab = _sweep(cfg, masks, device, energy, trace.link_at(0.0))
            t_best = min(tab, key=lambda r: r["T"])
            e_best = min(tab, key=lambda r: r["E_edge"])
            w_best = min(tab, key=policy.score)
            front = pareto_front(tab)
            rows.append({
                "device": dev_name, "trace": tr_name,
                "weight_s_per_j": weight,
                "latency_split": int(t_best["split"]),
                "energy_split": int(e_best["split"]),
                "weighted_split": int(w_best["split"]),
                "flip": int(w_best["split"]) != int(t_best["split"]),
                "T_latency_ms": t_best["T"] * 1e3,
                "E_latency_mj": t_best["E_edge"] * 1e3,
                "T_weighted_ms": w_best["T"] * 1e3,
                "E_weighted_mj": w_best["E_edge"] * 1e3,
                "front": [{"split": int(r["split"]), "T_ms": r["T"] * 1e3,
                           "E_mj": r["E_edge"] * 1e3} for r in front],
            })
    print(table(
        rows, ["device", "trace", "latency_split", "weighted_split",
               "energy_split", "T_latency_ms", "E_latency_mj",
               "T_weighted_ms", "E_weighted_mj"],
        "latency-only vs energy-aware split per (device, trace @ t=0)"))
    for r in rows:
        front = " -> ".join(f"c={p['split']} ({p['T_ms']:.2f}ms,"
                            f"{p['E_mj']:.2f}mJ)" for p in r["front"])
        print(f"   {r['device']}/{r['trace']} Pareto: {front}")
    return rows


def serve_flip(cfg, params, masks, n_requests: int) -> Dict:
    """Acceptance: the energy-aware objective picks a different split
    than the latency sweep on (MCU, steady 50 Mbps), both plans serve
    bit-identical logits, and the energy-aware plan spends fewer joules.
    """
    device, en_name, weight = DEVICES["mcu"]
    policy = EnergyPolicy(profile=ENERGY_PROFILES[en_name],
                          energy_weight_s_per_j=weight)
    profile = TwoTierProfile(device, PAPER_PROFILE.server,
                             STEADY_50.link_at(0.0))
    common = dict(masks=masks, compact=True, codec="fp32",
                  profile=profile, shape_link=False)
    plan_t = serving.DeploymentPlan.from_args(params, cfg, None, **common)
    plan_e = serving.DeploymentPlan.from_args(params, cfg, None,
                                              energy=policy, **common)
    # meter the latency plan too (same power model, same split choice as
    # a pure-latency deployment: the weight only changes the *pick*, so
    # pin its split explicitly to keep the latency-only choice)
    plan_t = serving.DeploymentPlan.from_args(params, cfg, plan_t.split,
                                              energy=EnergyPolicy(
                                                  profile=policy.profile),
                                              **common)
    assert plan_e.split != plan_t.split, (
        "energy-aware objective picked the latency split "
        f"(both c={plan_e.split}); no flip to demonstrate")
    print(f"latency-only pick: c={plan_t.split}; energy-aware "
          f"(w={weight} s/J): c={plan_e.split}")

    rng = np.random.RandomState(0)
    imgs = [rng.rand(1, 32, 32, 3).astype(np.float32)
            for _ in range(n_requests)]
    totals = {}
    logits = {}
    for name, plan in (("latency", plan_t), ("energy", plan_e)):
        sess = serving.connect(plan, backend="local", trace=STEADY_50)
        t_sum = e_sum = 0.0
        outs = []
        for img in imgs:
            res = sess.infer(img)
            t_sum += res["t_total"]
            e_sum += res["e_edge_j"]
            outs.append(res["logits"])
        totals[name] = {"T_s": t_sum, "E_j": e_sum}
        logits[name] = outs
    for a, b in zip(logits["latency"], logits["energy"]):
        np.testing.assert_array_equal(a, b)     # fp32: split never
        #                                         changes the math
    print(table(
        [{"objective": k, "split": p.split, "total_ms": v["T_s"] * 1e3,
          "total_mj": v["E_j"] * 1e3,
          "mj_per_req": v["E_j"] * 1e3 / n_requests}
         for (k, v), p in zip(totals.items(), (plan_t, plan_e))],
        ["objective", "split", "total_ms", "total_mj", "mj_per_req"],
        f"{n_requests} requests, MCU edge @ steady 50 Mbps"))
    assert totals["energy"]["E_j"] < totals["latency"]["E_j"], (
        "energy-aware split did not reduce measured joules", totals)
    return {"latency_split": plan_t.split, "energy_split": plan_e.split,
            "latency_total": totals["latency"],
            "energy_total": totals["energy"],
            "energy_saving": 1.0 - (totals["energy"]["E_j"]
                                    / totals["latency"]["E_j"]),
            "bit_identical": True}


def battery_replay(cfg, params, masks, n_requests: int) -> Dict:
    """An MCU edge with a draining battery: starts at the latency
    optimum (offload) and re-splits toward all-edge as the budget runs
    down — the radio is the expensive peripheral, so a dying device
    stops transmitting."""
    device, en_name, _ = DEVICES["mcu"]
    policy = EnergyPolicy(profile=ENERGY_PROFILES[en_name],
                          energy_weight_s_per_j=0.1, battery_j=0.1)
    profile = TwoTierProfile(device, PAPER_PROFILE.server,
                             STEADY_50.link_at(0.0))
    plan = serving.DeploymentPlan.from_args(
        params, cfg, None, masks=masks, compact=True, codec="fp32",
        profile=profile, shape_link=False, energy=policy,
        adaptive=serving.AdaptivePolicy(candidates=CANDIDATES,
                                        ewma_alpha=0.5, min_samples=2,
                                        hysteresis=0.02, dwell=2))
    print(plan.describe())
    rng = np.random.RandomState(1)
    sess = serving.connect(plan, backend="local", trace=STEADY_50)
    splits = []
    for _ in range(n_requests):
        sess.infer(rng.rand(1, 32, 32, 3).astype(np.float32))
        splits.append(sess.split)
    for sw in sess.switches:
        print("  " + sw.describe())
    ctl = sess._controller
    print(f"   battery after {n_requests} requests: "
          f"{ctl.battery_j:.4f} J of {policy.battery_j} J")
    assert sess.switches, "battery drain never re-split the deployment"
    # every switch under drain moves to a lower-predicted-energy split,
    # and the first one fires while meaningful budget remains (the
    # urgency curve must act BEFORE exhaustion, not at it)
    for sw in sess.switches:
        assert sw.predicted_E < sw.current_E, sw.describe()
    assert sess.switches[0].battery_j > 0.1 * policy.battery_j, (
        "first battery-driven switch only happened at exhaustion",
        sess.switches[0].describe())
    return {"start_split": int(splits[0]), "end_split": int(splits[-1]),
            "battery_j": policy.battery_j,
            "battery_left_j": float(ctl.battery_j),
            "switches": [{"request": sw.request_index,
                          "from": sw.old_split, "to": sw.new_split,
                          "battery_j": sw.battery_j}
                         for sw in sess.switches]}


def run(fast: bool = False) -> dict:
    cfg, params, masks = _setup()
    traces = dict(TRACES)
    if fast:
        traces = {k: traces[k] for k in ("wifi_steady", "wifi_degrading")}
    traces["bench_wifi_50"] = STEADY_50

    rows = pareto_section(cfg, masks, traces)
    flips = [r for r in rows if r["flip"]]
    assert flips, ("energy-aware objective never picked a different split "
                   "than the latency sweep on any (device, trace) pair")
    print(f"objective flips on {len(flips)}/{len(rows)} (device, trace) "
          f"pairs")

    n = 24 if fast else 64
    flip = serve_flip(cfg, params, masks, n)
    battery = battery_replay(cfg, params, masks, 48 if fast else 96)

    out = {"pairs": rows, "n_flips": len(flips), "n_pairs": len(rows),
           "flip_demo": flip, "battery_demo": battery}
    save_result("energy_split", out)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer traces and requests)")
    ap.add_argument("--json", action="store_true",
                    help="write the tracked BENCH_energy.json perf record")
    args = ap.parse_args()
    res = run(fast=args.smoke)
    if args.json or args.smoke:
        # the CI smoke path owns the tracked record, like cloud_batching
        print(f"perf record: {write_energy_record(res)}")
