"""Cross-client dynamic batching: cloud req/s vs concurrent edges (BENCH).

The claim: with N edges connected concurrently, the cloud peer's dynamic
batching engine (``repro.core.collab.batching``) recovers the throughput
the threaded batch-1 server leaves on the table — N handler threads each
dispatch a serial batch-1 device invocation per frame, while the batcher
fuses the same concurrent requests into ONE bucketed cloud call per
window. Logits are bit-identical to sequential serving (the batched
executable maps the batch-1 computation over rows).

Measured on real localhost sockets with the **sim profile**: every cloud
invocation is charged its analytic ``batched_server_time`` on the
paper's RTX 3090, serialized server-wide (``serve(simulate_server=...)``
— the same stance as ``CollabRunner.simulate_compute``: this container
is not a 3090, and N colocated batch-1 calls would otherwise borrow
*this host's* CPU parallelism, which the one-accelerator target does not
have). Real jitted compute still runs first, so the bit-identity checks
are real. Link shaping is off — the engine is the unit under test, not
the modeled radio. Reported per engine and edge count:

  * req/s and per-request p50/p95 latency (the batching window is a
    deliberate latency-for-throughput trade — at high concurrency it
    wins BOTH, because a fused batch clears the serial device queue
    8x faster than eight batch-1 invocations);
  * per-lane occupancy, average fused batch size, padding waste;
  * a real-compute (no sim) contrast pair at max edges, reported
    unasserted — colocated edges contend with the cloud for this
    container's cores, which no fleet deployment does.

Emits ``experiments/bench/cloud_batching.json`` and the tracked
``BENCH_collab.json`` perf record (req/s, p50/p95, tx bytes, padding
waste — the trajectory CI uploads).
"""
from __future__ import annotations

import argparse
import threading
import time

import jax
import numpy as np

from benchmarks.common import save_result, table, write_collab_record
from repro import serving
from repro.core.partition.latency_model import (batched_server_time,
                                                cnn_input_bytes,
                                                compacted_cnn_layer_costs)
from repro.core.partition.profiles import (ComputeProfile, PAPER_PROFILE,
                                           TwoTierProfile)
from repro.core.partition.splitter import greedy_split
from repro.core.pruning.masks import cnn_masks_from_ratios
from repro.models.cnn import init_cnn_params, prunable_layers, tiny_cnn_config

BASE_PORT = 29750

#: the heavy-traffic regime the engine targets: MANY thin edges, one fat
#: cloud. An MCU-class edge keeps only the first layers (same profile
#: trick as benchmarks/adaptive_split.py — on paper hardware the tiny
#: 32px CNN would be device-dominant and leave the cloud nothing to
#: batch); the greedy sweep then plants the split early and the cloud
#: carries the bulk of the network, which is what a fleet deployment
#: looks like from the server room.
MCU_EDGE = ComputeProfile("MCU-class edge", flops_per_s=0.15e9,
                          mem_bw=0.5e9, overhead_s=3e-4)
FARM_PROFILE = TwoTierProfile(MCU_EDGE, PAPER_PROFILE.server,
                              PAPER_PROFILE.link)


def _serve_edges(plan, n_edges: int, imgs, port: int,
                 simulate_server=None, pipeline: bool = False):
    """Drive one server with ``n_edges`` concurrent edges.

    ``pipeline=False`` — closed-loop: each edge serves its request list
    synchronously (1 outstanding request per edge), which is what
    per-request p50/p95 latency means. ``pipeline=True`` — each edge
    ships its whole list through the pipelined ``infer_many`` (async
    submit/collect), the sustained-traffic regime: the server always has
    a backlog, so measured req/s reflects engine capacity rather than
    the thread-scheduling luck of N closed loops staying in phase.
    Returns (wall_s, per-request latencies, per-edge logits, batch stats).
    """
    lat = [[] for _ in range(n_edges)]
    logits = [[] for _ in range(n_edges)]
    errs = []
    barrier = threading.Barrier(n_edges + 1)

    def edge(i):
        try:
            with serving.connect(plan, backend="socket", port=port) as s:
                s.infer(imgs[0])     # warm this edge's jits off the clock
                barrier.wait()
                if pipeline:
                    t0 = time.perf_counter()
                    res = s.infer_many(imgs)
                    dt = time.perf_counter() - t0
                    lat[i] = [dt / len(imgs)] * len(imgs)
                    logits[i] = [r["logits"] for r in res]
                    return
                for img in imgs:
                    t0 = time.perf_counter()
                    r = s.infer(img)
                    lat[i].append(time.perf_counter() - t0)
                    logits[i].append(r["logits"])
        except Exception as e:                           # noqa: BLE001
            errs.append(e)
            try:
                barrier.abort()
            except threading.BrokenBarrierError:
                pass

    with serving.CloudServer(plan, port=port, max_clients=None,
                             simulate_server=simulate_server) as srv:
        ts = [threading.Thread(target=edge, args=(i,))
              for i in range(n_edges)]
        for t in ts:
            t.start()
        barrier.wait()                   # all edges connected and warmed
        t0 = time.perf_counter()
        for t in ts:
            t.join()
        wall = time.perf_counter() - t0
        srv.stop()
        stats = dict(srv.batch_stats)
    if errs:
        raise errs[0]
    return wall, [x for per in lat for x in per], logits, stats


def _row(label, n_edges, n_requests, wall, lats, stats):
    lane = next(iter(stats.values())) if stats else {}
    return {"engine": label, "edges": n_edges,
            "req_s": n_edges * n_requests / wall,
            "p50_ms": float(np.percentile(lats, 50)) * 1e3,
            "p95_ms": float(np.percentile(lats, 95)) * 1e3,
            "avg_batch": lane.get("avg_batch"),
            "pad_waste": lane.get("padding_waste")}


def run(fast: bool = False, smoke: bool = False) -> dict:
    fast = fast or smoke
    n_requests = 8 if smoke else (16 if fast else 32)
    # sustained-traffic phase: enough backlog per edge that steady state
    # dominates connection ramp-up (it is what the req/s claim is about)
    n_stream = 4 * n_requests
    edge_counts = (2, 8) if fast else (1, 2, 4, 8)
    max_batch = 8

    cfg = tiny_cnn_config(num_classes=38, hw=32)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    masks = cnn_masks_from_ratios(params, cfg,
                                  {i: 0.5 for i in prunable_layers(cfg)})
    n = len(cfg.layers)
    costs = compacted_cnn_layer_costs(cfg, masks)
    split = greedy_split(costs, FARM_PROFILE, cnn_input_bytes(cfg),
                         candidates=range(1, n), tx_scale=0.25).split_point

    # fp32 codec: the throughput phases below are feed-limited by edge
    # CPU on this container, and per-frame int8 quantization would bill
    # both engines the same extra encode cost without changing the
    # engine comparison (codec coverage lives in tests/test_batching.py
    # and benchmarks/collab_throughput.py)
    policy = serving.BatchingPolicy(max_batch=max_batch, max_wait_ms=3.0)
    mk = dict(masks=masks, compact=True, codec="fp32", shape_link=False)
    plain = serving.DeploymentPlan.from_args(params, cfg, split, **mk)
    batched = serving.DeploymentPlan.from_args(params, cfg, split,
                                               batching=policy, **mk)
    print(batched.describe())
    t1 = batched_server_time(costs, split, PAPER_PROFILE.server, 1)
    t8 = batched_server_time(costs, split, PAPER_PROFILE.server, max_batch)
    print(f"sim 3090 T_S: batch-1 {t1 * 1e3:.3f} ms/req, bucket-{max_batch} "
          f"{t8 / max_batch * 1e3:.3f} ms/req "
          f"({t1 * max_batch / t8:.2f}x amortization headroom)")

    rng = np.random.RandomState(0)
    imgs = [jax.device_put(rng.rand(1, 32, 32, 3).astype(np.float32))
            for _ in range(n_requests)]       # pre-staged: a real edge
    # holds its camera frame on-device already; per-request host->device
    # copies would bill the *harness* to both engines equally

    # sequential reference (local backend, same frames) — every serving
    # mode below must reproduce these logits BIT-identically
    with serving.connect(plain, backend="local") as ref_sess:
        ref = [ref_sess.infer(img)["logits"] for img in imgs]

    stream_imgs = [imgs[i % n_requests] for i in range(n_stream)]

    def check(label, n_edges, logits):
        for per_edge in logits:
            for j, b in enumerate(per_edge):
                assert np.array_equal(ref[j % n_requests], b), (
                    f"{label} @ {n_edges} edges: logits diverged from "
                    f"sequential serving")

    rows, sweep = [], {}
    port = BASE_PORT
    top = max(edge_counts)
    for n_edges in edge_counts:
        # best-of-3 at the headline point: 30+ python threads on a small
        # container make single trials scheduling-noisy; best-of controls
        # for the harness, not the engine
        trials = 3 if n_edges == top else 1
        for label, plan in (("threaded-b1", plain), ("batched", batched)):
            best_wall = None
            for _ in range(trials):
                # sustained traffic (pipelined bursts): the req/s claim
                wall, _, logits, stats = _serve_edges(
                    plan, n_edges, stream_imgs, port,
                    simulate_server=PAPER_PROFILE.server, pipeline=True)
                port += 1
                check(label, n_edges, logits)
                if best_wall is None or wall < best_wall:
                    best_wall, best_stats = wall, stats
            # closed loop (1 outstanding/edge): the latency distribution
            _, lats, logits2, _ = _serve_edges(
                plan, n_edges, imgs, port,
                simulate_server=PAPER_PROFILE.server)
            check(label, n_edges, logits2)
            port += 1
            row = _row(label, n_edges, n_stream, best_wall, lats,
                       best_stats)
            rows.append(row)
            sweep[f"{label}_{n_edges}"] = row
        base = sweep[f"threaded-b1_{n_edges}"]["req_s"]
        sweep[f"speedup_{n_edges}"] = (sweep[f"batched_{n_edges}"]["req_s"]
                                       / base)

    print(table(rows, ["engine", "edges", "req_s", "p50_ms", "p95_ms",
                       "avg_batch", "pad_waste"],
                f"split c={split}, compact+fp32, max_batch={max_batch}, "
                f"window 3 ms, sim-3090 cloud; req/s over {n_stream} "
                f"pipelined req/edge, p50/p95 closed-loop over "
                f"{n_requests} (logits bit-identical to sequential)"))
    speedup = sweep[f"speedup_{top}"]
    print(f"   batched vs threaded-batch-1 at {top} edges: "
          f"{speedup:.2f}x req/s")

    # real-compute contrast (no device sim): colocated edges contend with
    # the cloud for this container's cores, so this under-reports the
    # engine — reported, not asserted
    real = {}
    for label, plan in (("threaded-b1", plain), ("batched", batched)):
        wall, lats, logits, stats = _serve_edges(plan, top, stream_imgs,
                                                 port, pipeline=True)
        port += 1
        check(f"real/{label}", top, logits)
        real[label] = _row(label, top, n_stream, wall, lats, stats)
    real_speedup = real["batched"]["req_s"] / real["threaded-b1"]["req_s"]
    print(f"   real-compute contrast at {top} edges (colocated, "
          f"{real['threaded-b1']['req_s']:.0f} vs "
          f"{real['batched']['req_s']:.0f} req/s): {real_speedup:.2f}x")

    with serving.connect(plain, backend="local") as s:
        tx_bytes = int(s.infer(imgs[0])["tx_bytes"])

    floor = 1.5 if smoke else 2.0       # smoke: tiny run, CI-noise margin
    assert speedup >= floor, (
        f"batched engine {speedup:.2f}x < {floor}x threaded batch-1 at "
        f"{top} edges")

    out = {"split": int(split), "n_requests": n_requests,
           "max_batch": max_batch, "edge_counts": list(edge_counts),
           "rows": rows, "speedup_at_max_edges": speedup,
           "real_compute_at_max_edges": real,
           "real_compute_speedup": real_speedup,
           "tx_bytes_per_request": tx_bytes,
           "analytic_server_amortization": t1 * max_batch / t8,
           "bit_identical": True}
    save_result("cloud_batching", out)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer requests, 2 edge counts)")
    args = ap.parse_args()
    # standalone invocation (the CI smoke path) owns the tracked record;
    # a full `benchmarks.run --json` pass writes it instead, with the
    # streaming numbers filled in
    print(f"perf record: "
          f"{write_collab_record(run(fast=args.fast, smoke=args.smoke))}")
