"""Fleet-scale simulation sweep: fleet size x cloudlet count x SLO mix.

The paper validates one edge against one cloud; the real question for
the ROADMAP's "millions of users" north star is what happens when
thousands of heterogeneous, battery-constrained, wireless edges share
a cloudlet tier. This benchmark drives ``repro.core.fleet`` — the
virtual-clock discrete-event simulator — over a grid of scenarios and
reports the numbers a fleet operator stares at: p50/p99 end-to-end
latency, joules per request, % deadlines met, and % shed per reason,
plus per-tier utilization and batching efficiency.

Three properties are asserted, not just reported:

  1. **Scale** — the headline cell simulates >= 1000 heterogeneous
     edges through the full edge -> cloudlet -> cloud hierarchy in
     well under 60 s wall-clock (virtual time is decoupled from wall
     time, so 10k-edge cells are minutes of traffic in seconds).
  2. **Determinism** — the headline scenario runs twice with the same
     seed and must produce bit-identical rollups (the contract the
     virtual clock + seeded arrival streams exist to provide; no
     wall-clock value ever enters a rollup).
  3. **Conservation** — every arrival is accounted: served (collab or
     degraded-to-edge) + shed == arrivals, per cell.

``--smoke`` runs the CI-sized grid; the tracked perf record
``experiments/bench/BENCH_fleet.json`` is written by ``--json`` (or by
``benchmarks.run --json``), next to the other BENCH records.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

from benchmarks.common import table, write_fleet_record
from repro.core.collab.faults import FaultPolicy
from repro.core.fleet import (DEFAULT_SLO_CLASSES, FleetScenario, SLOClass,
                              simulate_fleet)

#: a deadline-heavy traffic mix: everything is interactive-or-standard,
#: deadlines twice as tight as the default — the cell that makes the
#: admission controller and the cloudlet spillover actually sweat
STRICT_SLO_CLASSES = (
    SLOClass("interactive", 0.50,
             FaultPolicy(request_deadline_s=0.15, fallback="edge",
                         max_retries=0)),
    SLOClass("standard", 0.50,
             FaultPolicy(request_deadline_s=0.5, fallback="edge")),
)

SLO_MIXES = {"default": DEFAULT_SLO_CLASSES, "strict": STRICT_SLO_CLASSES}


def _cells(fast: bool) -> List[Dict]:
    """(fleet size, cloudlet count, SLO mix) grid; first cell is the
    headline the BENCH record leads with."""
    if fast:
        return [
            {"n_edges": 1000, "n_cloudlets": 8, "slo_mix": "default",
             "duration_s": 30.0},
            {"n_edges": 1000, "n_cloudlets": 2, "slo_mix": "strict",
             "duration_s": 30.0},
        ]
    return [
        {"n_edges": 1000, "n_cloudlets": 8, "slo_mix": "default",
         "duration_s": 60.0},
        {"n_edges": 2000, "n_cloudlets": 4, "slo_mix": "default",
         "duration_s": 60.0},
        {"n_edges": 5000, "n_cloudlets": 8, "slo_mix": "default",
         "duration_s": 60.0},
        {"n_edges": 10000, "n_cloudlets": 16, "slo_mix": "default",
         "duration_s": 60.0},
        {"n_edges": 10000, "n_cloudlets": 4, "slo_mix": "strict",
         "duration_s": 60.0},
    ]


def _scenario(cell: Dict, seed: int = 7) -> FleetScenario:
    return FleetScenario(
        name=f"{cell['slo_mix']}-{cell['n_edges']}x{cell['n_cloudlets']}",
        seed=seed, n_edges=cell["n_edges"],
        n_cloudlets=cell["n_cloudlets"], duration_s=cell["duration_s"],
        slo_classes=SLO_MIXES[cell["slo_mix"]])


def run(fast: bool = False) -> Dict:
    cells = _cells(fast)
    rows: List[Dict] = []
    headline = None
    wall_total = 0.0
    for cell in cells:
        sc = _scenario(cell)
        t0 = time.perf_counter()   # wall-clock: sweep speed report only
        rollup = simulate_fleet(sc)
        wall = time.perf_counter() - t0   # wall-clock: never in a rollup
        wall_total += wall
        assert rollup["arrivals"] == rollup["served"] + rollup["shed"], (
            f"arrival conservation broken in {sc.name}")
        print(f"{sc.describe()}\n  -> {rollup['arrivals']} arrivals in "
              f"{wall:.1f}s wall ({cell['duration_s']:g}s virtual)")
        rows.append({
            "slo_mix": cell["slo_mix"], "n_edges": cell["n_edges"],
            "n_cloudlets": cell["n_cloudlets"],
            "arrivals": rollup["arrivals"],
            "deadline_met_frac": rollup["deadline_met_frac"],
            "shed_frac": rollup["shed_frac"],
            "latency_p50_s": rollup["latency_p50_s"],
            "latency_p99_s": rollup["latency_p99_s"],
            "joules_per_req": rollup["edge_joules_per_request"],
            "cloudlet_util": rollup["cloudlet_util"],
            "cloud_util": rollup["cloud_util"],
            "cloud_avg_batch": rollup["cloud_avg_batch"],
        })
        if headline is None:
            headline = rollup
            # acceptance: >= 1000 edges through the hierarchy, fast
            assert sc.n_edges >= 1000 and wall < 60.0, (
                f"headline cell too slow/small: {sc.n_edges} edges, "
                f"{wall:.1f}s wall")
            # acceptance: bit-identical rollup on a same-seed re-run
            rerun = simulate_fleet(_scenario(cell))
            assert rerun == rollup, "same-seed rollups differ"
    print("\n" + table(rows, ["slo_mix", "n_edges", "n_cloudlets",
                              "arrivals", "deadline_met_frac", "shed_frac",
                              "latency_p50_s", "latency_p99_s",
                              "joules_per_req", "cloudlet_util",
                              "cloud_util", "cloud_avg_batch"],
                       title="fleet sweep (virtual clock)"))
    # per-SLO-class detail of the headline cell
    slo_rows = []
    for cls in DEFAULT_SLO_CLASSES:
        k = cls.name
        slo_rows.append({
            "class": k, "deadline_s": cls.deadline_s,
            "arrivals": headline[f"{k}_arrivals"],
            "met_frac": headline[f"{k}_deadline_met_frac"],
            "shed_frac": headline[f"{k}_shed_frac"],
            "p50_s": headline[f"{k}_latency_p50_s"],
            "p99_s": headline[f"{k}_latency_p99_s"],
        })
    print("\n" + table(slo_rows, ["class", "deadline_s", "arrivals",
                                  "met_frac", "shed_frac", "p50_s",
                                  "p99_s"],
                       title="headline cell, per SLO class"))
    print(f"\ntotal sweep wall-clock: {wall_total:.1f}s "
          f"(virtual: {sum(c['duration_s'] for c in cells):g}s)")
    # wall seconds stay OUT of the returned payload's headline/rows —
    # they would break the bit-identical determinism contract
    return {"headline": headline, "rows": rows, "determinism_ok": True}


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized grid (2 cells, 30s virtual each)")
    ap.add_argument("--json", action="store_true",
                    help="write the tracked BENCH_fleet.json perf record")
    args = ap.parse_args()
    res = run(fast=args.smoke)
    if args.json or args.smoke:
        # the CI smoke path owns the tracked record, like cloud_batching
        print(f"perf record: {write_fleet_record(res)}")
