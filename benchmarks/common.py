"""Shared benchmark plumbing: result records + pretty tables + JSON dump."""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "bench")


def save_result(name: str, payload: Dict) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    payload = dict(payload, benchmark=name, timestamp=time.time())
    fn = os.path.join(OUT_DIR, f"{name}.json")
    with open(fn, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return fn


def table(rows: List[Dict], cols: List[str], title: str = "") -> str:
    widths = {c: max([len(c)] + [len(_fmt(r.get(c))) for r in rows])
              for c in cols}
    lines = []
    if title:
        lines.append(f"== {title} ==")
    lines.append(" | ".join(c.ljust(widths[c]) for c in cols))
    lines.append("-+-".join("-" * widths[c] for c in cols))
    for r in rows:
        lines.append(" | ".join(_fmt(r.get(c)).ljust(widths[c])
                                for c in cols))
    return "\n".join(lines)


def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1e4 or abs(v) < 1e-3:
            return f"{v:.3e}"
        return f"{v:.4g}"
    return str(v)
