"""Shared benchmark plumbing: result records + pretty tables + JSON dump."""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "bench")


def save_result(name: str, payload: Dict) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    payload = dict(payload, benchmark=name, timestamp=time.time())
    fn = os.path.join(OUT_DIR, f"{name}.json")
    with open(fn, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return fn


def write_collab_record(cloud_batching: Dict,
                        collab_throughput: Dict = None) -> str:
    """The tracked collab-serving perf record, ``BENCH_collab.json``:
    one flat summary (req/s, p50/p95, tx bytes, padding waste) distilled
    from the cloud_batching sweep, plus the streaming numbers when a
    full ``benchmarks.run --json`` pass has them (``None`` otherwise —
    the schema is identical either way, so the serving-path trajectory
    is comparable across commits). Written by exactly one caller per
    invocation: ``benchmarks.cloud_batching`` run as ``__main__`` (the
    CI smoke path), or ``benchmarks.run --json``. CI uploads it as an
    artifact."""
    top = max(cloud_batching["edge_counts"])
    rows = {(r["engine"], r["edges"]): r for r in cloud_batching["rows"]}
    b, t = rows[("batched", top)], rows[("threaded-b1", top)]
    ct = collab_throughput or {}
    rec = {
        "edges": top,
        "batched_req_s": b["req_s"],
        "threaded_b1_req_s": t["req_s"],
        "speedup": cloud_batching["speedup_at_max_edges"],
        "p50_ms": b["p50_ms"],
        "p95_ms": b["p95_ms"],
        "avg_batch": b["avg_batch"],
        "padding_waste": b["pad_waste"],
        "tx_bytes_per_request": cloud_batching["tx_bytes_per_request"],
        "bit_identical": cloud_batching["bit_identical"],
        "streaming_pipelined_req_s": ct.get("pipelined_rps"),
        "streaming_sequential_req_s": ct.get("sequential_rps"),
    }
    return save_result("BENCH_collab", rec)


def write_energy_record(energy_split: Dict) -> str:
    """The tracked energy-aware-serving perf record,
    ``BENCH_energy.json``: one flat summary distilled from the
    energy_split benchmark — how often the weighted objective flips the
    split, the measured joules saving of the flip demo, and the battery
    replay's switch trajectory. Written by ``benchmarks.energy_split``
    run with ``--json``/``--smoke`` (the CI path) or by
    ``benchmarks.run --json``; CI uploads it as an artifact next to
    ``BENCH_collab.json``."""
    flip, battery = energy_split["flip_demo"], energy_split["battery_demo"]
    rec = {
        "n_flips": energy_split["n_flips"],
        "n_pairs": energy_split["n_pairs"],
        "latency_split": flip["latency_split"],
        "energy_split": flip["energy_split"],
        "energy_saving_frac": flip["energy_saving"],
        "latency_total_s": flip["latency_total"]["T_s"],
        "latency_total_j": flip["latency_total"]["E_j"],
        "energy_total_s": flip["energy_total"]["T_s"],
        "energy_total_j": flip["energy_total"]["E_j"],
        "bit_identical": flip["bit_identical"],
        "battery_switches": len(battery["switches"]),
        "battery_start_split": battery["start_split"],
        "battery_end_split": battery["end_split"],
    }
    return save_result("BENCH_energy", rec)


def write_faults_record(fault_injection: Dict) -> str:
    """The tracked fault-tolerance record, ``BENCH_faults.json``: one
    flat summary per canned storm — availability (served requests,
    fallbacks included, over total), p50/p99 request wall-clock under
    faults, retries/fallbacks spent — plus the cloud-death drill's
    recovery time. Written by ``benchmarks.fault_injection`` run with
    ``--json``/``--smoke`` (the CI path) or ``benchmarks.run --json``;
    CI uploads it next to ``BENCH_collab.json``/``BENCH_energy.json``."""
    rec: Dict = {"bit_identical": fault_injection["bit_identical"],
                 "n_requests_per_scenario": fault_injection["n_requests"]}
    for row in fault_injection["rows"]:
        s = row["scenario"]
        rec[f"{s}_availability"] = row["availability"]
        rec[f"{s}_p50_ms"] = row["p50_ms"]
        rec[f"{s}_p99_ms"] = row["p99_ms"]
        rec[f"{s}_faults"] = row["faults"]
        rec[f"{s}_retries"] = row["retries"]
        rec[f"{s}_fallbacks"] = row["fallbacks"]
    rec["cloud_death_recovery_s"] = (
        fault_injection["cloud_death"]["recovery_s"])
    return save_result("BENCH_faults", rec)


def table(rows: List[Dict], cols: List[str], title: str = "") -> str:
    widths = {c: max([len(c)] + [len(_fmt(r.get(c))) for r in rows])
              for c in cols}
    lines = []
    if title:
        lines.append(f"== {title} ==")
    lines.append(" | ".join(c.ljust(widths[c]) for c in cols))
    lines.append("-+-".join("-" * widths[c] for c in cols))
    for r in rows:
        lines.append(" | ".join(_fmt(r.get(c)).ljust(widths[c])
                                for c in cols))
    return "\n".join(lines)


def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1e4 or abs(v) < 1e-3:
            return f"{v:.3e}"
        return f"{v:.4g}"
    return str(v)
