"""Shared benchmark plumbing: result records + pretty tables + JSON dump.

Record naming scheme (``experiments/bench/``): every file this module
writes is ``BENCH_<name>.json`` — ``save_result`` enforces the prefix,
so a raw per-benchmark dump (``BENCH_cloud_batching.json``) and the
distilled tracked records the ``write_*_record`` helpers own
(``BENCH_collab.json`` / ``BENCH_energy.json`` / ``BENCH_faults.json``
/ ``BENCH_fleet.json`` / ``BENCH_failover.json``) follow one convention
instead of the historical mix of bare and prefixed names. The distilled records are the ones
ROADMAP.md / docs/benchmarks.md reference, git tracks, and CI uploads.
"""
from __future__ import annotations

import json
import os
import time
from typing import Dict, List

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                       "bench")


def save_result(name: str, payload: Dict) -> str:
    """Dump one record as ``experiments/bench/BENCH_<name>.json`` (the
    prefix is added unless already present). Adds ``benchmark`` and a
    wall-clock ``timestamp`` — determinism comparisons must exclude
    ``timestamp``, and benchmark payloads must never carry wall-clock
    values of their own."""
    os.makedirs(OUT_DIR, exist_ok=True)
    payload = dict(payload, benchmark=name, timestamp=time.time())
    stem = name if name.startswith("BENCH_") else f"BENCH_{name}"
    fn = os.path.join(OUT_DIR, f"{stem}.json")
    with open(fn, "w") as f:
        json.dump(payload, f, indent=1, default=float)
    return fn


def write_collab_record(cloud_batching: Dict,
                        collab_throughput: Dict = None) -> str:
    """The tracked collab-serving perf record, ``BENCH_collab.json``:
    one flat summary (req/s, p50/p95, tx bytes, padding waste) distilled
    from the cloud_batching sweep, plus the streaming numbers when a
    full ``benchmarks.run --json`` pass has them (``None`` otherwise —
    the schema is identical either way, so the serving-path trajectory
    is comparable across commits). Written by exactly one caller per
    invocation: ``benchmarks.cloud_batching`` run as ``__main__`` (the
    CI smoke path), or ``benchmarks.run --json``. CI uploads it as an
    artifact."""
    top = max(cloud_batching["edge_counts"])
    rows = {(r["engine"], r["edges"]): r for r in cloud_batching["rows"]}
    b, t = rows[("batched", top)], rows[("threaded-b1", top)]
    ct = collab_throughput or {}
    rec = {
        "edges": top,
        "batched_req_s": b["req_s"],
        "threaded_b1_req_s": t["req_s"],
        "speedup": cloud_batching["speedup_at_max_edges"],
        "p50_ms": b["p50_ms"],
        "p95_ms": b["p95_ms"],
        "avg_batch": b["avg_batch"],
        "padding_waste": b["pad_waste"],
        "tx_bytes_per_request": cloud_batching["tx_bytes_per_request"],
        "bit_identical": cloud_batching["bit_identical"],
        "streaming_pipelined_req_s": ct.get("pipelined_rps"),
        "streaming_sequential_req_s": ct.get("sequential_rps"),
    }
    return save_result("BENCH_collab", rec)


def write_energy_record(energy_split: Dict) -> str:
    """The tracked energy-aware-serving perf record,
    ``BENCH_energy.json``: one flat summary distilled from the
    energy_split benchmark — how often the weighted objective flips the
    split, the measured joules saving of the flip demo, and the battery
    replay's switch trajectory. Written by ``benchmarks.energy_split``
    run with ``--json``/``--smoke`` (the CI path) or by
    ``benchmarks.run --json``; CI uploads it as an artifact next to
    ``BENCH_collab.json``."""
    flip, battery = energy_split["flip_demo"], energy_split["battery_demo"]
    rec = {
        "n_flips": energy_split["n_flips"],
        "n_pairs": energy_split["n_pairs"],
        "latency_split": flip["latency_split"],
        "energy_split": flip["energy_split"],
        "energy_saving_frac": flip["energy_saving"],
        "latency_total_s": flip["latency_total"]["T_s"],
        "latency_total_j": flip["latency_total"]["E_j"],
        "energy_total_s": flip["energy_total"]["T_s"],
        "energy_total_j": flip["energy_total"]["E_j"],
        "bit_identical": flip["bit_identical"],
        "battery_switches": len(battery["switches"]),
        "battery_start_split": battery["start_split"],
        "battery_end_split": battery["end_split"],
    }
    return save_result("BENCH_energy", rec)


def write_faults_record(fault_injection: Dict) -> str:
    """The tracked fault-tolerance record, ``BENCH_faults.json``: one
    flat summary per canned storm — availability (served requests,
    fallbacks included, over total), p50/p99 request wall-clock under
    faults, retries/fallbacks spent — plus the cloud-death drill's
    recovery time. Written by ``benchmarks.fault_injection`` run with
    ``--json``/``--smoke`` (the CI path) or ``benchmarks.run --json``;
    CI uploads it next to ``BENCH_collab.json``/``BENCH_energy.json``."""
    rec: Dict = {"bit_identical": fault_injection["bit_identical"],
                 "n_requests_per_scenario": fault_injection["n_requests"]}
    for row in fault_injection["rows"]:
        s = row["scenario"]
        rec[f"{s}_availability"] = row["availability"]
        rec[f"{s}_p50_ms"] = row["p50_ms"]
        rec[f"{s}_p99_ms"] = row["p99_ms"]
        rec[f"{s}_faults"] = row["faults"]
        rec[f"{s}_retries"] = row["retries"]
        rec[f"{s}_fallbacks"] = row["fallbacks"]
    rec["cloud_death_recovery_s"] = (
        fault_injection["cloud_death"]["recovery_s"])
    return save_result("BENCH_faults", rec)


def write_failover_record(failover: Dict) -> str:
    """The tracked high-availability record, ``BENCH_failover.json``:
    one flat summary of the fleet drills — the kill drill's availability,
    reroute recovery time and request percentiles under a member death,
    and the rolling-drain drill's zero-failed-requests contract — plus
    the fleet-wide reroute/migration counts. Written by
    ``benchmarks.failover`` run with ``--json``/``--smoke`` (the CI
    path); CI uploads it next to the other BENCH records."""
    kill, drain = failover["kill_drill"], failover["drain_drill"]
    rec = {
        "n_edges": failover["n_edges"],
        "n_servers": failover["n_servers"],
        "bit_identical": failover["bit_identical"],
        "kill_availability": kill["availability"],
        "kill_recovery_max_s": kill["recovery_max_s"],
        "kill_p50_ms": kill["p50_ms"],
        "kill_p99_ms": kill["p99_ms"],
        "kill_faults": kill["faults"],
        "kill_reroutes": kill["reroutes"],
        "drain_availability": drain["availability"],
        "drain_faults": drain["faults"],
        "drain_migrations": drain["migrations"],
        "drain_p99_ms": drain["p99_ms"],
    }
    return save_result("BENCH_failover", rec)


def write_kernels_record(kernel_edge: Dict) -> str:
    """The tracked quantized-kernel-edge record, ``BENCH_kernels.json``:
    the three edge wall-clock numbers at the deploy split (fp32 dense /
    compacted kernel fp32 / compacted int8 kernel, batch-1 ms), the
    int8-vs-dense speedup and top-1 delta, the Pallas/ref parity bit,
    the calibrated split, the MCU/Pi fc memory shares and the edge
    weight footprint at both widths. Written by
    ``benchmarks.kernel_edge`` run with ``--json``/``--smoke`` (the CI
    path) or by ``benchmarks.run --json``; CI uploads it next to the
    other BENCH records. (The raw Pallas micro-sweep from
    ``kernels_bench`` lives in ``BENCH_kernels_micro.json``.)"""
    rec = {k: kernel_edge[k] for k in (
        "split", "fp32_dense_edge_ms", "kernel_fp32_edge_ms",
        "int8_kernel_edge_ms", "int8_speedup_vs_dense", "top1_fp32",
        "top1_int8", "top1_delta_points", "bit_identical_pallas_ref",
        "calibrated_split", "mcu_fc_memory_share_min",
        "pi_fc_memory_share_min", "edge_weight_bytes_fp32",
        "edge_weight_bytes_int8")}
    return save_result("BENCH_kernels", rec)


def write_fleet_record(fleet_sim: Dict) -> str:
    """The tracked fleet-simulation record, ``BENCH_fleet.json``: the
    headline scenario's full rollup (fleet p50/p99, joules/request,
    deadline attainment, per-tier shed/utilization/queue metrics — all
    virtual-clock, so bit-identical across same-seed runs) plus the
    sweep's per-cell summary keys. Written by ``benchmarks.fleet_sim``
    run with ``--json``/``--smoke`` (the CI path) or by
    ``benchmarks.run --json``; CI uploads it next to the other BENCH
    records."""
    rec: Dict = dict(fleet_sim["headline"])
    rec["determinism_ok"] = fleet_sim["determinism_ok"]
    for row in fleet_sim["rows"]:
        k = (f"{row['slo_mix']}_{row['n_edges']}edges"
             f"_{row['n_cloudlets']}cl")
        rec[f"{k}_deadline_met_frac"] = row["deadline_met_frac"]
        rec[f"{k}_shed_frac"] = row["shed_frac"]
        rec[f"{k}_latency_p99_s"] = row["latency_p99_s"]
        rec[f"{k}_cloud_util"] = row["cloud_util"]
    return save_result("BENCH_fleet", rec)


def table(rows: List[Dict], cols: List[str], title: str = "") -> str:
    widths = {c: max([len(c)] + [len(_fmt(r.get(c))) for r in rows])
              for c in cols}
    lines = []
    if title:
        lines.append(f"== {title} ==")
    lines.append(" | ".join(c.ljust(widths[c]) for c in cols))
    lines.append("-+-".join("-" * widths[c] for c in cols))
    for r in rows:
        lines.append(" | ".join(_fmt(r.get(c)).ljust(widths[c])
                                for c in cols))
    return "\n".join(lines)


def _fmt(v) -> str:
    if v is None:
        return "-"
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1e4 or abs(v) < 1e-3:
            return f"{v:.3e}"
        return f"{v:.4g}"
    return str(v)
