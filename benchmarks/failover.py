"""High-availability fleet drills: server death and rolling restart
under multi-edge load (BENCH).

A single cloud process is a single point of failure: one restart drops
every connected edge. The fleet front tier (``RoutingPolicy`` +
``FleetRouter`` + ``CloudFleet``) spreads edges across N servers and
keeps collaborative serving available through member loss and rolling
restarts. This benchmark measures exactly that contract with a real
3-server fleet and 8 fleet-routed edge sessions:

  1. **Kill drill** — mid-load, the member every edge's lane hashes to
     is crashed (hard connection resets, no goodbye). Each edge detects
     the death, marks the member dead, reroutes to the next healthy
     server, and replays its in-flight request — logits bit-identical
     to the fault-free reference. Reported: availability (acceptance:
     >= 99%), the worst per-edge reroute recovery time (the wall-clock
     of the faulted request, detection + reroute + replay — acceptance:
     < 250 ms), and p50/p99 request latency across the whole drill.
  2. **Rolling-drain drill** — every member is restarted in sequence:
     DRAIN announcements migrate the edges (no fault budget spent), the
     member restarts, the routers revive it, and the next member drains.
     Acceptance: availability 100%, zero faults — a full fleet rollout
     with zero failed requests.

``--smoke`` runs the CI-sized version; the tracked perf record
``experiments/bench/BENCH_failover.json`` is written by ``--json`` (or
the smoke path), next to ``BENCH_faults.json``.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import save_result, table, write_failover_record
from repro import serving
from repro.core.partition.profiles import PAPER_PROFILE
from repro.core.pruning.masks import cnn_masks_from_ratios
from repro.models.cnn import init_cnn_params, prunable_layers, tiny_cnn_config

BASE_PORT = 29960
SPLIT = 6
N_EDGES = 8
N_SERVERS = 3
#: untimed warm-up requests per session (jit compile on both peers)
N_WARMUP = 2

#: bench-scaled recovery contract: ms-range backoff, deadline sliced
#: across 1+3 attempts, deterministic jitter, edge fallback as the
#: bottom rung (the drills must never reach it while a member survives)
POLICY = serving.FaultPolicy(max_retries=3, backoff_base_s=0.01,
                             backoff_max_s=0.05, backoff_jitter=0.0,
                             request_deadline_s=0.8, fallback="edge",
                             seed=0)


def _setup() -> serving.DeploymentPlan:
    ports = tuple(BASE_PORT + k for k in range(N_SERVERS))
    cfg = tiny_cnn_config(num_classes=38, hw=32)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    masks = cnn_masks_from_ratios(params, cfg,
                                  {i: 0.5 for i in prunable_layers(cfg)})
    return serving.DeploymentPlan.from_args(
        params, cfg, SPLIT, masks=masks, compact=True, codec="fp32",
        profile=PAPER_PROFILE, shape_link=False, faults=POLICY,
        port=ports[0],
        routing=serving.RoutingPolicy(ports=ports, dead_after_count=1))


def _images(n: int) -> List[np.ndarray]:
    rng = np.random.RandomState(0)
    return [rng.rand(1, 32, 32, 3).astype(np.float32) for _ in range(n)]


def _reference(plan, imgs) -> List[np.ndarray]:
    """Fault-free logits per image from the local backend — the bit
    budget every rerouted/replayed socket answer must still hit."""
    sess = serving.connect(plan, backend="local")
    try:
        return [sess.infer(img)["logits"] for img in imgs]
    finally:
        sess.close()


def _sessions(plan) -> List:
    out = [serving.connect(plan, backend="socket") for _ in range(N_EDGES)]
    for s in out:
        for _ in range(N_WARMUP):
            s.infer(_images(1)[0])
    return out


def _sweep(sessions, imgs, ref, counters: Dict,
           lats: List[float]) -> None:
    """One full round: every edge serves every image, faithfully
    accounted (latency, fault budget, bit-identity)."""
    for i, img in enumerate(imgs):
        for sess in sessions:
            t0 = time.perf_counter()
            try:
                res = sess.infer(img)
            except Exception:               # noqa: BLE001 — counted
                continue                    # as unavailability
            lats.append(time.perf_counter() - t0)
            counters["served"] += 1
            rec = res["fault"]
            counters["faults"] += rec["faults"]
            counters["retries"] += rec["retries"]
            counters["migrations"] += rec["migrations"]
            counters["fallbacks"] += int(rec["fallback"])
            counters["mismatches"] += int(
                not np.array_equal(res["logits"], ref[i]))


def _counters() -> Dict:
    return {"served": 0, "faults": 0, "retries": 0, "migrations": 0,
            "fallbacks": 0, "mismatches": 0}


def _row(name: str, n: int, c: Dict, lats: List[float]) -> Dict:
    return {
        "scenario": name, "requests": n, "served": c["served"],
        "availability": c["served"] / n,
        "faults": c["faults"], "retries": c["retries"],
        "migrations": c["migrations"], "fallbacks": c["fallbacks"],
        "mismatches": c["mismatches"],
        "p50_ms": float(np.percentile(lats, 50)) * 1e3 if lats else None,
        "p99_ms": float(np.percentile(lats, 99)) * 1e3 if lats else None,
    }


def kill_drill(plan, imgs, ref) -> Dict:
    """Crash the member every lane hashes to, mid-load: the edges mark
    it dead, reroute, and replay — ``recovery_max_s`` is the worst
    per-edge wall-clock of the faulted request."""
    c, lats = _counters(), []
    with serving.CloudFleet(plan) as fleet:
        sessions = _sessions(plan)
        try:
            _sweep(sessions, imgs, ref, c, lats)
            victim = sessions[0]._client._port
            fleet.kill(victim)
            # the rerouted replay: every edge's next request eats the
            # death, reroutes, and must still answer bit-identically
            recoveries = []
            for sess in sessions:
                t0 = time.perf_counter()
                res = sess.infer(imgs[0])
                recoveries.append(time.perf_counter() - t0)
                lats.append(recoveries[-1])
                c["served"] += 1
                c["faults"] += res["fault"]["faults"]
                c["retries"] += res["fault"]["retries"]
                c["migrations"] += res["fault"]["migrations"]
                c["fallbacks"] += int(res["fault"]["fallback"])
                c["mismatches"] += int(
                    not np.array_equal(res["logits"], ref[0]))
            _sweep(sessions, imgs, ref, c, lats)
            reroutes = sum(s.router.stats()["reroutes_count"]
                           for s in sessions)
            dead = {p: sessions[0].router.state(p)
                    for p in plan.routing.ports}
        finally:
            for s in sessions:
                s.close()
    n = 2 * len(imgs) * N_EDGES + N_EDGES
    row = _row("kill_member", n, c, lats)
    row["victim"] = victim
    row["reroutes"] = reroutes
    row["recovery_max_s"] = max(recoveries)
    row["states_after"] = dead
    return row


def drain_drill(plan, imgs, ref) -> Dict:
    """Roll the whole fleet, one member at a time: drain -> the edges
    migrate on DRAIN replies (zero fault budget) -> restart -> revive.
    A full rollout must lose nothing: availability 1.0, faults 0."""
    c, lats = _counters(), []
    rounds = 0
    with serving.CloudFleet(plan) as fleet:
        sessions = _sessions(plan)
        try:
            for _ in range(N_SERVERS):
                victim = sessions[0]._client._port
                fleet.drain(victim)
                _sweep(sessions, imgs, ref, c, lats)
                fleet.restart(victim)
                for s in sessions:
                    s.router.revive(victim)
                rounds += 1
        finally:
            for s in sessions:
                s.close()
    n = rounds * len(imgs) * N_EDGES
    return _row("rolling_drain", n, c, lats)


def run(fast: bool = False) -> dict:
    plan = _setup()
    n = 3 if fast else 8
    imgs = _images(n)
    ref = _reference(plan, imgs)
    print(plan.describe())

    kill = kill_drill(plan, imgs, ref)
    drain = drain_drill(plan, imgs, ref)
    rows = [kill, drain]

    print(table(rows, ["scenario", "requests", "served", "availability",
                       "faults", "migrations", "fallbacks", "p50_ms",
                       "p99_ms"],
                f"{N_SERVERS}-server fleet, {N_EDGES} edges, "
                f"split c={SPLIT}, retries<={POLICY.max_retries}, "
                f"deadline {POLICY.request_deadline_s}s"))
    print(f"   kill: member {kill['victim']} died under load — worst "
          f"reroute recovery {kill['recovery_max_s'] * 1e3:.0f} ms, "
          f"{kill['reroutes']} reroutes")
    print(f"   drain: full {N_SERVERS}-member rollout, "
          f"{drain['migrations']} migrations, {drain['faults']} faults")

    assert kill["availability"] >= 0.99, (
        f"kill drill availability {kill['availability']:.3f} < 0.99", kill)
    assert kill["recovery_max_s"] < 0.25, (
        f"reroute recovery {kill['recovery_max_s'] * 1e3:.0f} ms "
        f">= 250 ms", kill)
    assert kill["fallbacks"] == 0, (
        "an edge fell back to local serving while healthy members "
        "remained", kill)
    assert drain["availability"] == 1.0 and drain["faults"] == 0, (
        "a rolling drain failed requests — the zero-loss rollout "
        "contract is broken", drain)
    assert drain["migrations"] >= N_EDGES, (
        "the drain never actually migrated the edges", drain)
    bit_identical = all(r["mismatches"] == 0 for r in rows)
    assert bit_identical, ("served logits diverged from the fault-free "
                           "reference", rows)

    out = {"n_edges": N_EDGES, "n_servers": N_SERVERS, "split": SPLIT,
           "policy": POLICY.to_json(),
           "routing": plan.routing.to_json(),
           "kill_drill": kill, "drain_drill": drain,
           "bit_identical": bit_identical}
    # raw per-drill dump; the distilled tracked record is
    # BENCH_failover.json (write_failover_record)
    save_result("failover_drills", out)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer requests per drill)")
    ap.add_argument("--json", action="store_true",
                    help="write the tracked BENCH_failover.json record")
    args = ap.parse_args()
    res = run(fast=args.smoke)
    if args.json or args.smoke:
        # the CI smoke path owns the tracked record, like fault_injection
        print(f"perf record: {write_failover_record(res)}")
