"""Fault-injection replay: availability and recovery under canned
storms (BENCH).

The paper's deployment is split inference over field Wi-Fi; real links
drop frames, stall, tear connections down, and the cloud peer itself can
die mid-stream. This benchmark replays the canned deterministic
``FAULT_SCHEDULES`` storms against the real-socket backend with a
fault-tolerant plan (CRC + sequence numbers, retries with backoff,
edge-only fallback) and reports what the recovery machinery buys:

  1. **Storm replay** — ``drop_burst`` (lossy uplink), ``stall_storm``
     (congested AP), and ``outage`` (coverage hole) are injected on the
     edge's data frames. Reported per storm: availability (served
     requests, edge-fallbacks included, over total — acceptance:
     >= 99% on the drop/stall storms), p50/p99 request wall-clock
     *including* all retry/backoff/fallback time, and the
     faults/retries/fallbacks spent. Every served request's logits are
     checked bit-identical to a fault-free local run of the same plan
     (fp32 codec: neither the split nor the recovery path changes the
     math — an edge-fallback answer equals the collaborative answer).
  2. **Cloud-death drill** — the ``cloud_death`` schedule kills the
     serving process mid-response (server-side injection). The edge
     rides it out: retries exhaust against the dead peer, the request is
     served edge-only, a replacement cloud comes up on the same
     endpoint, and the next requests reconnect (re-HELLO, re-RESPLIT)
     and go collaborative again. Reported: time from the death to the
     first clean collaborative response.

``--smoke`` runs the CI-sized version; the tracked perf record
``experiments/bench/BENCH_faults.json`` is written by ``--json`` (or by
``benchmarks.run --json``), next to ``BENCH_collab.json`` and
``BENCH_energy.json``.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import numpy as np

from benchmarks.common import save_result, table, write_faults_record
from repro import serving
from repro.core.partition.profiles import PAPER_PROFILE
from repro.core.pruning.masks import cnn_masks_from_ratios
from repro.models.cnn import init_cnn_params, prunable_layers, tiny_cnn_config

BASE_PORT = 29860
SPLIT = 6
#: client-side storms replayed over the socket backend, in order
STORMS = ("drop_burst", "stall_storm", "outage")
#: untimed warm-up requests per scenario (jit compile on both peers;
#: they consume the first schedule attempts, which the canned storms
#: leave clean)
N_WARMUP = 2

#: bench-scaled recovery contract: ms-range backoff so a whole storm
#: replays in seconds, deadline sliced across 1+3 attempts (0.2 s
#: per-attempt read timeout), deterministic jitter, edge fallback
POLICY = serving.FaultPolicy(max_retries=3, backoff_base_s=0.01,
                             backoff_max_s=0.05, backoff_jitter=0.0,
                             request_deadline_s=0.8, fallback="edge",
                             seed=0)


def _setup() -> serving.DeploymentPlan:
    cfg = tiny_cnn_config(num_classes=38, hw=32)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    masks = cnn_masks_from_ratios(params, cfg,
                                  {i: 0.5 for i in prunable_layers(cfg)})
    return serving.DeploymentPlan.from_args(
        params, cfg, SPLIT, masks=masks, compact=True, codec="fp32",
        profile=PAPER_PROFILE, shape_link=False, faults=POLICY)


def _images(n: int) -> List[np.ndarray]:
    rng = np.random.RandomState(0)
    return [rng.rand(1, 32, 32, 3).astype(np.float32) for _ in range(n)]


def _reference(plan, imgs) -> List[np.ndarray]:
    """Fault-free logits per image from the local backend — the bit
    budget every faulted socket answer must still hit exactly."""
    sess = serving.connect(plan, backend="local")
    try:
        return [sess.infer(img)["logits"] for img in imgs]
    finally:
        sess.close()


def _row(name: str, n: int, served: int, lats: List[float], faults: int,
         retries: int, fallbacks: int, mismatches: int) -> Dict:
    return {
        "scenario": name, "requests": n, "served": served,
        "availability": served / n,
        "faults": faults, "retries": retries, "fallbacks": fallbacks,
        "mismatches": mismatches,
        "p50_ms": float(np.percentile(lats, 50)) * 1e3 if lats else None,
        "p99_ms": float(np.percentile(lats, 99)) * 1e3 if lats else None,
    }


def replay_storm(name: str, plan, imgs, ref, port: int) -> Dict:
    """Replay one canned storm on the edge's data frames; every request
    must come back (retried, replayed, or served edge-only) with the
    fault-free logits, and the row records what that cost."""
    inj = serving.FaultInjector(serving.FAULT_SCHEDULES[name])
    with serving.CloudServer(plan, port=port) as srv:
        sess = serving.connect(plan, backend="socket", port=port,
                               faults=inj)
        lats: List[float] = []
        served = faults = retries = fallbacks = mismatches = 0
        try:
            for _ in range(N_WARMUP):
                sess.infer(imgs[0])
            for i, img in enumerate(imgs):
                t0 = time.perf_counter()
                try:
                    res = sess.infer(img)
                except Exception:               # noqa: BLE001 — counted
                    continue                    # as unavailability
                lats.append(time.perf_counter() - t0)
                served += 1
                rec = res["fault"]
                faults += rec["faults"]
                retries += rec["retries"]
                fallbacks += int(rec["fallback"])
                mismatches += int(not np.array_equal(res["logits"],
                                                     ref[i]))
        finally:
            sess.close()
    row = _row(name, len(imgs), served, lats, faults, retries, fallbacks,
               mismatches)
    row["injected"] = dict(inj.counts)
    row["server_stats"] = dict(srv.fault_stats)
    return row


def cloud_death_drill(plan, imgs, ref, port: int) -> Dict:
    """The ``cloud_death`` schedule kills the server mid-response; the
    drill measures the edge's road back: fallback serves the faulted
    request, a replacement cloud comes up, and ``recovery_s`` is the
    wall-clock from the death to the first clean collaborative response.
    """
    inj = serving.FaultInjector(serving.FAULT_SCHEDULES["cloud_death"])
    srv = serving.CloudServer(plan, port=port, faults=inj)
    sess = serving.connect(plan, backend="socket", port=port)
    lats: List[float] = []
    served = faults = retries = fallbacks = mismatches = 0
    t_death = None
    death_request = None
    recovery_s = None
    try:
        for _ in range(N_WARMUP):
            sess.infer(imgs[0])
        for i, img in enumerate(imgs):
            t0 = time.perf_counter()
            res = sess.infer(img)
            now = time.perf_counter()
            lats.append(now - t0)
            served += 1
            rec = res["fault"]
            faults += rec["faults"]
            retries += rec["retries"]
            fallbacks += int(rec["fallback"])
            mismatches += int(not np.array_equal(res["logits"], ref[i]))
            if rec["fallback"] and t_death is None:
                # the injected die tore the cloud down mid-response and
                # this request was served edge-only; bring up the
                # replacement and time the reconnect
                t_death, death_request = now, i
                srv.kill()
                srv = serving.CloudServer(plan, port=port)
            elif (t_death is not None and recovery_s is None
                  and not rec["fallback"]):
                recovery_s = now - t_death
    finally:
        sess.close()
        srv.stop()
    row = _row("cloud_death", len(imgs), served, lats, faults, retries,
               fallbacks, mismatches)
    assert t_death is not None, (
        "cloud_death schedule never killed the server — no death to "
        "recover from")
    assert recovery_s is not None, (
        "edge never returned to collaborative serving after the "
        "replacement cloud came up")
    return {"row": row, "death_request": death_request,
            "recovery_s": recovery_s}


def run(fast: bool = False) -> dict:
    plan = _setup()
    n = 40 if fast else 100
    imgs = _images(n)
    ref = _reference(plan, imgs)
    print(plan.describe())

    rows = [replay_storm(name, plan, imgs, ref, BASE_PORT + k)
            for k, name in enumerate(STORMS)]
    drill = cloud_death_drill(plan, imgs, ref, BASE_PORT + len(STORMS))
    rows.append(drill["row"])

    print(table(rows, ["scenario", "requests", "served", "availability",
                       "faults", "retries", "fallbacks", "p50_ms",
                       "p99_ms"],
                f"{n} requests per storm, split c={SPLIT}, "
                f"retries<={POLICY.max_retries}, "
                f"deadline {POLICY.request_deadline_s}s, edge fallback"))
    print(f"   cloud death at request {drill['death_request']}: back to "
          f"collaborative serving in {drill['recovery_s'] * 1e3:.0f} ms")

    by_name = {r["scenario"]: r for r in rows}
    for name in ("drop_burst", "stall_storm"):
        assert by_name[name]["availability"] >= 0.99, (
            f"{name}: availability "
            f"{by_name[name]['availability']:.3f} < 0.99", by_name[name])
    for r in rows:
        assert r["faults"] > 0, (
            f"{r['scenario']}: storm injected no faults — nothing was "
            "exercised", r)
    bit_identical = all(r["mismatches"] == 0 for r in rows)
    assert bit_identical, ("served logits diverged from the fault-free "
                           "reference", rows)

    out = {"n_requests": n, "split": SPLIT, "policy": POLICY.to_json(),
           "rows": rows,
           "cloud_death": {"death_request": drill["death_request"],
                           "recovery_s": drill["recovery_s"]},
           "bit_identical": bit_identical}
    save_result("fault_injection", out)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer requests per storm)")
    ap.add_argument("--json", action="store_true",
                    help="write the tracked BENCH_faults.json perf record")
    args = ap.parse_args()
    res = run(fast=args.smoke)
    if args.json or args.smoke:
        # the CI smoke path owns the tracked record, like energy_split
        print(f"perf record: {write_faults_record(res)}")
