"""Paper Fig. 4 — layer-wise output data size and processing latency,
original vs pruned.

Claims validated: pruning reduces per-layer output bytes by ~the pruned
fraction and reduces per-layer latency; conv1 (kept at ratio 1.0) is
unchanged. Analytic sizes on full AlexNet with the paper's Fig. 3 ratios +
measured wall-clock per layer on the reduced CNN (this container's CPU
stands in for the edge device).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import save_result, table
from benchmarks.table2_split_latency import PAPER_FIG3_RATIOS, _paper_masks
from repro.core.partition.latency_model import (cnn_layer_costs,
                                                measure_cnn_layer_times)
from repro.models.cnn import (alexnet_config, init_cnn_params,
                              tiny_cnn_config)
from repro.core.pruning.masks import cnn_masks_from_ratios


def run(fast: bool = False) -> dict:
    # analytic: full AlexNet, dense vs paper-Fig.3-pruned
    cfg = alexnet_config()
    dense = cnn_layer_costs(cfg)
    pruned = cnn_layer_costs(cfg, _paper_masks(cfg))
    conv_ids = [i for i, s in enumerate(cfg.layers) if s.kind == "conv"]
    rows = []
    for i in conv_ids:
        rows.append({
            "layer": f"conv{conv_ids.index(i) + 1}",
            "ratio": PAPER_FIG3_RATIOS.get(i, 1.0),
            "size_KB_dense": dense[i].out_bytes / 1024,
            "size_KB_pruned": pruned[i].out_bytes / 1024,
            "size_drop_%": 100 * (1 - pruned[i].out_bytes
                                  / dense[i].out_bytes),
            "flops_drop_%": 100 * (1 - pruned[i].flops / dense[i].flops),
        })
    print(table(rows, ["layer", "ratio", "size_KB_dense", "size_KB_pruned",
                       "size_drop_%", "flops_drop_%"],
                "Fig. 4 (analytic): layer-wise size/FLOPs, dense vs pruned"))
    # conv1 kept at 1.0 -> unchanged; others shrink by 1-ratio
    assert rows[0]["size_drop_%"] < 1e-6
    for r in rows[1:]:
        assert abs(r["size_drop_%"] - 100 * (1 - r["ratio"])) < 2.0

    # measured: reduced CNN on this CPU
    tcfg = tiny_cnn_config(hw=32)
    params = init_cnn_params(jax.random.PRNGKey(0), tcfg)
    x = jax.numpy.asarray(
        np.random.RandomState(0).rand(1, 32, 32, 3).astype(np.float32))
    ratios = {i: 0.5 for i, s in enumerate(tcfg.layers)
              if s.kind == "conv" and i > 0}
    masks = cnn_masks_from_ratios(params, tcfg, ratios)
    t_dense = measure_cnn_layer_times(params, tcfg, x,
                                      repeats=2 if fast else 5)
    t_pruned = measure_cnn_layer_times(params, tcfg, x, masks=masks,
                                       repeats=2 if fast else 5)
    mrows = [{"layer": f"{s.kind}{i}",
              "t_dense_us": t_dense[i] * 1e6,
              "t_pruned_us": t_pruned[i] * 1e6}
             for i, s in enumerate(tcfg.layers)]
    print(table(mrows, ["layer", "t_dense_us", "t_pruned_us"],
                "Fig. 4 (measured, reduced CNN on this CPU)"))
    out = {"analytic": rows, "measured": mrows}
    save_result("fig4_layerwise", out)
    return out


if __name__ == "__main__":
    run()
