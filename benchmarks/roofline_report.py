"""Roofline report — aggregates experiments/dryrun/*.json into the
per-(arch x shape x mesh) three-term roofline table (EXPERIMENTS.md
§Roofline reads this)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import save_result, table

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")


def load_records(mesh: str = "pod"):
    recs = []
    for fn in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*_{mesh}.json"))):
        with open(fn) as f:
            r = json.load(f)
        if r.get("status") == "ok":
            recs.append(r)
    return recs


def run(fast: bool = False, mesh: str = "pod") -> dict:
    recs = load_records(mesh)
    rows = []
    for r in recs:
        t = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"],
            "mode": "scan~" if r.get("scan_counted") else "unrolled",
            "t_comp_ms": t["t_compute_s"] * 1e3,
            "t_mem_ms": t["t_memory_s"] * 1e3,
            "t_coll_ms": t["t_collective_s"] * 1e3,
            "dominant": t["dominant"],
            "useful_flops": (round(r["useful_flops_ratio"], 3)
                             if r.get("useful_flops_ratio") else None),
        })
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    print(table(rows, ["arch", "shape", "t_comp_ms", "t_mem_ms",
                       "t_coll_ms", "dominant", "useful_flops", "mode"],
                f"Roofline terms per (arch x shape), mesh={mesh} "
                f"({len(rows)} compiled pairs)"))
    by_dom = {}
    for r in rows:
        if r["mode"] == "unrolled":
            by_dom[r["dominant"]] = by_dom.get(r["dominant"], 0) + 1
    print("   (scan~ rows: loop body counted once by HloCostAnalysis — "
          "they prove compile+sharding; roofline terms are lower bounds)")
    print("   dominant-term histogram:", by_dom)
    out = {"rows": rows, "dominant_histogram": by_dom, "mesh": mesh}
    save_result(f"roofline_{mesh}", out)
    return out


if __name__ == "__main__":
    run()
