"""Render §Dry-run and §Roofline tables from experiments/dryrun/*.json into
EXPERIMENTS.md (replaces the RESULTS_PLACEHOLDER_* markers).

    PYTHONPATH=src python -m benchmarks.fill_experiments
"""
from __future__ import annotations

import glob
import json
import os

ROOT = os.path.join(os.path.dirname(__file__), "..")
DRY = os.path.join(ROOT, "experiments", "dryrun")

ARCH_ORDER = ["mamba2-2.7b", "gemma-7b", "qwen1.5-4b", "qwen2-7b",
              "hubert-xlarge", "nemotron-4-340b", "qwen2-vl-7b",
              "zamba2-1.2b", "deepseek-v3-671b", "mixtral-8x7b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def load():
    recs = {}
    for fn in glob.glob(os.path.join(DRY, "*.json")):
        r = json.load(open(fn))
        if "shape" in r:
            recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def fmt_bytes(b):
    if b is None:
        return "-"
    for u, d in (("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if b >= d:
            return f"{b / d:.1f}{u}"
    return f"{b:.0f}B"


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    return f"{x * 1e3:.2f}ms"


def dryrun_table(recs):
    lines = ["| arch | shape | pod compile | multipod compile | "
             "bytes/dev (args+temp, scan*) | collectives (pod) |",
             "|---|---|---|---|---|---|"]
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            p = recs.get((a, s, "pod"))
            m = recs.get((a, s, "multipod"))
            if p is None and m is None:
                continue

            def cstat(r):
                if r is None:
                    return "—"
                tag = " (scan)" if r.get("scan_counted") else ""
                return f"ok {r.get('compile_s', '?')}s{tag}"

            mem = "-"
            if p and p.get("memory_analysis"):
                ma = p["memory_analysis"]
                mem = (fmt_bytes(ma.get("argument_size_in_bytes", 0))
                       + " + " + fmt_bytes(ma.get("temp_size_in_bytes", 0)))
            colls = "-"
            if p and p.get("collectives"):
                c = p["collectives"]["count_by_op"]
                colls = " ".join(f"{k.split('-')[-1][:4]}:{v}"
                                 for k, v in sorted(c.items()))
            lines.append(f"| {a} | {s} | {cstat(p)} | {cstat(m)} | {mem} "
                         f"| {colls} |")
    n_ok = sum(1 for r in recs.values() if r.get("status") == "ok")
    lines.append("")
    lines.append(f"Compiled pairs: **{n_ok}** records "
                 "(pod + multipod). `(scan)` rows lowered with "
                 "scan-over-layers (unrolled straight-line HLO exceeded "
                 "this 1-core host's compile budget) — they prove "
                 "lower+compile+sharding; their cost_analysis counts the "
                 "loop body once, so they are excluded from the roofline "
                 "comparison below and marked `~` there.")
    return "\n".join(lines)


def roofline_table(recs):
    from repro.roofline import hw
    lines = ["| arch | shape | t_compute | t_memory | t_collective | "
             "dominant | useful_FLOPs |",
             "|---|---|---|---|---|---|---|"]
    doms = {}
    for a in ARCH_ORDER:
        for s in SHAPE_ORDER:
            r = recs.get((a, s, "pod"))
            if r is None or r.get("status") != "ok":
                continue
            t = r["roofline"]
            scan = r.get("scan_counted")
            mark = "~" if scan else ""
            uf = r.get("useful_flops_ratio")
            lines.append(
                f"| {a} | {s} | {mark}{fmt_s(t['t_compute_s'])} "
                f"| {mark}{fmt_s(t['t_memory_s'])} "
                f"| {mark}{fmt_s(t['t_collective_s'])} | {t['dominant']} "
                f"| {'' if uf is None else round(uf, 2)} |")
            if not scan:
                doms[t["dominant"]] = doms.get(t["dominant"], 0) + 1
    lines.append("")
    lines.append(f"Dominant-term histogram (unrolled rows): {doms}. "
                 "Sentence-per-row 'what would move it' analysis: "
                 "collective-dominated rows are FSDP weight all-gathers + "
                 "attention/FFN layout reshards (fixed for the hillclimbed "
                 "pairs in §Perf — the same two levers apply per-family); "
                 "memory-dominated decode rows are KV/state-cache streaming "
                 "(roofline-optimal; lever = cache dtype / MLA-style "
                 "compression); compute-dominated rows are already near "
                 "the MXU roof.")
    return "\n".join(lines)


def main():
    recs = load()
    path = os.path.join(ROOT, "EXPERIMENTS.md")
    text = open(path).read()
    text = text.replace("RESULTS_PLACEHOLDER_DRYRUN", dryrun_table(recs))
    text = text.replace("RESULTS_PLACEHOLDER_ROOFLINE", roofline_table(recs))
    ss = []
    for fn in sorted(glob.glob(os.path.join(DRY, "*split_serve*.json"))):
        r = json.load(open(fn))
        ss.append(f"* {r['arch']}: compile {r['compile_s']}s, "
                  f"ppermute {fmt_bytes(r['collectives']['bytes_by_op'].get('collective-permute', 0))}/chip, "
                  f"Eq.5 boundary {fmt_bytes(r['boundary_bytes_model'])} global")
    text = text.replace("RESULTS_PLACEHOLDER_SPLITSERVE",
                        "Split-serve dry-runs (multipod):\n" + "\n".join(ss)
                        if ss else "")
    open(path, "w").write(text)
    print("EXPERIMENTS.md updated with", len(recs), "dry-run records")


if __name__ == "__main__":
    main()
