"""Benchmark harness entry point — one benchmark per paper table/figure,
plus the kernel sweeps and the roofline aggregation.

    PYTHONPATH=src python -m benchmarks.run [--fast] [--only NAME]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from benchmarks import (adaptive_split, cloud_batching, collab_throughput,
                        energy_split, fault_injection, fig4_layerwise,
                        fig5_methods, fleet_sim, kernel_edge,
                        kernels_bench, roofline_report, table1_accuracy,
                        table2_split_latency)
from benchmarks.common import (write_collab_record, write_energy_record,
                               write_faults_record, write_fleet_record,
                               write_kernels_record)

BENCHES = [
    ("table2_split_latency", table2_split_latency.run),
    ("fig4_layerwise", fig4_layerwise.run),
    ("fig5_methods", fig5_methods.run),
    ("collab_throughput", collab_throughput.run),
    ("cloud_batching", cloud_batching.run),
    ("adaptive_split", adaptive_split.run),
    ("energy_split", energy_split.run),
    ("fault_injection", fault_injection.run),
    ("fleet_sim", fleet_sim.run),
    ("kernels_micro", kernels_bench.run),
    ("kernel_edge", kernel_edge.run),
    ("table1_accuracy", table1_accuracy.run),
    ("roofline", roofline_report.run),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="reduced sizes/epochs for CI-style runs")
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", action="store_true",
                    help="write the tracked BENCH_collab.json perf record "
                         "(req/s, p50/p95, tx bytes, padding waste, "
                         "streaming req/s) from the collab-serving "
                         "results of this pass")
    args = ap.parse_args()
    failures = []
    results = {}
    for name, fn in BENCHES:
        if args.only and args.only != name:
            continue
        print(f"\n######## {name} ########")
        t0 = time.time()
        try:
            results[name] = fn(fast=args.fast)
            print(f"######## {name}: OK ({time.time() - t0:.1f}s)")
        except Exception:                               # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
            print(f"######## {name}: FAILED")
    if args.json and "cloud_batching" in results:
        fn = write_collab_record(results["cloud_batching"],
                                 results.get("collab_throughput"))
        print(f"\nperf record: {fn}")
    if args.json and "energy_split" in results:
        print(f"perf record: {write_energy_record(results['energy_split'])}")
    if args.json and "fault_injection" in results:
        print("perf record: "
              f"{write_faults_record(results['fault_injection'])}")
    if args.json and "fleet_sim" in results:
        print(f"perf record: {write_fleet_record(results['fleet_sim'])}")
    if args.json and "kernel_edge" in results:
        print("perf record: "
              f"{write_kernels_record(results['kernel_edge'])}")
    if failures:
        sys.exit(f"benchmark failures: {failures}")
    print("\nall benchmarks passed")


if __name__ == "__main__":
    main()
