"""Paper Table 1 — Top-k accuracy of original / pruned / fine-tuned models.

Runs the full two-stage pipeline (train -> DDPG prune -> fine-tune) on the
synthetic PlantVillage-38 at reduced scale and reports the paper's table.
Claims validated: pruning costs a small accuracy drop; fine-tuning recovers
it; top-k monotone in k. Absolute values differ from the paper (synthetic
data, reduced width — DESIGN.md §7); orderings are the reproduction target.
"""
from __future__ import annotations

from benchmarks.common import save_result, table
from repro.core.pipeline import run_paper_pipeline
from repro.data.synthetic import PlantVillageSynthetic
from repro.models.cnn import tiny_cnn_config

PAPER = {  # the paper's Table 1, for side-by-side reporting
    "original": {"top1": 93.67, "top3": 99.32, "top5": 99.77},
    "pruned": {"top1": 92.76, "top3": 99.17, "top5": 99.70},
    "finetuned": {"top1": 97.17, "top3": 99.77, "top5": 99.96},
}


def run(fast: bool = False) -> dict:
    cfg = tiny_cnn_config(num_classes=38, width=0.25, hw=32)
    data = PlantVillageSynthetic(n_per_class=8 if fast else 16, hw=32)
    res = run_paper_pipeline(
        cfg, data,
        train_epochs=4 if fast else 10, finetune_epochs=2 if fast else 4,
        episodes=6 if fast else 16, warmup=2 if fast else 5,
        flops_budget=0.5, seed=0,
        optimizer_name="adamw", lr=3e-3,
        log=lambda s: print("   ", s))
    rows = []
    for name, acc in [("original", res.acc_original),
                      ("pruned", res.acc_pruned),
                      ("finetuned", res.acc_finetuned)]:
        rows.append({"model": name,
                     "top1": 100 * acc["top1"], "top3": 100 * acc["top3"],
                     "top5": 100 * acc["top5"],
                     "paper_top1": PAPER[name]["top1"]})
    print(table(rows, ["model", "top1", "top3", "top5", "paper_top1"],
                "Table 1: top-k accuracy (synthetic reduced scale)"))
    checks = {
        "topk_monotone": all(r["top1"] <= r["top3"] <= r["top5"]
                             for r in rows),
        "prune_drop_small": rows[1]["top1"] >= rows[0]["top1"] - 15.0,
        "finetune_recovers": rows[2]["top1"] >= rows[1]["top1"] - 1.0,
        "flops_kept": res.search.best_flops_kept,
    }
    print("   checks:", checks)
    out = {"rows": rows, "checks": checks,
           "ratios": {str(k): v for k, v in res.ratios.items()},
           "split_point": res.split.split_point}
    save_result("table1_accuracy", out)
    return out


if __name__ == "__main__":
    run()
