"""Quantized kernel edge path: wall-clock + accuracy differential (BENCH).

The tentpole claim behind ``repro.core.collab.quant``: after pruning,
compaction and int8 weight quantization, the kernel-dispatched edge
forward is *measurably faster* than the fp32 dense edge it replaces —
at the same split, on the same host, with top-1 within a point. This
benchmark runs the paper's own recipe at CI scale — train the
full-width tiny CNN on the synthetic PlantVillage stand-in, prune to
``PRUNE_RATIO`` kept channels, fine-tune under the masks — then
measures:

  1. **Edge wall-clock at the deploy split** — batch-1 edge prefix,
     jitted, three ways: fp32 dense (masked, uncompacted — the
     pre-ROADMAP-item-3 path), compacted kernel fp32
     (``quant_cnn_apply``, ``weight_bits=None``), compacted int8
     kernel. The quantized params ride as a jit *argument*, not a
     closure, so XLA cannot constant-fold the dequant away — the int8
     number includes the real dequant cost. Acceptance: int8 kernel
     beats fp32 dense.
  2. **Top-1 differential** — dense fp32 vs the int8 kernel forward on
     the synthetic test split. Acceptance: delta <= 1 point.
  3. **Pallas parity in-run** — the interpret-mode Pallas kernel and
     the pure-XLA ref backend agree bit-for-bit on the same int8 bank
     (the differential suite's contract, re-checked on the benchmark's
     trained weights).
  4. **Calibration + roofline** — ``calibrate_quant_edge`` feeds
     ``sweep_splits(measured_device_s=...)`` for the calibrated split,
     and ``check_quant_edge_roofline`` pins the memory-bound-ceiling
     claim on the MCU/Pi profiles.

``--smoke`` runs the CI-sized version; ``--json`` (or
``benchmarks.run --json``) writes the tracked perf record
``experiments/bench/BENCH_kernels.json`` next to the other records.
"""
from __future__ import annotations

import argparse
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result, table, write_kernels_record
from repro.core.collab.quant import (QuantPolicy, calibrate_quant_edge,
                                     quant_cnn_apply, quantize_params)
from repro.core.collab.runtime import deploy_submodels
from repro.core.partition.latency_model import (cnn_input_bytes,
                                                quantized_cnn_layer_costs)
from repro.core.partition.profiles import MCU_EDGE, PAPER_PROFILE, PI_EDGE
from repro.core.partition.splitter import sweep_splits
from repro.core.pipeline import train_cnn
from repro.core.pruning.masks import cnn_masks_from_ratios
from repro.data.synthetic import PlantVillageSynthetic
from repro.models.cnn import (cnn_apply, init_cnn_params, prunable_layers,
                              tiny_cnn_config)
from repro.roofline.analysis import check_quant_edge_roofline

SPLIT = 11           # deploy split: convs + the big dense on the edge
PRUNE_RATIO = 0.3
HW = 64              # full-width tiny_alexnet at 64x64: compute-dominated
                     # on CPU, so the path differences are physical, not
                     # dispatch-overhead noise


def _time_ms(fn, *args, repeats: int, chunks: int = 5) -> float:
    """Best-of-``chunks`` mean over ``repeats`` calls (min filters out
    scheduler noise the way timeit does)."""
    jax.block_until_ready(fn(*args))                  # compile + warm
    best = float("inf")
    for _ in range(chunks):
        t0 = time.perf_counter()
        for _ in range(repeats):
            jax.block_until_ready(fn(*args))
        best = min(best, (time.perf_counter() - t0) / repeats * 1e3)
    return best


def _top1(logits_fn, data: PlantVillageSynthetic) -> float:
    hits = n = 0
    for batch in data.test_batches(64):
        pred = np.argmax(np.asarray(logits_fn(batch["image"])), axis=-1)
        hits += int((pred == batch["label"]).sum())
        n += len(batch["label"])
    return hits / n


def run(fast: bool = False) -> Dict:
    """Returns the raw result dict (see module docstring for sections)."""
    repeats = 20 if fast else 60
    cfg = tiny_cnn_config(num_classes=38, width=1.0, hw=HW)
    data = PlantVillageSynthetic(n_per_class=5 if fast else 10, hw=HW)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    params, _ = train_cnn(params, cfg, data, epochs=6 if fast else 12,
                          batch_size=16, lr=3e-3, optimizer_name="adamw")
    masks = cnn_masks_from_ratios(
        params, cfg, {i: PRUNE_RATIO for i in prunable_layers(cfg)})
    # the paper's recipe: prune, then fine-tune under the masks to
    # recover accuracy before deploying the compacted network
    params, _ = train_cnn(params, cfg, data, epochs=2 if fast else 4,
                          batch_size=16, lr=1e-3, masks=masks,
                          optimizer_name="adamw")
    dparams, dcfg, _ = deploy_submodels(params, cfg, masks, compact=True)
    qp_fp = quantize_params(dparams, dcfg, QuantPolicy(weight_bits=None))
    qp8 = quantize_params(dparams, dcfg, QuantPolicy(weight_bits=8))
    x0 = jnp.asarray(data._batch(data.test_ids[:1])["image"])
    assert x0.shape == (1, HW, HW, 3)

    # -- 1. batch-1 edge wall-clock at the deploy split -----------------
    # params/qparams are jit ARGUMENTS: donating them to the closure
    # would let XLA fold the dequant into baked fp32 weights and the
    # int8 number would time a fiction.
    dense_fn = jax.jit(lambda p, v: cnn_apply(p, cfg, v, masks=masks,
                                              stop_layer=SPLIT))
    kfp_fn = jax.jit(lambda qp, v: quant_cnn_apply(
        qp, dcfg, v, stop_layer=SPLIT, backend="ref"))
    k8_fn = jax.jit(lambda qp, v: quant_cnn_apply(
        qp, dcfg, v, stop_layer=SPLIT, backend="ref"))
    rows = [
        {"path": "fp32-dense (masked)",
         "edge_ms": _time_ms(dense_fn, params, x0, repeats=repeats)},
        {"path": "kernel fp32 (compacted)",
         "edge_ms": _time_ms(kfp_fn, qp_fp, x0, repeats=repeats)},
        {"path": "kernel int8 (compacted)",
         "edge_ms": _time_ms(k8_fn, qp8, x0, repeats=repeats)},
    ]

    # -- 2. top-1 differential ------------------------------------------
    dense_logits = jax.jit(lambda v: cnn_apply(params, cfg, v, masks=masks))
    int8_logits = jax.jit(lambda v: quant_cnn_apply(qp8, dcfg, v,
                                                    backend="ref"))
    top1_fp32 = _top1(dense_logits, data)
    top1_int8 = _top1(int8_logits, data)
    delta_pts = (top1_fp32 - top1_int8) * 100.0

    # -- 3. pallas parity on the trained int8 bank ----------------------
    ref_out = quant_cnn_apply(qp8, dcfg, x0, stop_layer=SPLIT,
                              backend="ref")
    pal_out = quant_cnn_apply(qp8, dcfg, x0, stop_layer=SPLIT,
                              backend="pallas", interpret=True)
    bit_identical = bool(np.array_equal(np.asarray(ref_out),
                                        np.asarray(pal_out)))

    # -- 4. calibration -> split sweep; roofline check ------------------
    cal = calibrate_quant_edge(qp8, dcfg, x0, backend="ref",
                               repeats=3 if fast else 10)
    sweep = sweep_splits(quantized_cnn_layer_costs(cfg, masks, 8),
                         PAPER_PROFILE, cnn_input_bytes(cfg),
                         measured_device_s=cal.layer_s)
    calibrated_split = int(min(sweep, key=lambda r: r["T"])["split"])
    mcu = check_quant_edge_roofline(cfg, masks, MCU_EDGE, weight_bits=8)
    pi = check_quant_edge_roofline(cfg, masks, PI_EDGE, weight_bits=8)
    fc_share = lambda rows_: min(  # noqa: E731
        r["memory_share"] for r in rows_ if r["name"].startswith("fc"))

    w_fp32 = sum(int(np.asarray(lp["w"]).nbytes) for lp in qp_fp.values())
    w_int8 = sum(int(np.asarray(lp["wq"]).nbytes
                     + np.asarray(lp["scale"]).nbytes
                     + np.asarray(lp["zero"]).nbytes)
                 for lp in qp8.values())

    print(table(rows, ["path", "edge_ms"],
                f"batch-1 edge wall-clock at split {SPLIT} "
                f"(CPU, {repeats} repeats)"))
    print(f"top-1: fp32 {top1_fp32:.3f}  int8 {top1_int8:.3f}  "
          f"delta {delta_pts:.2f} pts")
    print(f"pallas/ref bit-identical: {bit_identical}; calibrated split "
          f"{calibrated_split}; fc memory share mcu {fc_share(mcu):.2f} "
          f"pi {fc_share(pi):.2f}")

    ms = {r["path"]: r["edge_ms"] for r in rows}
    assert ms["kernel int8 (compacted)"] < ms["fp32-dense (masked)"], (
        "acceptance: the compacted int8 kernel edge must beat the fp32 "
        f"dense edge in wall-clock at split {SPLIT} ({ms})")
    assert abs(delta_pts) <= 1.0, (
        f"acceptance: int8 top-1 delta {delta_pts:.2f} pts exceeds 1 point")
    assert bit_identical, "pallas/ref parity broke on the trained bank"

    out = {
        "split": SPLIT,
        "rows": rows,
        "fp32_dense_edge_ms": ms["fp32-dense (masked)"],
        "kernel_fp32_edge_ms": ms["kernel fp32 (compacted)"],
        "int8_kernel_edge_ms": ms["kernel int8 (compacted)"],
        "int8_speedup_vs_dense": (ms["fp32-dense (masked)"]
                                  / ms["kernel int8 (compacted)"]),
        "top1_fp32": top1_fp32,
        "top1_int8": top1_int8,
        "top1_delta_points": delta_pts,
        "bit_identical_pallas_ref": bit_identical,
        "calibrated_split": calibrated_split,
        "mcu_fc_memory_share_min": fc_share(mcu),
        "pi_fc_memory_share_min": fc_share(pi),
        "edge_weight_bytes_fp32": w_fp32,
        "edge_weight_bytes_int8": w_int8,
    }
    save_result("kernel_edge", out)
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer images/epochs/repeats)")
    ap.add_argument("--json", action="store_true",
                    help="write the tracked BENCH_kernels.json record")
    args = ap.parse_args()
    res = run(fast=args.smoke)
    if args.json or args.smoke:
        # the CI smoke path owns the tracked record, like energy_split
        print(f"perf record: {write_kernels_record(res)}")
