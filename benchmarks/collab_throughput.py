"""Streaming collab-serving throughput + feature-codec wire bytes (BENCH).

Two claims of the fast deployment path, measured on this CPU through the
unified serving API (one ``DeploymentPlan``, two backends):

  1. *Pipelining wins*: serving a stream of requests through the
     3-stage ``streaming`` backend (edge ∥ link ∥ cloud, bounded
     queues) yields more req/s than the paper's strictly sequential
     loop (the ``local`` backend) over the same plan.
  2. *The codec shrinks T_TX*: int8 + mask-aware channel packing puts
     <= 0.25-0.5x the raw fp32 bytes on the wire at the chosen split.

Both backends charge the channel in real time (the link sleep is the
transmission), compute is the real jitted CPU compute of the compacted
submodels — so the sequential baseline pays T_D + T_TX + T_S per request
while the pipeline pays ~max of the three in steady state.
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import save_result, table
from repro import serving
from repro.core.collab.protocol import encode_feature, encode_tensor
from repro.core.partition.latency_model import (cnn_input_bytes,
                                                compacted_cnn_layer_costs)
from repro.core.partition.profiles import (LinkProfile, PAPER_PROFILE,
                                           TwoTierProfile)
from repro.core.partition.splitter import greedy_split
from repro.core.pruning.masks import cnn_masks_from_ratios
from repro.models.cnn import (cnn_apply, init_cnn_params, prunable_layers,
                              split_keep_indices, tiny_cnn_config)


def run(fast: bool = False) -> dict:
    n_requests = 16 if fast else 32
    cfg = tiny_cnn_config(num_classes=38, hw=32)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    masks = cnn_masks_from_ratios(params, cfg,
                                  {i: 0.5 for i in prunable_layers(cfg)})
    # a slow-ish link so transmission is a real pipeline stage on this
    # tiny model (paper-profile Wi-Fi at full 224px would dominate)
    link = LinkProfile("Wi-Fi 10 Mbps", bandwidth=10e6 / 8, rtt_s=2e-3)
    profile = TwoTierProfile(PAPER_PROFILE.device, PAPER_PROFILE.server,
                             link)
    # deployment split: best co-inference point on the COMPACTED shapes
    # (interior candidates: the stream benchmark needs a real edge+cloud)
    n = len(cfg.layers)
    dec = greedy_split(compacted_cnn_layer_costs(cfg, masks), profile,
                       cnn_input_bytes(cfg),
                       candidates=range(1, n), tx_scale=0.25)
    split = dec.split_point
    print(f"deployment split c={split} (compacted shapes, int8 pricing)")

    rng = np.random.RandomState(0)
    imgs = [rng.rand(1, 32, 32, 3).astype(np.float32)
            for _ in range(n_requests)]

    # --- codec bytes on the wire at this split --------------------------
    feat = np.asarray(cnn_apply(params, cfg, imgs[0], masks=masks,
                                stop_layer=split))
    keep = split_keep_indices(cfg, masks, split)
    codec_rows = [{"codec": "raw_fp32", "tx_bytes": len(encode_tensor(feat))}]
    for codec in ("fp32", "fp16", "int8"):
        for packed in (False, True):
            buf = encode_feature(feat, codec=codec,
                                 keep=keep if packed else None)
            codec_rows.append({"codec": codec + ("+packed" if packed else ""),
                               "tx_bytes": len(buf)})
    raw = codec_rows[0]["tx_bytes"]
    for r in codec_rows:
        r["vs_raw"] = r["tx_bytes"] / raw
    print(table(codec_rows, ["codec", "tx_bytes", "vs_raw"],
                f"feature codec, split c={split} "
                f"(tensor {tuple(feat.shape)})"))
    int8_packed = next(r for r in codec_rows if r["codec"] == "int8+packed")
    assert int8_packed["tx_bytes"] <= 0.5 * raw, codec_rows

    # --- sequential vs pipelined serving: one plan, two backends --------
    plan = serving.DeploymentPlan.from_args(params, cfg, split, masks=masks,
                                            compact=True, codec="int8",
                                            profile=profile)
    print(plan.describe())
    seq = serving.connect(plan, backend="local", realtime_channel=True)
    seq.infer(imgs[0])                                   # warm up the jits
    t0 = time.perf_counter()
    seq_logits = [seq.infer(img)["logits"] for img in imgs]
    seq_wall = time.perf_counter() - t0
    seq_rps = n_requests / seq_wall

    pipe = serving.connect(plan, backend="streaming", queue_depth=4,
                           microbatch=1, realtime_channel=True)
    pipe.infer_many(imgs[:1])                            # warm up the jits
    results = pipe.infer_many(imgs)
    rep = pipe.last_report
    for a, b in zip(seq_logits, results):
        np.testing.assert_allclose(a, b["logits"], rtol=1e-4, atol=1e-4)

    rows = [
        {"runtime": "sequential", "req_s": seq_rps,
         "wall_ms": seq_wall * 1e3},
        {"runtime": "pipelined", "req_s": rep.throughput_rps,
         "wall_ms": rep.wall_s * 1e3,
         **{f"occ_{k}": v for k, v in rep.occupancy.items()}},
    ]
    print(table(rows, ["runtime", "req_s", "wall_ms",
                       "occ_edge", "occ_tx", "occ_cloud"],
                f"{n_requests}-request stream, compact+int8, "
                f"split c={split}, 10 Mbps"))
    speedup = rep.throughput_rps / seq_rps
    print(f"   pipelined speedup: {speedup:.2f}x "
          f"(bottleneck occupancy "
          f"{max(rep.occupancy.values()):.2f})")
    assert rep.throughput_rps > seq_rps, (rep.throughput_rps, seq_rps)

    out = {"split": split, "n_requests": n_requests,
           "codec_tx_bytes": {r["codec"]: r["tx_bytes"] for r in codec_rows},
           "sequential_rps": seq_rps, "pipelined_rps": rep.throughput_rps,
           "speedup": speedup, "occupancy": rep.occupancy,
           "tx_bytes_total": rep.tx_bytes_total}
    save_result("collab_throughput", out)
    return out


if __name__ == "__main__":
    run()
