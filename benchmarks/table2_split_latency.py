"""Paper Table 2 — per-split-point collaborative-inference latency.

Two parts:
  (a) replay the paper's own measured Table 2 through Algorithm 1's greedy
      loop — the argmin must be split 6 (the paper's optimum);
  (b) the analytic sweep on full AlexNet under the paper's hardware profile
      (i7 edge / 3090 server / 50 Mbps link), dense and pruned (Fig. 3
      ratios), reporting the T_D/T_TX/T_S breakdown per candidate.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import save_result, table
from repro.core.partition.latency_model import (cnn_input_bytes,
                                                cnn_layer_costs)
from repro.core.partition.profiles import PAPER_PROFILE
from repro.core.partition.splitter import greedy_split
from repro.models.cnn import alexnet_config

PAPER_TABLE2_MS = {1: 99.91, 2: 166.98, 3: 65.89, 4: 85.03, 5: 31.91,
                   6: 20.07, 7: 60.88, 8: 40.98, 9: 55.93, 10: 37.96,
                   11: 57.79, 12: 36.11, 13: 27.96, 14: 26.34, 15: 39.15,
                   16: 34.57, 17: 31.75, 18: 36.04, 19: 36.67, 20: 36.59}

# paper Fig. 3 preserve ratios (conv1..conv5); fc unspecified -> 0.5
PAPER_FIG3_RATIOS = {0: 1.0, 3: 0.875, 6: 0.125, 8: 0.292, 10: 0.313,
                     14: 0.5, 16: 0.5}


def _paper_masks(cfg):
    import jax.numpy as jnp
    masks = {}
    for i, a in PAPER_FIG3_RATIOS.items():
        spec = cfg.layers[i]
        n = spec.out_channels or spec.features
        m = np.zeros(n, np.float32)
        m[:max(1, int(round(a * n)))] = 1
        masks[i] = jnp.asarray(m)
    return masks


def run(fast: bool = False) -> dict:
    # (a) Algorithm 1 on the paper's measured numbers
    c, t = 1, PAPER_TABLE2_MS[1]
    for j in range(2, 21):
        if PAPER_TABLE2_MS[j] < t:
            c, t = j, PAPER_TABLE2_MS[j]
    print(f"   Algorithm 1 on the paper's measured Table 2: "
          f"split={c} T={t} ms (paper: split=6, 20.07 ms)")
    assert c == 6

    # (b) analytic sweep, dense + pruned
    cfg = alexnet_config()
    out_tables = {}
    for tag, masks in [("dense", None), ("pruned", _paper_masks(cfg))]:
        costs = cnn_layer_costs(cfg, masks)
        dec = greedy_split(costs, PAPER_PROFILE, cnn_input_bytes(cfg))
        rows = [{"split": r["split"], "T_ms": r["T"] * 1e3,
                 "T_D_ms": r["T_D"] * 1e3, "T_TX_ms": r["T_TX"] * 1e3,
                 "T_S_ms": r["T_S"] * 1e3,
                 "tx_KB": r["tx_bytes"] / 1024}
                for r in dec.table]
        print(table(rows[:12] + [rows[-1]],
                    ["split", "T_ms", "T_D_ms", "T_TX_ms", "T_S_ms",
                     "tx_KB"],
                    f"Table 2 (analytic, {tag} AlexNet, paper profile)"))
        print(f"   optimum: split={dec.split_point} "
              f"T={dec.latency['T'] * 1e3:.2f} ms")
        print("   (T_TX/tx_KB are uplink-only: feature tensor + one RTT, "
              "per Eq. 5; see latency_model.split_latency(round_trip=))")
        out_tables[tag] = {"rows": rows, "optimum": dec.split_point,
                           "T_ms": dec.latency["T"] * 1e3}
    out = {"paper_replay": {"split": c, "T_ms": t},
           "analytic": out_tables}
    save_result("table2_split_latency", out)
    return out


if __name__ == "__main__":
    run()
