"""Activation sharding constraints (GSPMD hints inside model code).

``maybe_constrain(x, P(...))`` is a no-op outside a mesh context (smoke
tests, 1-device CPU) and drops axes the current mesh does not have, so model
code can state its preferred layout unconditionally. Uneven dims are allowed
(GSPMD pads), which matters for head counts like 28 on a 16-way model axis.
"""
from __future__ import annotations

import contextlib
import threading

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

# jax versions without mesh axis_types (<= 0.4.x) can't tell us which axes
# a surrounding shard_map made manual; the shard_map entry points in this
# repo declare them here instead (trace-time, thread-local).
_manual = threading.local()


@contextlib.contextmanager
def declared_manual_axes(*names):
    old = getattr(_manual, "axes", ())
    _manual.axes = old + tuple(names)
    try:
        yield
    finally:
        _manual.axes = old


def _current_axes():
    declared = getattr(_manual, "axes", ())
    # explicit-sharding mode / inside shard_map: only AUTO axes are
    # constrainable (manual axes belong to the shard_map body)
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and not m.empty:
            return tuple(n for n, t in zip(m.axis_names, m.axis_types)
                         if str(t) == "Auto" and n not in declared)
    except Exception:                                     # noqa: BLE001
        pass
    # classic `with mesh:` context (auto axes)
    try:
        from jax._src.mesh import thread_resources
        pm = thread_resources.env.physical_mesh
        if pm is not None and not pm.empty:
            return tuple(n for n in pm.axis_names if n not in declared)
    except Exception:                                     # noqa: BLE001
        pass
    return ()


def maybe_constrain(x, spec: P):
    axes = _current_axes()
    if not axes:
        return x
    fixed = []
    changed = False
    want = tuple(spec) + (None,) * (np.ndim(x) - len(tuple(spec)))
    for ax in want[:np.ndim(x)]:
        parts = ax if isinstance(ax, tuple) else ((ax,) if ax else ())
        if parts and all(a in axes for a in parts):
            fixed.append(ax)
            changed = True
        else:
            fixed.append(None)
    if not changed:
        return x
    return jax.lax.with_sharding_constraint(x, P(*fixed))


def data_axes_spec():
    """The batch axis of the current mesh: ("pod","data") / ("data",)."""
    axes = _current_axes()
    if "pod" in axes and "data" in axes:
        return ("pod", "data")
    if "data" in axes:
        return "data"
    return None
