"""Sharding planner: PartitionSpec pytrees for params, optimizer state,
batches, and caches, per (config, mesh).

Strategy (baseline; §Perf iterates on it):
  * 2-D weight sharding — every large matmul weight shards its d_model-side
    dim over the combined data axes (FSDP-style; gathered per layer inside
    the scan) and its output/expert dim over "model" (Megatron-style).
    This is what lets 340B/671B configs fit 16 GB/chip (DESIGN.md §5).
  * MoE expert dim shards over "model" (expert parallelism).
  * Batch shards over ("pod","data") / ("data",) when divisible; otherwise
    the sequence (context parallelism) or nothing (B=1 long-context decode).
  * Norms/scalars replicate.

Rules are name-based over the param tree paths, so they apply uniformly to
stacked (scan) and unstacked (shared/mtp) blocks.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def path_key(p) -> str:
    """Robust tree-path element -> string (DictKey/SequenceKey/GetAttrKey)."""
    for attr in ("key", "idx", "name"):
        if hasattr(p, attr):
            return str(getattr(p, attr))
    return str(p)


def path_keys(path):
    return tuple(path_key(p) for p in path)


def mesh_axes(mesh: Mesh) -> Tuple[Tuple[str, ...], str]:
    """(data_axes, model_axis) from a production mesh."""
    names = mesh.axis_names
    if "pod" in names:
        return ("pod", "data"), "model"
    return ("data",), "model"


def _divisible(n: int, mesh: Mesh, axes) -> bool:
    size = int(np.prod([mesh.shape[a] for a in (axes if isinstance(axes, tuple)
                                                else (axes,))]))
    return n % size == 0


# ---------------------------------------------------------------------------
# parameter specs
# ---------------------------------------------------------------------------
_COL_NAMES = {"wq", "wk", "wv", "w_up", "w_gate", "w_dq", "w_uq", "w_dkv",
              "w_uk", "w_uv", "w_in", "w_up_sh", "w_gate_sh", "proj"}
_ROW_NAMES = {"wo", "w_down", "w_out", "w_down_sh"}
_BIAS_NAMES = {"bq", "bk", "bv"}
_REPL_NAMES = {"ln1", "ln2", "ln", "final_norm", "q_norm", "kv_norm",
               "norm_scale", "A_log", "dt_bias", "D", "conv_b", "w_router"}


def _leaf_spec(path_keys, leaf, cfg: ModelConfig, data, model,
               shard_data_dim: bool) -> P:
    name = path_keys[-1]
    in_moe = "moe" in path_keys
    nd = np.ndim(leaf)
    dspec = data if shard_data_dim else None

    def lead(base):
        return P(*([None] * (nd - len(base)) + list(base)))

    if name == "embed":
        return P("model", dspec)
    if name == "lm_head":
        return P(dspec, "model")
    if name in _REPL_NAMES:
        return lead([None] * min(nd, 1))
    if name == "conv_w":
        return lead([None, "model"])
    if name in _BIAS_NAMES:
        return lead(["model"])
    if in_moe and name in ("w_up", "w_gate"):
        return lead(["model", dspec, None])
    if in_moe and name == "w_down":
        return lead(["model", None, dspec])
    if name in _COL_NAMES:
        return lead([dspec, "model"])
    if name in _ROW_NAMES:
        return lead(["model", dspec])
    # default: replicate
    return P()


def param_specs(params, cfg: ModelConfig, mesh: Mesh,
                shard_data_dim: bool = True):
    """PartitionSpec pytree matching ``params``."""
    data, model = mesh_axes(mesh)

    def spec_for(path, leaf):
        keys = path_keys(path)
        sp = _leaf_spec(keys, leaf, cfg, data, model, shard_data_dim)
        # drop axes that do not divide evenly (GSPMD handles uneven, but we
        # prefer clean layouts; uneven dims fall back to replication on
        # that axis)
        dims = np.shape(leaf)
        fixed = []
        for dim, ax in zip(dims, tuple(sp) + (None,) * (len(dims) - len(sp))):
            if ax is None:
                fixed.append(None)
            elif _divisible(dim, mesh, ax):
                fixed.append(ax)
            else:
                fixed.append(None)
        return P(*fixed)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def opt_state_specs(opt_state, pspecs):
    """Optimizer moments mirror the param specs; counters replicate."""
    def match(path, leaf):
        keys = list(path_keys(path))
        if keys and keys[0] in ("m", "v", "mom"):
            sub = keys[1:]
            node = pspecs
            for k in sub:
                if isinstance(node, (list, tuple)):
                    node = node[int(k)]
                else:
                    node = node[k]
            return node
        return P()
    return jax.tree_util.tree_map_with_path(match, opt_state)


# ---------------------------------------------------------------------------
# batch / cache specs
# ---------------------------------------------------------------------------
def batch_specs(batch, cfg: ModelConfig, mesh: Mesh):
    data, _ = mesh_axes(mesh)

    def spec_for(path, leaf):
        keys = path_keys(path)
        name = keys[-1]
        shape = np.shape(leaf)
        if name == "mrope_positions":           # (3, B, S)
            b_ok = _divisible(shape[1], mesh, data)
            return P(None, data if b_ok else None, None)
        if not shape:
            return P()
        b_ok = _divisible(shape[0], mesh, data)
        if b_ok:
            return P(*([data] + [None] * (len(shape) - 1)))
        # small batch: shard sequence instead when possible
        if len(shape) >= 2 and _divisible(shape[1], mesh, data):
            return P(*([None, data] + [None] * (len(shape) - 2)))
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(spec_for, batch)


def cache_specs(cache, cfg: ModelConfig, mesh: Mesh):
    """KV/MLA/SSM cache sharding.

    KVCache   (L, B, S, Hkv, D): B over data if divisible, else S over data
              (context parallelism); Hkv over model if divisible else D.
    MLACache  (L, B, S, rank): rank over model.
    SSMCache  conv (L, B, K-1, cdim): cdim over model.
              state (L, B, H, P, N): H over model.
    pos       replicated.
    """
    data, model = mesh_axes(mesh)

    def spec_for(path, leaf):
        keys = path_keys(path)
        name = keys[-1]
        shape = np.shape(leaf)
        if name == "pos" or not shape:
            return P()
        if name == "conv":
            return P(*([None] * (len(shape) - 1) + [
                model if _divisible(shape[-1], mesh, model) else None]))
        if name == "state":
            h_ax = model if _divisible(shape[-3], mesh, model) else None
            out = [None] * len(shape)
            out[-3] = h_ax
            b_idx = len(shape) - 4
            if b_idx >= 0 and _divisible(shape[b_idx], mesh, data):
                out[b_idx] = data
            return P(*out)
        if name in ("k", "v"):                  # (..., B, S, Hkv, D)
            out = [None] * len(shape)
            b_idx, s_idx, h_idx, d_idx = (len(shape) - 4, len(shape) - 3,
                                          len(shape) - 2, len(shape) - 1)
            if _divisible(shape[b_idx], mesh, data):
                out[b_idx] = data
            elif _divisible(shape[s_idx], mesh, data):
                out[s_idx] = data
            if _divisible(shape[h_idx], mesh, model):
                out[h_idx] = model
            elif _divisible(shape[d_idx], mesh, model):
                out[d_idx] = model
            return P(*out)
        if name in ("ckv", "krope"):            # (L, B, S, rank)
            out = [None] * len(shape)
            if _divisible(shape[1], mesh, data):
                out[1] = data
            elif _divisible(shape[2], mesh, data):
                out[2] = data
            if _divisible(shape[-1], mesh, model):
                out[-1] = model
            return P(*out)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(spec_for, cache)


def to_shardings(specs, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda sp: NamedSharding(mesh, sp), specs,
        is_leaf=lambda x: isinstance(x, P))
