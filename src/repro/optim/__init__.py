from repro.optim.optimizers import (Optimizer, adamw, sgd_momentum,
                                    make_optimizer)
from repro.optim.schedules import step_lr, cosine_warmup, constant
