"""Optimizers as pure-pytree transforms (no external deps).

``sgd_momentum`` is the paper's fine-tuning optimizer (§4.1: momentum 0.9).
``adamw`` drives transformer training. Moment dtype is configurable so the
giant-config dry-runs can hold optimizer state in bf16 (see DESIGN.md §5 and
the memory roofline discussion in EXPERIMENTS.md).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Any]   # (grads, state, params) -> (params, state)


def _tree_zeros_like(params, dtype=None):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, dtype or p.dtype), params)


def sgd_momentum(schedule, momentum: float = 0.9,
                 weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {"mom": _tree_zeros_like(params, jnp.float32),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        lr = schedule(state["step"])
        mom = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g.astype(jnp.float32),
            state["mom"], grads)
        new_params = jax.tree_util.tree_map(
            lambda p, m: (p.astype(jnp.float32)
                          - lr * (m + weight_decay * p.astype(jnp.float32))
                          ).astype(p.dtype),
            params, mom)
        return new_params, {"mom": mom, "step": state["step"] + 1}

    return Optimizer(init, update)


def adamw(schedule, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, moment_dtype=jnp.float32,
          grad_clip: float = 1.0) -> Optimizer:
    def init(params):
        return {"m": _tree_zeros_like(params, moment_dtype),
                "v": _tree_zeros_like(params, moment_dtype),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        lr = schedule(state["step"])
        if grad_clip:
            gnorm = jnp.sqrt(sum(
                jnp.sum(jnp.square(g.astype(jnp.float32)))
                for g in jax.tree_util.tree_leaves(grads)))
            scale = jnp.minimum(1.0, grad_clip / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree_util.tree_map(
                lambda g: g * scale.astype(g.dtype), grads)
        m = jax.tree_util.tree_map(
            lambda mm, g: (b1 * mm.astype(jnp.float32)
                           + (1 - b1) * g.astype(jnp.float32)
                           ).astype(moment_dtype), state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda vv, g: (b2 * vv.astype(jnp.float32)
                           + (1 - b2) * jnp.square(g.astype(jnp.float32))
                           ).astype(moment_dtype), state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, mm, vv):
            mhat = mm.astype(jnp.float32) / bc1
            vhat = vv.astype(jnp.float32) / bc2
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, m, v)
        return new_params, {"m": m, "v": v, "step": step}

    return Optimizer(init, update)


def make_optimizer(name: str, schedule, **kw) -> Optimizer:
    if name == "sgd":
        return sgd_momentum(schedule, **kw)
    if name == "adamw":
        return adamw(schedule, **kw)
    raise ValueError(name)
