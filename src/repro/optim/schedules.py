"""Learning-rate schedules. ``step_lr`` is the paper's setup (§4.1):
lr0=0.01, gamma=0.1 every 20 epochs."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    def sched(step):
        return jnp.asarray(lr, jnp.float32)
    return sched


def step_lr(lr0: float = 0.01, gamma: float = 0.1, step_size: int = 20,
            steps_per_epoch: int = 1):
    """StepLR in epochs, evaluated per optimizer step (paper §4.1)."""
    def sched(step):
        epoch = step // steps_per_epoch
        return jnp.asarray(lr0, jnp.float32) * gamma ** (epoch // step_size)
    return sched


def cosine_warmup(lr0: float, warmup: int, total: int, floor: float = 0.1):
    def sched(step):
        step = jnp.asarray(step, jnp.float32)
        warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return lr0 * warm * cos
    return sched
