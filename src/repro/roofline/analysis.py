"""Roofline-term extraction from a compiled dry-run artifact.

    compute term    = HLO_FLOPs_global / (chips x peak_FLOP/s)
    memory term     = HLO_bytes_global / (chips x HBM_bw)
    collective term = collective_bytes_global / (chips x link_bw)

UNITS: ``compiled.cost_analysis()`` on an SPMD-partitioned module reports
the PER-DEVICE program (XLA compiles one replica); global = per-device x
chips, so the assignment's formulas reduce to per-device quantity / per-chip
throughput — which is how they are computed here.

Collective bytes are NOT in cost_analysis: we parse the post-SPMD optimized
HLO and sum the operand sizes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute. Post-SPMD operand shapes
are per-device, so the sum is per-chip traffic; dividing by the per-chip
link bandwidth gives the collective term. (Ring all-reduce actually moves
~2x its operand bytes per chip; operand-size is therefore a <=2x-optimistic
proxy, uniform across configs, which is what the hillclimb compares.)
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.roofline import hw

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.+?)\s+"
                     r"([\w\-]+)\(", re.M)
COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter",
                  "all-to-all", "collective-permute")


def shape_bytes(type_str: str) -> int:
    """Bytes of an HLO result type (handles tuples by summing)."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class CollectiveStats:
    bytes_by_op: Dict[str, int] = field(default_factory=dict)
    count_by_op: Dict[str, int] = field(default_factory=dict)

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum operand bytes per collective op kind from optimized HLO."""
    # 1st pass: result bytes of every definition
    sizes: Dict[str, int] = {}
    for m in _DEF_RE.finditer(hlo_text):
        sizes[m.group(1)] = shape_bytes(m.group(2))
    stats = CollectiveStats()
    for m in _DEF_RE.finditer(hlo_text):
        op = m.group(3)
        base = None
        for c in COLLECTIVE_OPS:
            if op == c or op.startswith(c + "-start"):
                base = c
                break
        if base is None:
            continue
        # operand list: text after '(' up to matching ')'
        line_start = m.end()
        rest = hlo_text[line_start:hlo_text.find("\n", line_start)]
        depth = 1
        args = ""
        for ch in rest:
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
            args += ch
        nbytes = 0
        for op_m in re.finditer(r"%[\w\.\-]+", args):
            nbytes += sizes.get(op_m.group(0), 0)
        stats.bytes_by_op[base] = stats.bytes_by_op.get(base, 0) + nbytes
        stats.count_by_op[base] = stats.count_by_op.get(base, 0) + 1
    return stats


@dataclass
class RooflineTerms:
    flops: float                 # PER-DEVICE HLO flops (cost_analysis)
    hbm_bytes: float             # PER-DEVICE HLO bytes accessed
    collective_bytes: float      # per-chip collective operand bytes
    chips: int

    @property
    def flops_global(self) -> float:
        return self.flops * self.chips

    @property
    def hbm_bytes_global(self) -> float:
        return self.hbm_bytes * self.chips

    @property
    def t_compute(self) -> float:
        # global/(chips*peak) == per-device/peak
        return self.flops / hw.PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hbm_bytes / hw.HBM_BW

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / hw.ICI_BW_PER_LINK

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    def as_dict(self) -> Dict:
        return {
            "flops": self.flops, "hbm_bytes": self.hbm_bytes,
            "flops_global": self.flops_global,
            "hbm_bytes_global": self.hbm_bytes_global,
            "collective_bytes_per_chip": self.collective_bytes,
            "chips": self.chips,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective, "dominant": self.dominant,
        }


def model_flops(cfg, shape_name: str, n_params_active: Optional[int] = None,
                n_params: Optional[int] = None) -> float:
    """MODEL_FLOPS = 6 * N * D (dense) / 6 * N_active * D (MoE); decode uses
    D = tokens generated this step (=batch)."""
    from repro.launch.specs import SHAPES, mode_of
    S, B = SHAPES[shape_name]
    mode = mode_of(shape_name)
    N = n_params_active if n_params_active is not None else n_params
    D = B * S if mode != "decode" else B
    factor = 6.0 if mode == "train" else 2.0
    return factor * float(N) * float(D)


def terms_from_compiled(compiled, chips: int,
                        hlo_text: Optional[str] = None) -> RooflineTerms:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):           # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    nbytes = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = parse_collectives(text)
    return RooflineTerms(flops, nbytes, float(coll.total_bytes), chips), coll


# ---------------------------------------------------------------------------
# quantized edge-kernel roofline (the MCU/Pi memory-bound ceiling)
# ---------------------------------------------------------------------------
def quant_edge_roofline(cfg, masks, profile,
                        weight_bits: Optional[int] = 8) -> list:
    """Per-layer roofline of the quantized kernel edge path on an edge
    ``ComputeProfile``: compute at the profile's int8 MAC throughput
    (fp32 throughput when ``weight_bits=None``), memory as weight
    streaming at the quantized width *plus* the activation traffic the
    split model already prices (``2 * out_bytes``). The interesting
    layers are the batch-1 GEMMs (``fc*``): their weight traffic is
    O(model) while their compute is only 2 FLOPs per weight, so int8
    pushes them through the ridge point into the memory-bound regime —
    which is the whole point of weight-only quantization on an edge
    device, and what ``check_quant_edge_roofline`` pins for the MCU/Pi
    profiles.

    Returns one dict per conv/dense layer: ``{index, name,
    t_compute_s, t_memory_s, memory_bound, memory_share}`` with
    ``memory_share = t_memory / (t_compute + t_memory)`` (how close the
    kernel's modeled time sits to the pure memory-streaming ceiling)."""
    from repro.core.partition.latency_model import quantized_cnn_layer_costs
    ops_per_s = (profile.flops_per_s if weight_bits is None
                 else profile.int8_ops_per_s)
    rows = []
    for c in quantized_cnn_layer_costs(cfg, masks, weight_bits):
        if not (c.name.startswith("conv") or c.name.startswith("fc")):
            continue
        t_c = c.flops / ops_per_s
        t_m = (c.params_bytes + 2 * c.out_bytes) / profile.mem_bw
        rows.append({"index": c.index, "name": c.name,
                     "t_compute_s": t_c, "t_memory_s": t_m,
                     "memory_bound": t_m >= t_c,
                     "memory_share": t_m / (t_c + t_m) if t_c + t_m else 1.0})
    return rows


def check_quant_edge_roofline(cfg, masks, profile,
                              weight_bits: Optional[int] = 8,
                              min_memory_share: float = 0.5) -> list:
    """Assert the quantized GEMM (``fc``) layers approach the
    memory-bound ceiling on ``profile``: every one must be
    memory-bound (``t_memory >= t_compute``) with a memory share of at
    least ``min_memory_share`` — i.e. the kernel's modeled time is
    dominated by weight streaming, so the analytic split model prices
    the quantized edge at (close to) its bandwidth floor. Raises
    ``AssertionError`` naming the offending layer; returns the full
    ``quant_edge_roofline`` report on success."""
    rows = quant_edge_roofline(cfg, masks, profile, weight_bits)
    for r in rows:
        if not r["name"].startswith("fc"):
            continue
        assert r["memory_bound"], (
            f"{r['name']} on {profile.name}: compute-bound "
            f"(t_compute={r['t_compute_s']:.3e}s > "
            f"t_memory={r['t_memory_s']:.3e}s) at weight_bits="
            f"{weight_bits} — the quantized kernel does not reach the "
            f"memory-bound ceiling")
        assert r["memory_share"] >= min_memory_share, (
            f"{r['name']} on {profile.name}: memory share "
            f"{r['memory_share']:.2f} < {min_memory_share} at "
            f"weight_bits={weight_bits}")
    return rows
