"""TPU v5e hardware constants (assignment-specified)."""

PEAK_FLOPS_BF16 = 197e12          # per chip
HBM_BW = 819e9                    # bytes/s per chip
ICI_BW_PER_LINK = 50e9            # bytes/s per link (~per-chip collective bw)
HBM_BYTES = 16 * 1024 ** 3        # 16 GiB per chip

SINGLE_POD_CHIPS = 256
MULTI_POD_CHIPS = 512
