"""Checkpointing: pytree <-> .npz + structure JSON (no external deps).

Arrays are flattened with their tree paths as keys; the tree structure
(dict/list/tuple/namedtuple skeleton) is stored alongside so restore
round-trips exactly. Works for params, optimizer state, and caches.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Any]:
    paths = {}
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        from repro.sharding.specs import path_key
        key = "/".join(path_key(p) for p in path)
        paths[key] = np.asarray(leaf)
    return paths, treedef


def save(path: str, tree, metadata: Dict[str, Any] | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays, _ = _flatten(tree)
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    def as_np(leaf):
        arr = np.asarray(leaf)
        if arr.dtype.kind == "V" or arr.dtype.name == "bfloat16":
            # numpy can't serialize ml_dtypes (bf16 etc.) — widen to fp32;
            # restore() casts back to the template dtype
            arr = np.asarray(leaf, np.float32)
        return arr

    np.savez(path + ".npz", **{f"a{i}": as_np(l)
                               for i, l in enumerate(leaves)})
    with open(path + ".json", "w") as f:
        json.dump({"treedef": str(treedef),
                   "n_leaves": len(leaves),
                   "meta": metadata or {}}, f)


def restore(path: str, like) -> Any:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    data = np.load(path + ".npz")
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    n = len(leaves_like)
    got = len(data.files)
    if got != n:
        raise ValueError(f"checkpoint has {got} leaves, template has {n}")
    leaves = []
    for i, tmpl in enumerate(leaves_like):
        arr = data[f"a{i}"]
        if hasattr(tmpl, "shape") and tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(f"leaf {i}: shape {arr.shape} != {tmpl.shape}")
        leaves.append(jax.numpy.asarray(arr, dtype=getattr(tmpl, "dtype", None)))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_metadata(path: str) -> Dict[str, Any]:
    with open(path + ".json") as f:
        return json.load(f)["meta"]
