"""AST-based correctness gate for the collaborative serving stack.

The serving stack carries two kinds of invariants that convention alone
cannot hold: *concurrency* invariants (every shared-mutable attribute
written under its lock — the threaded ``serve_cloud`` accept loop,
per-lane batcher schedulers and per-connection writer threads all
mutate state concurrently) and *determinism* invariants (the fleet
simulator's same-seed bit-identity dies on the first ``time.time()`` or
module-level ``random`` call inside the virtual-clock domain). This
package makes both — plus the wire/plan serialization contracts —
machine-checked properties, using only the stdlib ``ast`` module:

* ``repro.analysis.concurrency`` — lock-discipline over an annotated
  registry of shared state (``repro.analysis.registry``);
* ``repro.analysis.purity`` — virtual-clock purity for ``core/fleet/``,
  ``SimChannel`` and ``LinkTrace``;
* ``repro.analysis.contracts`` — unit-suffixed plan-JSON keys, the
  ``DeploymentPlan`` digest fold-only-when-set rule, and
  ``struct.pack``/``unpack`` twin formats in the wire codec;
* ``repro.analysis.baseline`` — justified suppressions, with staleness
  and missing-justification themselves reported as findings;
* ``repro.analysis.runner`` — dispatch + the aggregate ``Report``.

Run the gate with ``python -m repro.analysis`` (``--json``, ``--out``,
``--baseline``; non-zero exit on unsuppressed findings) or through the
pytest gate in ``tests/test_analysis.py``. Semantics and the suppression
workflow are documented in ``docs/static-analysis.md``.
"""
from repro.analysis.baseline import (BaselineEntry, apply_baseline,
                                     load_baseline)
from repro.analysis.findings import Finding
from repro.analysis.runner import Report, analyze_file, run_analysis

__all__ = ["Finding", "Report", "BaselineEntry", "analyze_file",
           "run_analysis", "load_baseline", "apply_baseline"]
