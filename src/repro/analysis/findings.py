"""The finding record every checker emits and the baseline matches on.

A ``Finding`` is one violation of a machine-checked invariant: a shared
attribute written outside its lock, a wall-clock call inside the virtual
clock's domain, a plan-JSON key without a unit suffix. Findings are
identified for suppression purposes by ``(rule, path, symbol)`` — the
line number is carried for display but deliberately excluded from the
identity, so routine edits above a justified finding do not invalidate
its baseline entry.
"""
from __future__ import annotations

import os
from dataclasses import asdict, dataclass
from typing import Any, Dict, Tuple


@dataclass(frozen=True)
class Finding:
    """One static-analysis violation.

    ``rule`` names the checker clause (``lock-discipline``,
    ``unguarded-shared-write``, ``registry-justification``,
    ``stale-registry``, ``purity``, ``unit-suffix``, ``digest-fold``,
    ``pack-unpack``, ``baseline-justification``, ``stale-suppression``);
    ``path`` is the repo-relative posix path of the offending file;
    ``symbol`` is the dotted lexical location (``Class.method``,
    ``function``, or ``<module>``) plus, for contract rules, the key or
    format string at issue.
    """
    rule: str
    path: str
    line: int
    symbol: str
    message: str

    @property
    def key(self) -> Tuple[str, str, str]:
        """The identity the baseline suppresses on (no line number)."""
        return (self.rule, self.path, self.symbol)

    def to_json(self) -> Dict[str, Any]:
        """Plain-dict form for the ``--json`` report."""
        return asdict(self)

    def render(self) -> str:
        """One human-readable report line."""
        return f"{self.path}:{self.line}: [{self.rule}] {self.symbol} — " \
               f"{self.message}"


def repo_relative(path: str) -> str:
    """Normalize ``path`` to a posix path relative to the repo root (the
    directory holding ``src/``) when it lives under it, so findings and
    baseline entries match regardless of how the CLI was invoked."""
    apath = os.path.abspath(path)
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    if apath.startswith(root + os.sep):
        apath = apath[len(root) + 1:]
    return apath.replace(os.sep, "/")
