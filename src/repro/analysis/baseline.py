"""Baseline suppression: every silenced finding carries its *why*.

The baseline file (``analysis_baseline.json`` at the repo root) is a
JSON list of entries::

    [{"rule": "purity",
      "path": "src/repro/core/collab/channel.py",
      "symbol": "SimChannel.send",
      "justification": "realtime=True is an explicit opt-in demo mode"}]

An entry suppresses findings whose ``(rule, path, symbol)`` matches
exactly. Two properties are enforced, not hoped for:

* an entry without a non-empty ``justification`` string is itself a
  finding (``baseline-justification``) — the baseline documents debt,
  it does not hide it;
* an entry that matches nothing is a ``stale-suppression`` finding —
  fixed findings must leave the baseline with the fix, so the file
  never accretes dead exemptions. Staleness is only decided for entries
  whose ``path`` was actually scanned: a partial run (e.g. the CI step
  that checks ``benchmarks/fleet_sim.py`` alone) cannot conclude an
  entry is dead for a file it never analyzed.
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.findings import Finding


@dataclass(frozen=True)
class BaselineEntry:
    """One justified suppression."""
    rule: str
    path: str
    symbol: str
    justification: str = ""

    @property
    def key(self) -> Tuple[str, str, str]:
        return (self.rule, self.path, self.symbol)


def load_baseline(path: str) -> List[BaselineEntry]:
    """Parse a baseline file; raises ``ValueError`` on malformed docs."""
    with open(path) as f:
        doc = json.load(f)
    if not isinstance(doc, list):
        raise ValueError(f"baseline {path} must be a JSON list")
    entries = []
    for i, rec in enumerate(doc):
        try:
            entries.append(BaselineEntry(
                rule=rec["rule"], path=rec["path"], symbol=rec["symbol"],
                justification=rec.get("justification", "")))
        except (TypeError, KeyError) as e:
            raise ValueError(
                f"baseline {path} entry {i} lacks rule/path/symbol: {e}")
    return entries


def apply_baseline(findings: Sequence[Finding],
                   entries: Sequence[BaselineEntry],
                   baseline_path: str = "analysis_baseline.json",
                   scanned_paths: Optional[Set[str]] = None,
                   ) -> Tuple[List[Finding], List[Finding]]:
    """Split findings into (unsuppressed, suppressed) and append the
    baseline's own violations — unjustified entries and stale ones — to
    the unsuppressed list. ``scanned_paths`` (repo-relative) limits the
    staleness check to entries whose file this run actually analyzed;
    ``None`` means the run was complete and every entry is in scope."""
    by_key: Dict[Tuple[str, str, str], BaselineEntry] = {
        e.key: e for e in entries}
    used = set()
    unsuppressed: List[Finding] = []
    suppressed: List[Finding] = []
    for f in findings:
        entry = by_key.get(f.key)
        if entry is not None and entry.justification.strip():
            suppressed.append(f)
            used.add(entry.key)
        else:
            unsuppressed.append(f)
    for e in entries:
        if not e.justification.strip():
            unsuppressed.append(Finding(
                "baseline-justification", baseline_path, 1,
                f"{e.rule}:{e.path}:{e.symbol}",
                "baseline entry carries no justification string — "
                "suppressed findings must say why"))
        elif e.key not in used and (scanned_paths is None
                                    or e.path in scanned_paths):
            unsuppressed.append(Finding(
                "stale-suppression", baseline_path, 1,
                f"{e.rule}:{e.path}:{e.symbol}",
                "baseline entry matches no current finding — remove it"))
    return unsuppressed, suppressed
