"""Lock-discipline checker: shared-mutable writes vs. their locks.

Three clauses, all lexical (no runtime instrumentation):

1. **lock-discipline** — a registry-annotated attribute with a guarding
   lock must have every write outside ``__init__`` lexically inside a
   ``with self.<lock>:`` (or ``with <lock>:`` for closures) block.
   ``__init__`` is exempt: construction happens-before publication to
   any other thread.
2. **unguarded-shared-write** — in a class that spawns threads
   (``threading.Thread(target=...)``), any ``self.<attr>`` write inside
   a method lexically reachable from a thread entry point (via the
   ``self.m()`` call graph) that is neither registry-annotated nor
   inside *some* ``with`` block is flagged: it is cross-thread mutable
   state nobody has claimed.
3. **stale-registry** — a registry entry whose class, attribute, lock or
   function no longer exists in the source is itself a finding, so the
   annotations cannot rot; ``lock=None`` (single-thread ownership)
   entries additionally require a non-empty justification note
   (**registry-justification**).

Writes tracked: plain/augmented/annotated assignment to ``self.attr``
and subscript stores through it (``self.cache[k] = v``). Mutations via
method calls (``self.history.append(x)``) and writes through non-self
aliases are out of lexical reach — the registry's ownership notes are
where those contracts get documented.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.findings import Finding
from repro.analysis.registry import ClosureVar, SharedAttr


# ---------------------------------------------------------------------------
# lexical helpers
# ---------------------------------------------------------------------------
def _lock_name(expr: ast.expr) -> Optional[str]:
    """The lock name a ``with`` context expression acquires, if it looks
    like one we can track: ``self.X`` -> ``X``, bare ``name`` ->
    ``name``."""
    if isinstance(expr, ast.Attribute) and \
            isinstance(expr.value, ast.Name) and expr.value.id == "self":
        return expr.attr
    if isinstance(expr, ast.Name):
        return expr.id
    return None


def _self_attrs_in_target(t: ast.expr) -> List[str]:
    """Attribute names of ``self`` written by one assignment target."""
    if isinstance(t, ast.Attribute) and isinstance(t.value, ast.Name) \
            and t.value.id == "self":
        return [t.attr]
    if isinstance(t, ast.Subscript):
        return _self_attrs_in_target(t.value)
    if isinstance(t, (ast.Tuple, ast.List)):
        out: List[str] = []
        for elt in t.elts:
            out.extend(_self_attrs_in_target(elt))
        return out
    if isinstance(t, ast.Starred):
        return _self_attrs_in_target(t.value)
    return []


def _assign_targets(node: ast.AST) -> List[ast.expr]:
    if isinstance(node, ast.Assign):
        return list(node.targets)
    if isinstance(node, (ast.AugAssign, ast.AnnAssign)):
        return [node.target]
    return []


def iter_writes_with_locks(fn: ast.AST):
    """Yield ``(attr, node, locks)`` for every ``self.<attr>`` write
    lexically inside ``fn`` (descending into nested defs, which inherit
    the enclosing with-stack — a nested body *defined* under a lock may
    still run without it, but the registry's owned entries are the place
    to annotate that, and the common case here is plain lock bodies)."""
    out: List[Tuple[str, ast.AST, frozenset]] = []

    def visit(node: ast.AST, locks: frozenset) -> None:
        if isinstance(node, ast.With):
            held = set(locks)
            for item in node.items:
                name = _lock_name(item.context_expr)
                if name is not None:
                    held.add(name)
            for child in node.body:
                visit(child, frozenset(held))
            return
        for t in _assign_targets(node):
            for attr in _self_attrs_in_target(t):
                out.append((attr, node, locks))
        for child in ast.iter_child_nodes(node):
            visit(child, locks)

    for stmt in getattr(fn, "body", []):
        visit(stmt, frozenset())
    return out


def _iter_name_writes(fn: ast.AST, var: str):
    """Yield ``(node, locks)`` for writes to closure name ``var``
    (assignment or subscript store) anywhere inside ``fn``."""
    out: List[Tuple[ast.AST, frozenset]] = []

    def hits(t: ast.expr) -> bool:
        if isinstance(t, ast.Name) and t.id == var:
            return True
        if isinstance(t, ast.Subscript):
            return hits(t.value)
        if isinstance(t, (ast.Tuple, ast.List)):
            return any(hits(e) for e in t.elts)
        return False

    def visit(node: ast.AST, locks: frozenset) -> None:
        if isinstance(node, ast.With):
            held = set(locks)
            for item in node.items:
                name = _lock_name(item.context_expr)
                if name is not None:
                    held.add(name)
            for child in node.body:
                visit(child, frozenset(held))
            return
        if any(hits(t) for t in _assign_targets(node)):
            out.append((node, locks))
        for child in ast.iter_child_nodes(node):
            visit(child, locks)

    for stmt in getattr(fn, "body", []):
        visit(stmt, frozenset())
    return out


# ---------------------------------------------------------------------------
# thread reachability
# ---------------------------------------------------------------------------
def _spawns_thread(fn: ast.AST) -> bool:
    """True when ``fn`` lexically constructs a ``threading.Thread``."""
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        f = node.func
        if isinstance(f, ast.Attribute) and f.attr == "Thread":
            return True
        if isinstance(f, ast.Name) and f.id == "Thread":
            return True
    return False


def _method_refs(fn: ast.AST, methods: Set[str]) -> Set[str]:
    """Method names referenced (not directly called) via ``self.m`` in
    ``fn`` — the thread-target heuristic: ``target=self._loop`` and
    ``for f in (self._a, self._b)`` both reference without calling."""
    called_funcs = {id(n.func) for n in ast.walk(fn)
                    if isinstance(n, ast.Call)}
    refs: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Attribute) and \
                isinstance(node.value, ast.Name) and \
                node.value.id == "self" and node.attr in methods and \
                id(node) not in called_funcs:
            refs.add(node.attr)
    return refs


def _self_calls(fn: ast.AST, methods: Set[str]) -> Set[str]:
    """Methods invoked as ``self.m(...)`` inside ``fn``."""
    out: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                isinstance(node.func.value, ast.Name) and \
                node.func.value.id == "self" and \
                node.func.attr in methods:
            out.add(node.func.attr)
    return out


def _thread_reachable(cls_methods: Dict[str, ast.AST]) -> Set[str]:
    """Methods lexically reachable from any thread entry point of the
    class (empty when the class spawns no threads)."""
    names = set(cls_methods)
    entries: Set[str] = set()
    for name, fn in cls_methods.items():
        if _spawns_thread(fn):
            entries |= _method_refs(fn, names)
    seen: Set[str] = set()
    frontier = list(entries)
    while frontier:
        m = frontier.pop()
        if m in seen:
            continue
        seen.add(m)
        frontier.extend(_self_calls(cls_methods[m], names) - seen)
    return seen


# ---------------------------------------------------------------------------
# the checker
# ---------------------------------------------------------------------------
def _class_defs(tree: ast.Module) -> Dict[str, ast.ClassDef]:
    return {n.name: n for n in tree.body if isinstance(n, ast.ClassDef)}


def _func_defs(tree: ast.Module) -> Dict[str, ast.AST]:
    return {n.name: n for n in tree.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def _methods(cls: ast.ClassDef) -> Dict[str, ast.AST]:
    return {n.name: n for n in cls.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def _attr_exists(cls: ast.ClassDef, attr: str) -> bool:
    """The attribute is a class-level annotation/assignment (dataclass
    field) or a ``self.<attr>`` write somewhere in the class."""
    for node in cls.body:
        if isinstance(node, ast.AnnAssign) and \
                isinstance(node.target, ast.Name) and \
                node.target.id == attr:
            return True
        if isinstance(node, ast.Assign) and any(
                isinstance(t, ast.Name) and t.id == attr
                for t in node.targets):
            return True
    for node in ast.walk(cls):
        for t in _assign_targets(node):
            if attr in _self_attrs_in_target(t):
                return True
    return False


def check_concurrency(tree: ast.Module, path: str,
                      entries: Iterable) -> List[Finding]:
    """Run all three clauses over one module. ``entries`` is the
    registry's tuple of ``SharedAttr``/``ClosureVar`` for this path."""
    findings: List[Finding] = []
    classes = _class_defs(tree)
    functions = _func_defs(tree)
    entries = tuple(entries)

    attr_entries = [e for e in entries if isinstance(e, SharedAttr)]
    closure_entries = [e for e in entries if isinstance(e, ClosureVar)]
    registered: Dict[Tuple[str, str], SharedAttr] = {
        (e.cls, e.attr): e for e in attr_entries}

    # clause 3: registry drift + ownership justification
    for e in attr_entries:
        sym = f"{e.cls}.{e.attr}"
        cls = classes.get(e.cls)
        if cls is None:
            findings.append(Finding(
                "stale-registry", path, 1, sym,
                f"registered class {e.cls!r} no longer exists"))
            continue
        if not _attr_exists(cls, e.attr):
            findings.append(Finding(
                "stale-registry", path, cls.lineno, sym,
                f"registered attribute {e.attr!r} is never written in "
                f"{e.cls}"))
        if e.lock is None and not e.note.strip():
            findings.append(Finding(
                "registry-justification", path, cls.lineno, sym,
                "single-thread-ownership entry carries no justification "
                "note"))
        if e.lock is not None and not _attr_exists(cls, e.lock):
            findings.append(Finding(
                "stale-registry", path, cls.lineno, sym,
                f"guarding lock {e.lock!r} is never assigned in {e.cls}"))
    for e in closure_entries:
        sym = f"{e.func}.{e.var}"
        fn = functions.get(e.func)
        if fn is None:
            findings.append(Finding(
                "stale-registry", path, 1, sym,
                f"registered function {e.func!r} no longer exists"))
        if e.lock is None and not e.note.strip():
            findings.append(Finding(
                "registry-justification", path,
                fn.lineno if fn is not None else 1, sym,
                "single-thread-ownership entry carries no justification "
                "note"))

    # clauses 1 + 2, per class
    for cname, cls in classes.items():
        methods = _methods(cls)
        reachable = _thread_reachable(methods)
        for mname, fn in methods.items():
            if mname == "__init__":
                continue
            for attr, node, locks in iter_writes_with_locks(fn):
                sym = f"{cname}.{mname}"
                entry = registered.get((cname, attr))
                if entry is not None:
                    if entry.lock is not None and entry.lock not in locks:
                        findings.append(Finding(
                            "lock-discipline", path, node.lineno,
                            f"{cname}.{attr}",
                            f"write in {sym} is outside "
                            f"`with self.{entry.lock}:`"))
                elif mname in reachable and not locks:
                    findings.append(Finding(
                        "unguarded-shared-write", path, node.lineno,
                        f"{cname}.{attr}",
                        f"cross-thread write in {sym} (reachable from a "
                        f"thread entry point) has no guarding lock and no "
                        f"registry annotation"))

    # clause 1 for closures: registered vars in module-level functions
    for e in closure_entries:
        fn = functions.get(e.func)
        if fn is None or e.lock is None:
            continue
        for node, locks in _iter_name_writes(fn, e.var):
            if e.lock not in locks:
                findings.append(Finding(
                    "lock-discipline", path, node.lineno,
                    f"{e.func}.{e.var}",
                    f"closure write is outside `with {e.lock}:`"))
    return findings
