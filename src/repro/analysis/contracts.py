"""Serialization-contract linter: units, digest folds, wire twins.

Three clauses:

1. **unit-suffix** — in the registered policy classes' ``to_json``
   methods, every JSON key whose name carries a physical-quantity stem
   (wait, deadline, backoff, duration, rtt, latency, battery, energy,
   bytes, bandwidth, rate, period, power, ...) must end with an
   approved unit suffix (``_s``, ``_ms``, ``_j``, ``_bytes``, ``_bps``,
   ``_mbps``, ``_hz``, ``_w``, ``_s_per_j``, ...) or an explicitly
   dimensionless one (``_jitter``, ``_frac``, ``_alpha``, ``_weight``,
   ``_amplitude``, ``_share``, ``_scale``, ``_ratio``). An ambiguous
   key like ``upload_wait`` is exactly the bug this kills: seconds or
   milliseconds is a wire-contract question, not a reader's guess.
2. **digest-fold** — every registered optional ``DeploymentPlan``
   section must be folded into the contract dict *only* under a literal
   ``if self.<section> is not None:`` guard, and every registered
   section must be folded somewhere: an unguarded fold makes two plans
   with and without the section digest-identical, a missing fold lets
   peers disagree silently.
3. **pack-unpack** — in the wire codec module, every ``struct.pack``
   format (literal or f-string, normalized with ``{}`` placeholders)
   must have a byte-compatible ``struct.unpack``/``unpack_from`` twin,
   and every module-level ``Struct`` constant whose ``.pack`` is used
   must also have its ``.unpack*`` used — a pack without a decoder twin
   is a frame nobody can read back (or worse, reads back by hand with
   silently drifting offsets).
"""
from __future__ import annotations

import ast
import struct as _struct
from typing import Dict, Iterable, List, Optional, Set, Tuple

from repro.analysis.findings import Finding

# ---------------------------------------------------------------------------
# clause 1: unit suffixes
# ---------------------------------------------------------------------------
QUANTITY_STEMS = ("wait", "deadline", "backoff", "heartbeat", "duration",
                  "timeout", "interval", "rtt", "latency", "busy",
                  "elapsed", "battery", "energy", "joule", "watt",
                  "power", "bytes", "bandwidth", "backhaul", "rate",
                  "period", "freq")
UNIT_SUFFIXES = ("_s", "_ms", "_us", "_ns", "_hz", "_khz", "_mhz", "_j",
                 "_mj", "_w", "_mw", "_bytes", "_bits", "_bps", "_kbps",
                 "_mbps", "_gbps", "_s_per_j", "_j_per_s", "_per_s",
                 "_per_req")
DIMENSIONLESS_SUFFIXES = ("_jitter", "_frac", "_fraction", "_amplitude",
                          "_alpha", "_weight", "_scale", "_share",
                          "_ratio", "_count", "_mix")


def key_needs_suffix(key: str) -> bool:
    """True when ``key`` names a physical quantity but carries neither a
    unit suffix nor a dimensionless exemption."""
    k = key.lower()
    if k.endswith(UNIT_SUFFIXES) or k.endswith(DIMENSIONLESS_SUFFIXES):
        return False
    return any(stem in k for stem in QUANTITY_STEMS)


def _dict_keys_in(fn: ast.AST) -> List[Tuple[str, int]]:
    """String keys of every dict literal and ``d["k"] = ...`` store
    inside ``fn``, with line numbers."""
    out: List[Tuple[str, int]] = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Dict):
            for k in node.keys:
                if isinstance(k, ast.Constant) and isinstance(k.value, str):
                    out.append((k.value, k.lineno))
        elif isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = (node.targets if isinstance(node, ast.Assign)
                       else [node.target])
            for t in targets:
                if isinstance(t, ast.Subscript) and \
                        isinstance(t.slice, ast.Constant) and \
                        isinstance(t.slice.value, str):
                    out.append((t.slice.value, t.lineno))
    return out


def check_unit_suffixes(tree: ast.Module, path: str,
                        classes: Iterable[str]) -> List[Finding]:
    """Clause 1 over one module's registered ``to_json`` surfaces; a
    registered class without a ``to_json`` (or missing entirely) is a
    ``stale-registry`` finding."""
    findings: List[Finding] = []
    defs = {n.name: n for n in tree.body if isinstance(n, ast.ClassDef)}
    for cname in classes:
        cls = defs.get(cname)
        if cls is None:
            findings.append(Finding(
                "stale-registry", path, 1, cname,
                f"registered serializable class {cname!r} no longer "
                f"exists"))
            continue
        to_json = next((n for n in cls.body
                        if isinstance(n, ast.FunctionDef)
                        and n.name == "to_json"), None)
        if to_json is None:
            findings.append(Finding(
                "stale-registry", path, cls.lineno, cname,
                f"registered serializable class {cname} has no to_json"))
            continue
        for key, lineno in _dict_keys_in(to_json):
            if key_needs_suffix(key):
                findings.append(Finding(
                    "unit-suffix", path, lineno,
                    f"{cname}.to_json:{key}",
                    f"JSON key {key!r} names a physical quantity but "
                    f"carries no unit suffix "
                    f"({'/'.join(UNIT_SUFFIXES[:6])}/...)"))
    return findings


# ---------------------------------------------------------------------------
# clause 2: digest fold-only-when-set
# ---------------------------------------------------------------------------
def _guard_sections(test: ast.expr, sections: Set[str]) -> Set[str]:
    """Section names proven non-None by an ``if`` test of the literal
    form ``self.<name> is not None`` (possibly ``and``-joined)."""
    out: Set[str] = set()
    if isinstance(test, ast.BoolOp) and isinstance(test.op, ast.And):
        for v in test.values:
            out |= _guard_sections(v, sections)
        return out
    if isinstance(test, ast.Compare) and len(test.ops) == 1 and \
            isinstance(test.ops[0], ast.IsNot) and \
            isinstance(test.comparators[0], ast.Constant) and \
            test.comparators[0].value is None and \
            isinstance(test.left, ast.Attribute) and \
            isinstance(test.left.value, ast.Name) and \
            test.left.value.id == "self" and test.left.attr in sections:
        out.add(test.left.attr)
    return out


def check_digest_fold(tree: ast.Module, path: str, cls_name: str,
                      method: str, sections: Iterable[str]
                      ) -> List[Finding]:
    """Clause 2: every registered optional section folded exactly under
    its own ``is not None`` guard inside ``cls_name.method``."""
    findings: List[Finding] = []
    wanted = set(sections)
    cls = next((n for n in tree.body if isinstance(n, ast.ClassDef)
                and n.name == cls_name), None)
    if cls is None:
        return [Finding("stale-registry", path, 1, cls_name,
                        f"plan class {cls_name!r} no longer exists")]
    fn = next((n for n in cls.body if isinstance(n, ast.FunctionDef)
               and n.name == method), None)
    if fn is None:
        return [Finding("stale-registry", path, cls.lineno,
                        f"{cls_name}.{method}",
                        f"contract method {method!r} no longer exists")]
    folded: Set[str] = set()

    def visit(node: ast.AST, guarded: Set[str]) -> None:
        if isinstance(node, ast.If):
            extra = _guard_sections(node.test, wanted)
            for child in node.body:
                visit(child, guarded | extra)
            for child in node.orelse:
                visit(child, guarded)
            return
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Subscript) and \
                        isinstance(t.slice, ast.Constant) and \
                        t.slice.value in wanted:
                    name = t.slice.value
                    folded.add(name)
                    if name not in guarded:
                        findings.append(Finding(
                            "digest-fold", path, node.lineno,
                            f"{cls_name}.{method}:{name}",
                            f"optional section {name!r} is folded into "
                            f"the digest outside its `if self.{name} is "
                            f"not None:` guard"))
        for child in ast.iter_child_nodes(node):
            visit(child, guarded)

    for stmt in fn.body:
        visit(stmt, set())
    for name in sorted(wanted - folded):
        findings.append(Finding(
            "digest-fold", path, fn.lineno,
            f"{cls_name}.{method}:{name}",
            f"registered optional section {name!r} is never folded into "
            f"the contract dict"))
    return findings


# ---------------------------------------------------------------------------
# clause 3: struct pack/unpack twins
# ---------------------------------------------------------------------------
PACKERS = frozenset({"pack", "pack_into"})
UNPACKERS = frozenset({"unpack", "unpack_from", "iter_unpack"})


def _normalize_fmt(node: ast.expr) -> Optional[str]:
    """A format-string expression as a comparable template: literals
    verbatim, f-string interpolations as ``{}`` placeholders."""
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    if isinstance(node, ast.JoinedStr):
        parts = []
        for v in node.values:
            if isinstance(v, ast.Constant):
                parts.append(str(v.value))
            else:
                parts.append("{}")
        return "".join(parts)
    return None


def check_pack_unpack(tree: ast.Module, path: str) -> List[Finding]:
    """Clause 3 over the wire codec module."""
    findings: List[Finding] = []
    # module-level Struct constants
    struct_vars: Dict[str, Tuple[str, int]] = {}
    for node in tree.body:
        if not isinstance(node, ast.Assign) or \
                not isinstance(node.value, ast.Call):
            continue
        f = node.value.func
        is_struct = (isinstance(f, ast.Name) and f.id == "Struct") or \
                    (isinstance(f, ast.Attribute) and f.attr == "Struct")
        if not is_struct or not node.value.args:
            continue
        fmt = _normalize_fmt(node.value.args[0])
        for t in node.targets:
            if isinstance(t, ast.Name) and fmt is not None:
                struct_vars[t.id] = (fmt, node.lineno)

    var_packs: Set[str] = set()
    var_unpacks: Set[str] = set()
    inline_packs: List[Tuple[str, int]] = []
    inline_unpacks: Set[str] = set()
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call) or \
                not isinstance(node.func, ast.Attribute):
            continue
        base, attr = node.func.value, node.func.attr
        if isinstance(base, ast.Name) and base.id in struct_vars:
            if attr in PACKERS:
                var_packs.add(base.id)
            elif attr in UNPACKERS:
                var_unpacks.add(base.id)
        elif isinstance(base, ast.Name) and base.id == "struct" and \
                node.args:
            fmt = _normalize_fmt(node.args[0])
            if fmt is None:
                continue
            if attr in PACKERS:
                inline_packs.append((fmt, node.lineno))
            elif attr in UNPACKERS:
                inline_unpacks.add(fmt)

    # a Struct var's unpack also satisfies an identical inline pack
    for name in var_unpacks:
        inline_unpacks.add(struct_vars[name][0])

    for name, (fmt, lineno) in sorted(struct_vars.items()):
        if name in var_packs and name not in var_unpacks and \
                fmt not in inline_unpacks:
            findings.append(Finding(
                "pack-unpack", path, lineno, name,
                f"Struct {name} ({fmt!r}) is packed but never unpacked "
                f"— the frame has no decoder twin"))
        if "{" not in fmt:
            try:
                _struct.calcsize(fmt)
            except _struct.error as e:
                findings.append(Finding(
                    "pack-unpack", path, lineno, name,
                    f"Struct {name} format {fmt!r} is invalid: {e}"))

    seen: Set[Tuple[str, int]] = set()
    for fmt, lineno in inline_packs:
        if (fmt, lineno) in seen:
            continue
        seen.add((fmt, lineno))
        if fmt not in inline_unpacks:
            findings.append(Finding(
                "pack-unpack", path, lineno, fmt,
                f"struct.pack format {fmt!r} has no byte-compatible "
                f"struct.unpack twin in this module"))
    return findings
