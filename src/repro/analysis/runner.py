"""Dispatch: which checker runs where, and the aggregate report.

The runner walks the given paths (files or directories), matches each
``.py`` file against the registry's path suffixes, runs the applicable
checkers, applies the baseline, and returns a ``Report``. This is the
single entry point both the CLI (``python -m repro.analysis``) and the
pytest gate (``tests/test_analysis.py``) call.
"""
from __future__ import annotations

import ast
import os
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

from repro.analysis import registry
from repro.analysis.baseline import (BaselineEntry, apply_baseline,
                                     load_baseline)
from repro.analysis.concurrency import check_concurrency
from repro.analysis.contracts import (check_digest_fold, check_pack_unpack,
                                      check_unit_suffixes)
from repro.analysis.findings import Finding, repo_relative
from repro.analysis.purity import check_purity


@dataclass
class Report:
    """Outcome of one analysis run."""
    findings: List[Finding] = field(default_factory=list)
    suppressed: List[Finding] = field(default_factory=list)
    n_files: int = 0

    @property
    def ok(self) -> bool:
        """True when nothing unsuppressed remains — the gate is green."""
        return not self.findings

    def to_json(self) -> Dict[str, Any]:
        """The ``--json`` report document."""
        return {"ok": self.ok, "n_files": self.n_files,
                "findings": [f.to_json() for f in self.findings],
                "suppressed": [f.to_json() for f in self.suppressed]}

    def render(self) -> str:
        """Human-readable multi-line report."""
        lines = [f.render() for f in self.findings]
        lines.append(f"{len(self.findings)} finding(s) "
                     f"({len(self.suppressed)} suppressed by baseline) "
                     f"across {self.n_files} file(s)")
        return "\n".join(lines)


def iter_python_files(paths: Sequence[str]) -> List[str]:
    """Every ``.py`` file under the given files/directories, sorted."""
    out = []
    for p in paths:
        if os.path.isfile(p):
            out.append(p)
        else:
            for dirpath, dirnames, filenames in os.walk(p):
                dirnames[:] = [d for d in dirnames
                               if d != "__pycache__"]
                out.extend(os.path.join(dirpath, f) for f in filenames
                           if f.endswith(".py"))
    return sorted(set(out))


def analyze_file(path: str) -> List[Finding]:
    """All applicable checkers over one source file. Files the registry
    does not scope (including the analysis package itself) yield no
    findings — the gate is invariant-driven, not a general linter."""
    rel = repo_relative(path)
    with open(path) as f:
        source = f.read()
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding("parse-error", rel, e.lineno or 1, "<module>",
                        f"file does not parse: {e.msg}")]
    lines = source.splitlines()
    findings: List[Finding] = []

    for suffix, entries in registry.CONCURRENCY.items():
        if rel.endswith(suffix):
            findings.extend(check_concurrency(tree, rel, entries))

    if registry.PURITY_TREE in rel:
        findings.extend(check_purity(tree, rel, lines))
    else:
        for suffix, classes in registry.PURITY_SCOPES.items():
            if rel.endswith(suffix):
                findings.extend(check_purity(tree, rel, lines,
                                             class_filter=classes))

    for suffix, classes in registry.UNIT_SUFFIX_CLASSES.items():
        if rel.endswith(suffix):
            findings.extend(check_unit_suffixes(tree, rel, classes))
    if rel.endswith(registry.PLAN_PATH):
        findings.extend(check_digest_fold(
            tree, rel, registry.PLAN_CLASS, registry.PLAN_METHOD,
            registry.PLAN_SECTIONS))
    if rel.endswith(registry.PROTOCOL_PATH):
        findings.extend(check_pack_unpack(tree, rel))
    return findings


def run_analysis(paths: Sequence[str],
                 baseline_path: Optional[str] = None,
                 entries: Optional[Sequence[BaselineEntry]] = None
                 ) -> Report:
    """Analyze ``paths``, apply the baseline (a file path or pre-loaded
    entries), return the report the CLI and the pytest gate consume."""
    files = iter_python_files(paths)
    findings: List[Finding] = []
    for path in files:
        findings.extend(analyze_file(path))
    if entries is None:
        entries = (load_baseline(baseline_path)
                   if baseline_path and os.path.exists(baseline_path)
                   else [])
    unsuppressed, suppressed = apply_baseline(
        findings, entries,
        baseline_path=repo_relative(baseline_path)
        if baseline_path else "analysis_baseline.json",
        scanned_paths={repo_relative(p) for p in files})
    return Report(findings=unsuppressed, suppressed=suppressed,
                  n_files=len(files))
