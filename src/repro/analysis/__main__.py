"""CLI for the static-analysis gate: ``python -m repro.analysis``.

Default target is the repo's ``src/`` tree; default baseline is
``analysis_baseline.json`` at the repo root (when present). Exits
non-zero when unsuppressed findings remain, so CI can gate on it.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.analysis.runner import run_analysis

_REPO = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.dirname(os.path.abspath(__file__)))))


def main(argv=None) -> int:
    """Parse args, run the gate, print the report, return the exit
    code (0 = green, 1 = unsuppressed findings)."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="AST-based correctness gate: lock discipline, "
                    "virtual-clock purity, serialization contracts")
    ap.add_argument("paths", nargs="*",
                    default=[os.path.join(_REPO, "src")],
                    help="files/directories to analyze (default: src/)")
    ap.add_argument("--json", action="store_true",
                    help="emit the report as JSON on stdout")
    ap.add_argument("--out", default=None,
                    help="also write the JSON report to this file")
    ap.add_argument("--baseline",
                    default=os.path.join(_REPO, "analysis_baseline.json"),
                    help="suppression file (default: repo "
                         "analysis_baseline.json)")
    ap.add_argument("--no-baseline", action="store_true",
                    help="ignore the baseline: report everything")
    args = ap.parse_args(argv)

    report = run_analysis(
        args.paths, baseline_path=None if args.no_baseline
        else args.baseline)
    doc = report.to_json()
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(doc, f, indent=2)
    if args.json:
        print(json.dumps(doc, indent=2))
    else:
        print(report.render())
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
