"""The annotated invariant registry the checkers run from.

Three maps, one per checker:

* ``CONCURRENCY`` — every shared-mutable attribute of the collab serving
  stack, annotated with the lock that must guard its writes, or — when a
  single thread owns it by construction — ``lock=None`` plus a
  justification note. The concurrency checker verifies the locked
  entries lexically and the registry itself doubles as a drift detector:
  an entry whose class or attribute no longer exists in the source is a
  ``stale-registry`` finding, so deleting or renaming state forces the
  annotation to move with it.

* ``PURITY_SCOPES`` — the virtual-clock domain: files (or single classes
  inside mixed files) where wall-clock reads, ``time.sleep`` and
  module-level ``random`` are forbidden because the fleet simulator's
  same-seed bit-identity contract dies the moment one sneaks in.

* ``SERIALIZATION`` — the serializable plan sections whose JSON keys
  must carry unit suffixes, the ``DeploymentPlan`` optional sections
  that must follow the digest fold-only-when-set rule, and the wire
  codec module whose ``struct.pack`` formats need byte-compatible
  ``unpack`` twins.

Paths are repo-relative posix suffixes; the runner matches them against
``str(file).endswith(suffix)`` so the registry works from any checkout
location.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple


@dataclass(frozen=True)
class SharedAttr:
    """One shared-mutable attribute of a class in the serving stack.

    ``lock`` names the instance attribute (``with self.<lock>:``) or
    closure name every write outside ``__init__`` must be lexically
    guarded by; ``lock=None`` declares single-thread ownership instead,
    and then ``note`` must say *why* that is safe — the checker rejects
    unjustified ownership claims.
    """
    cls: str
    attr: str
    lock: Optional[str]
    note: str = ""


@dataclass(frozen=True)
class ClosureVar:
    """A closure variable shared across threads spawned by a
    module-level function (e.g. ``serve_cloud``'s ``fault_stats`` dict,
    mutated by every handler/writer thread). Same lock/ownership
    semantics as ``SharedAttr``, with the lock being a closure name."""
    func: str
    var: str
    lock: Optional[str]
    note: str = ""


#: path suffix -> registered shared state in that module
CONCURRENCY: Dict[str, Tuple] = {
    "core/collab/runtime.py": (
        SharedAttr("SplitFnBank", "_fns", lock="_cache_lock"),
        SharedAttr("SplitFnBank", "_batched_fns", lock="_cache_lock"),
        SharedAttr("SplitFnBank", "n_traces", lock=None,
                   note="approximate diagnostic counter bumped inside "
                        "jax-traced closures; a lock cannot wrap a traced "
                        "body and an off-by-one trace count is harmless"),
        ClosureVar("serve_cloud", "fault_stats", lock="stats_lock"),
    ),
    "core/collab/batching.py": (
        SharedAttr("DynamicBatcher", "_lanes", lock="_lock"),
        SharedAttr("LaneStats", "rows", lock=None,
                   note="mutated only by the owning lane's single "
                        "scheduler thread; read after stop() joins it"),
        SharedAttr("LaneStats", "frames", lock=None,
                   note="single lane-scheduler-thread owner (see rows)"),
        SharedAttr("LaneStats", "batches", lock=None,
                   note="single lane-scheduler-thread owner (see rows)"),
        SharedAttr("LaneStats", "padded_rows", lock=None,
                   note="single lane-scheduler-thread owner (see rows)"),
        SharedAttr("LaneStats", "busy_s", lock=None,
                   note="single lane-scheduler-thread owner (see rows)"),
        SharedAttr("LaneStats", "failed_rows", lock=None,
                   note="single lane-scheduler-thread owner (see rows)"),
        SharedAttr("LaneStats", "cancelled_frames", lock=None,
                   note="written by the scheduler thread and by stop()'s "
                        "drain, which runs after _stop is set and the "
                        "scheduler has exited its pop loop"),
    ),
    "core/collab/channel.py": (
        SharedAttr("FaultInjector", "_attempt", lock="_lock"),
        SharedAttr("FaultInjector", "counts", lock="_lock"),
        SharedAttr("LinkShaper", "_budget", lock="_lock"),
        SharedAttr("LinkShaper", "_last", lock="_lock"),
        SharedAttr("ShapedSocket", "last_send_cost_s", lock=None,
                   note="one sender thread per connection by protocol "
                        "design; the reader thread never writes it"),
        SharedAttr("SimChannel", "sent_bytes", lock=None,
                   note="SimChannel is single-owner by contract: the "
                        "in-process runner or the one tx-stage thread"),
        SharedAttr("SimChannel", "elapsed_s", lock=None,
                   note="single-owner (see sent_bytes)"),
        SharedAttr("SimChannel", "last_send_events", lock=None,
                   note="single-owner (see sent_bytes)"),
    ),
    "core/collab/adaptive.py": (
        SharedAttr("BandwidthEstimator", "_ewma", lock="_lock"),
        SharedAttr("BandwidthEstimator", "n_samples", lock="_lock"),
        SharedAttr("AdaptiveSplitController", "split", lock="_lock"),
        SharedAttr("AdaptiveSplitController", "battery_j", lock="_lock"),
        SharedAttr("AdaptiveSplitController", "n_requests", lock="_lock"),
        SharedAttr("AdaptiveSplitController", "_since_switch",
                   lock="_lock"),
    ),
    "core/collab/streaming.py": (
        SharedAttr("StageStats", "busy_s", lock=None,
                   note="each pipeline stage charges only its own stats "
                        "object; read after join()"),
        SharedAttr("StageStats", "items", lock=None,
                   note="single-stage-thread owner (see busy_s)"),
        SharedAttr("StageStats", "batches", lock=None,
                   note="single-stage-thread owner (see busy_s)"),
    ),
    "core/collab/faults.py": (),     # pure-data policies: no shared state
    "core/collab/cluster.py": (
        SharedAttr("FleetRouter", "_state", lock="_lock"),
        SharedAttr("FleetRouter", "_miss", lock="_lock"),
        SharedAttr("FleetRouter", "_dead_at_s", lock="_lock"),
        SharedAttr("FleetRouter", "_routed", lock="_lock"),
        SharedAttr("FleetRouter", "_reroutes", lock="_lock"),
    ),
    "serving/session.py": (
        SharedAttr("CloudFleet", "_servers", lock="_lock"),
    ),
}

#: path suffix -> class names to scan (None = whole file). Everything
#: under core/fleet/ is added by the runner unconditionally.
PURITY_SCOPES: Dict[str, Optional[Tuple[str, ...]]] = {
    "core/collab/channel.py": ("SimChannel",),
    "core/partition/profiles.py": ("LinkTrace",),
    # the fleet benchmark drives the virtual clock; its two wall-clock
    # sweep-timing lines are pinned by justified `# wall-clock:` markers
    "benchmarks/fleet_sim.py": None,
}

#: directory fragment whose every file is in the purity domain
PURITY_TREE = "core/fleet/"

#: path suffix -> classes whose ``to_json`` keys must be unit-suffixed
UNIT_SUFFIX_CLASSES: Dict[str, Tuple[str, ...]] = {
    "core/collab/batching.py": ("BatchingPolicy", "LaneStats"),
    "core/collab/faults.py": ("FaultPolicy",),
    "core/collab/adaptive.py": ("AdaptivePolicy",),
    "core/collab/cluster.py": ("RoutingPolicy",),
    "core/partition/energy_model.py": ("EnergyPolicy", "EnergyProfile"),
    "core/fleet/scenario.py": ("FleetScenario", "SLOClass",
                               "ArrivalPattern", "ChaosEvent"),
    "core/collab/quant.py": ("QuantPolicy",),
}

#: the DeploymentPlan optional sections under the fold-only-when-set rule
PLAN_PATH = "serving/plan.py"
PLAN_CLASS = "DeploymentPlan"
PLAN_METHOD = "contract"
PLAN_SECTIONS: Tuple[str, ...] = ("adaptive", "batching", "energy",
                                  "faults", "fleet", "quant", "routing")

#: the wire codec whose pack formats need unpack twins
PROTOCOL_PATH = "core/collab/protocol.py"
