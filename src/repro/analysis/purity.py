"""Virtual-clock purity checker: no wall clock, no ambient randomness.

The fleet simulator's same-seed bit-identity contract (and ``SimChannel``
/ ``LinkTrace`` determinism) requires that nothing in the virtual-clock
domain ever reads the host clock or draws from a process-global RNG.
This checker forbids, lexically:

* ``time.time/monotonic/sleep/perf_counter/...`` (and the ``_ns``
  variants), including ``from time import ...`` of those names;
* ``datetime.now/utcnow/today`` (any ``datetime``/``date`` base);
* module-level ``random.<fn>()`` — the *only* sanctioned randomness is
  a seeded generator constructed once and passed around:
  ``random.Random(seed)`` (and ``SystemRandom``/``SeedSequence`` for
  completeness) stay legal, ``random.random()``/``random.randrange()``
  etc. do not;
* ``np.random.<convenience>`` — ``np.random.default_rng`` /
  ``Generator`` / ``PCG64`` / ``SeedSequence`` are the seeded
  constructors and stay legal.

An **allow marker** — a ``# wall-clock: <why>`` comment with a
non-empty justification on the offending line — suppresses the finding
in place; it is how ``benchmarks/fleet_sim.py`` pins its wall-vs-virtual
split (wall seconds are measured for the sweep report but must never
enter a rollup). Markers without a justification do not suppress.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Sequence, Tuple

from repro.analysis.findings import Finding

ALLOW_MARKER = "# wall-clock:"

FORBIDDEN_TIME = frozenset({
    "time", "time_ns", "monotonic", "monotonic_ns", "sleep",
    "perf_counter", "perf_counter_ns", "process_time",
    "process_time_ns"})
FORBIDDEN_DATETIME = frozenset({"now", "utcnow", "today"})
ALLOWED_RANDOM = frozenset({"Random", "SystemRandom", "SeedSequence"})
ALLOWED_NP_RANDOM = frozenset({"default_rng", "Generator", "PCG64",
                               "BitGenerator", "SeedSequence"})


def _root_name(expr: ast.expr) -> Optional[str]:
    """Leftmost ``Name`` of an attribute chain (``a.b.c`` -> ``a``)."""
    while isinstance(expr, ast.Attribute):
        expr = expr.value
    return expr.id if isinstance(expr, ast.Name) else None


def _line_allowed(lines: Sequence[str], lineno: int) -> bool:
    """True when the source line carries a justified allow marker."""
    if not lines or lineno > len(lines):
        return False
    line = lines[lineno - 1]
    idx = line.find(ALLOW_MARKER)
    return idx >= 0 and bool(line[idx + len(ALLOW_MARKER):].strip())


class _Scope:
    """Tracks the dotted lexical symbol (Class.method) during the walk."""

    def __init__(self) -> None:
        self.parts: List[str] = []

    def symbol(self) -> str:
        return ".".join(self.parts) if self.parts else "<module>"


def _check_node(node: ast.AST, sym: str, path: str,
                lines: Sequence[str], findings: List[Finding]) -> None:
    def emit(message: str) -> None:
        if not _line_allowed(lines, node.lineno):
            findings.append(Finding("purity", path, node.lineno, sym,
                                    message))

    if isinstance(node, ast.Attribute):
        root = _root_name(node)
        base = node.value
        if isinstance(base, ast.Name) and base.id == "time" and \
                node.attr in FORBIDDEN_TIME:
            emit(f"wall-clock call time.{node.attr} in the virtual-clock "
                 f"domain")
        elif node.attr in FORBIDDEN_DATETIME and root is not None and \
                "date" in root.lower():
            emit(f"wall-clock call {root}.{node.attr} in the "
                 f"virtual-clock domain")
        elif isinstance(base, ast.Name) and base.id == "random" and \
                node.attr not in ALLOWED_RANDOM:
            emit(f"module-level random.{node.attr}: pass a seeded "
                 f"random.Random instead")
        elif isinstance(base, ast.Attribute) and base.attr == "random" \
                and isinstance(base.value, ast.Name) and \
                base.value.id in ("np", "numpy") and \
                node.attr not in ALLOWED_NP_RANDOM:
            emit(f"np.random.{node.attr} draws from the global numpy "
                 f"RNG: pass a seeded np.random.Generator instead")
    elif isinstance(node, ast.ImportFrom):
        if node.module == "time":
            for alias in node.names:
                if alias.name in FORBIDDEN_TIME:
                    emit(f"`from time import {alias.name}` in the "
                         f"virtual-clock domain")
        elif node.module == "random":
            for alias in node.names:
                if alias.name not in ALLOWED_RANDOM:
                    emit(f"`from random import {alias.name}`: pass a "
                         f"seeded random.Random instead")


def check_purity(tree: ast.Module, path: str, lines: Sequence[str],
                 class_filter: Optional[Iterable[str]] = None
                 ) -> List[Finding]:
    """Scan one module. With ``class_filter`` set, only the named
    top-level classes are in the purity domain (for mixed files like
    ``channel.py`` where only ``SimChannel`` is virtual-clock code);
    module-level imports are then out of scope too."""
    findings: List[Finding] = []
    wanted = None if class_filter is None else frozenset(class_filter)
    scope = _Scope()

    def visit(node: ast.AST, in_scope: bool) -> None:
        entered = False
        if isinstance(node, (ast.ClassDef, ast.FunctionDef,
                             ast.AsyncFunctionDef)):
            scope.parts.append(node.name)
            entered = True
            if wanted is not None and isinstance(node, ast.ClassDef) \
                    and node.name in wanted:
                in_scope = True
        if in_scope:
            _check_node(node, scope.symbol(), path, lines, findings)
        for child in ast.iter_child_nodes(node):
            visit(child, in_scope)
        if entered:
            scope.parts.pop()

    for stmt in tree.body:
        visit(stmt, wanted is None)
    return findings


def marker_lines(lines: Sequence[str]) -> List[Tuple[int, str]]:
    """All justified allow markers in a file, as ``(lineno, why)`` —
    lets tests pin exactly which lines opt out of the purity rule."""
    out: List[Tuple[int, str]] = []
    for i, line in enumerate(lines, 1):
        idx = line.find(ALLOW_MARKER)
        if idx >= 0:
            why = line[idx + len(ALLOW_MARKER):].strip()
            if why:
                out.append((i, why))
    return out
