"""Config dataclasses for all model families supported by the framework.

Every assigned architecture gets one file in this package exporting
``CONFIG`` (the exact published shape, cited) and ``smoke_config()``
(a reduced variant for CPU smoke tests: <=2 layers, d_model<=512,
<=4 experts).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # per-expert hidden dim
    num_shared: int = 0           # always-on shared experts (DeepSeek-V3)
    capacity_factor: float = 1.0
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3
    # DeepSeek-V3 style sigmoid routing with bias-based balancing
    score_fn: str = "softmax"     # "softmax" | "sigmoid"


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention (DeepSeek-V2/V3)."""
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD block shape."""
    d_state: int
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    chunk_size: int = 256
    n_groups: int = 1


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    activation: str = "silu_glu"  # silu_glu | geglu | gelu | sq_relu
    norm: str = "rmsnorm"
    norm_eps: float = 1e-6
    qkv_bias: bool = False
    attention: str = "gqa"        # gqa | mla | none
    causal: bool = True           # False => bidirectional encoder (hubert)
    sliding_window: Optional[int] = None
    rope_mode: str = "standard"   # standard | mrope | none
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, ...] = ()
    moe: Optional[MoEConfig] = None
    # layers that use dense FFN even in an MoE model (DeepSeek-V3: first 3)
    num_dense_layers: int = 0
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): a single SHARED attention block applied after every
    # `shared_attn_period` ssm layers.
    shared_attn_period: int = 0
    tie_embeddings: bool = False
    scale_embeddings: bool = False          # gemma: embeds * sqrt(d_model)
    logit_softcap: Optional[float] = None   # gemma-style final-logit softcap
    # vlm: stubbed vision frontend feeds patch embeddings of this many tokens
    vision_tokens: int = 0
    # audio: stubbed conv frontend feeds frame embeddings directly
    embeds_input: bool = False
    # MTP: auxiliary next-next-token prediction head depth (DeepSeek-V3)
    mtp_depth: int = 0
    vocab_pad_to: int = 0          # pad vocab for even sharding (0 = none)
    dtype: str = "bfloat16"
    remat: bool = True
    # scan_layers=False unrolls the layer stack into straight-line HLO.
    # Used by the dry-run: XLA's HloCostAnalysis counts a while-loop body
    # ONCE regardless of trip count, so roofline FLOPs/bytes/collectives
    # must come from unrolled lowerings (see roofline/analysis.py).
    scan_layers: bool = True
    # unroll the chunked-attention KV-block scan (same cost_analysis reason)
    attn_block_unroll: bool = False
    # naive (S^2-materializing) attention below this length; chunked above
    naive_attn_max: int = 4096
    # head-atomic chunked attention: keep H as one dim (sharding-friendly
    # when the model axis divides neither Hkv nor the GQA group; §Perf-1)
    attn_head_atomic: bool = False
    citation: str = ""

    # ---- derived helpers -------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        if self.vocab_pad_to and self.vocab_size % self.vocab_pad_to:
            return (self.vocab_size // self.vocab_pad_to + 1) * self.vocab_pad_to
        return self.vocab_size

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        """SSM inner dim."""
        assert self.ssm is not None
        return self.ssm.expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        assert self.ssm is not None
        return self.d_inner // self.ssm.head_dim

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind: 'attn', 'moe', 'ssm'."""
        if self.arch_type in ("dense", "audio", "vlm"):
            return ("attn",) * self.num_layers
        if self.arch_type == "moe":
            kinds = []
            for i in range(self.num_layers):
                kinds.append("attn_dense" if i < self.num_dense_layers else "moe")
            return tuple(kinds)
        if self.arch_type in ("ssm", "hybrid"):
            return ("ssm",) * self.num_layers
        raise ValueError(self.arch_type)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


def reduce_for_smoke(cfg: ModelConfig, **overrides) -> ModelConfig:
    """Reduced variant of the same family: 2 layers, d_model<=512, <=4 experts."""
    d_model = min(cfg.d_model, 256)
    head_dim = 64
    num_heads = max(2, d_model // head_dim)
    num_kv = max(1, min(cfg.num_kv_heads, num_heads))
    # preserve the GQA-vs-MHA character
    if cfg.num_kv_heads < cfg.num_heads:
        num_kv = max(1, num_heads // 2)
    else:
        num_kv = num_heads
    kw = dict(
        num_layers=2,
        d_model=d_model,
        num_heads=num_heads,
        num_kv_heads=num_kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 512) if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 512),
        vocab_pad_to=0,
        vision_tokens=min(cfg.vision_tokens, 16) if cfg.vision_tokens else 0,
        remat=False,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=min(cfg.moe.num_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            d_expert=min(cfg.moe.d_expert, 256),
        )
        kw["num_dense_layers"] = min(cfg.num_dense_layers, 1)
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(
            q_lora_rank=128, kv_lora_rank=64,
            qk_nope_head_dim=32, qk_rope_head_dim=16, v_head_dim=32,
        )
        kw["head_dim"] = 32
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(
            cfg.ssm, d_state=min(cfg.ssm.d_state, 32), head_dim=32,
            chunk_size=32,
        )
    if cfg.shared_attn_period:
        kw["shared_attn_period"] = 1
    if cfg.sliding_window:
        kw["sliding_window"] = 64
    if cfg.mrope_sections:
        # sections sum to head_dim//2
        kw["mrope_sections"] = (8, 12, 12)
    kw.update(overrides)
    return cfg.replace(**kw)


# ----------------------------------------------------------------------------
# CNN config (the paper's own model family: AlexNet on PlantVillage)
# ----------------------------------------------------------------------------
@dataclass(frozen=True)
class ConvLayerSpec:
    kind: str                     # conv | maxpool | flatten | dense | relu | lrn
    out_channels: int = 0
    kernel: int = 0
    stride: int = 1
    padding: int = 0
    features: int = 0             # dense width


@dataclass(frozen=True)
class CNNConfig:
    name: str
    layers: Tuple[ConvLayerSpec, ...]
    num_classes: int
    input_hw: Tuple[int, int] = (224, 224)
    input_channels: int = 3
    dtype: str = "float32"
    citation: str = ""
