"""zamba2-1.2b [arXiv:2411.15242]: 38 Mamba2 layers (d_model=2048,
ssm_state=64) + ONE shared transformer block (32H attention + 8192 MLP)
applied every 6 mamba layers. The per-invocation LoRA deltas on the shared
block are simplified to a single shared block (DESIGN.md §7)."""
from repro.configs.base import ModelConfig, SSMConfig, reduce_for_smoke

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    activation="gelu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64,
                  chunk_size=256, n_groups=1),
    shared_attn_period=6,
    citation="[arXiv:2411.15242] Zamba2 suite, 1.2B",
)


def smoke_config():
    return reduce_for_smoke(CONFIG)
