"""gemma-7b [arXiv:2403.08295]: 28L d_model=3072 16H (kv=16, MHA on 7b;
MQA is the 2b variant) d_ff=24576 GeGLU, head_dim=256, vocab=256000,
tied embeddings scaled by sqrt(d_model)."""
from repro.configs.base import ModelConfig, reduce_for_smoke

CONFIG = ModelConfig(
    name="gemma-7b",
    arch_type="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16, num_kv_heads=16, head_dim=256,
    d_ff=24576,
    vocab_size=256000,
    activation="geglu",
    rope_theta=10_000.0,
    tie_embeddings=True,
    scale_embeddings=True,
    citation="[arXiv:2403.08295] Gemma: Open Models..., 7B",
)


def smoke_config():
    return reduce_for_smoke(CONFIG)
