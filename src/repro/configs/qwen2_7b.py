"""qwen2-7b [arXiv:2407.10671]: 28L d_model=3584 28H (GQA kv=4)
d_ff=18944 vocab=152064, QKV bias, rope theta 1e6."""
from repro.configs.base import ModelConfig, reduce_for_smoke

CONFIG = ModelConfig(
    name="qwen2-7b",
    arch_type="dense",
    num_layers=28,
    d_model=3584,
    num_heads=28, num_kv_heads=4, head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    activation="silu_glu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    citation="[arXiv:2407.10671] Qwen2 Technical Report, 7B",
)


def smoke_config():
    return reduce_for_smoke(CONFIG)
