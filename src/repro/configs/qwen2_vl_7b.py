"""qwen2-vl-7b [arXiv:2409.12191]: qwen2-7b language backbone + M-RoPE
(sections t/h/w = 16/24/24 over head_dim/2 = 64) and dynamic-resolution
vision. The ViT frontend is STUBBED per the assignment carve-out:
input_specs provides projected patch embeddings (B, V, d_model) that
prefix the text tokens; M-RoPE itself is fully implemented."""
from repro.configs.base import ModelConfig, reduce_for_smoke

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    arch_type="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28, num_kv_heads=4, head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    activation="silu_glu",
    qkv_bias=True,
    rope_mode="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    vision_tokens=1024,          # fixed patch grid per request (stub)
    citation="[arXiv:2409.12191] Qwen2-VL, 7B",
)


def smoke_config():
    return reduce_for_smoke(CONFIG)
