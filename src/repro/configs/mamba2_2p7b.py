"""mamba2-2.7b — SSD (state-space duality) [arXiv:2405.21060].

64L, d_model=2560, attention-free, vocab=50280, ssm_state=128.
Mamba2 defaults: expand=2 (d_inner=5120), head_dim=64 (80 SSD heads),
d_conv=4, 1 B/C group, chunked SSD scan. Vocab padded to a multiple of 128
for even "model"-axis sharding (50280 -> 50304).
"""
from repro.configs.base import ModelConfig, SSMConfig, reduce_for_smoke

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    arch_type="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0, num_kv_heads=0, head_dim=0, d_ff=0,
    vocab_size=50280,
    vocab_pad_to=128,
    attention="none",
    rope_mode="none",
    causal=True,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                  chunk_size=256, n_groups=1),
    citation="[arXiv:2405.21060] Transformers are SSMs (Mamba-2), 2.7B",
)


def smoke_config():
    return reduce_for_smoke(CONFIG)
