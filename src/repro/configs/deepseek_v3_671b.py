"""deepseek-v3-671b [arXiv:2412.19437]: 61L d_model=7168, MLA attention
(128 heads; q_lora=1536, kv_lora=512, nope/rope head dims 128/64, v=128),
MoE with 1 shared + 256 routed experts top-8 (d_expert=2048, sigmoid
scores), first 3 layers dense (d_ff=18432), MTP depth 1, vocab=129280.

The assignment's "d_ff=2048" is the per-expert hidden dim; the dense
layers use the published 18432. DeepSeek's bias-based aux-free balancing
is approximated with the Switch aux loss (DESIGN.md §7)."""
from repro.configs.base import (MLAConfig, ModelConfig, MoEConfig,
                                reduce_for_smoke)

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128, num_kv_heads=128, head_dim=128,
    d_ff=18432,
    num_dense_layers=3,
    vocab_size=129280,
    activation="silu_glu",
    attention="mla",
    mla=MLAConfig(q_lora_rank=1536, kv_lora_rank=512,
                  qk_nope_head_dim=128, qk_rope_head_dim=64,
                  v_head_dim=128),
    moe=MoEConfig(num_experts=256, top_k=8, d_expert=2048, num_shared=1,
                  capacity_factor=1.0, score_fn="sigmoid"),
    mtp_depth=1,
    rope_theta=10_000.0,
    citation="[arXiv:2412.19437] DeepSeek-V3, 671B (37B active)",
)


def smoke_config():
    return reduce_for_smoke(CONFIG)
