"""qwen1.5-4b [hf:Qwen/Qwen1.5-0.5B family card]: 40L d_model=2560
20H (kv=20) d_ff=6912 vocab=151936, QKV bias, rope theta 1e6
(family-wide scaled base; 4B shape per the assignment)."""
from repro.configs.base import ModelConfig, reduce_for_smoke

CONFIG = ModelConfig(
    name="qwen1.5-4b",
    arch_type="dense",
    num_layers=40,
    d_model=2560,
    num_heads=20, num_kv_heads=20, head_dim=128,
    d_ff=6912,
    vocab_size=151936,
    activation="silu_glu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    citation="[hf:Qwen/Qwen1.5-0.5B] Qwen1.5 model card family, 4B shape",
)


def smoke_config():
    return reduce_for_smoke(CONFIG)
