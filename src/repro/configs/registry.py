"""Architecture registry: ``--arch <id>`` resolution for every launcher.

Each module in this package exports CONFIG (exact published shape, citation
in brackets) and smoke_config() (reduced same-family variant).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import ModelConfig

ARCH_IDS = [
    "mamba2-2.7b",
    "gemma-7b",
    "qwen1.5-4b",
    "qwen2-7b",
    "hubert-xlarge",
    "nemotron-4-340b",
    "qwen2-vl-7b",
    "zamba2-1.2b",
    "deepseek-v3-671b",
    "mixtral-8x7b",
]

_MODULES = {
    "mamba2-2.7b": "mamba2_2p7b",
    "gemma-7b": "gemma_7b",
    "qwen1.5-4b": "qwen1p5_4b",
    "qwen2-7b": "qwen2_7b",
    "hubert-xlarge": "hubert_xlarge",
    "nemotron-4-340b": "nemotron4_340b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "zamba2-1.2b": "zamba2_1p2b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "mixtral-8x7b": "mixtral_8x7b",
}


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.smoke_config()


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
