"""hubert-xlarge [arXiv:2106.07447]: 48L encoder-only, d_model=1280,
16H (kv=16), d_ff=5120, 504 cluster-unit vocab.

Bidirectional (causal=False); no decode shapes (DESIGN.md shape-skip
table). The conv waveform frontend is STUBBED per the assignment carve-out:
input_specs feeds precomputed frame embeddings (B, S, d_model). HuBERT's
conv positional embedding is adapted to rope-free attention + learned
frame embeddings (DESIGN.md hardware-adaptation notes)."""
from repro.configs.base import ModelConfig, reduce_for_smoke

CONFIG = ModelConfig(
    name="hubert-xlarge",
    arch_type="audio",
    num_layers=48,
    d_model=1280,
    num_heads=16, num_kv_heads=16, head_dim=80,
    d_ff=5120,
    vocab_size=504,
    activation="gelu",
    causal=False,
    rope_mode="none",
    embeds_input=True,
    citation="[arXiv:2106.07447] HuBERT, X-Large (same arch as w2v2)",
)


def smoke_config():
    return reduce_for_smoke(CONFIG)
