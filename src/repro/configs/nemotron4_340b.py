"""nemotron-4-340b [arXiv:2402.16819 / 2406.11704]: 96L d_model=18432
96H (GQA kv=8) d_ff=73728, squared-ReLU (non-gated) MLP, vocab=256000,
head_dim=192."""
from repro.configs.base import ModelConfig, reduce_for_smoke

CONFIG = ModelConfig(
    name="nemotron-4-340b",
    arch_type="dense",
    num_layers=96,
    d_model=18432,
    num_heads=96, num_kv_heads=8, head_dim=192,
    d_ff=73728,
    vocab_size=256000,
    activation="sq_relu",
    rope_theta=10_000.0,
    citation="[arXiv:2402.16819] Nemotron-4 340B",
)


def smoke_config():
    return reduce_for_smoke(CONFIG)
