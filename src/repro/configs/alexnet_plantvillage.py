"""The paper's own model: AlexNet on PlantVillage-38 (paper §4.1)."""
from repro.models.cnn import alexnet_config, tiny_cnn_config

CONFIG = alexnet_config(num_classes=38)


def smoke_config():
    return tiny_cnn_config(num_classes=38, width=0.25, hw=64)
