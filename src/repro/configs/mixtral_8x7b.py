"""mixtral-8x7b [arXiv:2401.04088]: 32L d_model=4096 32H (GQA kv=8),
8 experts top-2 (d_expert=14336), sliding-window attention (4096),
vocab=32000."""
from repro.configs.base import ModelConfig, MoEConfig, reduce_for_smoke

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    arch_type="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32, num_kv_heads=8, head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    activation="silu_glu",
    sliding_window=4096,
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=8, top_k=2, d_expert=14336,
                  capacity_factor=1.25),
    citation="[arXiv:2401.04088] Mixtral of Experts, 8x7B",
)


def smoke_config():
    return reduce_for_smoke(CONFIG)
