"""Pipelined streaming collaborative-inference runtime (beyond-paper).

.. note::
   **Internal layer.** Prefer the ``repro.serving`` front door:
   ``serving.connect(plan, backend="streaming")`` wraps
   ``StreamingCollabRunner`` behind the unified ``InferenceSession``
   interface and takes the whole deployment contract from one
   ``DeploymentPlan`` instead of loose constructor knobs. The raw
   constructor below stays as an internal/deprecated compatibility shim.

The paper's deployment (and ``CollabRunner``) serves requests strictly
sequentially: T_total = sum_i (T_D + T_TX + T_S). When requests stream,
the three stages are independent resources — edge CPU, wireless link,
cloud GPU — so edge compute of request i+1 can overlap transmission of
request i and cloud compute of request i-1. ``StreamingCollabRunner``
implements that overlap with one worker thread per stage connected by
bounded hand-off queues; steady-state throughput approaches
1 / max(T_D, T_TX, T_S) instead of 1 / (T_D + T_TX + T_S) — the regime
``balanced_split`` optimizes for.

Also supported:
  * micro-batching — while a stage is busy, arrivals queue up, and the
    edge stage drains up to ``microbatch`` of them into one jitted call
    (amortizing dispatch overhead and per-frame header bytes);
  * the compacted deployment path and the feature codec, with the same
    semantics as ``CollabRunner`` (frames are genuinely encoded/decoded);
  * per-stage busy-time accounting — ``run`` reports occupancy per stage,
    wire bytes, and end-to-end throughput.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CNNConfig
from repro.core.collab.channel import SimChannel
from repro.core.collab.protocol import decode_any, encode_feature
from repro.core.collab.batching import next_pow2_bucket, pad_rows
from repro.core.collab.runtime import SplitFnBank
from repro.core.partition.profiles import TwoTierProfile

_DONE = object()


@dataclass
class StageStats:
    name: str
    busy_s: float = 0.0
    items: int = 0
    batches: int = 0

    def charge(self, dt: float, n: int) -> None:
        self.busy_s += dt
        self.items += n
        self.batches += 1


@dataclass
class StreamReport:
    results: List[Dict]
    wall_s: float
    throughput_rps: float
    tx_bytes_total: int
    occupancy: Dict[str, float]          # busy fraction per stage
    stages: Dict[str, StageStats] = field(default_factory=dict)


class StreamingCollabRunner:
    """Three-stage pipelined split executor (edge -> link -> cloud).

    Same deployment knobs as ``CollabRunner`` (``compact``, ``codec``,
    ``pack``); ``queue_depth`` bounds the hand-off queues (backpressure),
    ``microbatch`` caps how many queued requests the edge stage fuses into
    one forward pass.
    """

    def __init__(self, params, cfg: CNNConfig, split: int,
                 profile: TwoTierProfile, masks=None,
                 compact: bool = False, codec: Optional[str] = None,
                 pack: bool = False, queue_depth: int = 4,
                 microbatch: int = 1, realtime_channel: bool = True,
                 trace=None, quant=None):
        self.split = split
        self.microbatch = max(1, microbatch)
        self.queue_depth = max(1, queue_depth)
        self.channel = SimChannel(profile.link, realtime=realtime_channel,
                                  trace=trace)
        self.codec = codec
        self._bank = SplitFnBank(params, cfg, masks, compact, pack,
                                 quant=quant)
        self._edge_fn, self._cloud_fn, self._keep = self._bank.get(split)
        self.deploy_cfg = self._bank.deploy_cfg

    def _run_rows(self, fn_single, x, role: int):
        """Run ``x`` (B rows) through the batch-1 fn (B == 1) or the
        bank's row-mapped bucketed variant (B > 1, zero-padded to the
        power-of-two bucket, padding sliced off) — per-row results are
        bit-identical either way."""
        n = int(x.shape[0])
        if n == 1:
            return fn_single(x)
        bucket = next_pow2_bucket(n)
        xs = pad_rows(np.asarray(x), bucket)
        fn_b = self._bank.get(self.split, batch_bucket=bucket)[role]
        return fn_b(jnp.asarray(xs))[:n]

    # -- stages -------------------------------------------------------------
    def _edge_stage(self, in_q: queue.Queue, tx_q: queue.Queue,
                    st: StageStats) -> None:
        while True:
            item = in_q.get()
            if item is _DONE:
                tx_q.put(_DONE)
                return
            ids, imgs = [item[0]], [item[1]]
            while len(ids) < self.microbatch:
                try:
                    nxt = in_q.get_nowait()
                except queue.Empty:
                    break
                if nxt is _DONE:
                    in_q.put(_DONE)      # re-post for the outer loop
                    break
                ids.append(nxt[0])
                imgs.append(nxt[1])
            t0 = time.perf_counter()
            x = jnp.asarray(np.concatenate(imgs, axis=0))
            if self._edge_fn is not None:
                x = self._run_rows(self._edge_fn, x, role=0)
                jax.block_until_ready(x)
            if self._cloud_fn is not None:
                buf = encode_feature(np.asarray(x),
                                     codec=self.codec or "fp32",
                                     keep=self._keep)
            else:
                buf = np.asarray(x)      # edge-only: carry logits through
            st.charge(time.perf_counter() - t0, len(ids))
            tx_q.put((ids, buf))

    def _tx_stage(self, tx_q: queue.Queue, cloud_q: queue.Queue,
                  st: StageStats) -> None:
        while True:
            item = tx_q.get()
            if item is _DONE:
                cloud_q.put(_DONE)
                return
            ids, buf = item
            t0 = time.perf_counter()
            t_model = 0.0
            if self._cloud_fn is not None:
                # the channel's *modeled* cost (bytes/bandwidth + RTT):
                # with realtime_channel=False the wall-clock here is ~0,
                # so per-request energy/latency attribution reads this
                t_model = self.channel.send(len(buf))
            st.charge(time.perf_counter() - t0, len(ids))
            cloud_q.put((ids, buf, t_model))

    def _cloud_stage(self, cloud_q: queue.Queue, results: Dict[int, Dict],
                     st: StageStats) -> None:
        while True:
            item = cloud_q.get()
            if item is _DONE:
                return
            ids, buf, t_model = item
            t0 = time.perf_counter()
            if self._cloud_fn is not None:
                x = jnp.asarray(decode_any(buf)[0])
                out = np.asarray(self._run_rows(self._cloud_fn, x, role=1))
                nbytes = len(buf)
            else:
                out, nbytes = np.asarray(buf), 0
            st.charge(time.perf_counter() - t0, len(ids))
            for j, rid in enumerate(ids):
                # frame_n lets downstream consumers amortize per-FRAME
                # constants (the RTT) the same way t_tx_model was split
                results[rid] = {"logits": out[j:j + 1],
                                "tx_bytes": nbytes / len(ids),
                                "t_tx_model": t_model / len(ids),
                                "frame_n": len(ids)}

    # -- driver -------------------------------------------------------------
    def run(self, images: Sequence[np.ndarray]) -> StreamReport:
        """Stream ``images`` (each (1, H, W, C)) through the pipeline.

        Returns per-request results in submission order plus stage
        occupancy and throughput.
        """
        in_q: queue.Queue = queue.Queue(maxsize=self.queue_depth)
        tx_q: queue.Queue = queue.Queue(maxsize=self.queue_depth)
        cloud_q: queue.Queue = queue.Queue(maxsize=self.queue_depth)
        results: Dict[int, Dict] = {}
        stats = {k: StageStats(k) for k in ("edge", "tx", "cloud")}
        workers = [
            threading.Thread(target=self._edge_stage,
                             args=(in_q, tx_q, stats["edge"]), daemon=True),
            threading.Thread(target=self._tx_stage,
                             args=(tx_q, cloud_q, stats["tx"]), daemon=True),
            threading.Thread(target=self._cloud_stage,
                             args=(cloud_q, results, stats["cloud"]),
                             daemon=True),
        ]
        t0 = time.perf_counter()
        for w in workers:
            w.start()
        for i, img in enumerate(images):
            in_q.put((i, np.asarray(img)))
        in_q.put(_DONE)
        for w in workers:
            w.join()
        wall = time.perf_counter() - t0
        n = len(images)
        tx_total = int(sum(r["tx_bytes"] for r in results.values()))
        return StreamReport(
            results=[results[i] for i in range(n)],
            wall_s=wall,
            throughput_rps=n / wall if wall > 0 else float("inf"),
            tx_bytes_total=tx_total,
            occupancy={k: s.busy_s / wall if wall > 0 else 0.0
                       for k, s in stats.items()},
            stages=stats,
        )
