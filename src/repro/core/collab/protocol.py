"""Length-prefixed tensor framing for the edge<->cloud hop (paper §3.3:
"intermediate features are transmitted to the cloud server through the
socket protocol").

Frame layout:
    magic  u32  = 0x52455052 ("REPR")
    ndim   u32
    dtype  16s  (numpy dtype str, ascii, NUL-padded)
    shape  ndim * u64
    nbytes u64
    payload
"""
from __future__ import annotations

import struct
from typing import BinaryIO, Tuple

import numpy as np

MAGIC = 0x52455052
_HDR = struct.Struct("<II16s")


def encode_tensor(arr: np.ndarray) -> bytes:
    arr = np.ascontiguousarray(arr)
    dt = arr.dtype.str.encode().ljust(16, b"\0")
    hdr = _HDR.pack(MAGIC, arr.ndim, dt)
    shape = struct.pack(f"<{arr.ndim}Q", *arr.shape)
    nbytes = struct.pack("<Q", arr.nbytes)
    return hdr + shape + nbytes + arr.tobytes()


def decode_tensor(buf: bytes) -> Tuple[np.ndarray, int]:
    """Returns (array, bytes_consumed)."""
    magic, ndim, dt = _HDR.unpack_from(buf, 0)
    if magic != MAGIC:
        raise ValueError("bad frame magic")
    off = _HDR.size
    shape = struct.unpack_from(f"<{ndim}Q", buf, off)
    off += 8 * ndim
    (nbytes,) = struct.unpack_from("<Q", buf, off)
    off += 8
    dtype = np.dtype(dt.rstrip(b"\0").decode())
    arr = np.frombuffer(buf, dtype, count=nbytes // dtype.itemsize,
                        offset=off).reshape(shape)
    return arr, off + nbytes


def write_tensor(fp: BinaryIO, arr: np.ndarray) -> int:
    data = encode_tensor(arr)
    fp.write(struct.pack("<Q", len(data)))
    fp.write(data)
    fp.flush()
    return len(data) + 8


def read_exact(fp: BinaryIO, n: int) -> bytes:
    chunks = []
    got = 0
    while got < n:
        chunk = fp.read(n - got)
        if not chunk:
            raise EOFError("peer closed")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def read_tensor(fp: BinaryIO) -> np.ndarray:
    (n,) = struct.unpack("<Q", read_exact(fp, 8))
    arr, _ = decode_tensor(read_exact(fp, n))
    return arr
