"""Length-prefixed tensor framing + feature codec for the edge<->cloud hop
(paper §3.3: "intermediate features are transmitted to the cloud server
through the socket protocol").

Raw frame layout (``encode_tensor``):
    magic  u32  = 0x52455052 ("REPR")
    ndim   u32
    dtype  16s  (numpy dtype str, ascii, NUL-padded)
    shape  ndim * u64
    nbytes u64
    payload

Feature-codec frame layout (``encode_feature``), negotiated *per frame* by
the leading magic word — a decoder calls ``decode_any`` and dispatches on
it, so raw-fp32 and codec peers interoperate without a handshake:
    magic  u32  = 0x46504552 ("REPF")
    codec  u8   (0 = fp32, 1 = fp16, 2 = int8 scale+zero-point)
    packed u8   (1 => only surviving channels of the last axis are shipped)
    ndim   u16  (of the LOGICAL full shape)
    shape  ndim * u64
    [packed]  keep bitmask over the last axis, ceil(shape[-1] / 8) bytes
    [int8]    scale f32, zero f32                  (x ~= q * scale + zero)
    nbytes u64
    payload

``decode_feature`` always reconstructs a float32 tensor at the logical full
shape, with zeros in the pruned (non-kept) channel slots — exactly what
masked execution produces — so a cloud submodel is agnostic to which codec
the edge picked for any given frame.

HELLO frame (``encode_hello``) — the deployment-contract handshake used by
``repro.serving``: the edge sends its ``DeploymentPlan`` digest on connect
and the cloud answers with its own digest plus an accept/reject status, so
a split/compact/codec mismatch between peers fails fast with
``PlanMismatchError`` instead of decoding garbage tensors:
    magic   u32  = 0x4F4C4548 ("HELO")
    version u16  (protocol version)
    status  u8   (0 = ok, 1 = digest mismatch — reply only)
    dlen    u8
    digest  dlen bytes (ascii hex, possibly empty for legacy peers)

RESPLIT frame (``encode_resplit``) — the live split-switch announcement
used by the adaptive controller: mid-connection, the edge proposes a new
split point and the cloud answers with accept/reject, after which both
peers swap their jitted sub-models *without reconnecting* (the cloud's
``start_layer`` becomes the edge's ``stop_layer``). Versioned like HELLO:
    magic   u32  = 0x4C505352 ("RSPL")
    version u16  (protocol version)
    status  u8   (0 = ok, 1 = split rejected — reply only)
    split   u16  (the proposed / acknowledged split point)

SEALED frame (``encode_sealed``) — integrity envelope around any data
frame, negotiated via the HELLO capability byte (``CAP_CRC``): a sealed
frame carries a request sequence number (u32, wraps) and the CRC32 of
the inner frame, so truncation and in-flight corruption surface as a
typed ``FrameIntegrityError`` instead of silently-wrong tensors, and a
reconnecting edge can replay an in-flight request and match the reply
by sequence number. Control frames (HELLO/RESPLIT/heartbeat) are never
sealed:
    magic   u32  = 0x46514553 ("SEQF")
    seq     u32  (request sequence number, wraps at 2**32)
    crc     u32  (CRC32 of the inner frame bytes)
    inner   the wrapped data frame (REPR / REPF / ...)

HEARTBEAT frame (``encode_heartbeat``) — one-way keepalive from edge to
cloud (no reply); a cloud serving a plan with a ``FaultPolicy`` whose
``heartbeat_s`` is set reaps clients idle for several intervals:
    magic   u32  = 0x42545248 ("HRTB")
    version u16  (protocol version)

DRAIN frame (``encode_drain``) — cloud-to-edge announcement that the
server is draining for a rolling restart: it stops admitting new
requests, flushes its batching lanes, and expects connected edges to
migrate to another fleet member mid-session (zero failed requests).
Versioned like HELLO:
    magic   u32  = 0x4E415244 ("DRAN")
    version u16  (protocol version)
    reason  u8   (0 = restart; reserved for future drain causes)

BUSY frame (``encode_busy``) — cloud-to-edge overload backpressure
reply sent instead of queueing a request on a saturated (bounded)
batching lane. Carries a shed-reason code mirroring the fleet
simulator's admission vocabulary and a redirect hint telling a
fleet-routed edge to retry the request on another healthy server:
    magic    u32  = 0x59535542 ("BUSY")
    version  u16  (protocol version)
    reason   u8   (shed reason code, 0 = "queue")
    redirect u8   (1 => retry on another fleet server)
"""
from __future__ import annotations

import struct
import zlib
from typing import BinaryIO, Dict, Optional, Tuple

import numpy as np

MAGIC = 0x52455052
FEATURE_MAGIC = 0x46504552
HELLO_MAGIC = 0x4F4C4548
RESPLIT_MAGIC = 0x4C505352
SEALED_MAGIC = 0x46514553
HEARTBEAT_MAGIC = 0x42545248
DRAIN_MAGIC = 0x4E415244
BUSY_MAGIC = 0x59535542
PROTOCOL_VERSION = 1
#: HELLO capability bit: peer understands sealed (CRC32 + seq) frames
CAP_CRC = 1
_HDR = struct.Struct("<II16s")
_FHDR = struct.Struct("<IBBH")
_HELLO = struct.Struct("<IHBB")
_RESPLIT = struct.Struct("<IHBH")
_SEALED = struct.Struct("<III")
_HEARTBEAT = struct.Struct("<IH")
_DRAIN = struct.Struct("<IHB")
_BUSY = struct.Struct("<IHBB")

#: BUSY shed-reason codes — the wire mirror of the fleet simulator's
#: admission vocabulary (``RequestRecord.shed_reason``); today only the
#: bounded-lane overflow reason exists on the socket path
BUSY_REASONS = {"queue": 0}
BUSY_REASON_NAMES = {v: k for k, v in BUSY_REASONS.items()}


class PlanMismatchError(ConnectionError):
    """The two peers of a split deployment disagree on the deployment
    contract (plan digest): split point, compaction, codec, or model shape.
    Raised by the HELLO handshake instead of letting the peers exchange
    undecodable / silently-wrong feature tensors."""


class FrameIntegrityError(ConnectionError):
    """A sealed frame failed its CRC32 check — the payload was corrupted
    or truncated in flight. Raised by ``decode_sealed`` instead of
    letting a flipped byte decode into silently-wrong tensors; the
    receiving peer treats the connection as compromised and the edge
    client retries the request on a fresh connection."""

CODEC_IDS = {"fp32": 0, "fp16": 1, "int8": 2}
CODEC_NAMES = {v: k for k, v in CODEC_IDS.items()}
#: wire bytes per element relative to raw fp32 — feeds the latency model's
#: T_TX pricing (see ``split_latency(tx_scale=...)``)
CODEC_TX_SCALE = {"fp32": 1.0, "fp16": 0.5, "int8": 0.25}
_CODEC_DTYPE = {"fp32": np.float32, "fp16": np.float16, "int8": np.uint8}


def encode_tensor(arr: np.ndarray) -> bytes:
    """Encode an ndarray as one self-describing raw tensor frame
    (``REPR`` magic + dtype + shape + payload). The returned length in
    bytes is what the runtimes report as ``tx_bytes`` when no feature
    codec is armed; the socket path's 8-byte length prefix is transport
    framing on top of this and is excluded from accounting."""
    arr = np.ascontiguousarray(arr)
    dt = arr.dtype.str.encode().ljust(16, b"\0")
    hdr = _HDR.pack(MAGIC, arr.ndim, dt)
    shape = struct.pack(f"<{arr.ndim}Q", *arr.shape)
    nbytes = struct.pack("<Q", arr.nbytes)
    return hdr + shape + nbytes + arr.tobytes()


def decode_tensor(buf: bytes) -> Tuple[np.ndarray, int]:
    """Decode one raw tensor frame -> (array, bytes consumed). The
    array is a zero-copy read-only view into ``buf``."""
    magic, ndim, dt = _HDR.unpack_from(buf, 0)
    if magic != MAGIC:
        raise ValueError("bad frame magic")
    off = _HDR.size
    shape = struct.unpack_from(f"<{ndim}Q", buf, off)
    off += 8 * ndim
    (nbytes,) = struct.unpack_from("<Q", buf, off)
    off += 8
    dtype = np.dtype(dt.rstrip(b"\0").decode())
    arr = np.frombuffer(buf, dtype, count=nbytes // dtype.itemsize,
                        offset=off).reshape(shape)
    return arr, off + nbytes


# ---------------------------------------------------------------------------
# feature codec (fp16 / int8 quantization + mask-aware channel packing)
# ---------------------------------------------------------------------------
def affine_qparams(mn: float, mx: float, levels: int) -> Tuple[float, float]:
    """Affine (scale, zero) mapping [mn, mx] onto the code points
    {0..levels}: dequant(q) = q * scale + zero. A degenerate range
    (mx == mn) gets scale 1.0 so round-tripping stays exact."""
    scale = (mx - mn) / float(levels) or 1.0
    return scale, mn


def affine_quantize(x: np.ndarray,
                    levels: int = 255) -> Tuple[np.ndarray, float, float]:
    """Min/max affine quantization onto uint8 code points {0..levels}
    -> (codes, scale, zero), with max-abs-error <= scale/2. This is the
    wire codec's int8 math (levels=255); the quantized edge path reuses
    it per weight channel (and with levels=15 for int4)."""
    mn = float(x.min()) if x.size else 0.0
    mx = float(x.max()) if x.size else 0.0
    scale, zero = affine_qparams(mn, mx, levels)
    q = np.clip(np.rint((x - zero) / scale), 0, levels).astype(np.uint8)
    return q, scale, zero


def encode_feature(arr: np.ndarray, codec: str = "fp32",
                   keep: Optional[np.ndarray] = None) -> bytes:
    """Encode an intermediate-feature tensor for the wire.

    ``keep`` — optional surviving-unit indices along the LAST axis (from
    ``repro.models.cnn.split_keep_indices``): only those slices are
    shipped; the decoder zero-fills the rest. ``codec`` picks the payload
    precision; int8 uses per-frame affine quantization (max-abs-error
    <= scale/2 where scale = (max-min)/255).
    """
    if codec not in CODEC_IDS:
        raise ValueError(f"unknown codec {codec!r} (use {list(CODEC_IDS)})")
    full_shape = arr.shape
    x = np.ascontiguousarray(arr, dtype=np.float32)
    packed = keep is not None
    if packed:
        keep = np.asarray(keep, np.int64)
        x = np.ascontiguousarray(x[..., keep])
    extra = b""
    if codec == "fp16":
        payload_arr = x.astype(np.float16)
    elif codec == "int8":
        payload_arr, scale, zero = affine_quantize(x, levels=255)
        extra = struct.pack("<ff", scale, zero)
    else:
        payload_arr = x
    payload = payload_arr.tobytes()
    hdr = _FHDR.pack(FEATURE_MAGIC, CODEC_IDS[codec], int(packed),
                     len(full_shape))
    shape = struct.pack(f"<{len(full_shape)}Q", *full_shape)
    pack_hdr = b""
    if packed:
        bits = np.zeros(full_shape[-1], np.uint8)
        bits[keep] = 1
        pack_hdr = np.packbits(bits).tobytes()
    return (hdr + shape + pack_hdr + extra
            + struct.pack("<Q", len(payload)) + payload)


def decode_feature(buf: bytes) -> Tuple[np.ndarray, int]:
    """Decode an ``encode_feature`` frame -> (float32 tensor, consumed).

    Pruned channels that were packed away come back as zeros, matching
    masked execution on the receiving submodel.
    """
    magic, codec_id, packed, ndim = _FHDR.unpack_from(buf, 0)
    if magic != FEATURE_MAGIC:
        raise ValueError("bad feature-frame magic")
    codec = CODEC_NAMES[codec_id]
    off = _FHDR.size
    full_shape = struct.unpack_from(f"<{ndim}Q", buf, off)
    off += 8 * ndim
    keep = None
    if packed:
        n_mask_bytes = (full_shape[-1] + 7) // 8
        bits = np.unpackbits(np.frombuffer(buf, np.uint8,
                                           count=n_mask_bytes, offset=off),
                             count=full_shape[-1])
        keep = np.nonzero(bits)[0]
        off += n_mask_bytes
    scale, zero = 1.0, 0.0
    if codec == "int8":
        scale, zero = struct.unpack_from("<ff", buf, off)
        off += 8
    (nbytes,) = struct.unpack_from("<Q", buf, off)
    off += 8
    dtype = np.dtype(_CODEC_DTYPE[codec])
    wire_shape = (full_shape[:-1] + (len(keep),)) if packed else full_shape
    raw = np.frombuffer(buf, dtype, count=nbytes // dtype.itemsize,
                        offset=off).reshape(wire_shape)
    if codec == "int8":
        x = raw.astype(np.float32) * scale + zero
    elif raw.dtype == np.float32:
        x = raw          # zero-copy (read-only view) on the fp32 hot path
    else:
        x = raw.astype(np.float32)
    if packed:
        out = np.zeros(full_shape, np.float32)
        out[..., np.asarray(keep, np.int64)] = x
        x = out
    return x, off + nbytes


# ---------------------------------------------------------------------------
# HELLO handshake (deployment-contract digest exchange)
# ---------------------------------------------------------------------------
def encode_hello(digest: str, status: int = 0,
                 version: int = PROTOCOL_VERSION, caps: int = 0) -> bytes:
    """Handshake frame carrying a plan digest (ascii hex, <= 255 chars).

    ``caps`` is an optional capability bitmask (``CAP_CRC`` => the peer
    speaks sealed CRC32+seq frames), appended as a single trailing byte
    only when non-zero. Legacy decoders slice the digest by ``dlen`` and
    ignore trailing bytes, so a caps-bearing HELLO is fully backward
    compatible — a legacy peer simply reads it as caps=0.
    """
    d = digest.encode("ascii")
    if len(d) > 255:
        raise ValueError("digest too long for HELLO frame")
    if not 0 <= caps <= 0xFF:
        raise ValueError("caps must fit one byte")
    tail = struct.pack("<B", caps) if caps else b""
    return _HELLO.pack(HELLO_MAGIC, version, status, len(d)) + d + tail


def decode_hello(buf: bytes) -> Tuple[str, int, int]:
    """Decode a HELLO frame -> (digest, status, version)."""
    magic, version, status, dlen = _HELLO.unpack_from(buf, 0)
    if magic != HELLO_MAGIC:
        raise ValueError("bad HELLO-frame magic")
    digest = buf[_HELLO.size:_HELLO.size + dlen].decode("ascii")
    return digest, status, version


def hello_caps(buf: bytes) -> int:
    """Capability bitmask of a HELLO frame; 0 for a legacy frame that
    carries no caps byte (pre-fault-tolerance peers)."""
    magic, _, _, dlen = _HELLO.unpack_from(buf, 0)
    if magic != HELLO_MAGIC:
        raise ValueError("bad HELLO-frame magic")
    off = _HELLO.size + dlen
    if len(buf) <= off:
        return 0
    return struct.unpack_from("<B", buf, off)[0]


def is_hello(buf: bytes) -> bool:
    """True when the frame's leading magic marks a HELLO handshake."""
    return (len(buf) >= 4
            and struct.unpack_from("<I", buf, 0)[0] == HELLO_MAGIC)


# ---------------------------------------------------------------------------
# RESPLIT control frame (live split-switch, no reconnect)
# ---------------------------------------------------------------------------
def encode_resplit(split: int, status: int = 0,
                   version: int = PROTOCOL_VERSION) -> bytes:
    """Control frame proposing (edge) or acknowledging (cloud) a new split
    point on the live connection."""
    if not 0 <= split <= 0xFFFF:
        raise ValueError(f"split {split} outside the u16 frame field")
    return _RESPLIT.pack(RESPLIT_MAGIC, version, status, split)


def decode_resplit(buf: bytes) -> Tuple[int, int, int]:
    """Decode a RESPLIT frame -> (split, status, version)."""
    magic, version, status, split = _RESPLIT.unpack_from(buf, 0)
    if magic != RESPLIT_MAGIC:
        raise ValueError("bad RESPLIT-frame magic")
    return split, status, version


def is_resplit(buf: bytes) -> bool:
    """True when the frame's leading magic marks a RESPLIT control frame."""
    return (len(buf) >= 4
            and struct.unpack_from("<I", buf, 0)[0] == RESPLIT_MAGIC)


# ---------------------------------------------------------------------------
# sealed frames (CRC32 + sequence number) and heartbeat keepalive
# ---------------------------------------------------------------------------
def encode_sealed(seq: int, inner: bytes) -> bytes:
    """Wrap a data frame in an integrity envelope: sequence number plus
    CRC32 of the inner bytes. The cloud echoes ``seq`` on its (sealed)
    response, letting a reconnecting edge replay an in-flight request
    and discard stale replies."""
    crc = zlib.crc32(inner) & 0xFFFFFFFF
    return _SEALED.pack(SEALED_MAGIC, seq & 0xFFFFFFFF, crc) + inner


def decode_sealed(buf: bytes) -> Tuple[int, bytes]:
    """Unwrap a sealed frame -> (seq, inner frame bytes).

    Raises ``FrameIntegrityError`` when the CRC32 does not match —
    corruption or truncation happened between the peers.
    """
    magic, seq, crc = _SEALED.unpack_from(buf, 0)
    if magic != SEALED_MAGIC:
        raise ValueError("bad sealed-frame magic")
    inner = bytes(buf[_SEALED.size:])
    if zlib.crc32(inner) & 0xFFFFFFFF != crc:
        raise FrameIntegrityError(
            f"sealed frame seq={seq} failed CRC32 check "
            f"({len(inner)} inner bytes)")
    return seq, inner


def is_sealed(buf: bytes) -> bool:
    """True when the frame's leading magic marks a sealed envelope."""
    return (len(buf) >= 4
            and struct.unpack_from("<I", buf, 0)[0] == SEALED_MAGIC)


def encode_heartbeat(version: int = PROTOCOL_VERSION) -> bytes:
    """One-way keepalive frame (edge -> cloud, no reply expected)."""
    return _HEARTBEAT.pack(HEARTBEAT_MAGIC, version)


def is_heartbeat(buf: bytes) -> bool:
    """True when the frame's leading magic marks a heartbeat keepalive."""
    return (len(buf) >= 4
            and struct.unpack_from("<I", buf, 0)[0] == HEARTBEAT_MAGIC)


def decode_heartbeat(buf: bytes) -> int:
    """Decode a heartbeat keepalive -> the sender's protocol version
    (the pack twin of ``encode_heartbeat``; raises on a non-heartbeat
    frame)."""
    magic, version = _HEARTBEAT.unpack_from(buf, 0)
    if magic != HEARTBEAT_MAGIC:
        raise ValueError("bad heartbeat-frame magic")
    return version


# ---------------------------------------------------------------------------
# DRAIN / BUSY control frames (fleet drain-migration and backpressure)
# ---------------------------------------------------------------------------
def encode_drain(reason: int = 0,
                 version: int = PROTOCOL_VERSION) -> bytes:
    """Control frame announcing the server is draining (rolling restart):
    it admits no new requests, flushes its lanes, and connected edges
    should migrate to another healthy fleet server mid-session."""
    if not 0 <= reason <= 0xFF:
        raise ValueError("drain reason must fit one byte")
    return _DRAIN.pack(DRAIN_MAGIC, version, reason)


def decode_drain(buf: bytes) -> Tuple[int, int]:
    """Decode a DRAIN frame -> (reason, version). A frame that is too
    short or carries the wrong magic raises ``ValueError`` (the bad-frame
    vocabulary every peer already classifies), never ``struct.error``."""
    if len(buf) < _DRAIN.size or not is_drain(buf):
        raise ValueError("bad DRAIN-frame magic")
    _, version, reason = _DRAIN.unpack_from(buf, 0)
    return reason, version


def is_drain(buf: bytes) -> bool:
    """True when the frame's leading magic marks a DRAIN control frame."""
    return (len(buf) >= 4
            and struct.unpack_from("<I", buf, 0)[0] == DRAIN_MAGIC)


def encode_busy(reason: str = "queue", redirect: bool = True,
                version: int = PROTOCOL_VERSION) -> bytes:
    """Overload-backpressure reply sent instead of queueing a request on
    a saturated bounded lane. ``reason`` is a fleet-simulator shed
    reason (``BUSY_REASONS``); ``redirect`` hints that a fleet-routed
    edge should replay the request on another healthy server."""
    if reason not in BUSY_REASONS:
        raise ValueError(
            f"unknown BUSY reason {reason!r} (use {list(BUSY_REASONS)})")
    return _BUSY.pack(BUSY_MAGIC, version, BUSY_REASONS[reason],
                      int(bool(redirect)))


def decode_busy(buf: bytes) -> Tuple[str, bool, int]:
    """Decode a BUSY frame -> (shed reason name, redirect hint, version).
    Too-short / wrong-magic frames raise ``ValueError`` (never
    ``struct.error``), and an unknown shed-reason id from a newer peer
    raises ``ValueError`` too, so the edge's recovery loop classifies it
    as a bad frame instead of crashing on a ``KeyError``."""
    if len(buf) < _BUSY.size or not is_busy(buf):
        raise ValueError("bad BUSY-frame magic")
    _, version, reason_id, redirect = _BUSY.unpack_from(buf, 0)
    if reason_id not in BUSY_REASON_NAMES:
        raise ValueError(f"unknown BUSY shed-reason id {reason_id}")
    return BUSY_REASON_NAMES[reason_id], bool(redirect), version


def is_busy(buf: bytes) -> bool:
    """True when the frame's leading magic marks a BUSY backpressure
    reply."""
    return (len(buf) >= 4
            and struct.unpack_from("<I", buf, 0)[0] == BUSY_MAGIC)


def decode_any(buf: bytes) -> Tuple[np.ndarray, int]:
    """Dispatch on the frame magic: raw tensor frame or codec frame
    (sealed envelopes are unwrapped — and CRC-checked — first)."""
    if is_sealed(buf):
        _, buf = decode_sealed(buf)
    (magic,) = struct.unpack_from("<I", buf, 0)
    if magic == FEATURE_MAGIC:
        return decode_feature(buf)
    return decode_tensor(buf)


def frame_lane(buf: bytes) -> str:
    """Wire-encoding lane tag of a tensor/feature frame, without decoding
    the payload: ``"raw"`` for a plain tensor frame, else the codec name
    with ``"+packed"`` appended when channel packing is on. The dynamic
    batching engine keys its per-lane queues on this (frames that took
    different wire paths are batched separately, so per-lane accounting
    stays attributable per encoding). Sealed envelopes are unwrapped
    first — the lane is a property of the inner data frame."""
    if is_sealed(buf):
        _, buf = decode_sealed(buf)
    (magic,) = struct.unpack_from("<I", buf, 0)
    if magic != FEATURE_MAGIC:
        return "raw"
    _, codec_id, packed, _ = _FHDR.unpack_from(buf, 0)
    return CODEC_NAMES[codec_id] + ("+packed" if packed else "")


def write_tensor(fp: BinaryIO, arr: np.ndarray) -> int:
    """Write one length-prefixed raw tensor frame to a binary stream;
    returns the total bytes written (payload + 8-byte prefix)."""
    data = encode_tensor(arr)
    fp.write(struct.pack("<Q", len(data)))
    fp.write(data)
    fp.flush()
    return len(data) + 8


def read_exact(fp: BinaryIO, n: int) -> bytes:
    """Read exactly ``n`` bytes from a binary stream (raises ``EOFError``
    if the peer closes early) — the stream twin of
    ``repro.core.collab.channel.recv_exact``."""
    chunks = []
    got = 0
    while got < n:
        chunk = fp.read(n - got)
        if not chunk:
            raise EOFError("peer closed")
        chunks.append(chunk)
        got += len(chunk)
    return b"".join(chunks)


def read_tensor(fp: BinaryIO) -> np.ndarray:
    """Read one length-prefixed raw tensor frame from a binary stream."""
    (n,) = struct.unpack("<Q", read_exact(fp, 8))
    arr, _ = decode_tensor(read_exact(fp, n))
    return arr
