"""Cross-client dynamic batching for the cloud peer (beyond-paper).

The paper's deployment serves one edge; ``serve_cloud`` grew a threaded
accept loop (one handler thread per connection), but each handler still
runs its own batch-1 jitted cloud call per frame — so with N concurrent
edges the cloud pays N dispatches (and their GIL/dispatch contention) per
"round" instead of one, and GPU-class hardware sits mostly idle between
launches. This module amortizes the cloud model invocation across
concurrent clients:

  * connection handlers stop calling ``cloud_fn`` directly and instead
    ``submit`` decoded feature tensors to a ``DynamicBatcher``;
  * requests are queued per **lane** — keyed by ``(split, wire lane,
    compact)`` — so tensors of different shapes or wire encodings are
    never fused and per-lane accounting stays attributable;
  * a scheduler thread per lane drains the queue with a short batching
    window: the first request opens a batch, then up to ``max_wait_ms``
    is spent topping it up to ``max_batch`` rows;
  * the batch is zero-padded to the next **bucket** shape (powers of two
    by default), so XLA compiles one executable per (split, bucket)
    instead of one per observed batch size — ``SplitFnBank.warm`` over
    splits x buckets means a live RESPLIT or a first burst never stalls
    on tracing;
  * ONE jitted cloud call runs the bucket, and the logits rows are
    scattered back to each request's future. Padded rows are sliced off
    before anything is returned.

Steady-state cloud throughput approaches ``max_batch / T_S`` instead of
``1 / T_S``. The batched executable maps the *batch-1 computation over
rows* (``jax.lax.map``), not a free reshape to a batched conv — XLA may
legally re-associate reductions under a different batch shape, and this
engine promises logits **bit-identical** to sequential batch-1 serving
(the property ``tests/test_batching.py`` pins down).

Knobs travel in ``DeploymentPlan.batching`` (a ``BatchingPolicy``),
digest-folded like the ``adaptive`` section: the bucket/warm set and the
in-order response pipelining are part of the deployment contract both
peers arm for. Plans without a ``batching`` section keep their digests.
"""
from __future__ import annotations

import queue
import threading
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Any, Dict, Hashable, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


def default_buckets(max_batch: int) -> Tuple[int, ...]:
    """Power-of-two bucket shapes 1, 2, 4, ... capped at ``max_batch``
    (which is always included, power of two or not)."""
    if max_batch < 1:
        raise ValueError("max_batch must be >= 1")
    out: List[int] = []
    b = 1
    while b < max_batch:
        out.append(b)
        b *= 2
    out.append(max_batch)
    return tuple(out)


def next_pow2_bucket(n: int) -> int:
    """Smallest power of two >= n — the default padded compilation shape
    when no explicit bucket set applies (shared by the engine's clients:
    ``CollabRunner.infer_batch``, the streaming micro-batcher)."""
    if n < 1:
        raise ValueError("bucket for < 1 rows")
    return 1 << (n - 1).bit_length()


def pad_rows(xs: np.ndarray, bucket: int) -> np.ndarray:
    """Zero-pad ``xs`` along the leading axis up to ``bucket`` rows (the
    one padding rule every bucketed call site shares — the padded rows
    are computed and discarded, never returned)."""
    n = xs.shape[0]
    if bucket <= n:
        return xs
    return np.concatenate(
        [xs, np.zeros((bucket - n,) + xs.shape[1:], xs.dtype)], axis=0)


def bucket_for(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket that holds ``n`` rows (buckets sorted ascending)."""
    for b in buckets:
        if b >= n:
            return b
    raise ValueError(f"batch of {n} rows exceeds the largest bucket "
                     f"{buckets[-1]}")


class LaneSaturated(RuntimeError):
    """A bounded lane queue is full: admitting this frame would stall
    the connection behind an overloaded server. ``serve_cloud`` answers
    the edge with a BUSY backpressure frame (shed reason ``"queue"``,
    mirroring the fleet simulator's admission semantics) so a
    fleet-routed edge redirects to another member instead of waiting."""


@dataclass(frozen=True)
class BatchingPolicy:
    """Serializable dynamic-batching knobs (the plan's ``batching``
    section).

    ``max_batch`` caps how many feature rows one cloud call fuses;
    ``max_wait_ms`` is the batching window — how long the scheduler holds
    the first request of a batch while topping it up (the latency price
    of throughput; 0 still fuses whatever is already queued);
    ``buckets`` are the padded compilation shapes (empty = powers of two
    up to ``max_batch``). ``max_queue`` bounds each lane's queue depth
    in frames: ``None`` (the default, and the historical behaviour)
    queues without bound, a positive bound makes ``submit`` raise
    ``LaneSaturated`` instead of stalling — the overload-backpressure
    contract behind the BUSY wire frame. Serialized only when set, so
    unbounded plans keep their digests.
    """
    max_batch: int = 8
    max_wait_ms: float = 3.0
    buckets: Tuple[int, ...] = ()
    max_queue: Optional[int] = None

    def __post_init__(self) -> None:
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.max_wait_ms < 0:
            raise ValueError("max_wait_ms must be >= 0")
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError("max_queue must be >= 1 (or None = unbounded)")
        if self.buckets:
            bs = tuple(int(b) for b in self.buckets)
            if sorted(set(bs)) != list(bs):
                raise ValueError("buckets must be sorted, unique, ascending")
            if bs[0] < 1:
                raise ValueError("buckets must be positive")
            if bs[-1] != self.max_batch:
                raise ValueError(f"largest bucket {bs[-1]} must equal "
                                 f"max_batch {self.max_batch}")
            object.__setattr__(self, "buckets", bs)

    @property
    def resolved_buckets(self) -> Tuple[int, ...]:
        """The effective bucket set (explicit, or powers of two)."""
        return self.buckets or default_buckets(self.max_batch)

    def to_json(self) -> Dict[str, Any]:
        """Serialize for ``plan.json`` (the digest-folded form); the
        lane bound is emitted only when set, so unbounded (historical)
        plans keep their digests byte-for-byte."""
        d = {"max_batch": self.max_batch,
             "max_wait_ms": self.max_wait_ms,
             "buckets": [int(b) for b in self.buckets]}
        if self.max_queue is not None:
            d["max_queue"] = self.max_queue
        return d

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "BatchingPolicy":
        mq = d.get("max_queue")
        return cls(max_batch=int(d["max_batch"]),
                   max_wait_ms=float(d["max_wait_ms"]),
                   buckets=tuple(int(b) for b in d.get("buckets", ())),
                   max_queue=int(mq) if mq is not None else None)


@dataclass
class LaneStats:
    """Per-lane accounting: how well the window is filling and how much
    padding the bucket shapes waste. ``batch_sizes`` keeps only the most
    recent cloud calls (bounded — a long-lived server must not leak)."""
    lane: Tuple
    rows: int = 0                 # real feature rows served
    frames: int = 0               # submitted frames (a frame may be B rows)
    batches: int = 0              # cloud calls
    padded_rows: int = 0          # zero rows added to reach the bucket
    busy_s: float = 0.0           # wall time inside the jitted cloud call
    failed_rows: int = 0          # rows whose future resolved to an error
    cancelled_frames: int = 0     # frames cancelled at drain/stop
    batch_sizes: "deque" = field(
        default_factory=lambda: deque(maxlen=256))

    @property
    def avg_batch(self) -> float:
        return self.rows / self.batches if self.batches else 0.0

    @property
    def padding_waste(self) -> float:
        """Fraction of computed rows that were padding."""
        total = self.rows + self.padded_rows
        return self.padded_rows / total if total else 0.0

    def to_json(self) -> Dict[str, Any]:
        """JSON-ready per-lane record (rows/frames/batches counts,
        ``busy_s`` seconds inside the jitted call, padding waste)."""
        return {"lane": list(map(str, self.lane)), "rows": self.rows,
                "frames": self.frames, "batches": self.batches,
                "padded_rows": self.padded_rows, "busy_s": self.busy_s,
                "failed_rows": self.failed_rows,
                "cancelled_frames": self.cancelled_frames,
                "avg_batch": self.avg_batch,
                "padding_waste": self.padding_waste,
                "batch_sizes": list(self.batch_sizes)[-64:]}


class _Lane:
    def __init__(self, key: Tuple):
        self.key = key
        self.q: "queue.Queue" = queue.Queue()
        self.stats = LaneStats(key)
        self.thread: Optional[threading.Thread] = None
        self.carry = None        # popped frame that must open the NEXT batch


class DynamicBatcher:
    """The cross-client dynamic batching engine.

    One instance per cloud server, built over the server's
    ``SplitFnBank`` (one deployed parameter set, jitted sub-model pairs
    per candidate split, batched variants per bucket). Handlers call
    ``submit(split, lane, x)`` and get a ``Future`` resolving to that
    frame's logits rows; a scheduler thread per lane fuses concurrent
    submissions into one padded, bucketed cloud call.

    ``submit`` accepts frames of any row count ``>= 1`` (a pipelined edge
    may ship multi-row frames); ``max_batch`` caps *rows* per cloud call.
    A frame wider than ``max_batch`` is rejected — the client should have
    chunked it.

    ``invoke_cost(split, bucket_rows)`` — optional hook charged once per
    cloud call (after the real compute): ``serve_cloud``'s simulated-
    server mode passes the analytic per-invocation device time here,
    serialized on the modeled accelerator, so colocated benchmarks
    measure the engine against the paper's hardware instead of this
    host's core count. Charged at the padded bucket size — the modeled
    device executes the padding too, which is what makes padding waste a
    physical quantity.
    """

    def __init__(self, bank, policy: BatchingPolicy,
                 invoke_cost: Optional[Any] = None):
        self.bank = bank
        self.policy = policy
        self.invoke_cost = invoke_cost
        self._lanes: Dict[Hashable, _Lane] = {}
        self._lock = threading.Lock()
        self._stop = threading.Event()

    # -- client side --------------------------------------------------------
    def submit(self, split: int, lane: str, x: np.ndarray) -> Future:
        """Queue a decoded feature tensor (rows of one frame) for the
        cloud sub-model at ``split``; returns a Future of its logits.
        With a bounded lane (``policy.max_queue``), raises
        ``LaneSaturated`` instead of queueing when the lane is already
        ``max_queue`` frames deep — the caller sheds with backpressure
        (the BUSY wire frame) rather than stalling the connection."""
        if self._stop.is_set():
            raise RuntimeError("batcher is stopped")
        x = np.asarray(x)
        rows = x.shape[0] if x.ndim > 0 else 1
        if rows > self.policy.max_batch:
            raise ValueError(f"frame has {rows} rows > max_batch "
                             f"{self.policy.max_batch}; chunk it client-side")
        key = (int(split), str(lane), bool(self.bank.compact))
        with self._lock:
            ln = self._lanes.get(key)
            if ln is None:
                ln = _Lane(key)
                ln.thread = threading.Thread(
                    target=self._scheduler, args=(ln,), daemon=True,
                    name=f"batcher-{key}")
                self._lanes[key] = ln
                ln.thread.start()
        if (self.policy.max_queue is not None
                and ln.q.qsize() + (1 if ln.carry is not None else 0)
                >= self.policy.max_queue):
            raise LaneSaturated(
                f"lane {key} is {self.policy.max_queue} frames deep")
        fut: Future = Future()
        ln.q.put((x, rows, fut))
        return fut

    # -- scheduler ----------------------------------------------------------
    def _collect(self, ln: _Lane) -> List[Tuple[np.ndarray, int, Future]]:
        """Block for the first request, then top the batch up (by rows)
        within the ``max_wait_ms`` window."""
        while True:
            if ln.carry is not None:
                first, ln.carry = ln.carry, None
            else:
                try:
                    first = ln.q.get(timeout=0.1)
                except queue.Empty:
                    if self._stop.is_set():
                        return []
                    continue
            if first is None:
                return []
            batch = [first]
            rows = first[1]
            deadline = time.monotonic() + self.policy.max_wait_ms / 1e3
            while rows < self.policy.max_batch:
                left = deadline - time.monotonic()
                try:
                    nxt = (ln.q.get_nowait() if left <= 0
                           else ln.q.get(timeout=left))
                except queue.Empty:
                    break
                if nxt is None:
                    ln.q.put(None)      # re-post for the outer loop
                    break
                if rows + nxt[1] > self.policy.max_batch:
                    # doesn't fit this bucket: hold it — it OPENS the next
                    # batch (re-queueing at the tail would let a steady
                    # stream of small frames starve a wide one forever)
                    ln.carry = nxt
                    break
                batch.append(nxt)
                rows += nxt[1]
            return batch

    def _scheduler(self, ln: _Lane) -> None:
        # a lane thread must never die with futures still queued — a
        # crash anywhere (collect, concatenate, bank build) fails every
        # request waiting on this lane instead of leaving them pending
        try:
            self._scheduler_loop(ln)
        except Exception as e:                           # noqa: BLE001
            self._fail_lane(ln, e)

    def _fail_lane(self, ln: _Lane, exc: Exception) -> None:
        """Resolve everything still queued on a crashed lane with
        ``exc`` — no request may wait forever on a dead scheduler."""
        items = []
        if ln.carry is not None:
            items.append(ln.carry)
            ln.carry = None
        while True:
            try:
                item = ln.q.get_nowait()
            except queue.Empty:
                break
            if item is not None:
                items.append(item)
        for _, n, fut in items:
            if not fut.done():
                fut.set_exception(exc)
                ln.stats.failed_rows += n

    def _scheduler_loop(self, ln: _Lane) -> None:
        split = ln.key[0]
        while not self._stop.is_set():
            batch = self._collect(ln)
            if not batch:
                return
            rows = sum(b[1] for b in batch)
            bucket = bucket_for(rows, self.policy.resolved_buckets)
            try:
                xs = pad_rows(np.concatenate([b[0] for b in batch],
                                             axis=0), bucket)
                _, cloud_fn, _ = self.bank.get(split, batch_bucket=bucket)
                t0 = time.perf_counter()
                out = np.asarray(cloud_fn(jnp.asarray(xs)))
                if self.invoke_cost is not None:
                    self.invoke_cost(split, bucket)
                dt = time.perf_counter() - t0
            except Exception as e:                       # noqa: BLE001
                for _, n, fut in batch:
                    if not fut.done():
                        fut.set_exception(e)
                        ln.stats.failed_rows += n
                continue
            st = ln.stats
            st.rows += rows
            st.frames += len(batch)
            st.batches += 1
            st.padded_rows += bucket - rows
            st.busy_s += dt
            st.batch_sizes.append(rows)
            off = 0
            for _, n, fut in batch:
                fut.set_result(out[off:off + n])
                off += n

    # -- lifecycle / reporting ----------------------------------------------
    def stop(self, timeout: float = 10.0) -> None:
        """Drain: schedulers finish their current batch, then exit.
        Futures still queued behind the sentinel are cancelled."""
        with self._lock:
            lanes = list(self._lanes.values())
        for ln in lanes:
            ln.q.put(None)
        self._stop.set()
        for ln in lanes:
            if ln.thread is not None:
                ln.thread.join(timeout)
        for ln in lanes:
            if ln.carry is not None:
                if not ln.carry[2].done() and ln.carry[2].cancel():
                    ln.stats.cancelled_frames += 1
                ln.carry = None
            while True:
                try:
                    item = ln.q.get_nowait()
                except queue.Empty:
                    break
                if item is not None and not item[2].done():
                    if item[2].cancel():
                        ln.stats.cancelled_frames += 1

    def pending(self) -> int:
        """Frames still sitting in lane queues (carry slots included) —
        0 after a drain, or the leak count the fault tests assert on."""
        with self._lock:
            lanes = list(self._lanes.values())
        return sum(ln.q.qsize() + (1 if ln.carry is not None else 0)
                   for ln in lanes)

    def stats(self) -> Dict[str, Dict]:
        """Per-lane accounting, JSON-ready, keyed by the lane tuple's
        string form; each record also carries the lane's live ``pending``
        queue depth (0 on a drained engine)."""
        with self._lock:
            out = {}
            for k, ln in self._lanes.items():
                rec = ln.stats.to_json()
                rec["pending"] = ln.q.qsize() + (
                    1 if ln.carry is not None else 0)
                out[str(k)] = rec
            return out
