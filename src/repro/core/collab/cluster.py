"""Fleet front tier — multi-server routing, health tracking, failover.

One ``CloudServer`` cannot carry a million-edge deployment: a single
process restart would drop every connected edge, and the only refuge
(PR 6) is degrading to edge-only inference. This module spreads edges
across N fleet servers and keeps collaborative serving available
through server loss, rolling restarts, and overload:

* ``RoutingPolicy`` — the serializable fleet description folded into
  ``DeploymentPlan`` (the ``routing`` section): the member ports plus
  the health thresholds (miss counts, dead-server retry interval).
* ``FleetRouter`` — the edge-side router. Assignment is
  rendezvous (highest-random-weight) hashing over the edge's wire
  **lane** key (``protocol.frame_lane`` vocabulary: ``"raw"``,
  ``"fp16+packed"``, ...), so every edge speaking one wire encoding
  lands on the same server and the dynamic batching engine's per-lane
  queues stay hot on one member instead of fragmenting fleet-wide.
  Health is tracked from observed transport outcomes (connect/request
  failures and heartbeat misses): ``miss count >= suspect`` demotes to
  *suspect* (still routable), ``>= dead`` removes the server from the
  ring; a dead server is re-probed after ``retry_dead_s`` so a
  restarted member heals back in without operator action.
* Degradation ladder (top to bottom): **reroute** to the next healthy
  member on death or a BUSY backpressure reply; **drain-migrate** on a
  DRAIN announcement (rolling restart, zero failed requests);
  **edge-only fallback** only when the whole fleet is gone
  (``FleetExhaustedError`` → the PR-6 ``SplitFnBank`` c=N pair).

All ``FleetRouter`` shared-mutable state is guarded by one lock and
registered with the ``repro.analysis`` lock-discipline gate.
"""
from __future__ import annotations

import hashlib
import threading
import time
from dataclasses import dataclass
from typing import Dict, Tuple

#: health states of a fleet member, in degradation order
STATE_HEALTHY = "healthy"
STATE_SUSPECT = "suspect"
STATE_DRAINING = "draining"
STATE_DEAD = "dead"
#: states the router will still hand out connections to
ROUTABLE_STATES = (STATE_HEALTHY, STATE_SUSPECT)


class FleetExhaustedError(ConnectionError):
    """Every fleet member is dead or draining — there is no server left
    to route to. The edge client catches this and serves the request
    locally (edge-only fallback), exactly like a single-server cloud
    death with the retry budget spent."""


@dataclass(frozen=True)
class RoutingPolicy:
    """Serializable fleet-routing contract (the plan's ``routing``
    section): which servers exist and when the router declares one
    suspect or dead.

    ``ports`` — fleet member ports (all on the plan's host).
    ``suspect_after_count`` / ``dead_after_count`` — consecutive
    transport misses (failed connects/requests, missed heartbeats)
    after which a member is demoted to suspect / removed from the
    routing ring.  ``retry_dead_s`` — seconds after which a dead member
    is offered again as a probe target, so a restarted server heals
    back into the ring.
    """

    ports: Tuple[int, ...] = ()
    suspect_after_count: int = 1
    dead_after_count: int = 2
    retry_dead_s: float = 5.0

    def __post_init__(self):
        object.__setattr__(self, "ports", tuple(int(p) for p in self.ports))
        if len(set(self.ports)) != len(self.ports):
            raise ValueError(f"duplicate fleet ports: {self.ports}")
        if self.suspect_after_count < 1:
            raise ValueError("suspect_after_count must be >= 1")
        if self.dead_after_count < self.suspect_after_count:
            raise ValueError(
                "dead_after_count must be >= suspect_after_count")
        if self.retry_dead_s <= 0:
            raise ValueError("retry_dead_s must be positive")

    def to_json(self) -> Dict:
        """JSON form for ``plan.json`` / the deployment contract."""
        return {
            "ports": list(self.ports),
            "suspect_after_count": self.suspect_after_count,
            "dead_after_count": self.dead_after_count,
            "retry_dead_s": self.retry_dead_s,
        }

    @classmethod
    def from_json(cls, doc: Dict) -> "RoutingPolicy":
        """Inverse of :meth:`to_json`."""
        return cls(ports=tuple(doc["ports"]),
                   suspect_after_count=int(doc["suspect_after_count"]),
                   dead_after_count=int(doc["dead_after_count"]),
                   retry_dead_s=float(doc["retry_dead_s"]))


def _rendezvous_score(key: str, port: int) -> int:
    """Deterministic highest-random-weight score of (lane key, member)."""
    h = hashlib.sha256(f"{key}|{port}".encode("ascii")).digest()
    return int.from_bytes(h[:8], "big")


class FleetRouter:
    """Edge-side fleet membership ring: consistent-hash routing plus
    miss-count health tracking (healthy → suspect → dead) and the
    drain/revive lifecycle used by rolling restarts.

    Thread-safe: every mutation of the per-server health maps happens
    under one internal lock (registered with the analysis gate), so a
    pipelined edge client's sender/receiver threads and the synchronous
    path can share one router.
    """

    def __init__(self, policy: RoutingPolicy, host: str = "127.0.0.1",
                 clock=time.monotonic):
        if not policy.ports:
            raise ValueError("RoutingPolicy has no fleet ports to route to")
        self.policy = policy
        self.host = host
        self._clock = clock
        self._lock = threading.Lock()
        self._state: Dict[int, str] = {p: STATE_HEALTHY for p in policy.ports}
        self._miss: Dict[int, int] = {p: 0 for p in policy.ports}
        self._dead_at_s: Dict[int, float] = {}
        self._routed: Dict[int, int] = {p: 0 for p in policy.ports}
        self._reroutes = 0

    # -- routing ------------------------------------------------------
    def _routable(self, now_s: float) -> Tuple[int, ...]:
        out = []
        for p in self.policy.ports:
            st = self._state[p]
            if st in ROUTABLE_STATES:
                out.append(p)
            elif (st == STATE_DEAD
                  and now_s - self._dead_at_s.get(p, now_s)
                  >= self.policy.retry_dead_s):
                out.append(p)      # probe: maybe it was restarted
        return tuple(out)

    def route(self, key: str,
              exclude: Tuple[int, ...] = ()) -> Tuple[str, int]:
        """Pick the fleet member for a lane key -> ``(host, port)``.

        Rendezvous hashing over the routable members: the same key maps
        to the same server until that server leaves the ring, and a
        member loss only remaps the lanes that lived there. ``exclude``
        deprioritizes members for this call (the server that just
        failed or answered BUSY) — a *preference*, not a filter: a
        lone routable member is still handed out so a single-server
        fleet keeps retrying it. Raises ``FleetExhaustedError`` only
        when nothing at all is routable — the caller degrades to
        edge-only inference.
        """
        now_s = self._clock()
        with self._lock:
            routable = self._routable(now_s)
            if not routable:
                raise FleetExhaustedError(
                    f"no routable fleet member for lane {key!r} "
                    f"(states: {dict(self._state)})")
            cands = [p for p in routable if p not in exclude] or list(routable)
            port = max(cands, key=lambda p: (_rendezvous_score(key, p), p))
            self._routed[port] += 1
            if exclude and port not in exclude:
                self._reroutes += 1
        return self.host, port

    # -- health tracking ----------------------------------------------
    def note_ok(self, port: int) -> None:
        """A request/heartbeat to ``port`` succeeded: reset its miss
        count and (unless draining) restore it to the healthy ring —
        this is how a dead-but-restarted member heals back in."""
        with self._lock:
            if port not in self._state:
                return
            self._miss[port] = 0
            if self._state[port] != STATE_DRAINING:
                self._state[port] = STATE_HEALTHY
                self._dead_at_s.pop(port, None)

    def note_miss(self, port: int) -> str:
        """A transport attempt to ``port`` failed (connect error, torn
        request, missed heartbeat): bump the miss count and demote
        through suspect to dead per the policy thresholds. Returns the
        member's new state."""
        now_s = self._clock()
        with self._lock:
            if port not in self._state:
                return STATE_DEAD
            self._miss[port] += 1
            if self._state[port] != STATE_DRAINING:
                if self._miss[port] >= self.policy.dead_after_count:
                    self._state[port] = STATE_DEAD
                    self._dead_at_s[port] = now_s
                elif self._miss[port] >= self.policy.suspect_after_count:
                    self._state[port] = STATE_SUSPECT
            return self._state[port]

    def note_drain(self, port: int) -> None:
        """The member announced DRAIN (rolling restart): take it out of
        the routing ring without counting it as a fault."""
        with self._lock:
            if port in self._state:
                self._state[port] = STATE_DRAINING

    def revive(self, port: int) -> None:
        """A drained/dead member finished restarting: put it straight
        back into the healthy ring."""
        with self._lock:
            if port in self._state:
                self._state[port] = STATE_HEALTHY
                self._miss[port] = 0
                self._dead_at_s.pop(port, None)

    # -- introspection ------------------------------------------------
    def state(self, port: int) -> str:
        """Current health state of one member."""
        with self._lock:
            return self._state.get(port, STATE_DEAD)

    def healthy_ports(self) -> Tuple[int, ...]:
        """Members the router would currently hand out (healthy or
        suspect; dead members past the retry window count as probes)."""
        now_s = self._clock()
        with self._lock:
            return self._routable(now_s)

    def stats(self) -> Dict:
        """Per-member rollup: state, miss/routed counts, plus the
        fleet-wide reroute count — merged into the serving benchmarks'
        fleet metrics."""
        with self._lock:
            return {
                "reroutes_count": self._reroutes,
                "servers": {
                    p: {"state": self._state[p],
                        "miss_count": self._miss[p],
                        "routed_count": self._routed[p]}
                    for p in self.policy.ports
                },
            }
