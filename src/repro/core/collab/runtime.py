"""Collaborative split-inference executors (paper §3.3 deployment).

.. note::
   **Internal layer.** The public front door for deployment is
   ``repro.serving``: build a ``DeploymentPlan`` (the serializable
   deployment contract) and open an ``InferenceSession`` via
   ``serving.connect(plan, backend="local"|"socket"|"streaming")``. The
   raw constructors below (``CollabRunner``, ``serve_cloud``,
   ``EdgeClient``, ``build_split_fns``) remain importable as thin
   compatibility shims but are considered internal/deprecated as direct
   entry points — they take the deployment contract as loose positional
   knobs and perform no peer-agreement check.

``CollabRunner`` — in-process: edge submodel -> (shaped) channel -> cloud
submodel, with the Eq. 5 timing breakdown measured per request. This is the
engine behind benchmarks fig5 and the Gradio-replacement CLI demo.

``serve_cloud`` / ``EdgeClient`` — real localhost TCP sockets with the
token-bucket shaper, mirroring the paper's socket deployment: the edge sends
the intermediate feature tensor, the cloud returns class logits.

The *compacted deployment path* (``compact=True``): pruning masks are
materialized via ``compact_params`` before the edge/cloud submodels are
jitted, so the deployed network is physically smaller — real FLOP and
wire-byte reduction rather than zeroed channels. The *feature codec*
(``codec=`` fp32 | fp16 | int8, plus mask-aware channel ``pack``-ing for
masked-but-dense deployments) shrinks T_TX bytes 2-4x; each frame carries
its own codec header, so the cloud decodes whatever each edge picked
per-frame (``decode_any``) with no connection-level handshake.

For overlapped (pipelined) streaming service of many requests, see
``repro.core.collab.streaming.StreamingCollabRunner`` (in-process) and
``EdgeClient.submit``/``collect`` (async socket path).

*Cross-client dynamic batching* (``serve_cloud(batching=...)``): handler
threads stop invoking the cloud sub-model per frame and submit decoded
features to the ``DynamicBatcher`` (``repro.core.collab.batching``) —
per-lane queues, a short batching window, power-of-two bucket padding,
ONE row-mapped jitted cloud call per fused batch, logits bit-identical
to the unbatched path. All connections of one server also share ONE
``LinkShaper`` token bucket for the bytes the server transmits, so N
concurrent edges contend for the modeled downlink instead of each
getting a private copy of it (each edge's uplink is still paced by that
edge's own radio — its private shaper).

*Adaptive split switching*: every executor resolves its sub-model
functions through a ``SplitFnBank`` — one deployed parameter set, a
jitted (edge_fn, cloud_fn) pair per candidate split — so changing the
split point at run time is a dictionary lookup, not a redeploy. The
socket pair switches live via the RESPLIT control frame
(``EdgeClient.resplit``): the edge announces the new split, the cloud
swaps its ``start_layer`` on the same connection, and the next request
already flows at the new partition. The decision logic (bandwidth
estimation + hysteresis) lives in ``repro.core.collab.adaptive``.

``tx_bytes`` is the transmitted frame *payload* in bytes — identical
across CollabRunner, EdgeClient, and the streaming runtime for the same
deployment; the socket executors' 8-byte length prefix is framing, not
payload, and is excluded.
"""
from __future__ import annotations

import queue
import socket
import struct
import threading
import time
from concurrent.futures import CancelledError, Future
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CNNConfig
from repro.core.collab.batching import (BatchingPolicy, DynamicBatcher,
                                        LaneSaturated, next_pow2_bucket,
                                        pad_rows)
from repro.core.collab.channel import (FaultInjector, LinkShaper,
                                       ShapedSocket, SimChannel,
                                       apply_send_fault, recv_exact)
from repro.core.collab.cluster import FleetExhaustedError, FleetRouter
from repro.core.collab.faults import (FaultPolicy, RequestTimeout,
                                      ServerBusy, ServerDraining,
                                      fault_record)
from repro.core.collab.protocol import (CAP_CRC, CODEC_TX_SCALE,
                                        PROTOCOL_VERSION,
                                        FrameIntegrityError,
                                        PlanMismatchError, decode_any,
                                        decode_busy, decode_drain,
                                        decode_heartbeat, decode_hello,
                                        decode_resplit,
                                        decode_sealed, decode_tensor,
                                        encode_busy, encode_drain,
                                        encode_feature, encode_heartbeat,
                                        encode_hello, encode_resplit,
                                        encode_sealed, encode_tensor,
                                        frame_lane, hello_caps, is_busy,
                                        is_drain, is_heartbeat,
                                        is_hello, is_resplit, is_sealed)
from repro.core.partition.profiles import (LinkProfile, LinkTrace,
                                           TwoTierProfile)
from repro.models.cnn import (cnn_apply, compact_params, split_keep_indices)


@dataclass
class RequestTiming:
    """Per-request accounting: ``t_*`` in seconds, ``tx_bytes`` the
    transmitted frame payload in bytes, ``e_edge_j`` the edge device's
    energy in joules (None on an un-metered deployment — i.e. no
    ``EnergyProfile`` attached)."""
    t_device: float
    t_tx: float
    t_server: float
    tx_bytes: int
    e_edge_j: Optional[float] = None

    @property
    def total(self) -> float:
        return self.t_device + self.t_tx + self.t_server


def _frame_io(sock: socket.socket, ch: Optional[ShapedSocket]):
    """(recv_exact, sendall) pair for a connection, shaped or raw."""
    rx = ch.recv_exact if ch else (lambda k: recv_exact(sock, k))
    tx = ch.sendall if ch else sock.sendall
    return rx, tx


def deploy_submodels(params, cfg: CNNConfig, masks=None,
                     compact: bool = False):
    """Resolve the deployed (params, cfg, masks) triple.

    ``compact=True`` materializes the pruning masks via ``compact_params``:
    the returned network is physically smaller and needs no masks at run
    time. Both peers of a split deployment must agree on this flag (the
    split-boundary tensor has compacted channel count)."""
    if compact:
        if not masks:
            raise ValueError(
                "compact=True requires pruning masks: a dense model has "
                "nothing to compact (pass compact=False, or provide the "
                "masks the plan was pruned with)")
        cparams, ccfg = compact_params(params, cfg, masks)
        return cparams, ccfg, None
    return params, cfg, masks


class SplitFnBank:
    """Jitted edge/cloud sub-model functions for *every* candidate split
    of one deployed network.

    The deployment (params, cfg, masks, compaction) is resolved once; each
    split's (edge_fn, cloud_fn, keep) triple is built on first request and
    cached, so an adaptive controller can switch splits mid-run with a
    dictionary lookup instead of a redeploy. Both peers of a socket
    deployment hold a bank over the same params, which is what makes the
    RESPLIT frame sufficient to move the partition without reconnecting.
    """

    def __init__(self, params, cfg: CNNConfig, masks=None,
                 compact: bool = False, pack: bool = False, quant=None):
        (self.dparams, self.deploy_cfg,
         self.dmasks) = deploy_submodels(params, cfg, masks, compact)
        self.pack = pack
        self.compact = compact
        #: optional ``QuantPolicy`` — when set, the EDGE closure of every
        #: split dispatches conv/dense through the masked-GEMM kernel
        #: over (possibly int8/int4-quantized) deployed weights; cloud
        #: halves stay fp32 dense (the server is not the device the
        #: paper quantizes for). Resolved eagerly so bank construction
        #: owns all quant state and the closures stay read-only.
        self.quant = quant
        if quant is not None:
            from repro.core.collab.quant import (quantize_params,
                                                 resolve_backend)
            self._qparams = quantize_params(self.dparams, self.deploy_cfg,
                                            quant)
            self._q_backend, self._q_interpret = resolve_backend(quant)
        self.n_layers = len(self.deploy_cfg.layers)
        self._fns: Dict[int, Tuple] = {}
        self._batched_fns: Dict[int, Tuple] = {}
        # serve_cloud handler threads share one bank: first-touch builds
        # of a (split, bucket) pair must not race the dict insert
        self._cache_lock = threading.Lock()
        #: traced-body counter — bumps once every time XLA (re)traces any
        #: sub-model function of this bank (a new split, a new batch
        #: bucket shape). ``warm`` followed by a steady count is the
        #: no-recompilation-in-steady-state regression guard.
        self.n_traces = 0

    def _build(self, split: int) -> Tuple:
        dparams, dcfg, dmasks = self.dparams, self.deploy_cfg, self.dmasks

        if self.quant is not None:
            from repro.core.collab.quant import quant_cnn_apply
            qp, qb, qi = self._qparams, self._q_backend, self._q_interpret

            def _edge(x):
                self.n_traces += 1      # runs at trace time only
                return quant_cnn_apply(qp, dcfg, x, masks=dmasks,
                                       stop_layer=split, backend=qb,
                                       interpret=qi)
        else:
            def _edge(x):
                self.n_traces += 1      # runs at trace time only
                return cnn_apply(dparams, dcfg, x, masks=dmasks,
                                 stop_layer=split)

        def _cloud(x):
            self.n_traces += 1          # runs at trace time only
            return cnn_apply(dparams, dcfg, jnp.asarray(x), masks=dmasks,
                             start_layer=split)

        edge_fn = jax.jit(_edge) if split > 0 else None
        cloud_fn = jax.jit(_cloud) if split < self.n_layers else None
        keep = (split_keep_indices(dcfg, dmasks, split)
                if self.pack and not self.compact else None)
        return edge_fn, cloud_fn, keep

    def _build_batched(self, split: int) -> Tuple:
        """Row-mapped variants: the batch-1 computation mapped over the
        leading axis (``jax.lax.map``) in ONE jitted call. Per-row results
        are bit-identical to the batch-1 functions — which a free
        reshape-to-batched conv would NOT guarantee (XLA may re-associate
        reductions under a different batch shape) — so the dynamic
        batching engine can promise batched == sequential logits exactly.
        """
        dparams, dcfg, dmasks = self.dparams, self.deploy_cfg, self.dmasks

        if self.quant is not None:
            from repro.core.collab.quant import quant_cnn_apply
            qp, qb, qi = self._qparams, self._q_backend, self._q_interpret

            def _edge_row(row):
                self.n_traces += 1      # runs at trace time only
                return quant_cnn_apply(qp, dcfg, row[None], masks=dmasks,
                                       stop_layer=split, backend=qb,
                                       interpret=qi)[0]
        else:
            def _edge_row(row):
                self.n_traces += 1      # runs at trace time only
                return cnn_apply(dparams, dcfg, row[None], masks=dmasks,
                                 stop_layer=split)[0]

        def _cloud_row(row):
            self.n_traces += 1          # runs at trace time only
            return cnn_apply(dparams, dcfg, row[None], masks=dmasks,
                             start_layer=split)[0]

        edge_fn = (jax.jit(lambda x: jax.lax.map(_edge_row, x))
                   if split > 0 else None)
        cloud_fn = (jax.jit(lambda x: jax.lax.map(_cloud_row,
                                                  jnp.asarray(x)))
                    if split < self.n_layers else None)
        keep = (split_keep_indices(dcfg, dmasks, split)
                if self.pack and not self.compact else None)
        return edge_fn, cloud_fn, keep

    def get(self, split: int, batch_bucket: Optional[int] = None):
        """(edge_fn, cloud_fn, keep) for ``split``; fns are None at the
        c=0 / c=N extremes. ``keep`` is the surviving-channel index set
        for the wire codec's packing — only set for masked-but-dense
        deployments (after compaction the dead channels are already gone
        from the tensor).

        ``batch_bucket`` selects the *bucketed* compilation cache: the
        returned pair is the row-mapped batched variant meant to be
        called at exactly that (padded) leading-axis size — one compiled
        executable per (split, bucket) shape, bit-identical per row to
        the batch-1 pair. ``None`` keeps the historical batch-1 pair."""
        if not 0 <= split <= self.n_layers:
            raise ValueError(f"split {split} outside [0, {self.n_layers}]")
        if batch_bucket is None:
            with self._cache_lock:
                if split not in self._fns:
                    self._fns[split] = self._build(split)
                return self._fns[split]
        if batch_bucket < 1:
            raise ValueError(f"batch_bucket must be >= 1, got {batch_bucket}")
        with self._cache_lock:
            if split not in self._batched_fns:
                self._batched_fns[split] = self._build_batched(split)
            return self._batched_fns[split]

    def warm(self, splits: Sequence[int], image: np.ndarray,
             edge_only: bool = False, buckets: Sequence[int] = (1,),
             cloud_only: bool = False) -> None:
        """Pre-jit (trace + compile) the edge/cloud pair of each candidate
        split by pushing one sample through, so a mid-run switch does not
        stall the first request at the new partition. ``edge_only`` skips
        compiling the cloud halves (the edge peer of a socket deployment
        never runs them).

        ``buckets`` additionally warms the row-mapped batched pair at
        each listed leading-axis size > 1 (splits x buckets), so a
        dynamic-batching server's first burst — or its first burst after
        a live RESPLIT — never stalls on tracing. ``cloud_only`` skips
        the batched *edge* halves there (the batching server executes
        only cloud sub-models; its batch-1 edge half still runs once to
        derive the split-boundary feature shape)."""
        for c in splits:
            edge_fn, cloud_fn, _ = self.get(c)
            feat = jnp.asarray(image)        # split-boundary tensor at c
            if edge_fn is not None:
                feat = edge_fn(feat)
                jax.block_until_ready(feat)
            if cloud_fn is not None and not edge_only:
                jax.block_until_ready(cloud_fn(feat))
            for b in buckets:
                if b <= 1:
                    continue
                edge_b, cloud_b, _ = self.get(c, batch_bucket=b)
                if edge_b is not None and not cloud_only:
                    tile = np.repeat(np.asarray(image), b, axis=0)
                    jax.block_until_ready(edge_b(jnp.asarray(tile)))
                if cloud_b is not None and not edge_only:
                    fb = np.repeat(np.asarray(feat), b, axis=0)
                    jax.block_until_ready(cloud_b(jnp.asarray(fb)))


def _warm_input(cfg: CNNConfig) -> np.ndarray:
    """A zero batch-1 sample at the model's input shape, for pre-jitting."""
    h, w = cfg.input_hw
    return np.zeros((1, h, w, cfg.input_channels), np.float32)


def build_split_fns(params, cfg: CNNConfig, split: int, masks=None,
                    compact: bool = False, pack: bool = False, quant=None):
    """One-stop deployment resolution shared by every executor: returns
    (edge_fn, cloud_fn, keep, deploy_cfg) for the given split (one-shot
    wrapper over ``SplitFnBank``)."""
    bank = SplitFnBank(params, cfg, masks, compact, pack, quant=quant)
    edge_fn, cloud_fn, keep = bank.get(split)
    return edge_fn, cloud_fn, keep, bank.deploy_cfg


class CollabRunner:
    """In-process split executor with simulated (or real-time) channel.

    ``compact`` deploys physically-pruned submodels; ``codec``/``pack``
    select the wire encoding of the split-boundary feature tensor (the
    payload is genuinely encoded and decoded, so lossy codecs see their
    true numerical effect and tx_bytes is the true frame size).
    """

    def __init__(self, params, cfg: CNNConfig, split: int,
                 profile: TwoTierProfile, masks=None,
                 realtime_channel: bool = False,
                 simulate_compute: bool = True,
                 compact: bool = False, codec: Optional[str] = None,
                 pack: bool = False, trace: Optional[LinkTrace] = None,
                 energy=None, faults: Optional[FaultInjector] = None,
                 quant=None):
        self.cfg = cfg
        self.split = split
        self.profile = profile
        self.masks = masks
        self.codec = codec
        self.compact = compact
        self.pack = pack
        self.channel = SimChannel(profile.link, realtime=realtime_channel,
                                  trace=trace, faults=faults)
        self.simulate_compute = simulate_compute
        #: optional ``EnergyProfile`` — when set, every RequestTiming
        #: carries ``e_edge_j`` (joules) priced from the same breakdown
        #: the timing reports (one formula for analytic and measured)
        self.energy = energy
        self._bank = SplitFnBank(params, cfg, masks, compact, pack,
                                 quant=quant)
        self.deploy_cfg = self._bank.deploy_cfg
        self.set_split(split)

    def _timing(self, t_device: float, t_tx: float, t_server: float,
                tx_bytes: int) -> RequestTiming:
        """Assemble one request's accounting record, energy-priced when
        the runner carries an ``EnergyProfile`` (RTT peeled off the
        uplink term and billed as waiting, per ``energy_breakdown``)."""
        e = (self.energy.request_energy(t_device, t_tx, t_server,
                                        rtt_s=self.profile.link.rtt_s)
             if self.energy is not None else None)
        return RequestTiming(t_device, t_tx, t_server, tx_bytes,
                             e_edge_j=e)

    def warm(self, splits: Sequence[int]) -> None:
        """Pre-jit every candidate's edge/cloud pair (batch-1 shape) so an
        adaptive switch doesn't stall its first request on compilation."""
        self._bank.warm(splits, _warm_input(self.cfg))

    def set_split(self, split: int) -> None:
        """Move the partition point (adaptive re-split): swap in the
        bank's jitted pair for ``split`` and re-price the analytic
        breakdown. The channel (and its virtual trace clock) carries over
        — the link doesn't reset because the deployment re-planned."""
        self._edge_fn, self._cloud_fn, self._keep = self._bank.get(split)
        self.split = split
        # analytic compute-time model for reporting at the paper's hardware
        from repro.core.partition.latency_model import (
            cnn_layer_costs, compacted_cnn_layer_costs, split_latency,
            cnn_input_bytes, wire_tx_scale)
        costs = (compacted_cnn_layer_costs(self.cfg, self.masks)
                 if self.compact else cnn_layer_costs(self.cfg, self.masks))
        # tx_scale composes the codec discount with the packing correction
        # so the analytic tx_bytes equals the measured wire payload
        self._analytic = split_latency(
            costs, split, self.profile, cnn_input_bytes(self.cfg),
            tx_scale=wire_tx_scale(self.cfg, self.masks, split,
                                   codec=self.codec, pack=self.pack,
                                   compact=self.compact))

    def _encode(self, x: np.ndarray) -> bytes:
        if self.codec is None and self._keep is None:
            return x.tobytes()          # legacy raw-payload accounting
        return encode_feature(x, codec=self.codec or "fp32",
                              keep=self._keep if x.ndim > 1 else None)

    def infer(self, image: np.ndarray) -> Dict:
        """image (B, H, W, C). Returns logits + RequestTiming.

        Wall-clock is measured for the actual CPU compute; the *reported*
        device/server terms come from the analytic profile when
        ``simulate_compute`` (the container has no i7/3090 pair), while the
        channel term is always charged per transmitted byte.
        """
        x = jnp.asarray(image)
        t0 = time.perf_counter()
        if self._edge_fn is not None:
            x = self._edge_fn(x)
            jax.block_until_ready(x)
        t1 = time.perf_counter()
        # a trace-driven channel keeps degrading during compute, so the
        # virtual clock must advance across the device time too
        if self.channel.trace is not None:
            self.channel.advance(self._analytic["T_D"] if
                                 self.simulate_compute else t1 - t0)
        if self._cloud_fn is not None:
            buf = self._encode(np.asarray(x))
            tx_bytes = len(buf)
            t_tx = self.channel.send(tx_bytes)
            if self.codec is not None or self._keep is not None:
                x = jnp.asarray(decode_any(buf)[0])
        else:
            tx_bytes, t_tx = 0, 0.0
        t2 = time.perf_counter()
        out = x
        if self._cloud_fn is not None:
            out = self._cloud_fn(x)
            jax.block_until_ready(out)
        t3 = time.perf_counter()
        if self.channel.trace is not None:
            self.channel.advance(self._analytic["T_S"] if
                                 self.simulate_compute else t3 - t2)
        if self.simulate_compute:
            timing = self._timing(self._analytic["T_D"], t_tx,
                                  self._analytic["T_S"], tx_bytes)
        else:
            timing = self._timing(t1 - t0, t_tx, t3 - t2, tx_bytes)
        # ARQ accounting from the channel: lost copies were retransmitted
        # by the modeled link layer, so the request was still served
        evs = (self.channel.last_send_events
               if self._cloud_fn is not None else ())
        return {"logits": np.asarray(out), "timing": timing,
                "wallclock": {"edge": t1 - t0, "cloud": t3 - t2},
                "fault": fault_record(
                    faults=len(evs),
                    retries=sum(1 for e in evs if e != "stall"))}

    def infer_batch(self, images: Sequence[np.ndarray],
                    bucket: Optional[int] = None) -> List[Dict]:
        """Serve a batch of requests through ONE edge call and ONE cloud
        call (the local fast path behind ``InferenceSession.infer_many``
        on a plan with a ``batching`` section).

        Results are **bit-identical per row** to batch-1 execution:
        compute is the bank's row-mapped batched pair (the batch-1
        computation mapped over rows, padded to ``bucket`` — next power
        of two by default — to bound recompilation), so single-row
        requests — the runtimes' standard granularity — match sequential
        ``infer`` bitwise. (A multi-row request's rows are computed as
        batch-1 rows; sequential ``infer`` of the same request would run
        one fused batch-B conv, which XLA does not keep bit-stable
        across batch shapes.) The wire step encodes/charges one frame
        *per request* exactly as the sequential loop does (per-frame
        int8 quantization scales and ``tx_bytes`` accounting are
        unchanged; only the dispatches are amortized)."""
        n = len(images)
        if n == 0:
            return []
        arrs = [np.asarray(im) for im in images]
        counts = [a.shape[0] for a in arrs]       # a request may be B rows
        offs = np.concatenate([[0], np.cumsum(counts)])
        rows = int(offs[-1])
        bucket = bucket or next_pow2_bucket(rows)
        if bucket < rows:
            raise ValueError(f"bucket {bucket} smaller than batch of "
                             f"{rows} rows")
        edge_b, cloud_b, _ = self._bank.get(self.split, batch_bucket=bucket)
        xs = pad_rows(np.concatenate(arrs, axis=0), bucket)
        t0 = time.perf_counter()
        feats = jnp.asarray(xs)
        if edge_b is not None:
            feats = edge_b(feats)
            jax.block_until_ready(feats)
        t1 = time.perf_counter()
        if self.channel.trace is not None:
            self.channel.advance(self._analytic["T_D"] if
                                 self.simulate_compute else t1 - t0)
        feats_np = np.asarray(feats)
        per_req: List[Tuple[int, float, Tuple[str, ...]]] = []
        if cloud_b is not None:
            decoded_frames = []
            for i in range(n):           # one frame per request, as infer()
                frame = feats_np[offs[i]:offs[i] + counts[i]]
                buf = self._encode(frame)
                t_tx = self.channel.send(len(buf))
                per_req.append((len(buf), t_tx,
                                self.channel.last_send_events))
                decoded_frames.append(decode_any(buf)[0]
                                      if (self.codec is not None
                                          or self._keep is not None)
                                      else frame)
            decoded = pad_rows(np.concatenate(
                [np.asarray(r) for r in decoded_frames], axis=0), bucket)
            t2 = time.perf_counter()
            out = cloud_b(jnp.asarray(decoded))
            jax.block_until_ready(out)
            t3 = time.perf_counter()
        else:
            per_req = [(0, 0.0, ())] * n
            t2 = t3 = time.perf_counter()
            out = feats
        if self.channel.trace is not None:
            self.channel.advance(self._analytic["T_S"] if
                                 self.simulate_compute else t3 - t2)
        out = np.asarray(out)
        results = []
        for i in range(n):
            nbytes, t_tx, evs = per_req[i]
            if self.simulate_compute:
                timing = self._timing(self._analytic["T_D"], t_tx,
                                      self._analytic["T_S"], nbytes)
            else:
                timing = self._timing((t1 - t0) / n, t_tx,
                                      (t3 - t2) / n, nbytes)
            results.append({"logits": out[offs[i]:offs[i] + counts[i]],
                            "timing": timing,
                            "wallclock": {"edge": t1 - t0,
                                          "cloud": t3 - t2},
                            "fault": fault_record(
                                faults=len(evs),
                                retries=sum(1 for e in evs
                                            if e != "stall"))})
        return results


# ---------------------------------------------------------------------------
# real-socket deployment (localhost stand-in for the paper's Wi-Fi pair)
# ---------------------------------------------------------------------------
def serve_cloud(params, cfg: CNNConfig, split: int, port: int,
                masks=None, link: Optional[LinkProfile] = None,
                max_requests: Optional[int] = None,
                ready: Optional[threading.Event] = None,
                compact: bool = False, host: str = "127.0.0.1",
                max_clients: Optional[int] = 1,
                stop: Optional[threading.Event] = None,
                plan_digest: Optional[str] = None,
                resplit_candidates: Optional[Sequence[int]] = None,
                trace: Optional[LinkTrace] = None,
                batching: Optional[BatchingPolicy] = None,
                batch_stats: Optional[Dict] = None,
                simulate_server=None,
                fault_policy: Optional[FaultPolicy] = None,
                faults: Optional[FaultInjector] = None,
                fault_stats: Optional[Dict] = None,
                die: Optional[threading.Event] = None,
                drain: Optional[threading.Event] = None,
                quant=None) -> None:
    """Cloud-side loop: accept edge connections, answer frames.

    A threaded accept loop serves each connection in its own handler
    thread, so one cloud process serves many edges concurrently.
    ``max_clients`` bounds how many connections are accepted before the
    loop drains and returns (default 1 — the paper's single-edge
    deployment and the historical behaviour); ``None`` accepts until the
    ``stop`` event is set. ``max_requests`` is a per-connection limit.

    All connections draw tokens from ONE ``LinkShaper`` (one token bucket
    per server): N concurrent edges contend for the server's modeled
    transmit path (the logits downlink) instead of each connection
    getting a private copy of it. Uplink pacing stays per-edge — each
    edge device shapes its own sends with its own radio's bucket; what
    this fixes is the server side multiplying ITS link by the number of
    connections.

    Frames are decoded via ``decode_any``: the edge negotiates the codec
    per frame through the frame header (raw fp32, fp16, int8, packed), so
    a single server loop accepts them all. ``compact=True`` serves the
    physically-pruned submodel (the connecting edge must match).

    ``plan_digest`` arms the HELLO handshake: an edge that opens with a
    HELLO frame has its plan digest compared against ours, and a mismatch
    is answered with a reject status before the connection closes — the
    contract check behind ``repro.serving``. Edges that skip the HELLO
    (legacy clients) are served unchecked.

    A RESPLIT control frame moves the connection's split point live: the
    handler swaps its cloud sub-model (``SplitFnBank`` lookup — the bank
    holds every candidate over the same deployed params) and acks, all on
    the same connection. Split state is per-connection, so concurrent
    edges can sit at different partitions. ``resplit_candidates``
    restricts which splits are accepted (the plan's adaptive section);
    ``None`` accepts any split valid for the deployed network.
    ``trace`` makes the shaper's rate follow a time-varying link.

    ``batching`` arms the cross-client dynamic batching engine
    (``repro.core.collab.batching``): handlers submit decoded feature
    tensors to per-lane queues instead of running ``cloud_fn`` per frame,
    a per-connection writer thread ships responses back in order while
    the handler keeps reading (so one pipelining edge can fill a batch by
    itself), and ONE bucketed jitted cloud call serves each fused batch —
    with logits bit-identical per row to batch-1 execution (i.e. the
    unbatched path, for the standard single-row frames; a multi-row
    frame's rows are computed as batch-1 rows, not one fused conv, and a
    frame wider than ``max_batch`` bypasses the engine). ``batch_stats``
    (a dict) receives the engine's per-lane accounting when the server
    shuts down.

    ``simulate_server`` (a ``ComputeProfile``) charges every cloud
    invocation the analytic ``batched_server_time`` on that hardware,
    serialized server-wide — the modeled accelerator executes one batch
    at a time, like ``CollabRunner``'s ``simulate_compute`` this
    container stands in for. Colocated benchmarks use it to measure the
    batching engine against the paper's 3090 rather than against this
    host's core count (on which N real batch-1 calls may parallelize in
    ways the target device cannot). Real compute still runs first, so
    logits and bit-identity are unaffected.

    Fault tolerance: an edge whose HELLO advertises ``CAP_CRC`` gets
    sealed (CRC32 + sequence-number) data frames both ways — a
    corrupted request surfaces as ``FrameIntegrityError`` and closes
    the connection (the edge retries on a fresh one), and every data
    response echoes the request's sequence number so a reconnecting
    edge can replay and match. ``fault_policy`` (the plan's ``faults``
    section) arms idle-client reaping: a connection silent for
    ``3 * heartbeat_s`` is closed (edges send HEARTBEAT keepalives
    between requests to stay alive). Setting ``stop`` now performs a
    *graceful drain* — handlers stop reading but flush every queued /
    batched response before closing — while the ``die`` event is the
    crash lever (connections dropped mid-frame, for fault drills).
    ``faults`` injects the schedule's faults into this server's *data
    responses* (drop/corrupt/stall/disconnect, plus ``die`` = kill the
    whole server); ``fault_stats`` (a dict) receives classified error
    counters (``reaped_conns``, ``integrity_errors``, ``conn_errors``,
    ``bad_frames``, ``writer_errors``, ``abandoned_futures``,
    ``heartbeats``, ``busy_shed``, ``drain_redirects``) at shutdown.

    Fleet membership: the ``drain`` event is the rolling-restart lever —
    once set, every *new* data request is answered with a DRAIN control
    frame instead of being served (in-flight batched work still
    completes), telling fleet-routed edges to migrate to another member
    mid-session with zero failed requests; ``stop`` afterwards flushes
    and exits as usual. With a bounded batching lane
    (``BatchingPolicy.max_queue``), a request that would overflow the
    lane queue is answered with a BUSY backpressure frame (shed reason
    ``"queue"``, mirroring the fleet simulator's admission vocabulary)
    instead of stalling the connection.
    """
    bank = SplitFnBank(params, cfg, masks, compact, quant=quant)
    charge = None
    if simulate_server is not None:
        from repro.core.partition.latency_model import (
            batched_server_time, cnn_layer_costs, compacted_cnn_layer_costs)
        sim_costs = (compacted_cnn_layer_costs(cfg, masks)
                     if compact else cnn_layer_costs(cfg, masks))
        device_lock = threading.Lock()

        def charge(c: int, rows: int) -> None:
            dt = batched_server_time(sim_costs, c, simulate_server, rows)
            with device_lock:            # one modeled accelerator
                time.sleep(dt)

    engine = (DynamicBatcher(bank, batching, invoke_cost=charge)
              if batching else None)
    warm_splits = list(resplit_candidates or ())
    if batching and split not in warm_splits:
        warm_splits.append(split)
    if warm_splits:
        # pre-jit every (candidate split x batch bucket) pair so a live
        # RESPLIT or the first concurrent burst doesn't stall its first
        # request on compilation (the edge blocks on recv meanwhile)
        bank.warm(warm_splits, _warm_input(cfg),
                  buckets=batching.resolved_buckets if batching else (1,),
                  cloud_only=True)
    shaper = LinkShaper(link, trace=trace) if link or trace else None
    _die = die if die is not None else threading.Event()
    stats_lock = threading.Lock()
    # signalled by every handler on exit so a max_clients-saturated
    # accept loop wakes the instant a slot frees instead of polling
    slot_free = threading.Event()

    def _count(key: str, n: int = 1) -> None:
        if fault_stats is None:
            return
        with stats_lock:
            fault_stats[key] = fault_stats.get(key, 0) + n

    def _handle(conn: socket.socket, rec: Dict) -> None:
        ch = (ShapedSocket(conn, link, trace=trace, shaper=shaper)
              if shaper is not None else None)
        rx, tx = _frame_io(conn, ch)
        cur_split = split
        _, cloud_fn, _ = bank.get(cur_split)
        served = 0
        # idle-client reaping: with a heartbeat interval armed, a client
        # silent for several intervals is presumed dead and its slot is
        # reclaimed (socket.timeout below)
        if fault_policy is not None and fault_policy.heartbeat_s > 0:
            conn.settimeout(3.0 * fault_policy.heartbeat_s)

        def _inject(frame: bytes) -> Optional[bytes]:
            """Server-side fault injection on one outgoing data frame."""
            if faults is None:
                return frame
            ev = faults.next_event()
            if ev is None:
                return frame
            if ev.kind == "die":
                # the cloud process is killed: stop accepting, and the
                # accept loop hard-drops every connection mid-frame
                _die.set()
                if stop is not None:
                    stop.set()
                raise ConnectionResetError("injected fault: die")
            return apply_send_fault(ev, frame, conn)

        # -- in-order response pipeline (batching mode) ---------------------
        # The handler thread keeps reading frames and submitting them to
        # the batcher; this writer drains ("ctl", bytes) and ("data",
        # seq, future|bytes) items in arrival order, so responses never
        # reorder even though batches complete asynchronously.
        resp_q: Optional[queue.Queue] = queue.Queue() if engine else None

        def _writer() -> None:
            try:
                while True:
                    item = resp_q.get()
                    if item is None:
                        return
                    if item[0] == "ctl":
                        tx(struct.pack("<Q", len(item[1])) + item[1])
                        continue
                    _, seq, val = item
                    payload = (encode_tensor(np.asarray(val.result()))
                               if isinstance(val, Future) else val)
                    frame = (encode_sealed(seq, payload)
                             if seq is not None else payload)
                    frame = _inject(frame)
                    if frame is None:
                        continue             # injected drop
                    tx(struct.pack("<Q", len(frame)) + frame)
            except (EOFError, ConnectionError, OSError):
                _count("conn_errors")
                try:
                    conn.shutdown(socket.SHUT_RDWR)      # unblock reader
                except OSError:
                    pass
            except (CancelledError, Exception):          # noqa: BLE001
                # a batch failed (or was cancelled at drain): there is no
                # payload to answer with — drop the connection so the
                # edge retries on a fresh one, and record why
                _count("writer_errors")
                try:
                    conn.shutdown(socket.SHUT_RDWR)
                except OSError:
                    pass

        writer = None
        if engine is not None:
            writer = threading.Thread(target=_writer, daemon=True)
            writer.start()

        def _respond_ctl(payload: bytes) -> None:
            if resp_q is not None:
                resp_q.put(("ctl", payload))
            else:
                tx(struct.pack("<Q", len(payload)) + payload)

        def _respond_data(payload: bytes, seq: Optional[int]) -> None:
            if resp_q is not None:
                resp_q.put(("data", seq, payload))
                return
            frame = (encode_sealed(seq, payload)
                     if seq is not None else payload)
            frame = _inject(frame)
            if frame is not None:
                tx(struct.pack("<Q", len(frame)) + frame)

        try:
            while max_requests is None or served < max_requests:
                (n,) = struct.unpack("<Q", rx(8))
                buf = rx(n)
                if is_heartbeat(buf):
                    # keepalive only, not a request; decode validates
                    # magic+version so a truncated frame counts as bad
                    decode_heartbeat(buf)
                    _count("heartbeats")
                    continue
                seq: Optional[int] = None
                if is_sealed(buf):
                    seq, buf = decode_sealed(buf)   # CRC-checked
                if is_hello(buf):
                    peer, _, pver = decode_hello(buf)
                    peer_caps = hello_caps(buf)
                    ok = (pver == PROTOCOL_VERSION
                          and (plan_digest is None or peer == plan_digest))
                    # capability echo: sealed frames are armed only when
                    # BOTH peers advertise CAP_CRC (legacy edges send no
                    # caps byte and keep the unsealed wire format)
                    _respond_ctl(encode_hello(
                        plan_digest or "", status=0 if ok else 1,
                        caps=CAP_CRC if peer_caps & CAP_CRC else 0))
                    if not ok:
                        return              # contract mismatch: fail fast
                    rec["claimed"] = True   # handshake is not a request
                    continue
                if is_resplit(buf):
                    want, _, pver = decode_resplit(buf)
                    ok = (pver == PROTOCOL_VERSION
                          and 0 <= want <= bank.n_layers
                          and (resplit_candidates is None
                               or want in resplit_candidates))
                    if ok:
                        cur_split = want
                        _, cloud_fn, _ = bank.get(want)
                    _respond_ctl(encode_resplit(want, status=0 if ok else 1))
                    rec["claimed"] = True   # control frame, not a request
                    continue
                if drain is not None and drain.is_set():
                    # rolling restart: stop admitting — answer DRAIN so
                    # a fleet-routed edge migrates and replays elsewhere
                    # (in-flight batched work still flushes via stop)
                    _count("drain_redirects")
                    _respond_ctl(encode_drain())
                    rec["claimed"] = True
                    continue
                arr, _ = decode_any(buf)
                rows = int(np.asarray(arr).shape[0]) if arr.ndim else 1
                if (engine is not None and cur_split < bank.n_layers
                        and rows <= batching.max_batch):
                    try:
                        fut = engine.submit(cur_split, frame_lane(buf),
                                            np.asarray(arr))
                    except LaneSaturated:
                        # bounded lane overflow: shed with backpressure
                        # instead of stalling the connection — the edge
                        # redirects to another fleet member (or backs
                        # off) and replays the request
                        _count("busy_shed")
                        _respond_ctl(encode_busy("queue"))
                        rec["claimed"] = True
                        continue
                    resp_q.put(("data", seq, fut))
                else:
                    # no engine, c=N passthrough, or a frame wider than
                    # any bucket — serve it exactly like the unbatched
                    # server would (batch-1 fns accept any leading dim)
                    logits = np.asarray(
                        cloud_fn(arr) if cloud_fn is not None
                        else arr)  # c=N: edge sent the logits
                    if charge is not None and cloud_fn is not None:
                        charge(cur_split, rows)
                    _respond_data(encode_tensor(logits), seq)
                served += 1
                rec["claimed"] = True
        except FrameIntegrityError:
            # corrupted/truncated request frame: the stream can no longer
            # be trusted — close; the edge retries on a fresh connection
            _count("integrity_errors")
        except socket.timeout:
            _count("reaped_conns")          # idle past the heartbeat window
        except (EOFError, ConnectionError, OSError):
            _count("conn_errors")           # peer went away mid-stream
        except ValueError:
            _count("bad_frames")            # garbage magic / header
        finally:
            if writer is not None:
                resp_q.put(None)
                writer.join(timeout=30)
                # fail anything the dead writer left behind: a future
                # still pending is cancelled (its edge will retry), a
                # failed one is observed so it never warns unretrieved
                leaked = 0
                while True:
                    try:
                        item = resp_q.get_nowait()
                    except queue.Empty:
                        break
                    if (item is not None and item[0] == "data"
                            and isinstance(item[2], Future)):
                        fut = item[2]
                        if not fut.done():
                            fut.cancel()
                            leaked += 1
                        elif not fut.cancelled():
                            fut.exception()
                if leaked:
                    _count("abandoned_futures", leaked)
            conn.close()
            slot_free.set()     # wake a max_clients-saturated accept loop

    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    srv.bind((host, port))
    srv.listen(16)
    srv.settimeout(0.2)
    if ready is not None:
        ready.set()
    # (thread, conn, rec) per in-flight connection; finished handlers are
    # reaped each loop turn. A connection "claims" a max_clients slot only
    # once it completes a handshake or serves a request — a stray probe,
    # a connect-and-drop, or a handshake-rejected peer can't drain a
    # bounded server before the legitimate edge connects.
    pending: List = []
    done_ok = 0
    try:
        while True:
            if (stop is not None and stop.is_set()) or _die.is_set():
                break
            live = []
            for w, c, rec in pending:
                if w.is_alive():
                    live.append((w, c, rec))
                elif rec["claimed"]:
                    done_ok += 1
            pending = live
            if max_clients is not None:
                claimed = done_ok + sum(1 for _, _, rec in pending
                                        if rec["claimed"])
                if claimed >= max_clients:
                    if not pending:
                        break               # budget served and drained
                    # block until a handler exits (slot release is
                    # immediate — no polling); the timeout only bounds
                    # how long a stop/die signal waits to be noticed
                    slot_free.wait(0.2)
                    slot_free.clear()
                    continue
            try:
                conn, _ = srv.accept()
            except socket.timeout:
                continue
            rec = {"claimed": False}
            w = threading.Thread(target=_handle, args=(conn, rec),
                                 daemon=True)
            w.start()
            pending.append((w, conn, rec))
    finally:
        srv.close()
        if _die.is_set():
            # crash semantics: drop every connection mid-frame (the
            # fault drills' "cloud process death")
            for _, c, _ in pending:
                try:
                    c.close()
                except OSError:
                    pass
        elif stop is not None and stop.is_set():
            # graceful drain: stop READING (handlers see EOF and exit
            # their loop) but keep the write side open, so each
            # handler's writer flushes every queued / batched response
            # before the connection closes — no abandoned futures
            for _, c, _ in pending:
                try:
                    c.shutdown(socket.SHUT_RD)
                except OSError:
                    pass
        for w, _, _ in pending:
            w.join(timeout=10)
        if engine is not None:
            engine.stop()
            if batch_stats is not None:
                batch_stats.update(engine.stats())


class EdgeClient:
    """Edge side: run layers [0, split), ship features, await logits.

    ``host``/``timeout`` make a real two-machine deployment expressible
    (``repro.serving`` plumbs them from the plan's link section);
    ``plan_digest`` arms the HELLO contract handshake against the cloud.

    Two call styles:
      * ``infer(image)`` — synchronous request/response (the paper's loop);
      * ``submit(image)`` / ``collect(count)`` — pipelined: a sender thread
        runs edge compute + transmission while a receiver thread drains
        responses, so edge compute of request i+1 overlaps the network and
        cloud time of request i. Results come back in submission order.
    Do not interleave ``infer`` with outstanding ``submit``s.

    ``resplit(split)`` moves the partition point on the live connection
    (RESPLIT control frame + ack): the local edge sub-model and the cloud
    peer's ``start_layer`` swap together without reconnecting — the hook
    the adaptive split controller drives when the measured link drifts.

    Fault tolerance (``fault_policy``): every socket read carries the
    per-request deadline (a dead cloud raises ``RequestTimeout`` instead
    of blocking forever); with a policy armed, ``infer`` survives frame
    corruption (CRC), timeouts, and mid-stream disconnects by
    reconnecting — exponential backoff with deterministic jitter,
    re-HELLO, re-RESPLIT to the current split — and replaying the
    in-flight request under its sequence number. When the retry budget
    or deadline is exhausted, ``fallback="edge"`` serves the request
    locally from the bank's c=N pair (logits bit-identical to an
    all-edge deployment). Every ``infer`` result carries the uniform
    ``fault`` record (``{faults, retries, migrations, fallback}``);
    ``faults=`` attaches a client-side ``FaultInjector`` applied to
    outgoing data frames (tests/benchmarks).

    Fleet routing (``router``): with a ``FleetRouter`` attached, every
    (re)connect asks the router for the target server — rendezvous-
    hashed over this client's wire *lane* key, so same-encoding edges
    share a server and its batching lanes stay hot. Transport faults
    feed the router's health tracking (miss-count → suspect → dead) and
    the recovery loop reroutes to the next healthy member; a DRAIN
    reply migrates without spending the fault budget (rolling restart),
    a BUSY reply redirects off a saturated lane; edge-only fallback
    engages only when no routable member remains
    (``FleetExhaustedError``). ``sleep_fn`` makes the backoff sleeps
    injectable (tests run recovery in milliseconds of wall-clock).
    """

    def __init__(self, params, cfg: CNNConfig, split: int, port: int,
                 masks=None, link: Optional[LinkProfile] = None,
                 compact: bool = False, codec: Optional[str] = None,
                 pack: bool = False, host: str = "127.0.0.1",
                 timeout: float = 30.0,
                 plan_digest: Optional[str] = None,
                 trace: Optional[LinkTrace] = None,
                 fault_policy: Optional[FaultPolicy] = None,
                 faults: Optional[FaultInjector] = None,
                 router: Optional[FleetRouter] = None,
                 sleep_fn: Callable[[float], None] = time.sleep,
                 quant=None):
        self._bank = SplitFnBank(params, cfg, masks, compact, pack,
                                 quant=quant)
        self.edge_fn, _, self._keep = self._bank.get(split)
        self.split = split
        self._plan_split = split      # the split a fresh cloud handler is at
        self.cfg = cfg
        self.codec = codec
        self._host, self._port = host, port
        self._timeout = timeout
        self._link, self._trace = link, trace
        self._digest = plan_digest
        self.policy = fault_policy
        self.faults = faults
        self._router = router
        self._avoid: Tuple[int, ...] = ()
        self._sleep = sleep_fn
        self._rng = fault_policy.make_rng() if fault_policy else None
        self._seq = 0
        self.use_crc = False
        self.last_fault = fault_record()
        self.sock: Optional[socket.socket] = None
        self.ch: Optional[ShapedSocket] = None
        self._send_q: Optional[queue.Queue] = None
        self._out_q: Optional[queue.Queue] = None
        self._outstanding = 0
        self._n_collected = 0
        self._ready: Dict[int, Dict] = {}    # dequeued-but-not-collected
        self._workers: List[threading.Thread] = []
        self._connect()

    # -- connection lifecycle ------------------------------------------------
    def _lane(self) -> str:
        """This client's wire-lane key (the ``protocol.frame_lane``
        vocabulary its data frames will carry): the fleet router hashes
        it so same-encoding edges land on one server and that server's
        batching lanes stay hot."""
        if self.codec is None and self._keep is None:
            return "raw"
        return ((self.codec or "fp32")
                + ("+packed" if self._keep is not None else ""))

    def _connect(self) -> None:
        """(Re)open the cloud connection: TCP connect, arm the read
        deadline, wrap in the shaper, HELLO (advertising the CRC
        capability), and — when the session's current split has drifted
        from the plan's (the fresh cloud handler starts there) —
        re-RESPLIT the new connection to the current split. With a
        fleet router attached the target (host, port) comes from the
        router (raising ``FleetExhaustedError`` when no member is
        routable)."""
        if self._router is not None:
            self._host, self._port = self._router.route(
                self._lane(), exclude=self._avoid)
            self._avoid = ()
        sock = socket.create_connection((self._host, self._port),
                                        timeout=self._timeout)
        # one attempt's slice of the per-request deadline is the socket
        # read timeout: a dead cloud surfaces as RequestTimeout, never a
        # forever-block, and a lost response leaves deadline budget for
        # the replays instead of consuming all of it on the first read
        sock.settimeout(self.policy.attempt_timeout_s()
                        if self.policy is not None else self._timeout)
        self.sock = sock
        self.ch = (ShapedSocket(sock, self._link, trace=self._trace)
                   if self._link or self._trace else None)
        self.use_crc = False
        if self._digest is not None:
            self._handshake(self._digest)
        if self.split != self._plan_split:
            self._resplit_on_wire(self.split)

    def _teardown(self) -> None:
        """Drop the (possibly half-dead) connection; ``_connect`` will
        rebuild it on the next attempt."""
        if self.sock is not None:
            try:
                (self.ch or self.sock).close()
            except OSError:
                pass
        self.sock = None
        self.ch = None
        self.use_crc = False

    def _handshake(self, digest: str) -> None:
        """HELLO exchange: send our plan digest, require the cloud's accept.
        Raises ``PlanMismatchError`` when the peers disagree on the
        deployment contract (or the peer cannot handshake at all). The
        HELLO advertises ``CAP_CRC``; sealed frames are armed iff the
        cloud echoes the capability (legacy clouds reply without a caps
        byte and the wire stays unsealed)."""
        hello = encode_hello(digest, caps=CAP_CRC)
        self._send(struct.pack("<Q", len(hello)) + hello)
        try:
            rx, _ = _frame_io(self.sock, self.ch)
            (n,) = struct.unpack("<Q", rx(8))
            buf = rx(n)
            peer, status, pver = decode_hello(buf)
        except (EOFError, OSError, ValueError) as e:
            self.sock.close()
            if self.policy is not None and not isinstance(e, ValueError):
                # fault-tolerant edge: a connection torn down during the
                # HELLO is transport trouble (the cloud may be dying or
                # restarting under us) — retriable, not a plan mismatch
                raise
            raise PlanMismatchError(
                f"cloud peer closed or answered garbage during the plan "
                f"handshake (legacy server without HELLO support?): {e}")
        if pver != PROTOCOL_VERSION:
            self.sock.close()
            raise PlanMismatchError(
                f"handshake protocol-version mismatch: edge speaks "
                f"v{PROTOCOL_VERSION}, cloud v{pver}")
        if status != 0 or (peer and peer != digest):
            self.sock.close()
            raise PlanMismatchError(
                f"deployment-plan mismatch: edge digest {digest!r}, "
                f"cloud digest {peer or '<unknown>'!r} — both peers must "
                f"load the same DeploymentPlan (split/compact/codec/model)")
        self.use_crc = bool(hello_caps(buf) & CAP_CRC)

    # -- framing ------------------------------------------------------------
    def _encode_payload(self, x: np.ndarray) -> bytes:
        """Frame payload (excluding the 8-byte length prefix): the prefix
        is transport framing, so reported ``tx_bytes`` stays comparable
        with the in-process executors' payload accounting."""
        if self.codec is None and self._keep is None:
            return encode_tensor(x)
        return encode_feature(x, codec=self.codec or "fp32",
                              keep=self._keep)

    def _send(self, frame: bytes) -> None:
        (self.ch.sendall if self.ch else self.sock.sendall)(frame)

    def _send_payload(self, payload: bytes) -> None:
        self._send(struct.pack("<Q", len(payload)) + payload)

    def _send_request(self, seq: int, payload: bytes) -> None:
        """Ship one data frame: sealed (CRC32 + seq) when negotiated,
        with the client-side fault injector applied to the wire bytes
        (drop / corrupt / stall / tear-down) when one is attached."""
        frame = encode_sealed(seq, payload) if self.use_crc else payload
        if self.faults is not None:
            ev = self.faults.next_event()
            if ev is not None:
                maybe = apply_send_fault(ev, frame, self.sock)
                if maybe is None:
                    return              # injected drop: frame never leaves
                frame = maybe
        self._send(struct.pack("<Q", len(frame)) + frame)

    def _recv_response(self, seq: Optional[int] = None) -> np.ndarray:
        """Read one logits response. With ``seq`` set (sealed wire),
        replies are CRC-checked and matched by sequence number — a stale
        reply to a superseded attempt is discarded, corruption raises
        ``FrameIntegrityError``. A read past the deadline raises
        ``RequestTimeout``. A DRAIN/BUSY control reply (never sealed)
        raises the matching typed signal — the recovery loop migrates
        the request to another fleet member."""
        rx, _ = _frame_io(self.sock, self.ch)
        try:
            while True:
                (n,) = struct.unpack("<Q", rx(8))
                buf = rx(n)
                if is_drain(buf):
                    decode_drain(buf)       # validates magic + version
                    raise ServerDraining(
                        f"server {self._host}:{self._port} is draining "
                        f"(rolling restart)")
                if is_busy(buf):
                    reason, redirect, _ = decode_busy(buf)
                    raise ServerBusy(reason=reason, redirect=redirect)
                if is_sealed(buf):
                    rseq, buf = decode_sealed(buf)
                    if seq is not None and rseq != seq:
                        continue        # stale reply from an old attempt
                logits, _ = decode_tensor(buf)
                return logits
        except socket.timeout as e:
            raise RequestTimeout(
                f"no cloud response within the "
                f"{self.sock.gettimeout():.3f}s deadline") from e

    def heartbeat(self) -> None:
        """Send one keepalive frame (no reply expected) so a cloud with
        idle-client reaping armed keeps this connection alive between
        requests."""
        hb = encode_heartbeat()
        self._send(struct.pack("<Q", len(hb)) + hb)

    def warm(self, splits: Sequence[int]) -> None:
        """Pre-jit the edge half of every candidate split (batch-1 shape)
        so a live resplit doesn't stall its first request on compilation
        (the cloud warms its own halves in ``serve_cloud``)."""
        self._bank.warm(splits, _warm_input(self.cfg), edge_only=True)

    # -- live split switch --------------------------------------------------
    def resplit(self, split: int) -> None:
        """Move the split point on the live connection.

        Sends a RESPLIT control frame, requires the cloud's ack, then
        swaps the local edge sub-model — the next ``infer`` already runs
        at the new partition on the same socket. Must not be called with
        outstanding async ``submit``s (the control frame would interleave
        with in-flight tensor frames)."""
        if self._outstanding != self._n_collected:
            raise RuntimeError(
                f"resplit with {self._outstanding - self._n_collected} "
                f"outstanding pipelined request(s); collect() them first")
        self._resplit_on_wire(split)
        self.adopt_split(split)

    def _resplit_on_wire(self, split: int) -> None:
        """The raw RESPLIT exchange (frame + ack) on the live connection,
        without touching local sub-model state — shared by ``resplit``
        and the reconnect path (which re-announces the current split to
        a fresh cloud handler)."""
        self._send_payload(encode_resplit(split))
        rx, _ = _frame_io(self.sock, self.ch)
        (n,) = struct.unpack("<Q", rx(8))
        got, status, _ = decode_resplit(rx(n))
        if status != 0 or got != split:
            raise PlanMismatchError(
                f"cloud rejected resplit to c={split} (not a candidate of "
                f"its deployment plan, or outside the deployed network)")

    def adopt_split(self, split: int) -> None:
        """Swap the local edge sub-model to ``split`` without touching
        the wire — used while the cloud is unreachable (edge-only
        degradation); the next successful reconnect re-RESPLITs the
        fresh connection to this split before replaying."""
        self.edge_fn, _, self._keep = self._bank.get(split)
        self.split = split

    # -- synchronous path ---------------------------------------------------
    def _infer_edge_only(self, image: np.ndarray, rec: Dict,
                         t0: float) -> Dict:
        """Degradation-ladder bottom rung: serve the request locally from
        the bank's c=N pair — the full network jitted exactly as an
        all-edge split deploys it, so the logits are bit-identical to a
        local c=N run. No bytes cross the wire (``tx_bytes`` 0)."""
        rec["fallback"] = True
        tf0 = time.perf_counter()
        full_fn, _, _ = self._bank.get(self._bank.n_layers)
        out = full_fn(jnp.asarray(image))
        jax.block_until_ready(out)
        tf1 = time.perf_counter()
        self.last_fault = dict(rec)
        return {"logits": np.asarray(out), "t_edge": tf1 - tf0,
                "t_net_and_cloud": 0.0, "t_tx": 0.0, "tx_bytes": 0,
                "t_total_with_recovery": tf1 - t0,
                "fault": dict(rec)}

    def infer(self, image: np.ndarray) -> Dict:
        """One request/response. ``t_tx`` is the uplink observation the
        bandwidth estimator feeds on: the shaper's modeled cost of the
        feature send when the socket is shaped (wall-clock is useless
        there — the token bucket lets small frames burst through), the
        send wall-clock on a raw socket. ``t_net_and_cloud`` additionally
        includes the cloud compute and the logits downlink.

        With a ``FaultPolicy`` armed this is the recovery loop: a fault
        (timeout, disconnect, CRC failure) tears the connection down and
        the request is retried — backoff, reconnect (re-HELLO,
        re-RESPLIT), replay under the same sequence number — until the
        retry budget or the per-request deadline runs out, at which
        point the policy's fallback serves it edge-only (or re-raises).
        The ``fault`` key of the result is the uniform per-request
        record ``{faults, retries, migrations, fallback}``."""
        rec = fault_record()
        t0 = time.perf_counter()
        x = jnp.asarray(image)
        if self.edge_fn is not None:
            x = self.edge_fn(x)
            jax.block_until_ready(x)
        t1 = time.perf_counter()
        payload = self._encode_payload(np.asarray(x))
        self._seq = (self._seq + 1) & 0xFFFFFFFF
        seq = self._seq
        deadline = (time.monotonic() + self.policy.request_deadline_s
                    if self.policy is not None else None)
        attempt = 0
        while True:
            try:
                if self.sock is None:
                    self._connect()     # reconnect: HELLO + re-RESPLIT
                self._send_request(seq, payload)
                t_sent = time.perf_counter()
                logits = self._recv_response(seq if self.use_crc else None)
                if self._router is not None:
                    self._router.note_ok(self._port)
                break
            except PlanMismatchError:
                raise                   # contract breakage is not transient
            except FleetExhaustedError:
                # the whole fleet is dead or draining: the bottom rung
                # (edge-only) is the only one left
                rec["faults"] += 1
                self.last_fault = dict(rec)
                if (self.policy is not None
                        and self.policy.fallback == "edge"):
                    return self._infer_edge_only(image, rec, t0)
                raise
            except ServerDraining:
                # rolling restart, not a fault: migrate to the next
                # healthy member and replay — the drained server is out
                # of the ring, so this terminates within the fleet size
                rec["migrations"] += 1
                self._teardown()
                if self._router is not None:
                    self._router.note_drain(self._port)
                    self._avoid = (self._port,)
                    continue            # immediate migration, no backoff
                exhausted = (self.policy is None
                             or attempt >= self.policy.max_retries
                             or (deadline is not None
                                 and time.monotonic() >= deadline))
                if exhausted:
                    self.last_fault = dict(rec)
                    if (self.policy is not None
                            and self.policy.fallback == "edge"):
                        return self._infer_edge_only(image, rec, t0)
                    raise
                rec["retries"] += 1
                self._sleep(self.policy.backoff_s(attempt, self._rng))
                attempt += 1
            except ServerBusy as e:
                # overload backpressure: redirect off the saturated lane
                # when the fleet has somewhere else to go, else back off
                # and retry (bounded by the normal retry budget)
                rec["migrations"] += 1
                self._teardown()
                redirect = e.redirect and self._router is not None
                if redirect:
                    self._avoid = (self._port,)
                exhausted = (self.policy is None
                             or attempt >= self.policy.max_retries
                             or (deadline is not None
                                 and time.monotonic() >= deadline))
                if exhausted:
                    self.last_fault = dict(rec)
                    if (self.policy is not None
                            and self.policy.fallback == "edge"):
                        return self._infer_edge_only(image, rec, t0)
                    raise
                rec["retries"] += 1
                if not redirect:
                    self._sleep(self.policy.backoff_s(attempt, self._rng))
                attempt += 1
            except (FrameIntegrityError, EOFError, OSError) as e:
                rec["faults"] += 1
                self._teardown()
                if self._router is not None:
                    # feed the health tracker; prefer another member on
                    # the next attempt (a lone member is still retried)
                    self._router.note_miss(self._port)
                    self._avoid = (self._port,)
                exhausted = (self.policy is None
                             or attempt >= self.policy.max_retries
                             or (deadline is not None
                                 and time.monotonic() >= deadline))
                if exhausted:
                    self.last_fault = dict(rec)
                    if (self.policy is not None
                            and self.policy.fallback == "edge"):
                        return self._infer_edge_only(image, rec, t0)
                    raise
                rec["retries"] += 1
                pause = self.policy.backoff_s(attempt, self._rng)
                if deadline is not None:
                    pause = min(pause, max(0.0,
                                           deadline - time.monotonic()))
                self._sleep(pause)
                attempt += 1
        t2 = time.perf_counter()
        self.last_fault = dict(rec)
        return {"logits": logits,
                "t_edge": t1 - t0,
                "t_net_and_cloud": t2 - t1,
                "t_tx": (self.ch.last_send_cost_s if self.ch is not None
                         else t_sent - t1),
                "tx_bytes": len(payload),
                "fault": dict(rec)}

    # -- pipelined (async) path ---------------------------------------------
    def _sender_loop(self) -> None:
        while True:
            item = self._send_q.get()
            if item is None:
                # forward the shutdown so the receiver stops only after
                # every request enqueued before close() has been answered
                self._inflight.put(None)
                break
            rid, image = item
            try:
                t0 = time.perf_counter()
                x = jnp.asarray(image)
                if self.edge_fn is not None:
                    x = self.edge_fn(x)
                    jax.block_until_ready(x)
                t_edge = time.perf_counter() - t0
                payload = self._encode_payload(np.asarray(x))
                self._send_payload(payload)
                self._inflight.put((rid, t_edge, len(payload)))
            except Exception as e:                      # noqa: BLE001
                self._inflight.put((rid, e, 0))

    def _receiver_loop(self) -> None:
        while True:
            item = self._inflight.get()
            if item is None:
                break
            rid, t_edge, nbytes = item
            if isinstance(t_edge, Exception):
                self._out_q.put((rid, t_edge))
                continue
            try:
                logits = self._recv_response()
                self._out_q.put((rid, {"logits": logits, "t_edge": t_edge,
                                       "tx_bytes": nbytes}))
            except Exception as e:                      # noqa: BLE001
                self._out_q.put((rid, e))

    def submit(self, image: np.ndarray) -> int:
        """Enqueue a request; returns its id. Blocks only while the
        64-deep send queue is full (backpressure against a stalled link)."""
        if self._send_q is None:
            self._send_q = queue.Queue(maxsize=64)
            self._inflight = queue.Queue()
            self._out_q = queue.Queue()
            self._workers = [threading.Thread(target=f, daemon=True)
                             for f in (self._sender_loop,
                                       self._receiver_loop)]
            for w in self._workers:
                w.start()
        rid = self._outstanding
        self._outstanding += 1
        self._send_q.put((rid, image))
        return rid

    def collect(self, count: Optional[int] = None,
                timeout: float = 60.0) -> List[Dict]:
        """Block until ``count`` results (default: all outstanding) arrive;
        returns them in submission order. A request that failed raises its
        worker error (after it is consumed, so a later ``collect`` resumes
        with the requests that followed it)."""
        if count is None:
            count = self._outstanding - self._n_collected
        out: List[Dict] = []
        while len(out) < count:
            rid = self._n_collected          # next id in submission order
            if rid in self._ready:
                res = self._ready.pop(rid)
            else:
                got_rid, res = self._out_q.get(timeout=timeout)
                if got_rid != rid:
                    self._ready[got_rid] = res
                    continue
            self._n_collected += 1
            if isinstance(res, Exception):
                raise res
            out.append(res)
        return out

    def close(self) -> None:
        if self._send_q is not None:
            # sender forwards this sentinel to the receiver once every
            # already-queued request has been sent (no responses dropped)
            self._send_q.put(None)
            for w in self._workers:
                w.join(timeout=30)
        if self.sock is not None:
            (self.ch or self.sock).close()
