"""Collaborative split-inference executors (paper §3.3 deployment).

``CollabRunner`` — in-process: edge submodel -> (shaped) channel -> cloud
submodel, with the Eq. 5 timing breakdown measured per request. This is the
engine behind benchmarks fig5 and the Gradio-replacement CLI demo.

``serve_cloud`` / ``EdgeClient`` — real localhost TCP sockets with the
token-bucket shaper, mirroring the paper's socket deployment: the edge sends
the intermediate feature tensor, the cloud returns class logits.
"""
from __future__ import annotations

import socket
import struct
import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CNNConfig
from repro.core.collab.channel import ShapedSocket, SimChannel
from repro.core.collab.protocol import decode_tensor, encode_tensor
from repro.core.partition.profiles import LinkProfile, TwoTierProfile
from repro.models.cnn import cnn_apply


@dataclass
class RequestTiming:
    t_device: float
    t_tx: float
    t_server: float
    tx_bytes: int

    @property
    def total(self) -> float:
        return self.t_device + self.t_tx + self.t_server


class CollabRunner:
    """In-process split executor with simulated (or real-time) channel."""

    def __init__(self, params, cfg: CNNConfig, split: int,
                 profile: TwoTierProfile, masks=None,
                 realtime_channel: bool = False,
                 simulate_compute: bool = True):
        self.cfg = cfg
        self.split = split
        self.profile = profile
        self.masks = masks
        self.channel = SimChannel(profile.link, realtime=realtime_channel)
        self.simulate_compute = simulate_compute
        n = len(cfg.layers)
        self._edge_fn = jax.jit(lambda x: cnn_apply(
            params, cfg, x, masks=masks, stop_layer=split)) if split > 0 else None
        self._cloud_fn = jax.jit(lambda x: cnn_apply(
            params, cfg, x, masks=masks, start_layer=split)) if split < n else None
        # analytic compute-time model for reporting at the paper's hardware
        from repro.core.partition.latency_model import (cnn_layer_costs,
                                                        split_latency,
                                                        cnn_input_bytes)
        self._analytic = split_latency(
            cnn_layer_costs(cfg, masks), split, profile,
            cnn_input_bytes(cfg))

    def infer(self, image: np.ndarray) -> Dict:
        """image (B, H, W, C). Returns logits + RequestTiming.

        Wall-clock is measured for the actual CPU compute; the *reported*
        device/server terms come from the analytic profile when
        ``simulate_compute`` (the container has no i7/3090 pair), while the
        channel term is always charged per transmitted byte.
        """
        x = jnp.asarray(image)
        t0 = time.perf_counter()
        if self._edge_fn is not None:
            x = self._edge_fn(x)
            jax.block_until_ready(x)
        t1 = time.perf_counter()
        payload = np.asarray(x)
        if self._cloud_fn is not None:
            tx_bytes = payload.nbytes
            t_tx = self.channel.send(tx_bytes)
        else:
            tx_bytes, t_tx = 0, 0.0
        t2 = time.perf_counter()
        out = x
        if self._cloud_fn is not None:
            out = self._cloud_fn(x)
            jax.block_until_ready(out)
        t3 = time.perf_counter()
        if self.simulate_compute:
            timing = RequestTiming(self._analytic["T_D"], t_tx,
                                   self._analytic["T_S"], tx_bytes)
        else:
            timing = RequestTiming(t1 - t0, t_tx, t3 - t2, tx_bytes)
        return {"logits": np.asarray(out), "timing": timing,
                "wallclock": {"edge": t1 - t0, "cloud": t3 - t2}}


# ---------------------------------------------------------------------------
# real-socket deployment (localhost stand-in for the paper's Wi-Fi pair)
# ---------------------------------------------------------------------------
def serve_cloud(params, cfg: CNNConfig, split: int, port: int,
                masks=None, link: Optional[LinkProfile] = None,
                max_requests: Optional[int] = None,
                ready: Optional[threading.Event] = None) -> None:
    """Cloud-side loop: accept one edge connection, answer frames."""
    cloud_fn = jax.jit(lambda x: cnn_apply(params, cfg, jnp.asarray(x),
                                           masks=masks, start_layer=split))
    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    srv.bind(("127.0.0.1", port))
    srv.listen(1)
    if ready is not None:
        ready.set()
    conn, _ = srv.accept()
    ch = ShapedSocket(conn, link) if link else None
    served = 0
    try:
        while max_requests is None or served < max_requests:
            if ch:
                (n,) = struct.unpack("<Q", ch.recv_exact(8))
                buf = ch.recv_exact(n)
            else:
                hdr = conn.recv(8, socket.MSG_WAITALL)
                if not hdr:
                    break
                (n,) = struct.unpack("<Q", hdr)
                buf = conn.recv(n, socket.MSG_WAITALL)
            arr, _ = decode_tensor(buf)
            logits = np.asarray(cloud_fn(arr))
            out = encode_tensor(logits)
            frame = struct.pack("<Q", len(out)) + out
            (ch.sendall if ch else conn.sendall)(frame)
            served += 1
    except (EOFError, ConnectionError):
        pass
    finally:
        conn.close()
        srv.close()


class EdgeClient:
    """Edge side: run layers [0, split), ship features, await logits."""

    def __init__(self, params, cfg: CNNConfig, split: int, port: int,
                 masks=None, link: Optional[LinkProfile] = None):
        self.edge_fn = (jax.jit(lambda x: cnn_apply(
            params, cfg, x, masks=masks, stop_layer=split))
            if split > 0 else None)
        sock = socket.create_connection(("127.0.0.1", port), timeout=30)
        self.ch = ShapedSocket(sock, link) if link else None
        self.sock = sock

    def infer(self, image: np.ndarray) -> Dict:
        t0 = time.perf_counter()
        x = jnp.asarray(image)
        if self.edge_fn is not None:
            x = self.edge_fn(x)
            jax.block_until_ready(x)
        t1 = time.perf_counter()
        payload = encode_tensor(np.asarray(x))
        frame = struct.pack("<Q", len(payload)) + payload
        if self.ch:
            self.ch.sendall(frame)
            (n,) = struct.unpack("<Q", self.ch.recv_exact(8))
            buf = self.ch.recv_exact(n)
        else:
            self.sock.sendall(frame)
            (n,) = struct.unpack("<Q",
                                 self.sock.recv(8, socket.MSG_WAITALL))
            buf = self.sock.recv(n, socket.MSG_WAITALL)
        t2 = time.perf_counter()
        logits, _ = decode_tensor(buf)
        return {"logits": logits,
                "t_edge": t1 - t0,
                "t_net_and_cloud": t2 - t1,
                "tx_bytes": len(frame)}

    def close(self) -> None:
        (self.ch or self.sock).close()
