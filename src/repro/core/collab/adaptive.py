"""Adaptive split control under time-varying wireless links.

The paper's Algorithm 1 picks the split once, for the bandwidth measured
at deployment time. A *wireless* link does not hold still — the edge
device roams, the cell hands over, the evening uplink congests — and the
greedy optimum moves with it. This module closes the loop at run time:

  * ``BandwidthEstimator`` — an EWMA over the per-request uplink
    observations every executor already produces (``tx_bytes`` payload
    size and ``t_tx`` transmission wall-clock), yielding a running
    estimate of the link the deployment is *actually* experiencing;
  * ``AdaptiveSplitController`` — re-runs the Eq. 5 greedy sweep
    (``sweep_splits``) against the measured link over the plan's
    candidate splits and emits a ``SplitSwitch`` decision, guarded by
    hysteresis (a switch must promise a minimum relative improvement)
    and a dwell period (minimum requests between switches) so estimator
    noise cannot make the partition flap;
  * ``AdaptivePolicy`` — the serializable knobs of the above, carried in
    ``DeploymentPlan.adaptive`` and folded into the plan digest so both
    peers agree on the candidate set before the first RESPLIT frame.

Execution of a switch lives in the runtimes: ``CollabRunner.set_split``
(in-process) and ``EdgeClient.resplit`` (RESPLIT control frame on the
live socket); ``repro.serving`` wires observation -> decision -> switch
per request.

**Battery-aware re-planning** (the energy subsystem's control hook): a
controller built with an ``EnergyPolicy`` prices every sweep row into a
``(T, E_edge)`` pair and scores candidates with the weighted
latency·energy objective instead of raw latency. When the policy
carries a ``battery_j`` budget, each request's reported ``e_edge_j``
drains it (``drain``), and the effective energy weight scales with
*urgency* — the inverse square of the remaining battery fraction — so
a full battery optimizes latency and a draining one walks the Pareto
front toward the low-energy splits (typically earlier splits on
compute-dominated devices: offload more, burn less) while meaningful
budget remains. Same hysteresis + dwell guards apply, on the scored
objective.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.configs.base import CNNConfig
from repro.core.partition.energy_model import (EnergyPolicy,
                                               urgency_scaled_weight)
from repro.core.partition.latency_model import (cnn_input_bytes,
                                                cnn_layer_costs,
                                                compacted_cnn_layer_costs,
                                                wire_tx_scale)
from repro.core.partition.profiles import LinkProfile, TwoTierProfile
from repro.core.partition.splitter import sweep_splits


@dataclass(frozen=True)
class AdaptivePolicy:
    """Serializable adaptive-split knobs (the plan's ``adaptive`` section).

    ``candidates`` are the split points both peers pre-arm in their
    ``SplitFnBank``; ``ewma_alpha``/``min_samples`` shape the bandwidth
    estimator; ``hysteresis`` is the minimum relative latency improvement
    a switch must promise (0.1 = predicted T at the new split must be at
    least 10% below the current split's predicted T); ``dwell`` is the
    minimum number of requests between switches.
    """
    candidates: Tuple[int, ...]
    ewma_alpha: float = 0.4
    min_samples: int = 2
    hysteresis: float = 0.1
    dwell: int = 3

    def __post_init__(self) -> None:
        if not self.candidates:
            raise ValueError("AdaptivePolicy needs at least one candidate "
                             "split")
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError("ewma_alpha must be in (0, 1]")
        if self.hysteresis < 0.0:
            raise ValueError("hysteresis must be >= 0")

    def to_json(self) -> Dict[str, Any]:
        """Serialize for ``plan.json`` (the digest-folded form)."""
        return {"candidates": [int(c) for c in self.candidates],
                "ewma_alpha": self.ewma_alpha,
                "min_samples": self.min_samples,
                "hysteresis": self.hysteresis, "dwell": self.dwell}

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "AdaptivePolicy":
        return cls(candidates=tuple(int(c) for c in d["candidates"]),
                   ewma_alpha=d["ewma_alpha"],
                   min_samples=d["min_samples"],
                   hysteresis=d["hysteresis"], dwell=d["dwell"])


class BandwidthEstimator:
    """EWMA uplink-bandwidth estimate from per-request (bytes, seconds).

    Each observation is one transmitted feature frame: ``tx_bytes``
    payload over ``t_tx`` wall-clock. The configured ``rtt_s`` is
    subtracted before dividing, since the per-send cost every channel
    charges is ``bytes/bandwidth + rtt``.

    EWMA state is lock-guarded: the serving loop's observation path and
    an outage report from a recovery thread may race (``serve_cloud``
    handlers and ``EdgeClient`` worker threads both feed controllers).
    """

    def __init__(self, alpha: float = 0.4, min_samples: int = 2,
                 rtt_s: float = 0.0):
        self.alpha = alpha
        self.min_samples = max(1, min_samples)
        self.rtt_s = rtt_s
        self.n_samples = 0
        self._ewma: Optional[float] = None
        self._lock = threading.Lock()

    def observe(self, tx_bytes: float, t_tx: float) -> None:
        """Feed one uplink observation (payload bytes over send
        seconds); edge-only requests (no uplink) are ignored."""
        if tx_bytes <= 0 or t_tx <= 0:
            return                       # edge-only request: no uplink signal
        sample = tx_bytes / max(t_tx - self.rtt_s, 1e-9)
        with self._lock:
            self._ewma = (sample if self._ewma is None else
                          self.alpha * sample
                          + (1 - self.alpha) * self._ewma)
            self.n_samples += 1

    #: bytes/s an outage forces the estimate to — effectively "link dead"
    #: (≈1 kbit/s) without dividing by zero anywhere downstream.
    OUTAGE_BANDWIDTH = 125.0

    def note_outage(self) -> None:
        """Collapse the estimate to ``OUTAGE_BANDWIDTH`` (link presumed
        dead) and mark the estimator ready, so the very next controller
        decision sees bandwidth→0 instead of the stale pre-outage EWMA.
        Subsequent healthy observations pull the EWMA back up at the
        usual ``alpha`` rate — that is the heal-back path."""
        with self._lock:
            self._ewma = self.OUTAGE_BANDWIDTH
            self.n_samples = max(self.n_samples, self.min_samples)

    @property
    def ready(self) -> bool:
        return self.n_samples >= self.min_samples

    @property
    def bandwidth(self) -> Optional[float]:
        """Estimated uplink bytes/s, or None before the first sample."""
        return self._ewma


@dataclass
class SplitSwitch:
    """One re-split decision, for logs and benchmark tables."""
    request_index: int
    old_split: int
    new_split: int
    est_bandwidth: float            # bytes/s the decision was based on
    current_T: float                # predicted Eq. 5 latency, old split
    predicted_T: float              # predicted Eq. 5 latency, new split
    current_E: Optional[float] = None    # predicted edge joules, old split
    predicted_E: Optional[float] = None  # predicted edge joules, new split
    battery_j: Optional[float] = None    # remaining budget at decision time

    def describe(self) -> str:
        """One-line human summary (ms, Mbps, mJ, remaining joules)."""
        energy = ""
        if self.predicted_E is not None:
            energy = (f", {self.current_E * 1e3:.1f} -> "
                      f"{self.predicted_E * 1e3:.1f} mJ")
            if self.battery_j is not None:
                energy += f", battery {self.battery_j * 1e3:.1f} mJ"
        return (f"resplit c={self.old_split}->{self.new_split} at request "
                f"{self.request_index} (est link "
                f"{self.est_bandwidth * 8 / 1e6:.1f} Mbps, predicted "
                f"{self.current_T * 1e3:.1f} -> "
                f"{self.predicted_T * 1e3:.1f} ms{energy})")


class AdaptiveSplitController:
    """Observation -> greedy re-sweep -> hysteresis-guarded switch.

    ``step(tx_bytes, t_tx)`` is the per-request entry point: feed the
    uplink observation, get back a ``SplitSwitch`` when the measured link
    has drifted far enough that a different candidate split wins by more
    than the hysteresis margin (and the dwell period has passed), else
    ``None``. The caller executes the switch (``CollabRunner.set_split``
    / ``EdgeClient.resplit``) — the controller only decides.

    Decision state (``split``, ``battery_j``, request/dwell counters) is
    lock-guarded: the request path and an outage report from a recovery
    thread may mutate it concurrently.
    """

    def __init__(self, costs, profile: TwoTierProfile, input_bytes: float,
                 policy: AdaptivePolicy, split: int, tx_scale=1.0,
                 energy: Optional[EnergyPolicy] = None):
        if split not in policy.candidates:
            raise ValueError(f"initial split {split} not among the "
                             f"candidates {policy.candidates}")
        self.costs = costs
        self.profile = profile
        self.input_bytes = input_bytes
        self.policy = policy
        self.split = split
        self.tx_scale = tx_scale            # scalar or callable(split)
        self.energy = energy
        #: remaining battery budget in joules (None = unmetered)
        self.battery_j = energy.battery_j if energy is not None else None
        self._battery_j_init = self.battery_j
        self.estimator = BandwidthEstimator(policy.ewma_alpha,
                                            policy.min_samples,
                                            rtt_s=profile.link.rtt_s)
        self.n_requests = 0
        self._since_switch = 0
        self.history: List[SplitSwitch] = []
        self._lock = threading.Lock()

    @classmethod
    def for_deployment(cls, cfg: CNNConfig, policy: AdaptivePolicy,
                       split: int, profile: TwoTierProfile, masks=None,
                       compact: bool = False, codec: Optional[str] = None,
                       pack: bool = False,
                       energy: Optional[EnergyPolicy] = None
                       ) -> "AdaptiveSplitController":
        """Build the controller for a concrete deployment: layer costs
        priced on the deployed (compacted/masked) shapes and a
        per-candidate ``wire_tx_scale`` so predicted T_TX matches what the
        runtime will actually put on the wire at each candidate.
        ``energy`` (the plan's ``energy`` section) arms the battery-aware
        weighted objective."""
        costs = (compacted_cnn_layer_costs(cfg, masks) if compact
                 else cnn_layer_costs(cfg, masks))
        return cls(costs, profile, cnn_input_bytes(cfg), policy, split,
                   tx_scale=lambda c: wire_tx_scale(
                       cfg, masks, c, codec=codec, pack=pack,
                       compact=compact),
                   energy=energy)

    # -- battery accounting --------------------------------------------------
    @property
    def battery_fraction(self) -> Optional[float]:
        """Remaining battery as a fraction of the configured budget
        (None when the deployment is unmetered)."""
        if self.battery_j is None or not self._battery_j_init:
            return None
        return max(self.battery_j, 0.0) / self._battery_j_init

    @property
    def effective_energy_weight(self) -> float:
        """The s/J exchange rate the scorer uses *right now*: the
        policy's static knob, scaled by battery urgency — the inverse
        *square* of the remaining fraction — when a ``battery_j``
        budget is armed. A full battery optimizes latency; at half
        charge the device already pays 4x more seconds per joule saved,
        so the walk toward the low-energy splits happens while there is
        still meaningful budget left, not at the moment of exhaustion.
        The curve itself is ``energy_model.urgency_scaled_weight`` —
        one formula shared with the fleet simulator's per-edge split
        decisions."""
        if self.energy is None:
            return 0.0
        return urgency_scaled_weight(self.energy.energy_weight_s_per_j,
                                     self.battery_fraction)

    def drain(self, e_edge_j: Optional[float]) -> None:
        """Subtract one request's measured edge energy from the battery
        budget (no-op when unmetered or the request reported no energy)."""
        if e_edge_j is None:
            return
        with self._lock:
            if self.battery_j is not None:
                self.battery_j = max(self.battery_j - e_edge_j, 0.0)

    def observe(self, tx_bytes: float, t_tx: float,
                e_edge_j: Optional[float] = None) -> None:
        """Record one request: uplink observation (bytes, seconds) for
        the bandwidth estimator, measured edge joules for the battery
        budget, and the dwell counter."""
        self.estimator.observe(tx_bytes, t_tx)
        self.drain(e_edge_j)
        with self._lock:
            self.n_requests += 1
            self._since_switch += 1

    def note_outage(self) -> Optional[SplitSwitch]:
        """React to a cloud outage (a request that fell back to
        edge-only after exhausting its retry budget): collapse the
        bandwidth estimate to ~zero, waive the dwell guard, and decide
        immediately — on a dead uplink the sweep's T_TX term dominates
        every offloading candidate, so the winner is the latest
        candidate split (c=N when armed: pure edge, zero wire bytes).
        Healing is symmetric: once requests flow again, their healthy
        uplink observations pull the EWMA back up and ``step`` re-splits
        toward offloading through the normal hysteresis/dwell guards."""
        self.estimator.note_outage()
        with self._lock:
            self._since_switch = self.policy.dwell
        return self.maybe_switch()

    def note_congestion(self) -> Optional[SplitSwitch]:
        """React to fleet backpressure (a request that had to migrate
        after a BUSY shed): waive the dwell guard and re-decide at the
        *current* bandwidth estimate. Unlike ``note_outage`` this does
        not collapse the estimator — the link is healthy, the cloud
        tier is the bottleneck — it just lets the controller answer the
        congestion signal immediately instead of waiting out the dwell
        window."""
        with self._lock:
            self._since_switch = self.policy.dwell
        return self.maybe_switch()

    def note_external_switch(self, split: int) -> None:
        """Adopt a split executed outside the controller (a manual
        ``resplit``) and restart the dwell window, so the controller does
        not immediately overrule the override on the next request."""
        with self._lock:
            self.split = split
            self._since_switch = 0

    def sweep(self, bandwidth: float) -> List[Dict[str, float]]:
        """The Eq. 5 greedy sweep over the candidates at ``bandwidth``,
        energy-priced (``E_edge`` joules per row) when the controller
        carries an ``EnergyPolicy``."""
        link = LinkProfile(f"measured {bandwidth * 8 / 1e6:.1f} Mbps",
                           bandwidth=bandwidth,
                           rtt_s=self.profile.link.rtt_s)
        prof = TwoTierProfile(self.profile.device, self.profile.server,
                              link)
        return sweep_splits(self.costs, prof, self.input_bytes,
                            candidates=self.policy.candidates,
                            tx_scale=self.tx_scale,
                            energy=(self.energy.profile
                                    if self.energy is not None else None))

    def _score(self, row: Dict[str, float]) -> float:
        """Objective of one sweep row: plain Eq. 5 latency, or the
        battery-urgency-weighted latency·energy score."""
        if self.energy is None:
            return row["T"]
        return self.energy.score(row, self.effective_energy_weight)

    def maybe_switch(self) -> Optional[SplitSwitch]:
        """Decide (but do not execute) a split switch: re-sweep at the
        estimated bandwidth, apply the objective (latency or
        battery-weighted latency·energy), guard with hysteresis and
        dwell; returns the ``SplitSwitch`` or None."""
        if not self.estimator.ready or self._since_switch < self.policy.dwell:
            return None
        bw = self.estimator.bandwidth
        table = self.sweep(bw)
        best = min(table, key=self._score)
        cur = next(r for r in table if r["split"] == self.split)
        if best["split"] == self.split:
            return None
        if self._score(best) > (1.0 - self.policy.hysteresis) \
                * self._score(cur):
            return None                  # not enough predicted win: hold
        sw = SplitSwitch(self.n_requests, self.split, int(best["split"]),
                         bw, cur["T"], best["T"],
                         current_E=cur.get("E_edge"),
                         predicted_E=best.get("E_edge"),
                         battery_j=self.battery_j)
        with self._lock:
            self.split = sw.new_split
            self._since_switch = 0
            self.history.append(sw)
        return sw

    def step(self, tx_bytes: float, t_tx: float,
             e_edge_j: Optional[float] = None) -> Optional[SplitSwitch]:
        """Feed one request's uplink observation (and, on an
        energy-metered deployment, its measured edge joules — it drains
        the battery budget); maybe decide a switch."""
        self.observe(tx_bytes, t_tx, e_edge_j)
        return self.maybe_switch()
