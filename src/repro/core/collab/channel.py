"""Bandwidth-shaped byte channels standing in for the paper's Wi-Fi hop.

``SimChannel`` computes transmission time analytically (and can optionally
sleep it away for realistic end-to-end demos). ``ShapedSocket`` wraps a real
TCP socket with a token-bucket rate limiter, so the localhost demo in
examples/collaborative_serve.py actually experiences ~50 Mbps.
"""
from __future__ import annotations

import socket
import time
from dataclasses import dataclass, field
from typing import Optional

from repro.core.partition.profiles import LinkProfile


def recv_exact(sock: socket.socket, n: int, chunk: int = 1 << 20) -> bytes:
    """Read exactly n bytes from a connected socket.

    ``sock.recv(n, MSG_WAITALL)`` may still return short (signal delivery,
    platform quirks, very large n), so every frame read — shaped or not —
    goes through this loop instead.
    """
    out = bytearray()
    while len(out) < n:
        got = sock.recv(min(chunk, n - len(out)))
        if not got:
            raise EOFError("peer closed")
        out += got
    return bytes(out)


@dataclass
class SimChannel:
    link: LinkProfile
    realtime: bool = False
    sent_bytes: int = 0
    elapsed_s: float = 0.0

    def send(self, nbytes: int) -> float:
        t = nbytes / self.link.bandwidth + self.link.rtt_s
        self.sent_bytes += nbytes
        self.elapsed_s += t
        if self.realtime:
            time.sleep(t)
        return t


class ShapedSocket:
    """Token-bucket pacing on top of a connected socket (both directions)."""

    def __init__(self, sock: socket.socket, link: LinkProfile,
                 chunk: int = 16384):
        self.sock = sock
        self.link = link
        self.chunk = chunk
        self._budget = 0.0
        self._last = time.perf_counter()

    def _pace(self, nbytes: int) -> None:
        now = time.perf_counter()
        self._budget += (now - self._last) * self.link.bandwidth
        self._budget = min(self._budget, self.link.bandwidth * 0.05)
        self._last = now
        if nbytes > self._budget:
            need = (nbytes - self._budget) / self.link.bandwidth
            time.sleep(need)
            self._last = time.perf_counter()
            self._budget = 0.0
        else:
            self._budget -= nbytes

    def sendall(self, data: bytes) -> None:
        for i in range(0, len(data), self.chunk):
            piece = data[i:i + self.chunk]
            self._pace(len(piece))
            self.sock.sendall(piece)

    def recv_exact(self, n: int) -> bytes:
        return recv_exact(self.sock, n, self.chunk)

    def close(self) -> None:
        self.sock.close()
