"""Bandwidth-shaped byte channels standing in for the paper's Wi-Fi hop.

``SimChannel`` computes transmission time analytically (and can optionally
sleep it away for realistic end-to-end demos). ``ShapedSocket`` wraps a real
TCP socket with a token-bucket rate limiter, so the localhost demo in
examples/collaborative_serve.py actually experiences ~50 Mbps.

Both channels accept a ``LinkTrace`` (``repro.core.partition.profiles``)
for *time-varying* links: ``SimChannel`` keeps a virtual clock and charges
each transmission piecewise against the trace segments it straddles (a
send that starts on 50 Mbps and ends on 5 Mbps pays exactly the blended
cost), while ``ShapedSocket`` refills its token bucket at whatever rate
the trace dictates at the current wall-clock offset. The per-send cost is
therefore a *measurement* of the link as it is right now — the signal the
adaptive split controller estimates bandwidth from.

Both channels also accept a ``FaultInjector`` replaying a deterministic
``FaultSchedule`` (``repro.core.partition.profiles``): ``SimChannel``
charges lost copies and ARQ retransmissions against the virtual clock,
while ``ShapedSocket`` drops, corrupts, stalls, or tears down real
frames on the wire — the reproducible storm the recovery machinery in
``repro.core.collab.faults`` and ``EdgeClient`` is tested against.
"""
from __future__ import annotations

import socket
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.core.partition.profiles import (FaultEvent, FaultSchedule,
                                           LinkProfile, LinkTrace)


def recv_exact(sock: socket.socket, n: int, chunk: int = 1 << 20) -> bytes:
    """Read exactly n bytes from a connected socket.

    ``sock.recv(n, MSG_WAITALL)`` may still return short (signal delivery,
    platform quirks, very large n), so every frame read — shaped or not —
    goes through this loop instead.
    """
    out = bytearray()
    while len(out) < n:
        got = sock.recv(min(chunk, n - len(out)))
        if not got:
            raise EOFError("peer closed")
        out += got
    return bytes(out)


def corrupt_bytes(data: bytes, index: Optional[int] = None) -> bytes:
    """Flip one byte of ``data`` (the middle byte by default).

    Deterministic by design — the corrupt-frame tests assert that the
    CRC layer catches *this exact* flip, not a random one.
    """
    if not data:
        return data
    i = len(data) // 2 if index is None else index
    return data[:i] + bytes([data[i] ^ 0xFF]) + data[i + 1:]


class FaultInjector:
    """Replays a ``FaultSchedule`` against a live attempt counter.

    The schedule is pure data; the injector owns the mutable state — a
    thread-safe, monotonically increasing transmission-attempt index and
    per-kind fault counts. One injector drives one run; build a fresh
    one to replay the same schedule again.
    """

    def __init__(self, schedule: FaultSchedule):
        self.schedule = schedule
        self._lock = threading.Lock()
        self._attempt = 0
        self.counts: Dict[str, int] = {}

    def next_event(self) -> Optional[FaultEvent]:
        """Consume one transmission attempt; the fault to inject on it,
        or None for a clean attempt."""
        with self._lock:
            ev = self.schedule.event_at(self._attempt)
            self._attempt += 1
            if ev is not None:
                self.counts[ev.kind] = self.counts.get(ev.kind, 0) + 1
            return ev

    @property
    def attempts(self) -> int:
        """Transmission attempts consumed so far."""
        with self._lock:
            return self._attempt

    @property
    def injected(self) -> int:
        """Total faults injected so far (all kinds)."""
        with self._lock:
            return sum(self.counts.values())

    def reset(self) -> None:
        """Rewind to attempt 0 and clear the per-kind counts."""
        with self._lock:
            self._attempt = 0
            self.counts = {}


def apply_send_fault(ev: FaultEvent, data: bytes,
                     sock: Optional[socket.socket]) -> Optional[bytes]:
    """Apply one injected fault to an outgoing frame.

    Returns the (possibly corrupted) bytes to put on the wire, or None
    when the frame is dropped. ``disconnect``/``die`` close ``sock``
    and raise ``ConnectionResetError`` — exactly what a torn-down TCP
    connection surfaces to the sender.
    """
    if ev.kind == "drop":
        return None
    if ev.kind == "corrupt":
        return corrupt_bytes(data)
    if ev.kind == "stall":
        time.sleep(ev.stall_s)
        return data
    if sock is not None:
        try:
            sock.close()
        except OSError:
            pass
    raise ConnectionResetError(f"injected fault: {ev.kind}")


@dataclass
class SimChannel:
    """Analytic byte channel with an optional time-varying link.

    With ``trace`` set, ``elapsed_s`` is the virtual deployment clock: each
    ``send`` drains bytes segment-by-segment from the trace starting at the
    current clock, and ``advance`` moves the clock across non-transmission
    time (edge/cloud compute) so the link keeps degrading while the radio
    is idle. Without a trace this is the original fixed-``link`` channel.

    With ``faults`` set, each ``send`` consults the injector: a lost copy
    (drop/corrupt/disconnect — the analytic channel models link-layer
    ARQ) burns a full transmission's airtime and is retransmitted on the
    next attempt index; a stall adds its delay. ``last_send_events``
    records what the most recent ``send`` suffered.
    """
    link: LinkProfile
    realtime: bool = False
    trace: Optional[LinkTrace] = None
    sent_bytes: int = 0
    elapsed_s: float = 0.0
    faults: Optional[FaultInjector] = None
    last_send_events: Tuple[str, ...] = ()

    def link_now(self) -> LinkProfile:
        """The link state at the current virtual clock."""
        if self.trace is None:
            return self.link
        return self.trace.link_at(self.elapsed_s)

    def advance(self, dt: float) -> None:
        """Advance the virtual clock without transmitting (compute time)."""
        if dt > 0:
            self.elapsed_s += dt

    def _trace_send_time(self, nbytes: int) -> float:
        bw, rtt, _ = self.trace.span_at(self.elapsed_s)
        t, now, remaining = rtt, self.elapsed_s + rtt, float(nbytes)
        while remaining > 0:
            bw, _, span = self.trace.span_at(now)
            can = bw * span                 # bytes this segment can carry
            if can >= remaining:
                dt = remaining / bw
                remaining = 0.0
            else:
                dt = span
                remaining -= can
            t += dt
            now += dt
        return t

    def _one_send(self, nbytes: int) -> float:
        if self.trace is None:
            t = nbytes / self.link.bandwidth + self.link.rtt_s
        else:
            t = self._trace_send_time(nbytes)
        self.sent_bytes += nbytes
        self.elapsed_s += t
        return t

    def send(self, nbytes: int) -> float:
        events = []
        t = 0.0
        if self.faults is not None:
            ev = self.faults.next_event()
            while ev is not None:
                events.append(ev.kind)
                if ev.kind == "stall":
                    self.elapsed_s += ev.stall_s
                    t += ev.stall_s
                    break               # delayed, then delivered
                t += self._one_send(nbytes)   # lost copy burns airtime ...
                ev = self.faults.next_event()  # ... retransmit = new attempt
        t += self._one_send(nbytes)
        self.last_send_events = tuple(events)
        if self.realtime:
            time.sleep(t)
        return t


class LinkShaper:
    """One token bucket modeling one physical link, shareable by many
    sockets.

    A wireless medium is a *shared* resource: every station associated
    with the access point contends for the same airtime. Modeling each
    TCP connection with its own private token bucket therefore multiplies
    the physical link by the number of connections. A ``LinkShaper`` is
    the fix — one bucket per physical medium; every ``ShapedSocket``
    wrapped around it draws tokens from the same budget, so N concurrent
    senders each see ~1/N of the modeled bandwidth.

    ``pace`` is thread-safe; the lock is deliberately held across the
    pacing sleep, which serializes concurrent senders exactly the way a
    busy channel serializes transmissions. With a ``trace``, the refill
    rate follows the trace at the wall-clock offset since construction.
    """

    def __init__(self, link: LinkProfile, trace: Optional[LinkTrace] = None,
                 burst_s: float = 0.05):
        self.link = link
        self.trace = trace
        self.burst_s = burst_s
        self._lock = threading.Lock()
        self._budget = 0.0
        self._t0 = time.perf_counter()
        self._last = self._t0

    def state(self, now: float):
        """(bandwidth, rtt_s) the shaper is enforcing right now."""
        if self.trace is None:
            return self.link.bandwidth, self.link.rtt_s
        return self.trace.state_at(now - self._t0)

    def pace(self, nbytes: int) -> None:
        """Block until the bucket can carry ``nbytes`` more bytes."""
        with self._lock:
            now = time.perf_counter()
            bw = self.state(now)[0]
            self._budget += (now - self._last) * bw
            self._budget = min(self._budget, bw * self.burst_s)
            self._last = now
            if nbytes > self._budget:
                need = (nbytes - self._budget) / bw
                time.sleep(need)
                self._last = time.perf_counter()
                self._budget = 0.0
            else:
                self._budget -= nbytes


class ShapedSocket:
    """Token-bucket pacing on top of a connected socket (both directions).

    By default each ShapedSocket owns a private ``LinkShaper``; pass
    ``shaper=`` to make several sockets contend for one modeled physical
    link (``serve_cloud`` does this — one bucket per server, so N
    concurrent edges share the medium instead of multiplying it).

    ``last_send_cost_s`` is the *modeled* link cost of the most recent
    ``sendall`` (bytes over the shaped bandwidth at send time, plus one
    RTT). The wall-clock a send took is a poor bandwidth signal here — the
    token bucket deliberately lets small frames burst through unpaced — so
    the adaptive estimator reads this modeled cost instead, which tracks
    whatever the (possibly trace-driven) shaper is currently enforcing.

    With ``faults`` set, every ``sendall`` consults the injector (each
    serving-stack ``sendall`` is exactly one wire frame): the frame may
    be dropped, corrupted, stalled, or the socket torn down mid-stream
    (``ConnectionResetError``) — see ``apply_send_fault``.
    """

    def __init__(self, sock: socket.socket, link: LinkProfile,
                 chunk: int = 16384, trace: Optional[LinkTrace] = None,
                 shaper: Optional[LinkShaper] = None,
                 faults: Optional[FaultInjector] = None):
        self.sock = sock
        self.shaper = shaper or LinkShaper(link, trace=trace)
        self.link = self.shaper.link
        self.chunk = chunk
        self.trace = self.shaper.trace
        self.faults = faults
        self.last_send_cost_s = 0.0

    def _state(self, now: float):
        """(bandwidth, rtt_s) the shaper is enforcing right now."""
        return self.shaper.state(now)

    def sendall(self, data: bytes) -> None:
        if self.faults is not None:
            ev = self.faults.next_event()
            if ev is not None:
                maybe = apply_send_fault(ev, data, self.sock)
                if maybe is None:             # frame lost in flight
                    self.last_send_cost_s = 0.0
                    return
                data = maybe
        cost, rtt = 0.0, 0.0
        for i in range(0, len(data), self.chunk):
            piece = data[i:i + self.chunk]
            self.shaper.pace(len(piece))
            self.sock.sendall(piece)
            bw, rtt = self._state(time.perf_counter())
            cost += len(piece) / bw
        self.last_send_cost_s = cost + rtt

    def recv_exact(self, n: int) -> bytes:
        return recv_exact(self.sock, n, self.chunk)

    def close(self) -> None:
        self.sock.close()
