"""Fault-tolerance policy and typed failure surface for collaborative
serving.

The paper's deployment is split inference over *wireless* links in the
field; links drop frames, stall, and die there. This module is the
recovery half of the fault story (the injection half lives in
``repro.core.collab.channel``):

- ``FaultPolicy`` — the serializable recovery contract carried as the
  ``faults`` section of a ``DeploymentPlan``: retry budget, exponential
  backoff with deterministic jitter, a per-request deadline, heartbeat
  interval, and what to do when the budget runs out (edge-only fallback
  or a raised error). Like the other optional plan sections it folds
  into the plan digest only when set, so pre-fault plans keep their
  digests byte-for-byte.
- ``RequestTimeout`` — the typed error replacing the historical
  hang-forever read on a dead cloud.
- ``ServerDraining`` / ``ServerBusy`` — typed signals decoded from the
  DRAIN and BUSY control frames: the server is not *failing*, it is
  restarting (drain-migrate) or shedding load (redirect), and a
  fleet-routed edge moves the request to another member instead of
  burning its fault budget.
- ``fault_record`` — the uniform per-request ``{faults, retries,
  migrations, fallback}`` accounting every backend (local, socket,
  streaming) attaches to its results.

The degradation ladder a policy drives, top to bottom: CRC catches the
corruption -> the deadline catches the hang -> retries with backoff ride
out transients (reconnect, re-HELLO, re-RESPLIT, replay by sequence
number) -> a fleet-routed edge reroutes to the next healthy server
(DRAIN/BUSY migrate without spending faults) -> edge-only fallback
serves the request from the ``SplitFnBank`` c=N pair, bit-identical to
an all-edge split, only once the whole fleet is gone -> the adaptive
controller treats the outage as bandwidth→0 and re-splits back once the
link heals.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Dict, Optional

#: what to do when the retry budget / deadline is exhausted
FALLBACK_MODES = ("edge", "fail")


class RequestTimeout(TimeoutError):
    """A collaborative-inference request exceeded its deadline waiting
    on the cloud (connect, send, or response read). Replaces the silent
    forever-block of a plain socket read against a dead peer; subclass
    of ``TimeoutError`` (hence ``OSError``), so generic socket-error
    handling still catches it."""


class ServerDraining(ConnectionError):
    """The cloud answered a request with a DRAIN control frame: it is
    flushing for a rolling restart and admits nothing new. Not a fault —
    a fleet-routed edge migrates to the next healthy member and replays
    the request there (zero failed requests across a rolling drain)."""


class ServerBusy(ConnectionError):
    """The cloud answered a request with a BUSY backpressure frame: the
    bounded batching lane is saturated (shed reason mirrors the fleet
    simulator's admission vocabulary). With ``redirect`` set, a
    fleet-routed edge replays the request on another member immediately
    instead of queueing behind the overload."""

    def __init__(self, reason: str = "queue", redirect: bool = True):
        super().__init__(f"server shed request (reason={reason!r}, "
                         f"redirect={redirect})")
        self.reason = reason
        self.redirect = redirect


def fault_record(faults: int = 0, retries: int = 0,
                 fallback: bool = False,
                 migrations: int = 0) -> Dict[str, object]:
    """The uniform per-request fault accounting record all backends
    report: ``faults`` = failures observed serving this request,
    ``retries`` = recovery attempts spent, ``migrations`` = DRAIN/BUSY
    reroutes to another fleet member, ``fallback`` = True when the
    request was served edge-only after exhausting the retry budget."""
    return {"faults": int(faults), "retries": int(retries),
            "migrations": int(migrations), "fallback": bool(fallback)}


@dataclass(frozen=True)
class FaultPolicy:
    """Serializable recovery contract for a collaborative deployment.

    Fields (units spelled out, all keys unit-suffixed in JSON):

    - ``max_retries``: recovery attempts per request after the first
      failure; 0 means fail (or fall back) on the first fault.
    - ``backoff_base_s`` / ``backoff_max_s``: exponential backoff —
      attempt k sleeps ``min(base * 2**k, max)`` seconds before
      reconnecting.
    - ``backoff_jitter``: multiplicative jitter fraction in [0, 1];
      each sleep is scaled by ``1 + jitter * u`` with ``u ~ U[0, 1)``
      drawn from a ``seed``-ed RNG, so backoff timing is deterministic
      per client while still de-synchronizing a fleet.
    - ``request_deadline_s``: wall-clock budget for one request
      including all retries; also applied as the socket read timeout,
      so a dead cloud raises ``RequestTimeout`` instead of hanging.
    - ``heartbeat_s``: edge keepalive interval; 0 disables. A cloud
      serving this policy reaps clients silent for
      ``3 * heartbeat_s``.
    - ``fallback``: ``"edge"`` serves the request locally from the
      c=N split pair when retries exhaust (bit-identical logits to an
      all-edge deployment); ``"fail"`` re-raises the last error.
    - ``seed``: RNG seed for the jitter draws.
    """
    max_retries: int = 3
    backoff_base_s: float = 0.05
    backoff_max_s: float = 2.0
    backoff_jitter: float = 0.5
    request_deadline_s: float = 10.0
    heartbeat_s: float = 0.0
    fallback: str = "edge"
    seed: int = 0

    def __post_init__(self) -> None:
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if self.backoff_base_s < 0 or self.backoff_max_s < 0:
            raise ValueError("backoff times must be >= 0")
        if not 0.0 <= self.backoff_jitter <= 1.0:
            raise ValueError("backoff_jitter must be in [0, 1]")
        if self.request_deadline_s <= 0:
            raise ValueError("request_deadline_s must be > 0")
        if self.heartbeat_s < 0:
            raise ValueError("heartbeat_s must be >= 0")
        if self.fallback not in FALLBACK_MODES:
            raise ValueError(f"fallback must be one of {FALLBACK_MODES}")

    def attempt_timeout_s(self) -> float:
        """Socket read timeout for ONE attempt: the per-request deadline
        split across the first try plus every retry, so a lost response
        burns one attempt's slice of the budget — not all of it — and
        the remaining slices still fit the replays. (A policy with no
        retries reads with the full deadline.)"""
        return self.request_deadline_s / (self.max_retries + 1)

    def make_rng(self) -> random.Random:
        """A fresh deterministic RNG for this policy's jitter draws."""
        return random.Random(self.seed)

    def backoff_s(self, attempt: int,
                  rng: Optional[random.Random] = None) -> float:
        """Seconds to sleep before recovery attempt ``attempt``
        (0-based): capped exponential backoff plus deterministic
        jitter from ``rng`` (jitter-free when ``rng`` is None)."""
        base = min(self.backoff_base_s * (2.0 ** attempt),
                   self.backoff_max_s)
        if rng is None or self.backoff_jitter == 0.0:
            return base
        return base * (1.0 + self.backoff_jitter * rng.random())

    def to_json(self) -> Dict[str, object]:
        """Plain-dict form for ``plan.json`` and the digest fold."""
        return {
            "max_retries": self.max_retries,
            "backoff_base_s": self.backoff_base_s,
            "backoff_max_s": self.backoff_max_s,
            "backoff_jitter": self.backoff_jitter,
            "request_deadline_s": self.request_deadline_s,
            "heartbeat_s": self.heartbeat_s,
            "fallback": self.fallback,
            "seed": self.seed,
        }

    @classmethod
    def from_json(cls, doc: Dict[str, object]) -> "FaultPolicy":
        """Rebuild a policy from its ``to_json`` dict."""
        return cls(
            max_retries=int(doc.get("max_retries", 3)),
            backoff_base_s=float(doc.get("backoff_base_s", 0.05)),
            backoff_max_s=float(doc.get("backoff_max_s", 2.0)),
            backoff_jitter=float(doc.get("backoff_jitter", 0.5)),
            request_deadline_s=float(doc.get("request_deadline_s", 10.0)),
            heartbeat_s=float(doc.get("heartbeat_s", 0.0)),
            fallback=str(doc.get("fallback", "edge")),
            seed=int(doc.get("seed", 0)),
        )
