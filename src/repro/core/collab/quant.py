"""Quantized kernel edge path — the layer that makes the pruning payoff
*physical* (ROADMAP item 3).

The paper's edge submodel historically ran fp32 dense ``jnp`` even after
compaction, so every latency/energy number downstream of ``sweep_splits``
was modeled, never measured. This module wires the edge forward through
the ``kernels/masked_matmul`` column-masked GEMM instead:

  * **conv layers** lower to im2col
    (``jax.lax.conv_general_dilated_patches``, channel-major patch
    features) followed by one masked GEMM against the HWIO weights
    re-laid-out as ``(Cin*kh*kw, Cout)``;
  * **dense layers** are the masked GEMM directly;
  * relu / maxpool / flatten keep the exact ``models.cnn.cnn_apply``
    ops, so those layers stay bit-identical to the dense reference.

Weights are optionally quantized to int8/int4 **per output channel**
with the wire codec's proven affine math
(``protocol.affine_quantize`` — the same min/max, rint, clip formula
every int8 feature frame already round-trips through), giving the
provable per-layer contract

    |dequant(w) - w| <= scale_n / 2        (per output channel n)

and therefore, for a GEMM row ``x``,

    |y_quant - y_fp32|_n <= (scale_n / 2) * ||x||_1

(``gemm_error_bound``). ``weight_bits=None`` keeps fp32 weights and
changes only the dispatch — the differential suite pins that
configuration bit-identical between the Pallas kernel (interpret mode,
whole-array blocks) and its pure-XLA ``ref`` twin.

Backend resolution (``resolve_backend``):

  * ``"ref"``    — pure-XLA im2col + ``masked_matmul_ref`` (the fast CPU
    path: XLA's native GEMM, used for wall-clock benchmarking on CI);
  * ``"pallas"`` — the real kernel body; interpret mode is forced on CPU
    (or under ``kernels.dispatch.use_pallas(interpret=True)``), compiled
    elsewhere;
  * ``"auto"``   — ``pallas`` when the global dispatch switch is on or a
    real accelerator backs JAX, else ``ref``.

``SplitFnBank`` consumes this module when a ``DeploymentPlan`` carries a
``quant`` section: the *edge* closures of every candidate split dispatch
through ``quant_cnn_apply`` while the cloud halves stay fp32 dense (the
server is not the device the paper quantizes for). See
``docs/quantized-edge.md``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CNNConfig
from repro.core.collab.protocol import affine_quantize
from repro.kernels import dispatch
from repro.kernels.masked_matmul.ops import masked_matmul
from repro.kernels.masked_matmul.ref import masked_matmul_ref

#: affine code-point count per bit width (the codec uses 255 for int8)
BITS_LEVELS: Dict[int, int] = {8: 255, 4: 15}
BACKENDS: Tuple[str, ...] = ("auto", "pallas", "ref")
CALIBRATIONS: Tuple[str, ...] = ("minmax",)

#: "whole-array" block request: ops.py clamps each block to the actual
#: dim, collapsing the grid to (1, 1, 1) — in interpret mode that makes
#: the kernel body ONE dot_general over the unpadded operands, which is
#: bit-identical to the XLA ref GEMM (the basis of the differential
#: suite's exactness contract). Compiled TPU runs keep the native 128s.
WHOLE_BLOCK = 1 << 30


@dataclass(frozen=True)
class QuantPolicy:
    """The ``quant`` section of a ``DeploymentPlan``: how the edge
    submodel's conv/dense layers execute.

    ``weight_bits`` — 8 or 4 for per-channel affine weight quantization,
    ``None`` for fp32 weights (kernel dispatch only — the bit-identity
    configuration). ``per_channel`` quantizes each output channel with
    its own (scale, zero); ``False`` uses one pair per tensor.
    ``backend`` picks the GEMM implementation (see module docstring);
    ``calibration`` names the range estimator (only ``"minmax"`` — the
    codec's — exists today). Folded into the plan digest **only when
    set**, like the other optional sections: both peers must agree on
    the edge's numerics for golden-logits comparisons to mean anything.
    """
    weight_bits: Optional[int] = 8
    per_channel: bool = True
    backend: str = "auto"
    calibration: str = "minmax"

    def __post_init__(self) -> None:
        if self.weight_bits is not None and self.weight_bits not in BITS_LEVELS:
            raise ValueError(f"weight_bits must be one of "
                             f"{sorted(BITS_LEVELS)} or None, "
                             f"got {self.weight_bits!r}")
        if self.backend not in BACKENDS:
            raise ValueError(f"unknown backend {self.backend!r} "
                             f"(use {BACKENDS})")
        if self.calibration not in CALIBRATIONS:
            raise ValueError(f"unknown calibration {self.calibration!r} "
                             f"(use {CALIBRATIONS})")

    def to_json(self) -> Dict[str, Any]:
        """Serializable section dict (``weight_bits`` is the only
        dimensioned key; the rest are enums/flags)."""
        return {"weight_bits": self.weight_bits,
                "per_channel": self.per_channel,
                "backend": self.backend,
                "calibration": self.calibration}

    @classmethod
    def from_json(cls, doc: Dict[str, Any]) -> "QuantPolicy":
        """Inverse of ``to_json`` (absent keys take the defaults)."""
        return cls(weight_bits=doc.get("weight_bits"),
                   per_channel=bool(doc.get("per_channel", True)),
                   backend=doc.get("backend", "auto"),
                   calibration=doc.get("calibration", "minmax"))

    def describe(self) -> str:
        """Short human summary, e.g. ``int8/pc@auto`` or ``fp32@ref``."""
        w = ("fp32" if self.weight_bits is None
             else f"int{self.weight_bits}"
                  + ("/pc" if self.per_channel else "/pt"))
        return f"{w}@{self.backend}"


def resolve_backend(policy: QuantPolicy) -> Tuple[str, bool]:
    """-> (``"pallas"`` | ``"ref"``, interpret). Resolved once at bank
    build time; the Pallas kernel always interprets on CPU hosts (there
    is no Mosaic CPU lowering) and compiles on real accelerators."""
    on_cpu = jax.default_backend() == "cpu"
    if policy.backend == "ref":
        return "ref", False
    if policy.backend == "pallas" or dispatch.enabled():
        return "pallas", bool(dispatch.interpret() or on_cpu)
    return ("ref", False) if on_cpu else ("pallas", False)


# ---------------------------------------------------------------------------
# weight quantization (the codec's affine math, per output channel)
# ---------------------------------------------------------------------------
def quantize_weights(w: np.ndarray, bits: int, per_channel: bool = True
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Quantize a weight tensor's values onto ``BITS_LEVELS[bits]`` code
    points with ``protocol.affine_quantize`` — per slice of the LAST
    axis (the output channel) when ``per_channel``. Returns
    (uint8 codes in ``w``'s shape, scale, zero); scale/zero are float32
    arrays of shape ``(N,)`` (or scalars for per-tensor)."""
    levels = BITS_LEVELS[bits]
    w = np.asarray(w, np.float32)
    if not per_channel:
        q, s, z = affine_quantize(w, levels)
        return q, np.float32(s), np.float32(z)
    flat = w.reshape(-1, w.shape[-1])
    codes = np.empty(flat.shape, np.uint8)
    scale = np.empty(flat.shape[-1], np.float32)
    zero = np.empty(flat.shape[-1], np.float32)
    for n in range(flat.shape[-1]):
        codes[:, n], scale[n], zero[n] = affine_quantize(flat[:, n], levels)
    return codes.reshape(w.shape), scale, zero


def conv_weight_gemm_layout(w: np.ndarray) -> np.ndarray:
    """HWIO conv weights ``(kh, kw, Cin, N)`` -> the im2col GEMM operand
    ``(Cin*kh*kw, N)``. The row order is channel-major ``(c, kh, kw)``
    to match ``conv_general_dilated_patches``'s NHWC feature layout."""
    kh, kw, cin, n = w.shape
    return np.transpose(np.asarray(w, np.float32),
                        (2, 0, 1, 3)).reshape(cin * kh * kw, n)


def quantize_params(params, cfg: CNNConfig,
                    policy: QuantPolicy) -> Dict[str, Dict[str, jnp.ndarray]]:
    """Resolve the deployed (post-compaction) params into the quantized
    GEMM-layout bank ``quant_cnn_apply`` consumes: per conv/dense layer
    either ``{"wq", "scale", "zero", "b"}`` (quantized codes + affine
    qparams) or ``{"w", "b"}`` (fp32, ``weight_bits=None``), with conv
    weights already in im2col layout. Biases are never quantized (they
    are O(N) values the codec bound would dominate for nothing)."""
    out: Dict[str, Dict[str, jnp.ndarray]] = {}
    for i, spec in enumerate(cfg.layers):
        if spec.kind not in ("conv", "dense"):
            continue
        p = params[f"l{i}"]
        w = np.asarray(p["w"], np.float32)
        if spec.kind == "conv":
            w = conv_weight_gemm_layout(w)
        b = jnp.asarray(p["b"], jnp.float32)
        if policy.weight_bits is None:
            out[f"l{i}"] = {"w": jnp.asarray(w), "b": b}
        else:
            codes, scale, zero = quantize_weights(
                w, policy.weight_bits, policy.per_channel)
            out[f"l{i}"] = {"wq": jnp.asarray(codes),
                            "scale": jnp.asarray(scale),
                            "zero": jnp.asarray(zero), "b": b}
    return out


def dequantize_weights(lp: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    """The traced dequant: codes * scale + zero (broadcast over the
    output-channel axis), or the fp32 passthrough."""
    if "wq" in lp:
        return lp["wq"].astype(jnp.float32) * lp["scale"] + lp["zero"]
    return lp["w"]


def gemm_error_bound(x: jnp.ndarray, scale) -> jnp.ndarray:
    """Elementwise bound on ``|GEMM(x, dequant(w)) - GEMM(x, w)|``: each
    weight of output channel n is off by at most ``scale_n / 2`` (the
    affine codec contract), so output n errs by at most
    ``(scale_n / 2) * ||x_row||_1``. Shape broadcasts to ``(..., N)``;
    float32 accumulation adds only relative-eps slack on top."""
    s = jnp.atleast_1d(jnp.asarray(scale, jnp.float32))
    l1 = jnp.sum(jnp.abs(x), axis=-1, keepdims=True)
    return l1 * (s * 0.5)


# ---------------------------------------------------------------------------
# the kernel-dispatched forward
# ---------------------------------------------------------------------------
def _gemm(x: jnp.ndarray, w2: jnp.ndarray, mvec: jnp.ndarray,
          backend: str, interpret: bool) -> jnp.ndarray:
    if backend == "ref":
        return masked_matmul_ref(x, w2, mvec)
    if interpret:
        return masked_matmul(x, w2, mvec, block_m=WHOLE_BLOCK,
                             block_n=WHOLE_BLOCK, block_k=WHOLE_BLOCK,
                             interpret=True)
    return masked_matmul(x, w2, mvec)


def quant_cnn_apply(qparams, cfg: CNNConfig, x: jnp.ndarray,
                    masks: Optional[Dict[int, jnp.ndarray]] = None,
                    start_layer: int = 0, stop_layer: Optional[int] = None,
                    backend: str = "ref", interpret: bool = False):
    """``models.cnn.cnn_apply`` with conv/dense dispatched through the
    masked GEMM kernel over a ``quantize_params`` bank.

    The channel mask rides in the kernel's fused epilogue, and the bias
    is added pre-masked (``b * mask``) so the result matches the dense
    reference's ``(conv(x) + b) * mask`` exactly. relu / maxpool /
    flatten are the reference ops verbatim.
    """
    masks = masks or {}
    stop = stop_layer if stop_layer is not None else len(cfg.layers)
    for i in range(start_layer, stop):
        spec = cfg.layers[i]
        if spec.kind == "conv":
            lp = qparams[f"l{i}"]
            w2 = dequantize_weights(lp)          # (Cin*kh*kw, N)
            patches = jax.lax.conv_general_dilated_patches(
                x, (spec.kernel, spec.kernel),
                (spec.stride, spec.stride),
                [(spec.padding, spec.padding)] * 2,
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            mvec = (masks[i].astype(jnp.float32) if i in masks
                    else jnp.ones((w2.shape[1],), jnp.float32))
            x = _gemm(patches, w2, mvec, backend, interpret) + lp["b"] * mvec
        elif spec.kind == "relu":
            x = jax.nn.relu(x)
        elif spec.kind == "maxpool":
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max,
                (1, spec.kernel, spec.kernel, 1),
                (1, spec.stride, spec.stride, 1), "VALID")
        elif spec.kind == "flatten":
            x = x.reshape(x.shape[0], -1)
        elif spec.kind == "dense":
            lp = qparams[f"l{i}"]
            w2 = dequantize_weights(lp)
            mvec = (masks[i].astype(jnp.float32) if i in masks
                    else jnp.ones((w2.shape[1],), jnp.float32))
            x = _gemm(x, w2, mvec, backend, interpret) + lp["b"] * mvec
    return x


# ---------------------------------------------------------------------------
# kernel-cost calibration (feeds latency_model.KernelCalibration)
# ---------------------------------------------------------------------------
def calibrate_quant_edge(qparams, cfg: CNNConfig, x,
                         masks: Optional[Dict[int, jnp.ndarray]] = None,
                         backend: str = "ref", interpret: bool = False,
                         repeats: int = 3):
    """Measure the quantized kernel path's per-layer wall-clock on this
    host -> ``KernelCalibration`` whose ``layer_s`` plugs straight into
    ``sweep_splits(..., measured_device_s=...)`` (Algorithm 1 line 22's
    timestamp hook, now over the *deployed* kernels instead of the fp32
    dense graph)."""
    from repro.core.partition.latency_model import KernelCalibration
    fns = [jax.jit(lambda v, s=i: quant_cnn_apply(
               qparams, cfg, v, masks=masks, start_layer=s,
               stop_layer=s + 1, backend=backend, interpret=interpret))
           for i in range(len(cfg.layers))]
    return KernelCalibration.measure(fns, jnp.asarray(x), repeats=repeats)
