"""AMC-style pruning environment (paper §3.2, Eq. 1).

The environment walks the prunable layers of a model; the agent emits a
preserve ratio a_i per layer. State s_i is the Eq. 1 descriptor

    (i, n, c, h, w, stride, k, FLOPs[i], F_rdc, F_rest, a_{i-1})

normalized feature-wise to [0, 1]. Actions are clipped AMC-style so the
episode can always still reach the global FLOPs budget: at layer i the
maximum allowed preserve ratio is the one that — even if every later layer
is pruned to its floor — keeps total FLOPs within budget.

The environment is model-agnostic: it takes a list of LayerDesc and an
``evaluate(ratios) -> accuracy`` callback, so the same machinery prunes the
paper's AlexNet and any assigned transformer (see masks.py for the unit
mapping).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Sequence

import numpy as np

from repro.configs.base import CNNConfig, ModelConfig
from repro.models.cnn import layer_shapes, prunable_layers

STATE_DIM = 11


@dataclass
class LayerDesc:
    index: int
    n: int            # out units
    c: int            # in units
    h: int
    w: int
    stride: int
    k: int
    flops: float
    in_coupled: bool = True   # does pruning layer i-1 shrink this layer's input?


def cnn_layer_descs(cfg: CNNConfig) -> List[LayerDesc]:
    shapes = layer_shapes(cfg)
    descs = []
    c_in = cfg.input_channels
    h_in, w_in = cfg.input_hw
    flat_in = None
    for i, spec in enumerate(cfg.layers):
        if spec.kind == "conv":
            c_out, h, w = shapes[i]
            fl = 2.0 * h * w * c_out * c_in * spec.kernel ** 2
            descs.append(LayerDesc(i, c_out, c_in, h, w, spec.stride,
                                   spec.kernel, fl))
            c_in, h_in, w_in = c_out, h, w
        elif spec.kind in ("maxpool",):
            c_in, h_in, w_in = shapes[i]
        elif spec.kind == "flatten":
            flat_in = shapes[i][0]
        elif spec.kind == "dense":
            d_in = flat_in if flat_in is not None else shapes[i - 1][0]
            fl = 2.0 * d_in * spec.features
            descs.append(LayerDesc(i, spec.features, d_in, 1, 1, 1, 1, fl))
            flat_in = spec.features
    keep = set(prunable_layers(cfg))
    return [d for d in descs if d.index in keep]


def transformer_layer_descs(cfg: ModelConfig, seq_len: int = 512
                            ) -> List[LayerDesc]:
    """LayerDesc per prunable (layer, axis) unit — matches
    masks.transformer_prunable_units ordering."""
    from repro.core.pruning.masks import transformer_prunable_units
    descs = []
    d = cfg.d_model
    for idx, u in enumerate(transformer_prunable_units(cfg)):
        if u["axis"] == "head_mask":
            per_head = cfg.head_dim if cfg.attention != "mla" else (
                cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim
                + cfg.mla.v_head_dim)
            fl = 2.0 * seq_len * (2 * d * per_head * u["n_units"]
                                  + 2 * seq_len * per_head * u["n_units"])
            descs.append(LayerDesc(idx, u["n_units"], d, seq_len, 1, 1, 1,
                                   fl, in_coupled=False))
        elif u["axis"] == "ffn_mask":
            mult = 3 if cfg.activation in ("silu_glu", "geglu") else 2
            fl = 2.0 * seq_len * d * u["n_units"] * mult
            descs.append(LayerDesc(idx, u["n_units"], d, seq_len, 1, 1, 1,
                                   fl, in_coupled=False))
        elif u["axis"] == "expert_mask":
            m = cfg.moe
            mult = 3 if cfg.activation in ("silu_glu", "geglu") else 2
            fl = 2.0 * seq_len * m.top_k * d * m.d_expert * mult
            descs.append(LayerDesc(idx, u["n_units"], d, seq_len, 1, 1, 1,
                                   fl, in_coupled=False))
        elif u["axis"] == "ssm_head_mask":
            s = cfg.ssm
            fl = 2.0 * seq_len * (2 * d * s.head_dim * u["n_units"]
                                  + s.head_dim * u["n_units"] * s.d_state * 4)
            descs.append(LayerDesc(idx, u["n_units"], d, seq_len, 1, 1, 1,
                                   fl, in_coupled=False))
    return descs


class PruningEnv:
    """Episode = one pass over prunable layers."""

    def __init__(self, descs: Sequence[LayerDesc],
                 evaluate: Callable[[List[float]], float],
                 flops_budget: float = 0.5,
                 action_floor: float = 0.1):
        self.descs = list(descs)
        self.evaluate = evaluate
        self.budget = flops_budget
        self.floor = action_floor
        self.total_flops = sum(d.flops for d in self.descs)
        self._norm = self._feature_norms()

    def _feature_norms(self) -> np.ndarray:
        feats = np.array([[d.index, d.n, d.c, d.h, d.w, d.stride, d.k,
                           d.flops, self.total_flops, self.total_flops, 1.0]
                          for d in self.descs], np.float32)
        return np.maximum(feats.max(0), 1e-9)

    def state(self, i: int, f_rdc: float, f_rest: float,
              a_prev: float) -> np.ndarray:
        d = self.descs[i]
        raw = np.array([d.index, d.n, d.c, d.h, d.w, d.stride, d.k,
                        d.flops, f_rdc, f_rest, a_prev], np.float32)
        return raw / self._norm

    def clip_action(self, i: int, a: float, f_rdc: float) -> float:
        """AMC resource-constrained clipping: keep the budget reachable."""
        d = self.descs[i]
        f_rest = sum(x.flops for x in self.descs[i + 1:])
        # best case: later layers pruned to floor
        rest_min = f_rest * self.floor
        target = self.budget * self.total_flops
        # flops kept so far + a*f_i + rest_min <= target  =>  a <= a_max
        kept_so_far = sum(x.flops for x in self.descs[:i]) - f_rdc
        a_max = (target - kept_so_far - rest_min) / max(d.flops, 1e-9)
        return float(np.clip(a, self.floor, max(self.floor, min(1.0, a_max))))

    def run_episode(self, act: Callable[[np.ndarray, int], float]
                    ) -> Dict:
        """act(state, layer_index) -> raw action. Returns episode record."""
        f_rdc = 0.0
        a_prev = 1.0
        states, actions = [], []
        for i, d in enumerate(self.descs):
            f_rest = sum(x.flops for x in self.descs[i + 1:])
            s = self.state(i, f_rdc, f_rest, a_prev)
            a = self.clip_action(i, float(act(s, i)), f_rdc)
            states.append(s)
            actions.append(a)
            in_ratio = a_prev if d.in_coupled else 1.0
            f_rdc += d.flops * (1.0 - a * in_ratio)
            a_prev = a
        acc = float(self.evaluate(actions))
        kept = 1.0 - f_rdc / self.total_flops
        # terminal next-state: zeros
        next_states = states[1:] + [np.zeros(STATE_DIM, np.float32)]
        return {"states": states, "actions": actions, "reward": acc,
                "flops_kept": kept, "next_states": next_states}
