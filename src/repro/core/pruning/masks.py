"""Structured pruning masks — the actuator of the DDPG policy.

The paper prunes conv channels of AlexNet. The framework generalizes the
action "keep fraction a of layer i's structured units" to every family:

  CNN         conv out-channels / dense units        (the paper's case)
  dense attn  attention heads + FFN inner channels
  MoE         routed experts
  SSD         ssm heads

Importance ranking is L1 weight magnitude (as in AMC): the kept units are
the top-a fraction by importance, emitted as 0/1 masks. Masked execution is
mathematically identical to physical removal (see models/cnn.compact_params
for the deployment-time compaction of the CNN path).
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CNNConfig, ModelConfig
from repro.models.cnn import prunable_layers
from repro.models.transformer import layer_runs


def _topk_mask(importance: np.ndarray, keep_ratio: float,
               min_keep: int = 1) -> np.ndarray:
    n = importance.shape[0]
    k = max(min_keep, int(round(keep_ratio * n)))
    k = min(k, n)
    keep = np.argsort(-importance)[:k]
    m = np.zeros(n, np.float32)
    m[keep] = 1.0
    return m


# ---------------------------------------------------------------------------
# CNN (paper-faithful)
# ---------------------------------------------------------------------------
def cnn_layer_importance(params, cfg: CNNConfig, layer: int) -> np.ndarray:
    w = np.asarray(params[f"l{layer}"]["w"], np.float32)
    if w.ndim == 4:     # (kh, kw, cin, cout)
        return np.abs(w).sum((0, 1, 2))
    return np.abs(w).sum(0)      # dense (din, dout)


def cnn_masks_from_ratios(params, cfg: CNNConfig,
                          ratios: Dict[int, float]) -> Dict[int, jnp.ndarray]:
    masks = {}
    for layer, a in ratios.items():
        imp = cnn_layer_importance(params, cfg, layer)
        masks[layer] = jnp.asarray(_topk_mask(imp, float(a)))
    return masks


# ---------------------------------------------------------------------------
# transformer families
# ---------------------------------------------------------------------------
def transformer_prunable_units(cfg: ModelConfig) -> List[Dict]:
    """One entry per (layer, axis) the agent controls, in layer order.

    Each entry: {run, layer_in_run, layer, axis, n_units}.
    """
    units = []
    for r_idx, run in enumerate(layer_runs(cfg)):
        for j in range(run.count):
            layer = run.start + j
            if run.kind in ("attn", "attn_dense"):
                units.append(dict(run=r_idx, layer_in_run=j, layer=layer,
                                  axis="head_mask", n_units=cfg.num_heads))
                units.append(dict(run=r_idx, layer_in_run=j, layer=layer,
                                  axis="ffn_mask", n_units=cfg.d_ff))
            elif run.kind == "moe":
                units.append(dict(run=r_idx, layer_in_run=j, layer=layer,
                                  axis="head_mask", n_units=cfg.num_heads))
                units.append(dict(run=r_idx, layer_in_run=j, layer=layer,
                                  axis="expert_mask",
                                  n_units=cfg.moe.num_experts))
            elif run.kind == "ssm":
                units.append(dict(run=r_idx, layer_in_run=j, layer=layer,
                                  axis="ssm_head_mask", n_units=cfg.ssm_heads))
    return units


def _axis_importance(params, cfg: ModelConfig, unit: Dict) -> np.ndarray:
    rp = params["runs"][unit["run"]]
    j = unit["layer_in_run"]
    axis = unit["axis"]
    if axis == "head_mask":
        if cfg.attention == "mla":
            w = np.asarray(rp["attn"]["w_uv"][j], np.float32)  # (rank, H*vd)
            w = w.reshape(w.shape[0], cfg.num_heads, -1)
            return np.abs(w).sum((0, 2))
        w = np.asarray(rp["attn"]["wo"][j], np.float32)        # (H*D, d)
        return np.abs(w.reshape(cfg.num_heads, -1)).sum(1)
    if axis == "ffn_mask":
        w = np.asarray(rp["mlp"]["w_down"][j], np.float32)     # (dff, d)
        return np.abs(w).sum(1)
    if axis == "expert_mask":
        w = np.asarray(rp["moe"]["w_down"][j], np.float32)     # (E, de, d)
        return np.abs(w).sum((1, 2))
    if axis == "ssm_head_mask":
        P = cfg.ssm.head_dim
        w = np.asarray(rp["ssm"]["w_out"][j], np.float32)      # (d_in, d)
        return np.abs(w.reshape(cfg.ssm_heads, P, -1)).sum((1, 2))
    raise ValueError(axis)


def transformer_masks_from_ratios(params, cfg: ModelConfig,
                                  ratios: List[float],
                                  min_keep: Optional[Dict[str, int]] = None
                                  ) -> List[Optional[Dict[str, jnp.ndarray]]]:
    """ratios[k] is the preserve ratio for transformer_prunable_units()[k].

    Returns the per-run mask structure ``forward``/``decode_step`` accept:
    a list (one per run) of dicts axis -> (count, n_units) stacked masks.
    GQA head masks keep whole KV groups intact (kv-head multiples) so the
    grouped attention layout survives pruning.
    """
    units = transformer_prunable_units(cfg)
    assert len(ratios) == len(units), (len(ratios), len(units))
    min_keep = min_keep or {}
    runs = layer_runs(cfg)
    out: List[Optional[Dict[str, np.ndarray]]] = []
    for r_idx, run in enumerate(runs):
        axes: Dict[str, np.ndarray] = {}
        for unit, a in zip(units, ratios):
            if unit["run"] != r_idx:
                continue
            imp = _axis_importance(params, cfg, unit)
            if unit["axis"] == "head_mask" and cfg.attention != "mla":
                # prune whole GQA groups: average importance per group,
                # then expand back to heads
                g = cfg.num_heads // cfg.num_kv_heads
                gi = imp.reshape(cfg.num_kv_heads, g).mean(1)
                gm = _topk_mask(gi, float(a),
                                min_keep.get("head_mask", 1))
                m = np.repeat(gm, g)
            else:
                mk = min_keep.get(unit["axis"],
                                  cfg.moe.top_k + cfg.moe.num_shared
                                  if unit["axis"] == "expert_mask" else 1)
                m = _topk_mask(imp, float(a), mk)
            axes.setdefault(unit["axis"],
                            np.zeros((run.count, unit["n_units"]),
                                     np.float32))[unit["layer_in_run"]] = m
        out.append({k: jnp.asarray(v) for k, v in axes.items()} if axes
                   else None)
    return out


def mask_sparsity(masks) -> float:
    """Fraction of units removed across all masks."""
    tot = kept = 0
    for leaf in jax.tree_util.tree_leaves(masks):
        arr = np.asarray(leaf)
        tot += arr.size
        kept += arr.sum()
    return 1.0 - kept / max(tot, 1)
