"""DDPG agent for layer-wise sparsity search (paper §3.2, Eqs. 2-4).

Actor and critic are 2x300-unit MLPs (paper §4.2). The critic target is the
baseline-subtracted one-step return of Eq. 3 with gamma = 1; exploration uses
truncated-normal noise around the actor output (Eq. 4) with sigma_0 = 0.5
decaying exponentially after a warm-up number of episodes (paper: 100).

Pure JAX: networks are pytrees, updates are jitted; the replay buffer is a
small numpy ring (paper: 500 transitions).
"""
from __future__ import annotations

import functools
import math
from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np

HIDDEN = 300
ACTION_LO, ACTION_HI = 0.05, 1.0     # a in (0, 1]


def _mlp_init(key, sizes):
    params = []
    keys = jax.random.split(key, len(sizes) - 1)
    for k, (i, o) in zip(keys, zip(sizes[:-1], sizes[1:])):
        w = jax.random.normal(k, (i, o), jnp.float32) * math.sqrt(2.0 / i)
        params.append({"w": w, "b": jnp.zeros((o,), jnp.float32)})
    return params


def _mlp_apply(params, x, final_act=None):
    for i, lyr in enumerate(params):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(params) - 1:
            x = jax.nn.relu(x)
    return final_act(x) if final_act else x


def actor_apply(params, state):
    """state (..., S) -> action in (0, 1]."""
    a = _mlp_apply(params, state, jax.nn.sigmoid)[..., 0]
    return ACTION_LO + (ACTION_HI - ACTION_LO) * a


def critic_apply(params, state, action):
    x = jnp.concatenate([state, action[..., None]], -1)
    return _mlp_apply(params, x)[..., 0]


class AgentState(NamedTuple):
    actor: list
    critic: list
    actor_tgt: list
    critic_tgt: list
    actor_opt: Dict
    critic_opt: Dict
    step: jnp.ndarray


def init_agent(key, state_dim: int) -> AgentState:
    k1, k2 = jax.random.split(key)
    actor = _mlp_init(k1, [state_dim, HIDDEN, HIDDEN, 1])
    critic = _mlp_init(k2, [state_dim + 1, HIDDEN, HIDDEN, 1])
    zeros = lambda tree: jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p), tree)
    adam = lambda tree: {"m": zeros(tree), "v": zeros(tree)}
    return AgentState(actor, critic,
                      jax.tree_util.tree_map(jnp.copy, actor),
                      jax.tree_util.tree_map(jnp.copy, critic),
                      adam(actor), adam(critic), jnp.zeros((), jnp.int32))


def _adam_update(params, grads, opt, step, lr, b1=0.9, b2=0.999, eps=1e-8):
    m = jax.tree_util.tree_map(lambda mm, g: b1 * mm + (1 - b1) * g,
                               opt["m"], grads)
    v = jax.tree_util.tree_map(lambda vv, g: b2 * vv + (1 - b2) * g * g,
                               opt["v"], grads)
    t = step.astype(jnp.float32) + 1
    bc1, bc2 = 1 - b1 ** t, 1 - b2 ** t
    new = jax.tree_util.tree_map(
        lambda p, mm, vv: p - lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + eps),
        params, m, v)
    return new, {"m": m, "v": v}


@functools.partial(jax.jit, static_argnames=("gamma",))
def agent_update(agent: AgentState, batch, baseline, gamma: float = 1.0,
                 actor_lr: float = 1e-4, critic_lr: float = 1e-3,
                 tau: float = 0.01) -> Tuple[AgentState, Dict]:
    """One DDPG update on a sampled batch.

    batch: dict of (B, ...) arrays: state, action, reward, next_state, done.
    Implements Eq. 2 (critic MSE) with target Eq. 3:
       y = (r - b) + gamma * Q'(s', mu'(s'))        (gamma = 1, paper)
    """
    s, a = batch["state"], batch["action"]
    r, s2, done = batch["reward"], batch["next_state"], batch["done"]

    a2 = actor_apply(agent.actor_tgt, s2)
    q2 = critic_apply(agent.critic_tgt, s2, a2)
    y = (r - baseline) + gamma * (1.0 - done) * q2

    def critic_loss(cp):
        q = critic_apply(cp, s, a)
        return jnp.mean((y - q) ** 2)

    closs, cgrad = jax.value_and_grad(critic_loss)(agent.critic)
    new_critic, new_copt = _adam_update(agent.critic, cgrad,
                                        agent.critic_opt, agent.step,
                                        critic_lr)

    def actor_loss(ap):
        return -jnp.mean(critic_apply(new_critic, s, actor_apply(ap, s)))

    aloss, agrad = jax.value_and_grad(actor_loss)(agent.actor)
    new_actor, new_aopt = _adam_update(agent.actor, agrad, agent.actor_opt,
                                       agent.step, actor_lr)

    soft = lambda tgt, src: jax.tree_util.tree_map(
        lambda t, p: (1 - tau) * t + tau * p, tgt, src)
    return AgentState(new_actor, new_critic,
                      soft(agent.actor_tgt, new_actor),
                      soft(agent.critic_tgt, new_critic),
                      new_aopt, new_copt, agent.step + 1), {
        "critic_loss": closs, "actor_loss": aloss}


def truncated_normal_action(key, mu, sigma):
    """Eq. 4: a' ~ TN(mu, sigma^2) truncated to [ACTION_LO, ACTION_HI]."""
    lo = (ACTION_LO - mu) / jnp.maximum(sigma, 1e-6)
    hi = (ACTION_HI - mu) / jnp.maximum(sigma, 1e-6)
    z = jax.random.truncated_normal(key, lo, hi)
    return mu + sigma * z


class ReplayBuffer:
    """Ring buffer (paper: capacity 500)."""

    def __init__(self, state_dim: int, capacity: int = 500):
        self.capacity = capacity
        self.n = 0
        self.i = 0
        self.state = np.zeros((capacity, state_dim), np.float32)
        self.action = np.zeros((capacity,), np.float32)
        self.reward = np.zeros((capacity,), np.float32)
        self.next_state = np.zeros((capacity, state_dim), np.float32)
        self.done = np.zeros((capacity,), np.float32)

    def add(self, s, a, r, s2, done):
        j = self.i
        self.state[j], self.action[j] = s, a
        self.reward[j], self.next_state[j], self.done[j] = r, s2, done
        self.i = (j + 1) % self.capacity
        self.n = min(self.n + 1, self.capacity)

    def sample(self, rng: np.random.RandomState, batch: int):
        idx = rng.randint(0, self.n, size=batch)
        return {"state": jnp.asarray(self.state[idx]),
                "action": jnp.asarray(self.action[idx]),
                "reward": jnp.asarray(self.reward[idx]),
                "next_state": jnp.asarray(self.next_state[idx]),
                "done": jnp.asarray(self.done[idx])}
