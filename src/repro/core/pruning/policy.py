"""The pruning-policy search loop — Algorithm 1, lines 3-19.

Runs DDPG episodes over the PruningEnv, stores per-layer transitions with
the episode's terminal accuracy as the (shared) reward — AMC's credit
assignment — updates the agent from replay, and tracks the best strategy
found. Exploration noise sigma starts at 0.5, stays fixed for ``warmup``
episodes, then decays exponentially (paper §4.2).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import numpy as np

from repro.core.pruning.amc_env import STATE_DIM, PruningEnv
from repro.core.pruning.ddpg import (AgentState, ReplayBuffer, actor_apply,
                                     agent_update, init_agent,
                                     truncated_normal_action)


@dataclass
class SearchResult:
    best_ratios: List[float]
    best_reward: float
    best_flops_kept: float
    history: List[Dict] = field(default_factory=list)


def search_pruning_policy(env: PruningEnv,
                          episodes: int = 120,
                          warmup: int = 20,
                          sigma0: float = 0.5,
                          sigma_decay: float = 0.97,
                          batch_size: int = 32,
                          updates_per_episode: int = 5,
                          seed: int = 0,
                          log: Optional[Callable[[str], None]] = None
                          ) -> SearchResult:
    key = jax.random.PRNGKey(seed)
    rng = np.random.RandomState(seed)
    agent = init_agent(key, STATE_DIM)
    buf = ReplayBuffer(STATE_DIM, capacity=500)
    baseline = 0.0
    best = SearchResult([], -1.0, 0.0)
    sigma = sigma0

    for ep in range(episodes):
        key, ek = jax.random.split(key)
        ek_layers = jax.random.split(ek, max(len(env.descs), 1))

        def act(state, layer_idx):
            mu = float(actor_apply(agent.actor, state[None])[0])
            if ep < warmup:
                # pure exploration around mu with fixed sigma (paper: first
                # 100 iterations keep sigma = 0.5)
                return float(truncated_normal_action(
                    ek_layers[layer_idx], mu, sigma0))
            return float(truncated_normal_action(
                ek_layers[layer_idx], mu, sigma))

        rec = env.run_episode(act)
        r = rec["reward"]
        baseline = 0.95 * baseline + 0.05 * r if ep else r
        for t, (s, a, s2) in enumerate(zip(rec["states"], rec["actions"],
                                           rec["next_states"])):
            done = 1.0 if t == len(rec["states"]) - 1 else 0.0
            buf.add(s, a, r, s2, done)
        if buf.n >= batch_size:
            for _ in range(updates_per_episode):
                agent, _ = agent_update(agent, buf.sample(rng, batch_size),
                                        baseline)
        if ep >= warmup:
            sigma = max(sigma * sigma_decay, 0.02)
        if r > best.best_reward:
            best = SearchResult(list(rec["actions"]), r, rec["flops_kept"],
                                best.history)
        best.history.append({"episode": ep, "reward": r,
                             "flops_kept": rec["flops_kept"],
                             "sigma": sigma})
        if log and (ep % 10 == 0 or ep == episodes - 1):
            log(f"ep {ep:4d} reward={r:.4f} kept={rec['flops_kept']:.3f} "
                f"sigma={sigma:.3f} best={best.best_reward:.4f}")
    return best
