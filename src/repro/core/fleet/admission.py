"""SLO-driven admission control for the fleet hierarchy.

Every arrival is triaged before any tier spends cycles on it, the same
way the real serving stack's ``FaultPolicy`` triages a straggling
request — and deliberately *with* the same policy type: an
``SLOClass`` wraps a PR-6 ``FaultPolicy`` whose ``request_deadline_s``
is the class deadline and whose ``fallback`` selects what a
deadline-infeasible request degrades to (``"edge"`` -> run the whole
network locally, ``"fail"`` -> shed). No forked enum, no parallel
semantics to keep in sync.

Split decisions are not invented here either. ``SplitPlanner`` calls
the partition subsystem's own optimizers — ``energy_aware_split`` with
the adaptive controller's urgency-scaled battery weight for the
edge->cloudlet point ``c1``, ``greedy_split`` restricted to candidates
``>= c1`` for the cloudlet->cloud point ``c2`` — and memoizes by
(device class, link state, battery decile), which stays small because
``LinkTrace``s are piecewise constant: a 10k-edge fleet resolves to a
few dozen distinct planning states.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

from repro.core.collab.protocol import CODEC_TX_SCALE
from repro.core.fleet.population import SimEdge
from repro.core.fleet.scenario import FleetScenario
from repro.core.fleet.tiers import (CLOUD_SERVER, CLOUDLET_SERVER,
                                    backhaul_link)
from repro.core.partition.energy_model import (EnergyPolicy,
                                               urgency_scaled_weight)
from repro.core.partition.latency_model import (LayerCost,
                                                batched_segment_time)
from repro.core.partition.profiles import LinkProfile, TwoTierProfile
from repro.core.partition.splitter import energy_aware_split, greedy_split


@dataclass(frozen=True)
class RoutePlan:
    """The admission verdict for one request.

    ``route`` is ``"collab"`` (edge runs ``[0, c1)``, cloudlet
    ``[c1, c2)``, cloud ``[c2, N)``; ``c2 == c1`` encodes the
    spillover bypass — a backlogged cloudlet forwards straight to the
    cloud), ``"edge"`` (deadline-degraded
    local-only execution, the ``FaultPolicy(fallback="edge")``
    semantics), or ``"shed"`` with ``reason`` saying why
    (``"battery"``, ``"deadline"``, or a tier ``"queue"`` later in the
    pipeline). Latency fields are the *planning estimates* Eq. 5
    produced; the simulator then charges actual queueing/batching on
    top.
    """
    route: str
    reason: str = ""
    c1: int = 0
    c2: int = 0
    t_edge_s: float = 0.0
    t_tx_s: float = 0.0
    t_rest_est_s: float = 0.0


class SplitPlanner:
    """Memoized per-tier split decisions over the scenario's network.

    The edge decision prices the device against the *cloudlet* server
    (that is the machine its features land on), battery urgency scaling
    the energy weight exactly as ``AdaptiveSplitController`` does. The
    cloudlet decision then places ``c2`` for the remaining layers
    against the cloud over the wired backhaul — cached per ``c1``
    because the backhaul is static.
    """

    def __init__(self, scenario: FleetScenario,
                 costs: Sequence[LayerCost], input_bytes: float):
        self.scenario = scenario
        self.costs = costs
        self.input_bytes = input_bytes
        self.tx_scale = CODEC_TX_SCALE[scenario.codec]
        self.backhaul = backhaul_link(scenario.backhaul_mbps,
                                      scenario.backhaul_rtt_ms)
        self._edge_cache: Dict[Tuple, Tuple[int, float, float, float]] = {}
        self._cloudlet_cache: Dict[int, int] = {}

    def edge_decision(self, edge: SimEdge,
                      now: float) -> Tuple[int, float, float, float]:
        """(c1, T_D, T_TX, T_edge_only) for this edge's link/battery
        state at fleet time ``now``. Battery urgency is bucketed to
        deciles so the cache stays finite while still shifting the
        split as the budget drains."""
        bw, rtt = edge.link_state(now)
        decile = min(int(edge.battery_fraction * 10), 10)
        key = (edge.device_class, bw, rtt, decile)
        hit = self._edge_cache.get(key)
        if hit is None:
            profile = TwoTierProfile(
                edge.compute, CLOUDLET_SERVER,
                LinkProfile("fleet-link", bandwidth=bw, rtt_s=rtt))
            policy = EnergyPolicy(
                profile=edge.energy,
                energy_weight_s_per_j=self.scenario.energy_weight_s_per_j)
            # urgency at the decile's midpoint, not the exact fraction —
            # the cache key is the decile, so the cached decision must
            # not depend on which edge populated it first
            frac = 1.0 if decile >= 10 else (decile + 0.5) / 10.0
            weight = urgency_scaled_weight(
                self.scenario.energy_weight_s_per_j, frac)
            dec = energy_aware_split(self.costs, profile, self.input_bytes,
                                     policy, energy_weight=weight,
                                     tx_scale=self.tx_scale)
            local = next(r for r in dec.table
                         if r["split"] == len(self.costs))
            hit = (dec.split_point, dec.latency["T_D"],
                   dec.latency["T_TX"], local["T_D"])
            self._edge_cache[key] = hit
        return hit

    def cloudlet_decision(self, c1: int) -> int:
        """c2 >= c1: where the cloudlet hands the tail of the network to
        the cloud. ``sweep_splits``' device time over ``[0, c2)`` differs
        from the cloudlet's true ``[c1, c2)`` only by the constant
        ``[0, c1)`` prefix, so the restricted argmin is exact."""
        c2 = self._cloudlet_cache.get(c1)
        if c2 is None:
            profile = TwoTierProfile(CLOUDLET_SERVER, CLOUD_SERVER,
                                     self.backhaul)
            dec = greedy_split(self.costs, profile, self.input_bytes,
                               candidates=range(c1, len(self.costs) + 1),
                               tx_scale=self.tx_scale)
            c2 = dec.split_point
            self._cloudlet_cache[c1] = c2
        return c2

    def boundary_bytes(self, c: int) -> float:
        """Wire bytes crossing split ``c`` (codec-scaled)."""
        raw = (self.input_bytes if c == 0
               else self.costs[c - 1].out_bytes)
        return raw * self.tx_scale


class AdmissionController:
    """Deadline triage at the fleet's front door.

    ``decide`` builds the request's ``RoutePlan``: shed exhausted
    batteries outright, estimate the collaborative path end-to-end
    (edge compute + wireless tx + cloudlet backlog + cloudlet segment +
    backhaul + cloud backlog + cloud segment), and compare against the
    SLO deadline; an infeasible request degrades to edge-only when its
    ``FaultPolicy`` says ``fallback="edge"`` *and* local execution
    meets the deadline, else it is shed. The backlog terms come from
    the tiers' ``backlog_s`` estimates — a heuristic operator, so the
    met-deadline fraction in the rollup is the honest scoreboard.
    """

    def __init__(self, planner: SplitPlanner):
        self.planner = planner
        self.costs = planner.costs

    def decide(self, edge: SimEdge, now: float,
               cloudlet_backlog_s: float,
               cloud_backlog_s: float) -> RoutePlan:
        if edge.exhausted:
            return RoutePlan(route="shed", reason="battery")
        deadline = edge.slo.deadline_s
        c1, t_d, t_tx, t_local = self.planner.edge_decision(edge, now)
        c2 = self.planner.cloudlet_decision(c1)
        n = len(self.costs)
        link = self.planner.backhaul

        def t_backhaul(c: int) -> float:
            return (link.rtt_s
                    + self.planner.boundary_bytes(c) / link.bandwidth)

        # path A: cloudlet runs [c1, c2), cloud the rest (if any)
        t_cloudlet = batched_segment_time(self.costs, c1, c2,
                                          CLOUDLET_SERVER, 1) \
            if c2 > c1 else 0.0
        via_cloudlet = cloudlet_backlog_s + t_cloudlet
        if c2 < n:
            via_cloudlet += (t_backhaul(c2) + cloud_backlog_s
                             + batched_segment_time(self.costs, c2, n,
                                                    CLOUD_SERVER, 1))
        # path B: bypass a backlogged cloudlet, cloud runs [c1, N) —
        # the spillover that keeps an under-provisioned cloudlet tier
        # from dragging every deadline down with it
        via_cloud = (t_backhaul(c1) + cloud_backlog_s
                     + batched_segment_time(self.costs, c1, n,
                                            CLOUD_SERVER, 1)) \
            if c1 < n else float("inf")
        if via_cloud < via_cloudlet:
            c2, t_rest = c1, via_cloud      # c2 == c1 encodes the bypass
        else:
            t_rest = via_cloudlet
        est = t_d + t_tx + t_rest
        if c1 < n and est <= deadline:
            return RoutePlan(route="collab", c1=c1, c2=c2, t_edge_s=t_d,
                             t_tx_s=t_tx, t_rest_est_s=t_rest)
        if c1 == n:
            # the optimizer itself chose local-only — not a degradation
            return RoutePlan(route="edge", c1=n, c2=n, t_edge_s=t_local)
        # collaborative path infeasible: degrade per the SLO's
        # FaultPolicy fallback semantics, or shed
        if edge.slo.policy.fallback == "edge" and t_local <= deadline:
            return RoutePlan(route="edge", reason="deadline", c1=n, c2=n,
                             t_edge_s=t_local)
        return RoutePlan(route="shed", reason="deadline")
