"""Virtual-clock fleet simulation: heterogeneous edge populations,
an edge -> cloudlet -> cloud hierarchy, SLO admission, and energy
budgets — all priced by the same Eq. 5 / batching / trace models the
single-edge subsystems calibrate, all bit-reproducible per seed.
"""
from repro.core.fleet.admission import (AdmissionController, RoutePlan,
                                        SplitPlanner)
from repro.core.fleet.clock import EventQueue
from repro.core.fleet.metrics import (FleetMetrics, RequestRecord,
                                      percentile)
from repro.core.fleet.population import (DEVICE_CLASSES, SimEdge,
                                         build_population)
from repro.core.fleet.scenario import (DEFAULT_SLO_CLASSES, ArrivalPattern,
                                       ChaosEvent, FleetScenario, SLOClass)
from repro.core.fleet.simulator import FleetSimulator, simulate_fleet
from repro.core.fleet.tiers import (CLOUD_SERVER, CLOUDLET_SERVER,
                                    TierServer, TierStats, backhaul_link)

__all__ = [
    "AdmissionController", "ArrivalPattern", "CLOUD_SERVER",
    "CLOUDLET_SERVER", "ChaosEvent", "DEFAULT_SLO_CLASSES",
    "DEVICE_CLASSES", "EventQueue", "FleetMetrics", "FleetScenario",
    "FleetSimulator", "RequestRecord", "RoutePlan", "SLOClass", "SimEdge",
    "SplitPlanner", "TierServer", "TierStats", "backhaul_link",
    "build_population", "percentile", "simulate_fleet",
]
