"""Virtual clock + deterministic discrete-event queue for the fleet
simulator.

Real-socket benchmarks top out at ~8 concurrent edges on this container;
studying a 1k-10k-edge deployment needs a *virtual* clock — the same
device the single-edge ``SimChannel`` already keeps (``elapsed_s``),
promoted to fleet scope. ``EventQueue`` is a classic discrete-event
core: a heap of ``(time, seq, callback)`` entries popped in time order,
with a monotonically increasing sequence number breaking ties in
*insertion order*, so two events scheduled for the same instant always
fire in the same order — the property the determinism regression test
(same scenario seed, bit-identical metrics) leans on. Nothing in this
module (or anything it schedules) may read the wall clock; all time is
``now`` and all randomness comes from seeded ``random.Random`` streams
owned by the scenario (``repro.core.fleet.scenario``).
"""
from __future__ import annotations

import heapq
from typing import Callable, List, Tuple


class EventQueue:
    """A virtual-clock discrete-event queue.

    ``push(t, fn)`` schedules ``fn`` at virtual time ``t`` (>= ``now``);
    ``run_until(horizon)`` pops and fires events in ``(time, seq)``
    order, advancing ``now`` to each event's timestamp, until the queue
    is empty or the next event lies beyond the horizon. Events may push
    further events (that is how the whole simulation unrolls).
    """

    def __init__(self) -> None:
        self.now = 0.0
        self._seq = 0
        self._heap: List[Tuple[float, int, Callable[[], None]]] = []

    def push(self, t: float, fn: Callable[[], None]) -> None:
        """Schedule ``fn`` at virtual time ``t`` (clamped to ``now`` —
        the past is immutable in a discrete-event world)."""
        heapq.heappush(self._heap, (max(t, self.now), self._seq, fn))
        self._seq += 1

    def __len__(self) -> int:
        return len(self._heap)

    def run_until(self, horizon: float = float("inf")) -> int:
        """Fire events in timestamp order up to (and including)
        ``horizon``; returns the number of events fired. ``now`` ends at
        the last fired event (or ``horizon`` if finite and later)."""
        fired = 0
        while self._heap and self._heap[0][0] <= horizon:
            t, _, fn = heapq.heappop(self._heap)
            self.now = t
            fn()
            fired += 1
        if horizon < float("inf"):
            self.now = max(self.now, horizon)
        return fired
