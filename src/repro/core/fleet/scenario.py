"""``FleetScenario`` — the serializable description of one simulated
fleet (the plan's optional ``fleet`` section).

A scenario is to the fleet simulator what a ``DeploymentPlan`` is to one
edge/cloud pair: everything needed to reproduce a run, as pure data —
fleet size, the heterogeneous device mix (MCU / Pi / phone classes),
per-class link-trace mix and battery budgets, the diurnal arrival
pattern, the cloudlet tier's size and batching knobs, and the SLO
classes traffic is admitted under. Same scenario + same ``seed`` =>
bit-identical metrics (the determinism contract
``tests/test_fleet.py`` pins down).

The policy types are deliberately *reused*, not forked:

- an ``SLOClass`` wraps a PR-6 ``FaultPolicy`` — its
  ``request_deadline_s`` is the deadline and its ``fallback`` field is
  the admission controller's degradation semantics (``"edge"`` =>
  degrade to edge-only when the deadline cannot be met
  collaboratively, ``"fail"`` => shed);
- the cloudlet and cloud tiers batch with the PR-4 ``BatchingPolicy``
  and are priced by ``latency_model.batched_segment_time``;
- per-edge energy is priced through
  ``energy_model.EnergyProfile.request_energy`` — one formula, every
  call site.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, Tuple

from repro.core.collab.batching import BatchingPolicy
from repro.core.collab.faults import FaultPolicy

#: device classes a scenario may mix (profiles resolved in
#: ``repro.core.fleet.population.DEVICE_CLASSES``)
DEVICE_CLASS_NAMES = ("mcu", "pi", "phone")

#: chaos-event kinds a scenario may schedule against a cloudlet
CHAOS_KINDS = ("kill", "drain", "revive")


@dataclass(frozen=True)
class ChaosEvent:
    """One scheduled cloudlet-tier chaos event on the virtual clock —
    the simulator analogue of the serving stack's failover drills.

    ``kind``: ``"kill"`` crashes the cloudlet (queued and in-flight
    work is orphaned and rerouted to the next admitting cloudlet, or
    shed when none is left); ``"drain"`` stops admission for a rolling
    restart (queued work still flushes; new arrivals reroute);
    ``"revive"`` puts the cloudlet back in service. ``cloudlet`` is the
    target index (modulo the scenario's ``n_cloudlets``)."""
    t_s: float
    kind: str
    cloudlet: int = 0

    def __post_init__(self) -> None:
        if self.t_s < 0:
            raise ValueError("chaos event t_s must be >= 0")
        if self.kind not in CHAOS_KINDS:
            raise ValueError(f"chaos kind must be one of {CHAOS_KINDS}")
        if self.cloudlet < 0:
            raise ValueError("chaos event cloudlet must be >= 0")

    def to_json(self) -> Dict[str, Any]:
        """Serialize for ``plan.json`` (the digest-folded form)."""
        return {"t_s": self.t_s, "kind": self.kind,
                "cloudlet": self.cloudlet}

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "ChaosEvent":
        """Rebuild from its ``to_json`` dict."""
        return cls(t_s=float(d["t_s"]), kind=str(d["kind"]),
                   cloudlet=int(d["cloudlet"]))


@dataclass(frozen=True)
class SLOClass:
    """One service-level class: a share of the traffic and the PR-6
    recovery contract it is admitted under.

    ``policy.request_deadline_s`` is the class deadline (seconds);
    ``policy.fallback`` is what the admission controller does when the
    collaborative path cannot meet it: ``"edge"`` degrades the request
    to edge-only execution (the same graceful-degradation semantics
    ``EdgeClient.infer`` applies when its retry budget exhausts),
    ``"fail"`` sheds it.
    """
    name: str
    share: float
    policy: FaultPolicy

    def __post_init__(self) -> None:
        if not 0.0 < self.share <= 1.0:
            raise ValueError("SLO class share must be in (0, 1]")

    @property
    def deadline_s(self) -> float:
        """The class deadline in seconds (the policy's request
        deadline)."""
        return self.policy.request_deadline_s

    def to_json(self) -> Dict[str, Any]:
        """Serialize for ``plan.json`` (the digest-folded form)."""
        return {"name": self.name, "share": self.share,
                "policy": self.policy.to_json()}

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "SLOClass":
        """Rebuild from its ``to_json`` dict."""
        return cls(name=str(d["name"]), share=float(d["share"]),
                   policy=FaultPolicy.from_json(d["policy"]))


#: the default traffic mix: latency-critical scans, ordinary requests,
#: and bulk uploads that tolerate seconds but must not be dropped
DEFAULT_SLO_CLASSES = (
    SLOClass("interactive", 0.30,
             FaultPolicy(request_deadline_s=0.25, fallback="edge",
                         max_retries=0)),
    SLOClass("standard", 0.50,
             FaultPolicy(request_deadline_s=1.0, fallback="edge")),
    SLOClass("bulk", 0.20,
             FaultPolicy(request_deadline_s=10.0, fallback="fail")),
)


@dataclass(frozen=True)
class ArrivalPattern:
    """Seeded inhomogeneous-Poisson arrivals with a diurnal rate.

    Per-edge instantaneous rate at virtual time ``t``::

        rate(t) = base_rate_hz * (1 + diurnal_amplitude
                                  * sin(2*pi * (t + phase) / period_s))

    Each edge draws a seeded ``phase`` so the fleet's load swells and
    ebbs like a day of field traffic instead of moving in lockstep.
    Arrivals are generated by thinning against ``peak_rate_hz``
    (deterministic given the edge's RNG stream).
    """
    base_rate_hz: float = 0.08
    diurnal_amplitude: float = 0.6
    period_s: float = 60.0

    def __post_init__(self) -> None:
        if self.base_rate_hz <= 0:
            raise ValueError("base_rate_hz must be > 0")
        if not 0.0 <= self.diurnal_amplitude < 1.0:
            raise ValueError("diurnal_amplitude must be in [0, 1)")
        if self.period_s <= 0:
            raise ValueError("period_s must be > 0")

    @property
    def peak_rate_hz(self) -> float:
        """The thinning envelope: the diurnal maximum of ``rate(t)``."""
        return self.base_rate_hz * (1.0 + self.diurnal_amplitude)

    def rate_at(self, t: float, phase: float = 0.0) -> float:
        """Instantaneous per-edge arrival rate (requests/s) at ``t``."""
        return self.base_rate_hz * (
            1.0 + self.diurnal_amplitude
            * math.sin(2.0 * math.pi * (t + phase) / self.period_s))

    def to_json(self) -> Dict[str, Any]:
        """Serialize for ``plan.json`` (the digest-folded form)."""
        return {"base_rate_hz": self.base_rate_hz,
                "diurnal_amplitude": self.diurnal_amplitude,
                "period_s": self.period_s}

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "ArrivalPattern":
        """Rebuild from its ``to_json`` dict."""
        return cls(base_rate_hz=float(d["base_rate_hz"]),
                   diurnal_amplitude=float(d["diurnal_amplitude"]),
                   period_s=float(d["period_s"]))


def _mix_to_json(mix: Tuple[Tuple[str, float], ...]):
    return [[name, share] for name, share in mix]


def _mix_from_json(doc) -> Tuple[Tuple[str, float], ...]:
    return tuple((str(name), float(share)) for name, share in doc)


@dataclass(frozen=True)
class FleetScenario:
    """Everything one fleet simulation needs, as pure data.

    ``device_mix`` / ``trace_mix`` are ``(name, share)`` tuples over the
    registries (``population.DEVICE_CLASSES`` / ``profiles.TRACES``);
    ``battery_j`` gives each device class its per-edge battery budget in
    joules (drained through ``EnergyProfile.request_energy``);
    ``energy_weight_s_per_j`` is the fleet-wide exchange rate of the
    energy-aware split objective (urgency-scaled per edge as its battery
    drains, same formula as the adaptive controller);
    ``cloudlet_batching`` / ``cloud_batching`` are the per-tier dynamic
    batching knobs; ``backhaul_mbps`` / ``backhaul_rtt_ms`` the
    cloudlet->cloud metro link; ``max_queue`` the per-cloudlet admission
    bound (arrivals beyond it are shed at the cloudlet tier);
    ``chaos`` schedules cloudlet kill/drain/revive events on the
    virtual clock (default none — the section serializes only when
    set, so pre-chaos scenario digests are unchanged).
    """
    name: str
    seed: int = 0
    n_edges: int = 1000
    n_cloudlets: int = 8
    duration_s: float = 60.0
    device_mix: Tuple[Tuple[str, float], ...] = (
        ("mcu", 0.25), ("pi", 0.35), ("phone", 0.40))
    trace_mix: Tuple[Tuple[str, float], ...] = (
        ("wifi_steady", 0.40), ("wifi_degrading", 0.20),
        ("lte_handover", 0.20), ("congested_sawtooth", 0.20))
    slo_classes: Tuple[SLOClass, ...] = DEFAULT_SLO_CLASSES
    arrival: ArrivalPattern = field(default_factory=ArrivalPattern)
    battery_j: Tuple[Tuple[str, float], ...] = (
        ("mcu", 40.0), ("pi", 250.0), ("phone", 120.0))
    energy_weight_s_per_j: float = 0.02
    cloudlet_batching: BatchingPolicy = field(
        default_factory=lambda: BatchingPolicy(max_batch=16, max_wait_ms=5.0))
    cloud_batching: BatchingPolicy = field(
        default_factory=lambda: BatchingPolicy(max_batch=64, max_wait_ms=5.0))
    backhaul_mbps: float = 1000.0
    backhaul_rtt_ms: float = 10.0
    max_queue: int = 128
    codec: str = "fp32"
    chaos: Tuple[ChaosEvent, ...] = ()

    def __post_init__(self) -> None:
        if self.n_edges < 1 or self.n_cloudlets < 1:
            raise ValueError("n_edges and n_cloudlets must be >= 1")
        if self.duration_s <= 0:
            raise ValueError("duration_s must be > 0")
        for label, mix in (("device_mix", self.device_mix),
                           ("trace_mix", self.trace_mix)):
            if not mix:
                raise ValueError(f"{label} must not be empty")
            total = sum(share for _, share in mix)
            if abs(total - 1.0) > 1e-6:
                raise ValueError(f"{label} shares sum to {total}, not 1")
        for name, _ in self.device_mix:
            if name not in DEVICE_CLASS_NAMES:
                raise ValueError(f"unknown device class {name!r}; expected "
                                 f"one of {DEVICE_CLASS_NAMES}")
        slo_total = sum(s.share for s in self.slo_classes)
        if not self.slo_classes or abs(slo_total - 1.0) > 1e-6:
            raise ValueError(f"SLO class shares sum to {slo_total}, not 1")
        battery = dict(self.battery_j)
        for name, _ in self.device_mix:
            if battery.get(name, 0.0) <= 0:
                raise ValueError(f"device class {name!r} needs a positive "
                                 f"battery_j budget")
        if self.backhaul_mbps <= 0 or self.backhaul_rtt_ms < 0:
            raise ValueError("backhaul needs bandwidth > 0 and rtt >= 0")
        if self.max_queue < 1:
            raise ValueError("max_queue must be >= 1")
        if self.energy_weight_s_per_j < 0:
            raise ValueError("energy_weight_s_per_j must be >= 0")
        for ev in self.chaos:
            if not isinstance(ev, ChaosEvent):
                raise ValueError("chaos must hold ChaosEvent entries")

    def battery_for(self, device_class: str) -> float:
        """The per-edge battery budget (joules) of one device class."""
        return dict(self.battery_j)[device_class]

    def to_json(self) -> Dict[str, Any]:
        """Serialize for ``plan.json`` — the digest-folded form of the
        plan's ``fleet`` section (keys unit-suffixed where scalar; the
        ``chaos`` list appears only when events are scheduled, so
        pre-chaos digests are byte-for-byte unchanged)."""
        out = {
            "name": self.name, "seed": self.seed,
            "n_edges": self.n_edges, "n_cloudlets": self.n_cloudlets,
            "duration_s": self.duration_s,
            "device_mix": _mix_to_json(self.device_mix),
            "trace_mix": _mix_to_json(self.trace_mix),
            "slo_classes": [s.to_json() for s in self.slo_classes],
            "arrival": self.arrival.to_json(),
            "battery_j": _mix_to_json(self.battery_j),
            "energy_weight_s_per_j": self.energy_weight_s_per_j,
            "cloudlet_batching": self.cloudlet_batching.to_json(),
            "cloud_batching": self.cloud_batching.to_json(),
            "backhaul_mbps": self.backhaul_mbps,
            "backhaul_rtt_ms": self.backhaul_rtt_ms,
            "max_queue": self.max_queue, "codec": self.codec,
        }
        if self.chaos:
            out["chaos"] = [ev.to_json() for ev in self.chaos]
        return out

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "FleetScenario":
        """Rebuild a scenario from its ``to_json`` dict."""
        return cls(
            name=str(d["name"]), seed=int(d["seed"]),
            n_edges=int(d["n_edges"]), n_cloudlets=int(d["n_cloudlets"]),
            duration_s=float(d["duration_s"]),
            device_mix=_mix_from_json(d["device_mix"]),
            trace_mix=_mix_from_json(d["trace_mix"]),
            slo_classes=tuple(SLOClass.from_json(s)
                              for s in d["slo_classes"]),
            arrival=ArrivalPattern.from_json(d["arrival"]),
            battery_j=_mix_from_json(d["battery_j"]),
            energy_weight_s_per_j=float(d["energy_weight_s_per_j"]),
            cloudlet_batching=BatchingPolicy.from_json(
                d["cloudlet_batching"]),
            cloud_batching=BatchingPolicy.from_json(d["cloud_batching"]),
            backhaul_mbps=float(d["backhaul_mbps"]),
            backhaul_rtt_ms=float(d["backhaul_rtt_ms"]),
            max_queue=int(d["max_queue"]), codec=str(d["codec"]),
            chaos=tuple(ChaosEvent.from_json(ev)
                        for ev in d.get("chaos", ())),
        )

    def describe(self) -> str:
        """One-line human summary of the scenario."""
        mix = "/".join(f"{n}:{s:.0%}" for n, s in self.device_mix)
        slo = "/".join(f"{s.name}@{s.deadline_s:g}s"
                       for s in self.slo_classes)
        return (f"FleetScenario[{self.name}] {self.n_edges} edges "
                f"({mix}) -> {self.n_cloudlets} cloudlets -> cloud, "
                f"{self.duration_s:g}s virtual, SLO {slo}, seed "
                f"{self.seed}")
