"""Fleet metrics: per-request records in, one flat rollup out.

The rollup is the BENCH_fleet.json payload — every key unit-suffixed
per the bench-record convention, every value derived from the virtual
clock and the analytic models. No wall-clock second ever lands here:
two runs of the same scenario seed must produce byte-identical
rollups, and the determinism regression test holds us to it.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.fleet.scenario import FleetScenario
from repro.core.fleet.tiers import TierStats


def percentile(values: List[float], q: float) -> float:
    """Linear-interpolated percentile (``q`` in [0, 100]) — pure
    Python so the rollup never depends on numpy float modes."""
    if not values:
        return 0.0
    xs = sorted(values)
    if len(xs) == 1:
        return xs[0]
    pos = (len(xs) - 1) * q / 100.0
    lo = int(pos)
    hi = min(lo + 1, len(xs) - 1)
    return xs[lo] + (xs[hi] - xs[lo]) * (pos - lo)


@dataclass
class RequestRecord:
    """One finished (or shed) request, as the simulator saw it."""
    slo: str
    route: str                    # "collab" | "edge" | "shed"
    shed_reason: str = ""         # "battery" | "deadline" | "queue"
    latency_s: float = 0.0        # virtual-clock end-to-end, served only
    deadline_s: float = 0.0
    e_edge_j: float = 0.0
    tx_bytes: float = 0.0
    device_class: str = ""


@dataclass
class FleetMetrics:
    """Accumulates ``RequestRecord``s and rolls them up."""
    scenario: FleetScenario
    records: List[RequestRecord] = field(default_factory=list)
    chaos_reroutes: int = 0

    def add(self, rec: RequestRecord) -> None:
        self.records.append(rec)

    def note_reroute(self) -> None:
        """Count one chaos reroute (a request moved off a dead or
        draining cloudlet to another admitting one)."""
        self.chaos_reroutes += 1

    # -- rollup -------------------------------------------------------------
    def rollup(self, cloudlet_stats: List[TierStats],
               cloud_stats: TierStats,
               exhausted_edges: int = 0) -> Dict[str, float]:
        """The flat, unit-suffixed summary dict for BENCH_fleet.json.

        Served = collab + degraded-edge; deadline attainment is judged
        over *arrivals* (a shed request is a missed deadline — hiding
        sheds from the denominator would let the admission controller
        game its own scoreboard).
        """
        recs = self.records
        served = [r for r in recs if r.route != "shed"]
        lat = [r.latency_s for r in served]
        met = sum(1 for r in served if r.latency_s <= r.deadline_s)
        n = len(recs)
        out: Dict[str, float] = {
            "n_edges": self.scenario.n_edges,
            "n_cloudlets": self.scenario.n_cloudlets,
            "sim_duration_s": self.scenario.duration_s,
            "seed": self.scenario.seed,
            "arrivals": n,
            "served": len(served),
            "served_collab": sum(1 for r in recs if r.route == "collab"),
            "served_edge_only": sum(1 for r in recs if r.route == "edge"),
            "shed": sum(1 for r in recs if r.route == "shed"),
            "shed_frac": _frac(sum(1 for r in recs if r.route == "shed"), n),
            "shed_battery_frac": _frac(
                sum(1 for r in recs if r.shed_reason == "battery"), n),
            "shed_deadline_frac": _frac(
                sum(1 for r in recs if r.shed_reason == "deadline"), n),
            "shed_queue_frac": _frac(
                sum(1 for r in recs if r.shed_reason == "queue"), n),
            "deadline_met_frac": _frac(met, n),
            "latency_p50_s": percentile(lat, 50),
            "latency_p99_s": percentile(lat, 99),
            "latency_mean_s": (sum(lat) / len(lat)) if lat else 0.0,
            "edge_joules_per_request": (
                sum(r.e_edge_j for r in served) / len(served)
                if served else 0.0),
            "uplink_mb_total": sum(r.tx_bytes for r in recs) / 1e6,
            "exhausted_edges": exhausted_edges,
            "chaos_reroutes_count": self.chaos_reroutes,
        }
        # per-SLO-class attainment and tails
        by_slo: Dict[str, List[RequestRecord]] = defaultdict(list)
        for r in recs:
            by_slo[r.slo].append(r)
        for cls in self.scenario.slo_classes:
            rs = by_slo.get(cls.name, [])
            sv = [r for r in rs if r.route != "shed"]
            ls = [r.latency_s for r in sv]
            k = cls.name
            out[f"{k}_arrivals"] = len(rs)
            out[f"{k}_deadline_met_frac"] = _frac(
                sum(1 for r in sv if r.latency_s <= r.deadline_s), len(rs))
            out[f"{k}_shed_frac"] = _frac(
                sum(1 for r in rs if r.route == "shed"), len(rs))
            out[f"{k}_latency_p50_s"] = percentile(ls, 50)
            out[f"{k}_latency_p99_s"] = percentile(ls, 99)
        # per-tier utilization / batching efficiency
        dur = self.scenario.duration_s
        cl_busy = sum(s.busy_s for s in cloudlet_stats)
        out.update({
            "cloudlet_util": _frac(cl_busy, dur * max(len(cloudlet_stats),
                                                      1)),
            "cloudlet_rows": sum(s.rows for s in cloudlet_stats),
            "cloudlet_batches": sum(s.batches for s in cloudlet_stats),
            "cloudlet_avg_batch": _frac(
                sum(s.rows for s in cloudlet_stats),
                sum(s.batches for s in cloudlet_stats)),
            "cloudlet_padding_waste": _frac(
                sum(s.padded_rows for s in cloudlet_stats),
                sum(s.rows + s.padded_rows for s in cloudlet_stats)),
            "cloudlet_max_queue": max(
                (s.max_queue for s in cloudlet_stats), default=0),
            "cloudlet_mean_queue": _frac(
                sum(s.queue_sum for s in cloudlet_stats),
                sum(s.queue_samples for s in cloudlet_stats)),
            "cloud_util": _frac(cloud_stats.busy_s, dur),
            "cloud_rows": cloud_stats.rows,
            "cloud_batches": cloud_stats.batches,
            "cloud_avg_batch": cloud_stats.avg_batch,
            "cloud_padding_waste": cloud_stats.padding_waste,
            "cloud_max_queue": cloud_stats.max_queue,
            "cloud_mean_queue": cloud_stats.mean_queue,
        })
        return out


def _frac(num: float, den: float) -> float:
    return num / den if den else 0.0
