"""The cloudlet and cloud tiers of the simulated hierarchy.

The hierarchical-FL plant-disease line of work motivates an
intermediate *cloudlet* between the field devices and the datacenter:
close enough for tight deadlines, big enough to batch. ``TierServer``
models one such aggregation point as a virtual-clock analogue of the
PR-4 ``DynamicBatcher``: per-lane queues keyed by the layer segment a
batch will run (requests of different splits never fuse — their
tensors have different shapes), a batching window while the server is
idle, padding to the ``BatchingPolicy``'s bucket shapes, and ONE
modeled invocation per fused batch priced by
``latency_model.batched_segment_time`` — the same single formula the
measured batching engine charges through ``simulate_server``, so fleet
numbers and socket-bench numbers can never drift apart.

Hardware defaults mirror the calibrated registry: a cloudlet is the
Jetson-class aggregation box (``profiles.CLOUDLET_SERVER``), the cloud
is the batched-sustained 3090 calibration (``PAPER_SERVER_BATCHED``),
and the cloudlet->cloud backhaul is a metro-fiber ``LinkProfile``
built by ``backhaul_link``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.collab.batching import BatchingPolicy, bucket_for
from repro.core.fleet.clock import EventQueue
from repro.core.partition.latency_model import (LayerCost,
                                                batched_segment_time)
from repro.core.partition.profiles import (CLOUDLET_SERVER, ComputeProfile,
                                           LinkProfile,
                                           PAPER_SERVER_BATCHED)

#: the cloud tier's accelerator: the batched-sustained calibration the
#: cross-client batching benchmarks validated
CLOUD_SERVER = PAPER_SERVER_BATCHED


def backhaul_link(mbps: float, rtt_ms: float) -> LinkProfile:
    """The cloudlet->cloud metro link as a ``LinkProfile`` (wired, so a
    static profile rather than a wireless ``LinkTrace``)."""
    return LinkProfile(f"backhaul {mbps:g} Mbps", bandwidth=mbps * 1e6 / 8,
                       rtt_s=rtt_ms * 1e-3)


@dataclass
class TierStats:
    """Per-server accounting the metrics rollup aggregates."""
    busy_s: float = 0.0
    rows: int = 0
    batches: int = 0
    padded_rows: int = 0
    shed: int = 0
    max_queue: int = 0
    queue_samples: int = 0
    queue_sum: int = 0

    @property
    def avg_batch(self) -> float:
        """Mean real rows per fused invocation."""
        return self.rows / self.batches if self.batches else 0.0

    @property
    def padding_waste(self) -> float:
        """Fraction of computed rows that were bucket padding."""
        total = self.rows + self.padded_rows
        return self.padded_rows / total if total else 0.0

    @property
    def mean_queue(self) -> float:
        """Queue depth averaged over arrival instants."""
        return (self.queue_sum / self.queue_samples
                if self.queue_samples else 0.0)


class TierServer:
    """One batched accelerator of a tier, on the fleet virtual clock.

    Lanes are keyed by the ``(start, stop)`` layer segment their
    requests run (the fleet analogue of the batching engine's
    ``(split, wire-lane, compact)`` key); the server serializes all
    lanes on one modeled accelerator, exactly like the measured
    ``DynamicBatcher`` over a single device. ``submit`` returns False
    when the queue bound is hit (the caller sheds). Completion
    callbacks fire on the event queue, which is what chains the
    hierarchy together.

    Chaos lifecycle (the scenario's ``chaos`` events): ``drain`` stops
    admission while queued batches keep flushing (the rolling-restart
    half of the serving stack's DRAIN frame); ``kill`` crashes the
    server — queued and in-flight entries are handed to ``on_orphan``
    (the simulator reroutes them to another admitting cloudlet);
    ``revive`` puts it back in service.
    """

    def __init__(self, name: str, profile: ComputeProfile,
                 policy: BatchingPolicy, costs: Sequence[LayerCost],
                 events: EventQueue, max_queue: Optional[int] = None):
        self.name = name
        self.profile = profile
        self.policy = policy
        self.costs = costs
        self.events = events
        self.max_queue = max_queue
        self.stats = TierStats()
        #: chaos state: a drained server stops admitting, a killed one
        #: is gone until revive()
        self.admitting = True
        self.alive = True
        #: where orphaned entries go on kill (set by the simulator);
        #: None silently drops them
        self.on_orphan: Optional[Callable[[object], None]] = None
        self._lanes: Dict[Tuple[int, int], List] = {}
        self._busy = False
        self._busy_until = 0.0
        self._start_pending = False

    # -- queue state --------------------------------------------------------
    @property
    def pending_rows(self) -> int:
        """Rows queued across all lanes right now."""
        return sum(len(q) for q in self._lanes.values())

    def backlog_s(self, now: float) -> float:
        """A deterministic service-backlog estimate for admission
        control: full batches ahead of a new arrival, each priced at
        the policy's max bucket over the deepest lane's segment. An
        estimate, not ground truth — the admission controller is a
        heuristic operator, not an oracle."""
        remainder = max(self._busy_until - now, 0.0) if self._busy else 0.0
        pending = self.pending_rows
        if pending == 0:
            return remainder
        seg = max(self._lanes, key=lambda k: (len(self._lanes[k]), k))
        t_batch = batched_segment_time(self.costs, seg[0], seg[1],
                                       self.profile,
                                       self.policy.max_batch)
        n_batches = (pending + self.policy.max_batch - 1) \
            // self.policy.max_batch
        return remainder + n_batches * t_batch

    # -- request flow -------------------------------------------------------
    def submit(self, segment: Tuple[int, int], payload,
               done: Callable[[object, float], None]) -> bool:
        """Queue one request (``payload``) for layers ``segment`` =
        ``(start, stop)``; ``done(payload, t)`` fires when its fused
        batch completes. Returns False (nothing queued) when the
        tier's queue bound is hit — the shed is the caller's to
        account. A dead or draining server admits nothing (the caller
        checks ``alive``/``admitting`` first to reroute instead)."""
        if not (self.alive and self.admitting):
            return False
        depth = self.pending_rows
        self.stats.queue_samples += 1
        self.stats.queue_sum += depth
        if self.max_queue is not None and depth >= self.max_queue:
            self.stats.shed += 1
            return False
        self._lanes.setdefault(segment, []).append((payload, done))
        self.stats.max_queue = max(self.stats.max_queue, depth + 1)
        if not self._busy and not self._start_pending:
            # idle server: open the batching window — immediately when a
            # full batch is already waiting, else hold max_wait_ms for
            # concurrent arrivals to fuse (the DynamicBatcher window)
            wait = (0.0 if self.pending_rows >= self.policy.max_batch
                    else self.policy.max_wait_ms * 1e-3)
            self._start_pending = True
            self.events.push(self.events.now + wait, self._start)
        return True

    def _start(self) -> None:
        self._start_pending = False
        if self._busy or not self._lanes:
            return
        # deepest lane first (deterministic tie-break on the key)
        seg = max(self._lanes, key=lambda k: (len(self._lanes[k]),
                                              (-k[0], -k[1])))
        lane = self._lanes[seg]
        batch = lane[:self.policy.max_batch]
        del lane[:self.policy.max_batch]
        if not lane:
            del self._lanes[seg]
        bucket = bucket_for(len(batch), self.policy.resolved_buckets)
        t_serve = batched_segment_time(self.costs, seg[0], seg[1],
                                       self.profile, bucket)
        self._busy = True
        self._busy_until = self.events.now + t_serve
        self.stats.busy_s += t_serve
        self.stats.batches += 1
        self.stats.rows += len(batch)
        self.stats.padded_rows += bucket - len(batch)
        self.events.push(self.events.now + t_serve,
                         lambda b=batch: self._finish(b))

    def _finish(self, batch) -> None:
        self._busy = False
        now = self.events.now
        if not self.alive:
            # the server died while this batch was on the accelerator:
            # its work is lost — orphan the entries for rerouting
            for payload, _done in batch:
                if self.on_orphan is not None:
                    self.on_orphan(payload)
            return
        for payload, done in batch:
            done(payload, now)
        if self._lanes and not self._start_pending:
            # completion path: fuse whatever queued meanwhile, no window
            # (matches the engine's drain-on-completion behaviour)
            self._start_pending = True
            self.events.push(now, self._start)

    # -- chaos lifecycle ----------------------------------------------------
    def drain(self) -> None:
        """Rolling-restart drain: stop admitting; queued batches keep
        flushing to completion."""
        self.admitting = False

    def kill(self) -> None:
        """Crash: stop admitting, drop every queued lane entry to
        ``on_orphan`` (in-flight batch entries follow when their modeled
        invocation would have completed)."""
        self.alive = False
        self.admitting = False
        orphans = [entry for q in self._lanes.values() for entry in q]
        self._lanes.clear()
        for payload, _done in orphans:
            if self.on_orphan is not None:
                self.on_orphan(payload)

    def revive(self) -> None:
        """Bring a drained/killed server back into service."""
        self.alive = True
        self.admitting = True
