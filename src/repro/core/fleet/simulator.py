"""The fleet simulator: 1k-10k heterogeneous edges through the
edge -> cloudlet -> cloud hierarchy on one virtual clock.

Every request walks the same path the real serving stack implements,
priced by the same models the single-edge benchmarks calibrate:

1. *Arrival* — the edge's seeded inhomogeneous-Poisson stream fires.
2. *Admission* — ``AdmissionController`` routes it (collab / degrade
   to edge-only / shed) against its SLO class's ``FaultPolicy``.
3. *Edge compute* — layers ``[0, c1)`` at the device's Eq. 5 time.
4. *Wireless uplink* — ``SimChannel`` piecewise trace accounting, the
   channel clock pinned to the fleet clock plus the edge's phase.
5. *Cloudlet* — its ``TierServer`` fuses the ``[c1, c2)`` segment into
   dynamic batches (or is skipped when ``c2 == c1``).
6. *Backhaul* — wired metro link to the datacenter (skipped when
   ``c2 == N``).
7. *Cloud* — the big batched tier runs ``[c2, N)`` and completes.

On completion the edge's battery pays ``EnergyProfile.request_energy``
for its compute, radio, and wait time; an exhausted edge sheds every
subsequent request it originates. All timing is virtual — wall-clock
only bounds how fast the heap drains, never what the metrics say —
so the whole run is bit-reproducible from ``FleetScenario.seed``.

Chaos (the scenario's ``chaos`` events, mirroring the serving stack's
failover drills): a killed or draining cloudlet stops admitting, and
requests bound for it — new arrivals and orphaned in-flight work —
reroute to the next admitting cloudlet (counted in the rollup's
``chaos_reroutes_count``), shedding with reason ``"queue"`` only when
every cloudlet is gone.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

from repro.core.fleet.admission import (AdmissionController, RoutePlan,
                                        SplitPlanner)
from repro.core.fleet.clock import EventQueue
from repro.core.fleet.metrics import FleetMetrics, RequestRecord
from repro.core.fleet.population import SimEdge, build_population
from repro.core.fleet.scenario import FleetScenario
from repro.core.fleet.tiers import (CLOUD_SERVER, CLOUDLET_SERVER,
                                    TierServer)
from repro.core.partition.latency_model import (LayerCost, cnn_input_bytes,
                                                cnn_layer_costs)
from repro.models.cnn import alexnet_config


@dataclass
class _Request:
    """In-flight request context threaded through the tier callbacks."""
    edge: SimEdge
    t_arrive: float
    plan: RoutePlan
    t_tx_s: float = 0.0
    tx_bytes: float = 0.0
    rtt_s: float = 0.0


class FleetSimulator:
    """Drives one ``FleetScenario`` to completion and rolls up metrics.

    ``run()`` returns the flat BENCH_fleet rollup dict. The network
    defaults to the paper's AlexNet/PlantVillage configuration (the
    same cost table every other subsystem prices), overridable for
    tests via ``costs``/``input_bytes``.
    """

    def __init__(self, scenario: FleetScenario,
                 costs: Optional[Sequence[LayerCost]] = None,
                 input_bytes: Optional[float] = None):
        if costs is None:
            cfg = alexnet_config()
            costs = cnn_layer_costs(cfg)
            input_bytes = cnn_input_bytes(cfg)
        if input_bytes is None:
            raise ValueError("input_bytes is required with custom costs")
        self.scenario = scenario
        self.costs = list(costs)
        self.input_bytes = float(input_bytes)
        self.events = EventQueue()
        self.edges = build_population(scenario)
        self.planner = SplitPlanner(scenario, self.costs, self.input_bytes)
        self.admission = AdmissionController(self.planner)
        self.cloudlets = [
            TierServer(f"cloudlet{i}", CLOUDLET_SERVER,
                       scenario.cloudlet_batching, self.costs, self.events,
                       max_queue=scenario.max_queue)
            for i in range(scenario.n_cloudlets)]
        self.cloud = TierServer("cloud", CLOUD_SERVER,
                                scenario.cloud_batching, self.costs,
                                self.events,
                                max_queue=scenario.max_queue
                                * scenario.n_cloudlets)
        for srv in self.cloudlets:
            srv.on_orphan = self._reroute
        self.metrics = FleetMetrics(scenario)

    # -- lifecycle ----------------------------------------------------------
    def run(self) -> Dict[str, float]:
        """Simulate ``duration_s`` of virtual time (arrivals stop at the
        horizon; in-flight requests drain to completion) and return the
        rollup."""
        for edge in self.edges:
            t0 = edge.next_arrival(0.0, self.scenario.arrival)
            if t0 < self.scenario.duration_s:
                self.events.push(t0, lambda e=edge: self._arrive(e))
        for ev in self.scenario.chaos:
            self.events.push(ev.t_s, lambda e=ev: self._chaos(e))
        self.events.run_until()
        return self.metrics.rollup(
            [c.stats for c in self.cloudlets], self.cloud.stats,
            exhausted_edges=sum(1 for e in self.edges if e.exhausted))

    # -- request pipeline ---------------------------------------------------
    def _arrive(self, edge: SimEdge) -> None:
        now = self.events.now
        nxt = edge.next_arrival(now, self.scenario.arrival)
        if nxt < self.scenario.duration_s:
            self.events.push(nxt, lambda e=edge: self._arrive(e))
        cloudlet = self.cloudlets[edge.cloudlet_id]
        plan = self.admission.decide(edge, now,
                                     cloudlet.backlog_s(now),
                                     self.cloud.backlog_s(now))
        if plan.route == "shed":
            self.metrics.add(RequestRecord(
                slo=edge.slo.name, route="shed", shed_reason=plan.reason,
                deadline_s=edge.slo.deadline_s,
                device_class=edge.device_class))
            return
        if plan.route == "edge":
            # local-only: no queueing, completes after the device time
            e_j = edge.energy.request_energy(plan.t_edge_s, 0.0, 0.0)
            edge.drain(e_j)
            self.metrics.add(RequestRecord(
                slo=edge.slo.name, route="edge", latency_s=plan.t_edge_s,
                deadline_s=edge.slo.deadline_s, e_edge_j=e_j,
                device_class=edge.device_class))
            return
        # collaborative: edge computes [0, c1), then ships the boundary
        req = _Request(edge=edge, t_arrive=now, plan=plan)
        t_ready = now + plan.t_edge_s
        req.tx_bytes = self.planner.boundary_bytes(plan.c1)
        _, req.rtt_s = edge.link_state(t_ready)
        req.t_tx_s = edge.send(req.tx_bytes, t_ready)
        self.events.push(t_ready + req.t_tx_s,
                         lambda r=req: self._at_cloudlet(r))

    def _at_cloudlet(self, req: _Request) -> None:
        plan = req.plan
        if plan.c2 == plan.c1:
            # nothing for the cloudlet to run — straight to backhaul
            self._to_cloud(req, self.events.now)
            return
        server = self.cloudlets[req.edge.cloudlet_id]
        if not (server.alive and server.admitting):
            self._reroute(req)
            return
        if not server.submit((plan.c1, plan.c2), req,
                             lambda r, t: self._cloudlet_done(r, t)):
            self._shed_inflight(req, "queue")

    # -- chaos --------------------------------------------------------------
    def _chaos(self, ev) -> None:
        """Apply one scheduled ``ChaosEvent`` to its target cloudlet."""
        srv = self.cloudlets[ev.cloudlet % len(self.cloudlets)]
        if ev.kind == "kill":
            srv.kill()
        elif ev.kind == "drain":
            srv.drain()
        else:
            srv.revive()

    def _next_admitting(self, home: int):
        """The nearest admitting cloudlet after ``home`` in ring order,
        or None when the whole tier is down."""
        n = len(self.cloudlets)
        for k in range(1, n):
            srv = self.cloudlets[(home + k) % n]
            if srv.alive and srv.admitting:
                return srv
        return None

    def _reroute(self, req: _Request) -> None:
        """Move a request whose home cloudlet is dead/draining to the
        next admitting one (the simulator analogue of the serving
        stack's fleet reroute); shed with reason ``"queue"`` only when
        no cloudlet admits."""
        server = self._next_admitting(req.edge.cloudlet_id)
        if server is None:
            self._shed_inflight(req, "queue")
            return
        self.metrics.note_reroute()
        plan = req.plan
        if not server.submit((plan.c1, plan.c2), req,
                             lambda r, t: self._cloudlet_done(r, t)):
            self._shed_inflight(req, "queue")

    def _cloudlet_done(self, req: _Request, t: float) -> None:
        self._to_cloud(req, t)

    def _to_cloud(self, req: _Request, now: float) -> None:
        plan = req.plan
        n = len(self.costs)
        if plan.c2 >= n:
            self._complete(req, now)
            return
        link = self.planner.backhaul
        t_bh = link.rtt_s + self.planner.boundary_bytes(plan.c2) \
            / link.bandwidth
        self.events.push(now + t_bh, lambda r=req: self._submit_cloud(r))

    def _submit_cloud(self, req: _Request) -> None:
        plan = req.plan
        if not self.cloud.submit((plan.c2, len(self.costs)), req,
                                 lambda r, t: self._complete(r, t)):
            self._shed_inflight(req, "queue")

    # -- terminal states ----------------------------------------------------
    def _complete(self, req: _Request, t_done: float) -> None:
        edge, plan = req.edge, req.plan
        latency = t_done - req.t_arrive
        # the edge waited (radio idle) from the end of its uplink until
        # the answer came back — that idle time costs joules too
        t_wait = max(latency - plan.t_edge_s - req.t_tx_s, 0.0)
        e_j = edge.energy.request_energy(plan.t_edge_s, req.t_tx_s,
                                         t_wait, rtt_s=req.rtt_s)
        edge.drain(e_j)
        self.metrics.add(RequestRecord(
            slo=edge.slo.name, route="collab", latency_s=latency,
            deadline_s=edge.slo.deadline_s, e_edge_j=e_j,
            tx_bytes=req.tx_bytes, device_class=edge.device_class))

    def _shed_inflight(self, req: _Request, reason: str) -> None:
        """A tier queue bound rejected the request after the edge already
        spent compute + uplink joules — charge the battery, count the
        shed."""
        edge, plan = req.edge, req.plan
        e_j = edge.energy.request_energy(plan.t_edge_s, req.t_tx_s, 0.0,
                                         rtt_s=req.rtt_s)
        edge.drain(e_j)
        self.metrics.add(RequestRecord(
            slo=edge.slo.name, route="shed", shed_reason=reason,
            deadline_s=edge.slo.deadline_s, e_edge_j=e_j,
            tx_bytes=req.tx_bytes, device_class=edge.device_class))


def simulate_fleet(scenario: FleetScenario, **kw) -> Dict[str, float]:
    """One-call convenience: build, run, roll up."""
    return FleetSimulator(scenario, **kw).run()
