"""Heterogeneous edge population for the fleet simulator.

``build_population`` instantiates ``FleetScenario.n_edges`` simulated
edges from the scenario's seeded mixes: each edge gets a device class
(compute + energy profile pair from ``DEVICE_CLASSES``), its own
``LinkTrace`` replayed through a private ``SimChannel`` (the *same*
piecewise trace accounting the single-edge benchmarks measure, with a
seeded phase offset so a fleet on ``wifi_degrading`` does not degrade in
lockstep), a battery budget in joules, an SLO class, and a seeded RNG
stream for its inhomogeneous-Poisson arrivals. Same scenario seed =>
byte-identical population, forever.
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.collab.channel import SimChannel
from repro.core.fleet.scenario import ArrivalPattern, FleetScenario, SLOClass
from repro.core.partition.energy_model import (ENERGY_PROFILES,
                                               EnergyProfile)
from repro.core.partition.profiles import (ComputeProfile, LinkTrace,
                                           MCU_EDGE, PHONE_EDGE, PI_EDGE,
                                           TRACES)

#: device-class registry: name -> (compute profile, energy profile) —
#: the heterogeneous hardware the fleet mixes (satellite: the phone
#: class joins the MCU/Pi pair from the energy subsystem)
DEVICE_CLASSES: Dict[str, Tuple[ComputeProfile, EnergyProfile]] = {
    "mcu": (MCU_EDGE, ENERGY_PROFILES["mcu"]),
    "pi": (PI_EDGE, ENERGY_PROFILES["pi"]),
    "phone": (PHONE_EDGE, ENERGY_PROFILES["phone"]),
}


def _weighted_pick(mix: Tuple[Tuple[str, float], ...],
                   u: float) -> str:
    """Deterministic cumulative-share pick: ``u`` in [0, 1)."""
    acc = 0.0
    for name, share in mix:
        acc += share
        if u < acc:
            return name
    return mix[-1][0]


@dataclass
class SimEdge:
    """One simulated edge device (mutable run state).

    ``channel`` replays the edge's ``LinkTrace`` with ``SimChannel``'s
    piecewise accounting — the simulator sets ``channel.elapsed_s`` to
    the fleet's virtual clock (plus this edge's ``trace_phase``) before
    each send, so a transmission straddling a bandwidth change pays
    exactly the blended cost. ``battery_left_j`` is drained through
    ``EnergyProfile.request_energy`` per served request; an exhausted
    edge sheds everything it originates.
    """
    eid: int
    device_class: str
    compute: ComputeProfile
    energy: EnergyProfile
    trace: LinkTrace
    trace_phase: float
    slo: SLOClass
    battery_j: float
    battery_left_j: float
    cloudlet_id: int
    rng: random.Random = field(repr=False)
    channel: SimChannel = field(repr=False)

    @property
    def battery_fraction(self) -> float:
        """Remaining battery as a fraction of the budget (>= 0)."""
        return max(self.battery_left_j, 0.0) / self.battery_j

    @property
    def exhausted(self) -> bool:
        """True once the battery budget has fully drained."""
        return self.battery_left_j <= 0.0

    def drain(self, e_j: float) -> None:
        """Subtract one request's edge joules from the battery."""
        self.battery_left_j = max(self.battery_left_j - e_j, 0.0)

    def link_state(self, now: float) -> Tuple[float, float]:
        """(bandwidth bytes/s, rtt_s) this edge's link shows at fleet
        virtual time ``now`` (phase-shifted into its trace)."""
        return self.trace.state_at(now + self.trace_phase)

    def send(self, nbytes: int, now: float) -> float:
        """Piecewise-accounted uplink cost (seconds, incl. one RTT) of
        sending ``nbytes`` at fleet virtual time ``now`` — a
        ``SimChannel.send`` with the channel clock pinned to the fleet
        clock first."""
        self.channel.elapsed_s = now + self.trace_phase
        return self.channel.send(nbytes)

    def next_arrival(self, t: float, pattern: ArrivalPattern) -> float:
        """The edge's next request time after ``t``: inhomogeneous
        Poisson by thinning against the diurnal peak rate, drawn from
        this edge's private seeded RNG stream."""
        lam = pattern.peak_rate_hz
        while True:
            t += self.rng.expovariate(lam)
            if (self.rng.random() * lam
                    <= pattern.rate_at(t, self.trace_phase)):
                return t


def build_population(scenario: FleetScenario) -> List[SimEdge]:
    """Instantiate the scenario's edges, deterministically.

    One master ``random.Random(scenario.seed)`` draws every class/trace/
    SLO assignment, phase offset, and per-edge child seed in a fixed
    order, so the population (and everything downstream of its RNG
    streams) is bit-reproducible per seed. Edges are spread over
    cloudlets round-robin — deterministic, and near-balanced for any
    mix.
    """
    rng = random.Random(scenario.seed)
    edges: List[SimEdge] = []
    for eid in range(scenario.n_edges):
        device = _weighted_pick(scenario.device_mix, rng.random())
        trace_name = _weighted_pick(scenario.trace_mix, rng.random())
        slo = scenario.slo_classes[_slo_pick(scenario.slo_classes,
                                             rng.random())]
        trace = TRACES[trace_name]
        # phase over one trace cycle (or arrival period for terminal
        # traces) — the fleet must not move in lockstep
        span = (trace.duration_s if trace.loop
                else scenario.arrival.period_s)
        if not math.isfinite(span):
            span = scenario.arrival.period_s
        phase = rng.random() * span
        compute, energy = DEVICE_CLASSES[device]
        budget = scenario.battery_for(device)
        child = random.Random(rng.randrange(1 << 32))
        edges.append(SimEdge(
            eid=eid, device_class=device, compute=compute, energy=energy,
            trace=trace, trace_phase=phase, slo=slo, battery_j=budget,
            battery_left_j=budget,
            cloudlet_id=eid % scenario.n_cloudlets, rng=child,
            channel=SimChannel(trace.link_at(0.0), trace=trace)))
    return edges


def _slo_pick(classes: Tuple[SLOClass, ...], u: float) -> int:
    acc = 0.0
    for i, s in enumerate(classes):
        acc += s.share
        if u < acc:
            return i
    return len(classes) - 1
