"""The paper's end-to-end pipeline, reusable by examples/ and benchmarks/:

  train CNN -> DDPG pruning search -> fine-tune -> greedy split ->
  compact -> deploy.

Runs at reduced scale on CPU (tiny AlexNet-family CNN + synthetic
PlantVillage-38); every stage is the real algorithm from core/, just on a
smaller model — see DESIGN.md §7.

The deployment stage materializes the pruning masks via ``compact_params``
(physically smaller edge/cloud submodels: real FLOP reduction, not zeroed
channels), re-prices the per-layer costs at the *compacted* shapes with the
chosen feature codec's wire discount, re-picks the split point on those
costs, and packages the whole deployment contract as a
``repro.serving.DeploymentPlan`` (``result.plan``) — save it once with
``plan.save(dir)`` and serve it anywhere via
``serving.connect(plan, backend="local"|"socket"|"streaming")``.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CNNConfig
from repro.core.collab.protocol import CODEC_TX_SCALE
from repro.core.partition.latency_model import (cnn_input_bytes,
                                                cnn_layer_costs,
                                                compacted_cnn_layer_costs)
from repro.core.partition.profiles import PAPER_PROFILE, TwoTierProfile
from repro.core.partition.splitter import SplitDecision, greedy_split
from repro.core.pruning.amc_env import PruningEnv, cnn_layer_descs
from repro.core.pruning.masks import cnn_masks_from_ratios
from repro.core.pruning.policy import SearchResult, search_pruning_policy
from repro.data.synthetic import PlantVillageSynthetic
from repro.models.cnn import (cnn_apply, compact_params, init_cnn_params,
                              prunable_layers)
from repro.optim import make_optimizer, step_lr
from repro.serving.plan import DeploymentPlan


def _xent(logits, labels):
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def make_train_step(cfg: CNNConfig, optimizer, masks=None):
    @jax.jit
    def step(params, opt_state, batch):
        def loss_fn(p):
            logits = cnn_apply(p, cfg, batch["image"], masks=masks)
            return _xent(logits, batch["label"])
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = optimizer.update(grads, opt_state, params)
        return params, opt_state, loss
    return step


def train_cnn(params, cfg: CNNConfig, data: PlantVillageSynthetic,
              epochs: int = 3, batch_size: int = 32, lr: float = 0.01,
              masks=None, log: Optional[Callable] = None,
              optimizer_name: str = "sgd"):
    """Default: SGD momentum 0.9 + StepLR(0.1/20) — the paper's §4.1 recipe.
    ``optimizer_name="adamw"`` is the reduced-scale CPU alternative used by
    smoke tests/examples (plain SGD needs many more epochs at tiny width;
    DESIGN.md §7)."""
    steps_per_epoch = max(len(data.train_ids) // batch_size, 1)
    if optimizer_name == "adamw":
        optimizer = make_optimizer("adamw", step_lr(lr, 0.1, 20,
                                                    steps_per_epoch))
    else:
        optimizer = make_optimizer(
            "sgd", step_lr(lr, 0.1, 20, steps_per_epoch), momentum=0.9)
    opt_state = optimizer.init(params)
    step_fn = make_train_step(cfg, optimizer, masks)
    history = []
    for ep in range(epochs):
        losses = []
        for batch in data.iter_train(batch_size, epochs=1, seed=100 + ep):
            params, opt_state, loss = step_fn(params, opt_state, batch)
            losses.append(float(loss))
        history.append(float(np.mean(losses)))
        if log:
            log(f"epoch {ep}: loss {history[-1]:.4f}")
    return params, history


def evaluate_topk(params, cfg: CNNConfig, data: PlantVillageSynthetic,
                  ks: Tuple[int, ...] = (1, 3, 5), masks=None,
                  batch_size: int = 64) -> Dict[str, float]:
    fn = jax.jit(lambda x: cnn_apply(params, cfg, x, masks=masks))
    hits = {k: 0 for k in ks}
    n = 0
    for batch in data.test_batches(batch_size):
        logits = np.asarray(fn(batch["image"]))
        order = np.argsort(-logits, axis=-1)
        for k in ks:
            hits[k] += (order[:, :k] == batch["label"][:, None]).any(1).sum()
        n += len(batch["label"])
    return {f"top{k}": hits[k] / n for k in ks}


@dataclass
class PaperPipelineResult:
    cfg: CNNConfig
    params: Dict
    masks: Dict
    acc_original: Dict[str, float]
    acc_pruned: Dict[str, float]
    acc_finetuned: Dict[str, float]
    ratios: Dict[int, float]
    search: SearchResult
    split: SplitDecision
    profile: TwoTierProfile
    # deployment artifacts (compacted fast path)
    compact_params: Optional[Dict] = None
    compact_cfg: Optional[CNNConfig] = None
    deploy_split: Optional[SplitDecision] = None
    deploy_codec: str = "fp32"
    # the unified deployment contract (repro.serving): save with
    # plan.save(dir), serve with serving.connect(plan, backend=...)
    plan: Optional[DeploymentPlan] = None


def run_paper_pipeline(cfg: CNNConfig, data: PlantVillageSynthetic,
                       train_epochs: int = 4, finetune_epochs: int = 2,
                       episodes: int = 40, warmup: int = 10,
                       flops_budget: float = 0.5,
                       profile: TwoTierProfile = PAPER_PROFILE,
                       seed: int = 0,
                       log: Optional[Callable] = None,
                       optimizer_name: str = "sgd", lr: float = 0.01,
                       deploy_codec: str = "fp32"
                       ) -> PaperPipelineResult:
    log = log or (lambda s: None)
    key = jax.random.PRNGKey(seed)
    params = init_cnn_params(key, cfg)

    log("[1/6] train original model")
    params, _ = train_cnn(params, cfg, data, epochs=train_epochs, log=log,
                          lr=lr, optimizer_name=optimizer_name)
    acc0 = evaluate_topk(params, cfg, data)
    log(f"    original acc: {acc0}")

    log("[2/6] DDPG pruning search (AMC, Eq. 1-4)")
    players = prunable_layers(cfg)
    descs = cnn_layer_descs(cfg)

    # fast reward evaluation on a fixed subset of the test split
    eval_ids = data.test_ids[::max(len(data.test_ids) // 256, 1)]
    eval_batch = data._batch(eval_ids)

    @functools.lru_cache(maxsize=512)
    def _acc_for(ratio_key) -> float:
        ratios = dict(zip(players, ratio_key))
        masks = cnn_masks_from_ratios(params, cfg, ratios)
        logits = np.asarray(cnn_apply(params, cfg,
                                      jnp.asarray(eval_batch["image"]),
                                      masks=masks))
        return float((logits.argmax(-1) == eval_batch["label"]).mean())

    def evaluate(actions: List[float]) -> float:
        return _acc_for(tuple(round(a, 3) for a in actions))

    env = PruningEnv(descs, evaluate, flops_budget=flops_budget)
    search = search_pruning_policy(env, episodes=episodes, warmup=warmup,
                                   seed=seed, log=log)
    ratios = dict(zip(players, search.best_ratios))
    log(f"    best ratios: { {k: round(v, 3) for k, v in ratios.items()} } "
        f"flops_kept={search.best_flops_kept:.3f}")

    log("[3/6] evaluate pruned model")
    masks = cnn_masks_from_ratios(params, cfg, ratios)
    acc_pruned = evaluate_topk(params, cfg, data, masks=masks)
    log(f"    pruned acc: {acc_pruned}")

    log("[4/6] fine-tune pruned model (SGD m=0.9, StepLR)")
    ft_params, _ = train_cnn(params, cfg, data, epochs=finetune_epochs,
                             masks=masks, log=log, lr=lr * 0.3,
                             optimizer_name=optimizer_name)
    acc_ft = evaluate_topk(ft_params, cfg, data, masks=masks)
    log(f"    fine-tuned acc: {acc_ft}")

    log("[5/6] greedy split search (Algorithm 1 lines 20-27)")
    costs = cnn_layer_costs(cfg, masks)
    split = greedy_split(costs, profile, cnn_input_bytes(cfg))
    log(f"    optimal split c={split.split_point} "
        f"T={split.latency['T'] * 1e3:.2f} ms "
        f"(T_D={split.latency['T_D'] * 1e3:.2f} "
        f"T_TX={split.latency['T_TX'] * 1e3:.2f} "
        f"T_S={split.latency['T_S'] * 1e3:.2f})")

    log("[6/6] compact deployment + re-priced split on compacted shapes")
    cparams, ccfg = compact_params(ft_params, cfg, masks)
    dcosts = compacted_cnn_layer_costs(cfg, masks)
    deploy = greedy_split(dcosts, profile, cnn_input_bytes(cfg),
                          tx_scale=CODEC_TX_SCALE[deploy_codec])
    log(f"    deploy split c={deploy.split_point} codec={deploy_codec} "
        f"T={deploy.latency['T'] * 1e3:.2f} ms "
        f"tx={deploy.latency['tx_bytes'] / 1024:.1f} KB")
    plan = DeploymentPlan.from_args(ft_params, cfg, deploy.split_point,
                                    masks=masks, compact=bool(masks),
                                    codec=deploy_codec, profile=profile)
    log(f"    {plan.describe()}")
    return PaperPipelineResult(cfg, ft_params, masks, acc0, acc_pruned,
                               acc_ft, ratios, search, split, profile,
                               compact_params=cparams, compact_cfg=ccfg,
                               deploy_split=deploy,
                               deploy_codec=deploy_codec, plan=plan)
