"""Tier-B split inference: the paper's edge/cloud partition mapped onto the
multi-pod mesh (DESIGN.md §2).

The split point ``c`` becomes a pod-boundary partition: pod p holds layers
[p*L/P, (p+1)*L/P); the boundary activation crosses pods as a
``jax.lax.ppermute`` over the (slow) inter-pod links — the TPU analogue of
the paper's wireless hop, and the T_TX term of Eq. 5 (visible in the
dry-run HLO as collective-permute bytes).

Execution is the SPMD microbatch pipeline (GPipe-style, collective-permute
formulation): requests are split into ``num_microbatches``; each pipeline
tick every pod runs its local stage on its current activation, then the
activation shifts one pod to the right. Ticks = microbatches + pods - 1
(fill + drain). Steady-state utilization = M / (M + P - 1).

``shard_map(axis_names={"pod"})`` makes only the pod axis manual: inside a
stage the layers still shard over ("data", "model") exactly as the
non-split model does (GSPMD auto axes).

Scope: architectures whose layer stack is a single homogeneous run
(dense GQA, pure-MoE, pure-SSM — 8 of the 10 assigned archs; zamba2's
shared-block hybrid and deepseek's dense-head+moe mix stay on the Tier-A
layer-range executor) and num_layers % n_pods == 0.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import transformer as tr


def _shard_map_pod_manual(f, mesh, in_specs, out_specs):
    """shard_map with only the "pod" axis manual, across jax versions:
    new API spells it axis_names={"pod"}/check_vma, jax 0.4.x spells the
    complement auto=<other axes>/check_rep — and 0.4.x can't report manual
    axes to ``maybe_constrain``, so the body declares them explicitly."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, axis_names={"pod"},
                             in_specs=in_specs, out_specs=out_specs,
                             check_vma=False)
    from jax.experimental.shard_map import shard_map
    from repro.sharding.constraints import declared_manual_axes

    @functools.wraps(f)
    def body(*args):
        with declared_manual_axes("pod"):
            return f(*args)

    return shard_map(body, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False,
                     auto=frozenset(mesh.axis_names) - {"pod"})


def pipeline_supported(cfg: ModelConfig) -> bool:
    runs = tr.layer_runs(cfg)
    return (len(runs) == 1 and not cfg.shared_attn_period
            and runs[0].kind in ("attn", "moe", "ssm"))


def stack_stage_params(params: Dict[str, Any], cfg: ModelConfig,
                       n_stages: int):
    """Restack the single run's (L, ...) weights into (n_stages, L/n, ...).

    The leading stage dim is the one the "pod" mesh axis shards — that is
    what gives each pod residency of ONLY its own layer range.
    """
    assert pipeline_supported(cfg), "single homogeneous run required"
    L = cfg.num_layers
    assert L % n_stages == 0, (L, n_stages)
    run = params["runs"][0]
    return jax.tree_util.tree_map(
        lambda a: a.reshape((n_stages, L // n_stages) + a.shape[1:]), run)


def _stage_apply(cfg: ModelConfig, stage_params, x, angles,
                 shard_acts: bool = True):
    """Run this pod's layer range over x (local microbatch).

    ``shard_acts`` keeps the microbatch activation sequence-sharded over
    "data" inside the (pod-manual) stage, so the boundary ppermute moves
    1/256th of the activation per chip instead of a full replica
    (EXPERIMENTS.md §Perf-3). Sequence (not batch) because the microbatch
    dim is already small (B/M can be < |data|).
    """
    kind = tr.layer_runs(cfg)[0].kind

    def cstr(h):
        if not shard_acts:
            return h
        return jax.lax.with_sharding_constraint(h, P(None, "data", "model"))

    x = cstr(x)

    def body(h, lp):
        if kind == "attn":
            h, _ = tr._attn_block(cfg, lp, h, angles, None)
        elif kind == "moe":
            h, _, _ = tr._moe_block(cfg, lp, h, angles, None)
        else:
            h, _ = tr._ssm_block(cfg, lp, h, None)
        return cstr(h), None

    h, _ = jax.lax.scan(body, x, stage_params)
    return h


def make_pipeline_forward(cfg: ModelConfig, n_pods: int,
                          num_microbatches: int, mesh):
    """Returns fn(stage_params, x, angles) -> y.

    x (B, S, d_model) hidden states (embedding/lm_head run outside — they
    are data-parallel); y (B, S, d_model) after all L layers.
    B % num_microbatches == 0.
    """
    def pipelined(stage_params, x, angles):
        # stage_params leaves: (1, L/P, ...) local slices  (pod manual axis)
        local = jax.tree_util.tree_map(lambda a: a[0], stage_params)
        B = x.shape[0]
        M = num_microbatches
        mb = x.reshape((M, B // M) + x.shape[1:])
        # angles ride along with their microbatch (per-row M-RoPE safe)
        amb = angles.reshape((M, B // M) + angles.shape[1:])
        pod = jax.lax.axis_index("pod")
        ticks = M + n_pods - 1
        state = jnp.zeros_like(mb[0])
        state_a = jnp.zeros_like(amb[0])
        outs = jnp.zeros_like(mb)

        def tick(carry, t):
            state, state_a, outs = carry
            sel = jnp.minimum(t, M - 1)
            inject = jnp.where(t < M, mb[sel], jnp.zeros_like(mb[0]))
            inject_a = jnp.where(t < M, amb[sel], jnp.zeros_like(amb[0]))
            x_in = jnp.where(pod == 0, inject, state)
            a_in = jnp.where(pod == 0, inject_a, state_a)
            h = _stage_apply(cfg, local, x_in, a_in)
            # shift one pod to the right (the paper's T_TX hop)
            shift = [(p, p + 1) for p in range(n_pods - 1)]
            nxt = jax.lax.ppermute(h, "pod", shift)
            nxt_a = jax.lax.ppermute(a_in, "pod", shift)
            # the LAST pod emits microbatch t-(P-1) at tick t
            out_idx = t - (n_pods - 1)
            outs = jnp.where(
                (pod == n_pods - 1) & (out_idx >= 0),
                outs.at[jnp.maximum(out_idx, 0)].set(h), outs)
            return (nxt, nxt_a, outs), None

        (state, state_a, outs), _ = jax.lax.scan(
            tick, (state, state_a, outs), jnp.arange(ticks))
        y = outs.reshape((B,) + x.shape[1:])
        # broadcast the last pod's result to every pod (replicated output).
        # fp32 psum: XLA CPU's AllReducePromotion pass crashes on bf16
        # all-reduce (compiler bug worked around; on TPU this is free).
        y = jax.lax.psum(
            jnp.where(pod == n_pods - 1, y.astype(jnp.float32),
                      jnp.zeros(y.shape, jnp.float32)), "pod")
        return y.astype(x.dtype)

    return _shard_map_pod_manual(
        pipelined, mesh,
        in_specs=(P("pod"), P(), P()),
        out_specs=P())


def make_split_serve_step(cfg: ModelConfig, n_pods: int,
                          num_microbatches: int, mesh):
    """Full request step: embed -> pod-pipelined stack -> final norm/head.

    Returns fn(params_with_stacked_runs, batch) -> last-position logits.
    ``params`` as from init_params but with params['runs'][0] restacked by
    stack_stage_params (leading (n_pods, L/P) dims).
    """
    pipe = make_pipeline_forward(cfg, n_pods, num_microbatches, mesh)

    def step(params, batch):
        x, B, S = tr.embed_inputs(params, cfg, batch)
        angles = tr._angles_for(cfg, batch, B, S)
        if angles is None:
            angles = jnp.zeros((B, S, max(cfg.head_dim // 2, 1)),
                               jnp.float32)
        y = pipe(params["runs"][0], x, angles)
        y = tr.rmsnorm(y, params["final_norm"], cfg.norm_eps)
        return tr._lm_logits(params, cfg, y[:, -1])

    return step
