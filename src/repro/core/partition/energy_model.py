"""Energy model for the edge device of a split deployment (joules).

The paper motivates collaborative inference with *both* "inference
latency" and "high energy consumption" on resource-limited embedded
devices, and claims pruning "reduce[s] energy consumption" — yet Eq. 5
prices latency only. This module closes that gap: it prices every
candidate split into a ``(T_total, E_edge)`` pair so the splitter can
optimize a weighted latency·energy objective, report the Pareto front,
and — through the adaptive controller — shift the partition toward the
low-energy end as a battery budget drains.

State machine behind the numbers (one request at split ``c``):

  1. **compute** — layers [0, c) run on the edge SoC for ``T_D`` seconds
     at ``compute_power_w`` (the radio draws its ``idle_power_w``);
  2. **transmit** — the radio spends ``tx_bytes / bandwidth`` seconds in
     the active TX state at ``tx_power_w`` (the SoC has finished; it
     draws ``idle_power_w``);
  3. **wait** — for one RTT plus the cloud's ``T_S`` the SoC idles and
     the radio listens for the logits downlink at ``rx_power_w``.

Every term is therefore a *time x power* product over the same latency
breakdown Eq. 5 produces, which keeps the analytic sweep
(``split_energy`` / ``sweep_splits(energy=...)``) and the runtimes'
per-request accounting (``EnergyProfile.request_energy`` fed with the
measured/modeled ``t_device`` / ``t_tx`` / ``t_server``) numerically
consistent by construction — one formula, two call sites.

Cloud energy is *optionally* priced for completeness
(``cloud_power_w > 0`` adds an ``E_cloud`` column) but never enters the
edge objective: the paper's constraint is the embedded device's battery,
not the datacenter's meter.

All JSON keys carry unit suffixes (``*_power_w`` watts, ``*_j`` joules,
``*_s_per_j`` seconds-per-joule) so they can never collide with the
batching section's power-of-two bucket vocabulary (``buckets``,
``max_batch``) in ``plan.json`` or ``LaneStats`` records.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence

from repro.core.partition.latency_model import LayerCost, split_latency
from repro.core.partition.profiles import TwoTierProfile


@dataclass(frozen=True)
class RadioProfile:
    """Power draw of the edge radio per state (watts).

    ``tx_power_w`` while actively transmitting bytes; ``rx_power_w``
    while listening for / receiving the response; ``idle_power_w`` the
    baseline draw while the SoC computes and the radio merely stays
    associated.
    """
    name: str
    tx_power_w: float
    rx_power_w: float
    idle_power_w: float = 0.0

    def __post_init__(self) -> None:
        if min(self.tx_power_w, self.rx_power_w, self.idle_power_w) < 0:
            raise ValueError("radio power draws must be >= 0 W")


@dataclass(frozen=True)
class EnergyProfile:
    """Per-state power model of one edge device (watts in, joules out).

    ``compute_power_w`` is the SoC's active draw while running edge
    layers; ``idle_power_w`` its draw while blocked on the link/cloud;
    ``radio`` the radio's per-state draws. ``cloud_power_w`` optionally
    prices the server side (reported as ``E_cloud``, never part of the
    edge objective).
    """
    name: str
    compute_power_w: float
    idle_power_w: float
    radio: RadioProfile
    cloud_power_w: float = 0.0

    def __post_init__(self) -> None:
        if min(self.compute_power_w, self.idle_power_w,
               self.cloud_power_w) < 0:
            raise ValueError("power draws must be >= 0 W")

    def energy_breakdown(self, t_device: float, t_tx: float,
                         t_server: float, rtt_s: float = 0.0
                         ) -> Dict[str, float]:
        """Edge energy (joules) of one request from its latency breakdown.

        The single pricing formula shared by the analytic sweep and the
        runtimes' per-request accounting. ``t_tx`` is the uplink term as
        every channel charges it — ``tx_bytes / bandwidth`` *plus one
        RTT* — so the RTT portion is peeled off and billed as waiting
        (SoC idle + radio listening), not as radio-active transmission.

        Returns ``e_comp_j`` / ``e_tx_j`` / ``e_wait_j`` / ``e_edge_j``
        (their sum), all in joules.
        """
        tx_active = max(t_tx - rtt_s, 0.0)
        t_wait = (t_tx - tx_active) + max(t_server, 0.0)
        e_comp = max(t_device, 0.0) * (self.compute_power_w
                                       + self.radio.idle_power_w)
        e_tx = tx_active * self.radio.tx_power_w
        e_wait = t_wait * (self.idle_power_w + self.radio.rx_power_w)
        return {"e_comp_j": e_comp, "e_tx_j": e_tx, "e_wait_j": e_wait,
                "e_edge_j": e_comp + e_tx + e_wait}

    def request_energy(self, t_device: float, t_tx: float, t_server: float,
                       rtt_s: float = 0.0) -> float:
        """Total edge energy of one request (joules) — the scalar the
        sessions report as ``e_edge_j``."""
        return self.energy_breakdown(t_device, t_tx, t_server,
                                     rtt_s)["e_edge_j"]

    def to_json(self) -> Dict[str, Any]:
        """Serialize for ``plan.json`` — every key unit-suffixed
        (``*_power_w`` watts)."""
        return {"name": self.name,
                "compute_power_w": self.compute_power_w,
                "idle_power_w": self.idle_power_w,
                "radio": {"name": self.radio.name,
                          "tx_power_w": self.radio.tx_power_w,
                          "rx_power_w": self.radio.rx_power_w,
                          "idle_power_w": self.radio.idle_power_w},
                "cloud_power_w": self.cloud_power_w}

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "EnergyProfile":
        return cls(name=d["name"],
                   compute_power_w=float(d["compute_power_w"]),
                   idle_power_w=float(d["idle_power_w"]),
                   radio=RadioProfile(name=d["radio"]["name"],
                                      tx_power_w=float(
                                          d["radio"]["tx_power_w"]),
                                      rx_power_w=float(
                                          d["radio"]["rx_power_w"]),
                                      idle_power_w=float(
                                          d["radio"]["idle_power_w"])),
                   cloud_power_w=float(d.get("cloud_power_w", 0.0)))


# --- canned device energy profiles ------------------------------------------
#: MCU-class embedded board with an on-module Wi-Fi radio (ESP32/Cortex-M
#: class): sub-watt SoC, a radio whose TX burst dwarfs the compute draw —
#: the battery-constrained class the paper's "resource-limited embedded
#: devices" motivation names.
MCU_ENERGY = EnergyProfile(
    "mcu", compute_power_w=0.30, idle_power_w=0.04,
    radio=RadioProfile("wifi-module", tx_power_w=0.80, rx_power_w=0.40,
                       idle_power_w=0.02))
#: Pi-class single-board computer: the SoC dominates the radio, so
#: offloading compute (earlier splits) saves energy even when it ships
#: more bytes.
PI_ENERGY = EnergyProfile(
    "pi", compute_power_w=5.5, idle_power_w=2.2,
    radio=RadioProfile("usb-wifi", tx_power_w=1.3, rx_power_w=0.9,
                       idle_power_w=0.1))
#: Phone-class edge (mid-range smartphone). Calibration: a big.LITTLE
#: SoC under sustained NN load draws ~3-4 W before thermal throttling
#: (compute clusters + LPDDR), idles near ~0.9 W with the screen's
#: share excluded; the Wi-Fi/LTE modem bursts ~1.2 W on TX and ~0.85 W
#: in active RX. Between MCU (radio-dominated) and Pi (SoC-dominated):
#: compute and radio costs are comparable, so the energy-optimal split
#: genuinely moves with the link. Pairs with ``profiles.PHONE_EDGE``.
PHONE_ENERGY = EnergyProfile(
    "phone", compute_power_w=3.5, idle_power_w=0.9,
    radio=RadioProfile("phone-modem", tx_power_w=1.2, rx_power_w=0.85,
                       idle_power_w=0.08))
#: the paper's i7-6700 edge box (mains-powered — energy pricing for
#: completeness, with the 3090 server's draw as E_cloud)
PAPER_EDGE_ENERGY = EnergyProfile(
    "i7-6700", compute_power_w=65.0, idle_power_w=20.0,
    radio=RadioProfile("wifi-nic", tx_power_w=2.5, rx_power_w=1.5,
                       idle_power_w=0.5),
    cloud_power_w=350.0)

ENERGY_PROFILES = {
    "mcu": MCU_ENERGY,
    "pi": PI_ENERGY,
    "phone": PHONE_ENERGY,
    "paper_edge": PAPER_EDGE_ENERGY,
}


def urgency_scaled_weight(weight_s_per_j: float,
                          battery_fraction: Optional[float],
                          floor: float = 1e-3) -> float:
    """The battery-urgency curve shared by the adaptive controller and
    the fleet simulator: the static s/J exchange rate scaled by the
    inverse *square* of the remaining battery fraction (clamped at
    ``floor``). A full battery optimizes latency; at half charge the
    device already pays 4x more seconds per joule saved — the walk
    toward the low-energy splits happens while meaningful budget
    remains, not at exhaustion. ``battery_fraction=None`` (unmetered)
    returns the static weight unchanged."""
    if battery_fraction is None:
        return weight_s_per_j
    return weight_s_per_j / max(battery_fraction, floor) ** 2


@dataclass(frozen=True)
class EnergyPolicy:
    """Serializable energy knobs (the plan's ``energy`` section).

    ``profile`` is the edge device's power model;
    ``energy_weight_s_per_j`` the exchange rate of the weighted
    objective ``score = latency_weight * T + energy_weight_s_per_j *
    E_edge`` (0 keeps the latency-only paper objective while still
    *reporting* joules); ``battery_j`` an optional remaining-energy
    budget — when set, the adaptive controller scales the energy weight
    up as the battery drains, shifting the partition toward the
    low-energy end of the Pareto front before the budget runs out.
    """
    profile: EnergyProfile
    latency_weight: float = 1.0
    energy_weight_s_per_j: float = 0.0
    battery_j: Optional[float] = None

    def __post_init__(self) -> None:
        if self.latency_weight < 0 or self.energy_weight_s_per_j < 0:
            raise ValueError("objective weights must be >= 0")
        if self.battery_j is not None and not self.battery_j > 0:
            raise ValueError("battery_j must be > 0 joules when set")

    def score(self, row: Dict[str, float],
              energy_weight: Optional[float] = None) -> float:
        """Weighted latency·energy objective of one priced sweep row
        (seconds-equivalents; lower is better). ``energy_weight``
        overrides the static knob — the battery-aware controller passes
        its urgency-scaled weight here."""
        w = (self.energy_weight_s_per_j if energy_weight is None
             else energy_weight)
        return self.latency_weight * row["T"] + w * row["E_edge"]

    def to_json(self) -> Dict[str, Any]:
        """Serialize for ``plan.json`` (the digest-folded form): watts
        inside ``profile``, ``battery_j`` joules, the weight in s/J."""
        return {"profile": self.profile.to_json(),
                "latency_weight": self.latency_weight,
                "energy_weight_s_per_j": self.energy_weight_s_per_j,
                "battery_j": self.battery_j}

    @classmethod
    def from_json(cls, d: Dict[str, Any]) -> "EnergyPolicy":
        return cls(profile=EnergyProfile.from_json(d["profile"]),
                   latency_weight=float(d["latency_weight"]),
                   energy_weight_s_per_j=float(d["energy_weight_s_per_j"]),
                   battery_j=(None if d.get("battery_j") is None
                              else float(d["battery_j"])))


def price_energy(row: Dict[str, float], energy: EnergyProfile,
                 rtt_s: float) -> Dict[str, float]:
    """Add the energy columns to one Eq. 5 latency row *in place* style:
    returns a new dict with ``E_comp``/``E_tx``/``E_wait``/``E_edge``
    (joules) — and ``E_cloud`` when the profile prices the server —
    derived from the row's ``T_D``/``T_TX``/``T_S``."""
    br = energy.energy_breakdown(row["T_D"], row["T_TX"], row["T_S"],
                                 rtt_s=rtt_s)
    out = dict(row, E_comp=br["e_comp_j"], E_tx=br["e_tx_j"],
               E_wait=br["e_wait_j"], E_edge=br["e_edge_j"])
    if energy.cloud_power_w > 0:
        out["E_cloud"] = row["T_S"] * energy.cloud_power_w
    return out


def split_energy(costs: Sequence[LayerCost], c: int,
                 profile: TwoTierProfile, energy: EnergyProfile,
                 input_bytes: float, tx_scale: float = 1.0,
                 **latency_kw) -> Dict[str, float]:
    """Eq. 5 latency breakdown at split ``c`` plus its edge energy
    (joules): the ``(T_total, E_edge)`` pair of one candidate split.
    Extra keyword arguments are forwarded to ``split_latency``."""
    row = split_latency(costs, c, profile, input_bytes, tx_scale=tx_scale,
                        **latency_kw)
    return price_energy(row, energy, profile.link.rtt_s)


def pareto_front(table: Sequence[Dict[str, float]], t_key: str = "T",
                 e_key: str = "E_edge") -> List[Dict[str, float]]:
    """Non-dominated (latency, energy) rows of a priced sweep table,
    sorted by ascending latency (``T`` seconds, ``E_edge`` joules).

    A row is kept iff no other row is at least as good on both axes and
    strictly better on one. Along the returned front, latency increases
    monotonically while energy strictly decreases — the menu of
    operating points the weighted objective (or a battery-aware
    controller) picks from.
    """
    rows = sorted(table, key=lambda r: (r[t_key], r[e_key]))
    front: List[Dict[str, float]] = []
    best_e = float("inf")
    for r in rows:
        if r[e_key] < best_e:
            front.append(r)
            best_e = r[e_key]
    return front
