"""Per-layer cost model + the collaborative-inference latency of Eq. 5:

    T(c) = T_D(c) + T_TX(c) + T_S(c)

Split point ``c`` means layers [0, c) run on the device and [c, N) on the
server; c = N is device-only, c = 0 is server-only (the raw input is
transmitted instead — the paper's 73.5 KB preprocessed tensor).

Two sources of per-layer numbers:
  * analytic — FLOPs and activation bytes from the layer specs (works for
    CNN and transformer configs alike; drives the dry-run-scale studies);
  * measured — wall-clock timestamps per layer (Algorithm 1 line 22), used
    by the Tier-A reproduction on this container's CPU.

Pruning feeds back into the model: masked channels shrink both FLOPs and
transmitted activation bytes (Fig. 4 of the paper).
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CNNConfig, ModelConfig
from repro.core.partition.profiles import TwoTierProfile
from repro.models.cnn import cnn_apply, layer_shapes


@dataclass
class LayerCost:
    index: int
    name: str
    flops: float                # forward FLOPs for batch=1
    out_bytes: float            # activation bytes crossing a split AFTER it
    params_bytes: float = 0.0


# ---------------------------------------------------------------------------
# analytic costs: CNN
# ---------------------------------------------------------------------------
def cnn_layer_costs(cfg: CNNConfig,
                    masks: Optional[Dict[int, np.ndarray]] = None,
                    bytes_per_elem: int = 4) -> List[LayerCost]:
    shapes = layer_shapes(cfg)
    masks = masks or {}
    costs = []
    c_in = cfg.input_channels
    keep_in = 1.0
    flat = None
    for i, spec in enumerate(cfg.layers):
        keep_out = (float(np.mean(np.asarray(masks[i]))) if i in masks
                    else 1.0)
        if spec.kind == "conv":
            c_out, h, w = shapes[i]
            fl = 2.0 * h * w * c_out * c_in * spec.kernel ** 2
            fl *= keep_in * keep_out
            ob = h * w * c_out * keep_out * bytes_per_elem
            pb = (spec.kernel ** 2 * c_in * c_out * keep_in * keep_out
                  + c_out * keep_out) * bytes_per_elem
            costs.append(LayerCost(i, f"conv{i}", fl, ob, pb))
            c_in = c_out
            keep_in = keep_out
        elif spec.kind == "relu":
            shp = shapes[i]
            nelem = int(np.prod(shp)) * keep_in
            costs.append(LayerCost(i, f"relu{i}", nelem,
                                   nelem * bytes_per_elem))
        elif spec.kind == "maxpool":
            c, h, w = shapes[i]
            nelem = c * h * w * keep_in
            costs.append(LayerCost(i, f"pool{i}",
                                   nelem * spec.kernel ** 2,
                                   nelem * bytes_per_elem))
        elif spec.kind == "flatten":
            nelem = shapes[i][0] * keep_in
            costs.append(LayerCost(i, f"flat{i}", 0.0,
                                   nelem * bytes_per_elem))
        elif spec.kind == "dense":
            d_in = (flat if flat is not None else shapes[i - 1][0])
            fl = 2.0 * d_in * spec.features * keep_in * keep_out
            ob = spec.features * keep_out * bytes_per_elem
            pb = (d_in * spec.features * keep_in * keep_out
                  + spec.features * keep_out) * bytes_per_elem
            costs.append(LayerCost(i, f"fc{i}", fl, ob, pb))
            keep_in = keep_out
            flat = spec.features
    return costs


def cnn_input_bytes(cfg: CNNConfig, bytes_per_elem: int = 4) -> float:
    h, w = cfg.input_hw
    return h * w * cfg.input_channels * bytes_per_elem


def compacted_cnn_layer_costs(cfg: CNNConfig, masks,
                              bytes_per_elem: int = 4) -> List[LayerCost]:
    """Price the *deployed* network: pruned channels physically removed
    (``compact_cnn_config``), so FLOPs, activation bytes, and param bytes
    reflect the compacted shapes rather than masked-but-dense execution.
    Feed the result to ``greedy_split`` to re-pick the deployment split."""
    from repro.models.cnn import compact_cnn_config
    return cnn_layer_costs(compact_cnn_config(cfg, masks or {}),
                           bytes_per_elem=bytes_per_elem)


def quantized_cnn_layer_costs(cfg: CNNConfig, masks=None,
                              weight_bits: Optional[int] = 8,
                              bytes_per_elem: int = 4) -> List[LayerCost]:
    """Price the *quantized* deployed network: compacted shapes with
    ``params_bytes`` scaled to the quantized weight width — the traffic
    the int8/int4 edge actually streams from flash per inference. FLOPs
    and activation bytes are unchanged (weight-only quantization keeps
    fp32 activations). ``weight_bits=None`` prices the fp32 kernel
    path (identical to ``compacted_cnn_layer_costs``)."""
    costs = compacted_cnn_layer_costs(cfg, masks, bytes_per_elem)
    if weight_bits is None:
        return costs
    frac = weight_bits / (8.0 * bytes_per_elem)
    return [LayerCost(c.index, c.name, c.flops, c.out_bytes,
                      c.params_bytes * frac) for c in costs]


# ---------------------------------------------------------------------------
# analytic costs: transformer (per decoder layer, batch=1)
# ---------------------------------------------------------------------------
def transformer_layer_costs(cfg: ModelConfig, seq_len: int,
                            bytes_per_elem: int = 2,
                            decode: bool = False) -> List[LayerCost]:
    """Uniform per-layer cost; embedding/head folded into first/last."""
    d = cfg.d_model
    S = 1 if decode else seq_len
    ctx = seq_len
    costs = []
    kinds = cfg.layer_kinds()
    for i, kind in enumerate(kinds):
        fl = 0.0
        if kind in ("attn", "attn_dense", "moe"):
            if cfg.attention == "mla":
                m = cfg.mla
                qk = m.qk_nope_head_dim + m.qk_rope_head_dim
                proj = (d * m.q_lora_rank + m.q_lora_rank * cfg.num_heads * qk
                        + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                        + m.kv_lora_rank * cfg.num_heads
                        * (m.qk_nope_head_dim + m.v_head_dim)
                        + cfg.num_heads * m.v_head_dim * d)
                att = cfg.num_heads * ctx * (qk + m.v_head_dim)
            else:
                proj = d * (cfg.q_dim + 2 * cfg.kv_dim) + cfg.q_dim * d
                win = min(ctx, cfg.sliding_window or ctx)
                att = cfg.num_heads * win * 2 * cfg.head_dim
            fl += 2.0 * S * (proj + att)
            if kind == "moe":
                m = cfg.moe
                mult = 3 if cfg.activation in ("silu_glu", "geglu") else 2
                fl += 2.0 * S * (m.top_k + m.num_shared) * d * m.d_expert * mult
                fl += 2.0 * S * d * m.num_experts     # router
            else:
                mult = 3 if cfg.activation in ("silu_glu", "geglu") else 2
                fl += 2.0 * S * d * cfg.d_ff * mult
        elif kind == "ssm":
            s = cfg.ssm
            d_in = cfg.d_inner
            proj = d * (2 * d_in + 2 * s.n_groups * s.d_state + cfg.ssm_heads)
            ssd = d_in * s.d_state * 6
            fl += 2.0 * S * (proj + ssd + d_in * d)
        out_bytes = S * d * bytes_per_elem
        costs.append(LayerCost(i, f"{kind}{i}", fl, out_bytes))
    return costs


# ---------------------------------------------------------------------------
# measured costs (Algorithm 1, line 22: "via timestamps")
# ---------------------------------------------------------------------------
def measure_cnn_layer_times(params, cfg: CNNConfig, x,
                            masks=None, repeats: int = 3) -> List[float]:
    """Wall-clock seconds per layer (jitted per-layer, CPU)."""
    times = []
    cur = x
    for i in range(len(cfg.layers)):
        fn = jax.jit(lambda v, p=params, s=i: cnn_apply(
            p, cfg, v, masks=masks, start_layer=s, stop_layer=s + 1))
        out = fn(cur)
        jax.block_until_ready(out)
        t0 = time.perf_counter()
        for _ in range(repeats):
            out = fn(cur)
            jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) / repeats)
        cur = out
    return times


@dataclass(frozen=True)
class KernelCalibration:
    """Measured per-layer edge seconds — the kernel-cost calibration hook
    of the split model. ``measure`` times any per-layer forward (fp32
    dense, kernel-dispatched, quantized — the caller passes the jitted
    layer callables, e.g. from ``repro.core.collab.quant
    .calibrate_quant_edge``), and ``layer_s`` plugs into
    ``split_latency`` / ``sweep_splits`` / ``energy_aware_split`` as
    ``measured_device_s``, so the sweep picks splits on the deployed
    kernels' real costs instead of the analytic roofline."""
    layer_s: tuple

    @classmethod
    def measure(cls, layer_fns: Sequence, x0,
                repeats: int = 3) -> "KernelCalibration":
        """``layer_fns[i]`` maps layer i's input to its output (jitted by
        the caller so the repeat loop times execution, not tracing);
        outputs thread forward so each layer is timed on its real input."""
        times = []
        cur = x0
        for fn in layer_fns:
            out = fn(cur)
            jax.block_until_ready(out)
            t0 = time.perf_counter()
            for _ in range(repeats):
                jax.block_until_ready(fn(cur))
            times.append(float((time.perf_counter() - t0) / repeats))
            cur = out
        return cls(tuple(times))

    def total_s(self, split: Optional[int] = None) -> float:
        """Measured device seconds for layers [0, split) (all when None)."""
        n = len(self.layer_s) if split is None else split
        return float(sum(self.layer_s[:n]))


def cnn_layer_output_bytes(params, cfg: CNNConfig, x, masks=None) -> List[int]:
    """True transmitted payload per split point: nonzero (surviving) units.

    Pruned channels are zeros under masked execution and are physically
    absent after compaction, so the honest wire size excludes them
    (paper Fig. 4 reports exactly this reduction)."""
    _, inter = cnn_apply(params, cfg, x, masks=masks,
                         return_intermediates=True)
    masks = masks or {}
    out = []
    shapes = layer_shapes(cfg)
    keep = 1.0
    for i, a in enumerate(inter):
        if i in masks:
            keep = float(np.mean(np.asarray(masks[i])))
        # relu/pool/flatten inherit the producer's surviving-channel ratio
        nbytes = a.nbytes / a.shape[0] * keep if keep < 1.0 else a.nbytes / a.shape[0]
        out.append(int(nbytes))
    return out


# ---------------------------------------------------------------------------
# the true wire payload at a split (codec x packing semantics of tx_scale)
# ---------------------------------------------------------------------------
def wire_tx_scale(cfg: CNNConfig, masks, split: int,
                  codec: Optional[str] = None, pack: bool = False,
                  compact: bool = False) -> float:
    """The ``tx_scale`` that makes the analytic ``tx_bytes`` equal the
    actual wire payload of the deployed runtime at ``split``.

    ``tx_scale`` is the product of two independent discounts:

      * **codec** — bytes per element relative to fp32 (1.0 / 0.5 / 0.25
        for fp32 / fp16 / int8, ``protocol.CODEC_TX_SCALE``);
      * **packing** — which elements ship at all. The masked layer costs
        (``cnn_layer_costs(cfg, masks)``) already price ``out_bytes`` at
        the surviving-channel fraction, which is the honest wire size only
        for ``pack=True`` (bitmask packing strips the dead channels) or
        ``compact=True`` (they are physically gone). A masked-but-dense
        deployment *without* packing ships the full tensor, zeros
        included, so this helper *un*-discounts by the keep ratio at the
        split boundary to match what actually crosses the link.

    Frame headers (a few tens of bytes) are not modelled.
    """
    from repro.core.collab.protocol import CODEC_TX_SCALE
    from repro.models.cnn import split_keep_indices
    scale = CODEC_TX_SCALE[codec or "fp32"]
    if compact or not masks or split <= 0:
        return scale
    keep = split_keep_indices(cfg, masks, split)
    if keep is None or pack:
        # all channels live, or the dead ones don't cross the wire: the
        # keep-discounted out_bytes already is the wire payload
        return scale
    n_full = layer_shapes(cfg)[split - 1][0]
    return scale * n_full / keep.size


# ---------------------------------------------------------------------------
# batched server time (what dynamic batching amortizes)
# ---------------------------------------------------------------------------
def _segment_time(costs: Sequence[LayerCost], idx, comp,
                  batch: int = 1) -> float:
    """Analytic time for layers ``idx`` on ``comp`` (a ComputeProfile):
    per-layer roofline (flops vs activation traffic) scaled by the batch
    plus the per-invocation overhead, paid once per layer per CALL. The
    single source of the formula — ``split_latency`` and
    ``batched_server_time`` must never drift apart."""
    t = 0.0
    for i in idx:
        work = max(batch * costs[i].flops / comp.flops_per_s,
                   2 * batch * costs[i].out_bytes / comp.mem_bw)
        t += work + comp.overhead_s
    return t


def batched_segment_time(costs: Sequence[LayerCost], start: int, stop: int,
                         comp, batch: int) -> float:
    """Analytic time for ONE invocation running layers ``[start, stop)``
    over ``batch`` fused rows on ``comp`` (a ``ComputeProfile``) — the
    same per-layer roofline + once-per-call overhead formula as
    ``split_latency``, exposed for *partial* stacks: the fleet
    simulator's cloudlet tier runs ``[c1, c2)`` and its cloud tier
    ``[c2, N)``, both priced here so tier numbers can never drift from
    the two-tier model."""
    if batch < 1:
        raise ValueError("batch must be >= 1")
    if not 0 <= start <= stop <= len(costs):
        raise ValueError(f"segment [{start}, {stop}) outside "
                         f"[0, {len(costs)}]")
    return _segment_time(costs, range(start, stop), comp, batch)


def batched_server_time(costs: Sequence[LayerCost], c: int,
                        server, batch: int) -> float:
    """Analytic T_S for ONE cloud invocation serving ``batch`` fused
    requests on ``server`` (a ``ComputeProfile``): per-layer FLOPs and
    activation traffic scale with the batch, but the per-invocation
    constant (``ComputeProfile.overhead_s`` — kernel launch, dispatch,
    framework overhead) is paid once per *batch* instead of once per
    *request*. The gap between ``batch * batched_server_time(..., 1)``
    and ``batched_server_time(..., batch)`` is exactly the throughput
    headroom the cross-client dynamic batching engine recovers; per
    request it approaches ``overhead_s``-free compute as the batching
    window fills."""
    return batched_segment_time(costs, c, len(costs), server, batch)


# ---------------------------------------------------------------------------
# Eq. 5: the latency of a split
# ---------------------------------------------------------------------------
def split_latency(costs: Sequence[LayerCost], c: int,
                  profile: TwoTierProfile,
                  input_bytes: float,
                  measured_device_s: Optional[Sequence[float]] = None,
                  measured_server_s: Optional[Sequence[float]] = None,
                  tx_scale: float = 1.0,
                  round_trip: bool = False
                  ) -> Dict[str, float]:
    """Latency breakdown for split point c (layers [0,c) on device).

    ``tx_scale`` discounts the bytes that actually cross the link relative
    to the masked/compacted fp32 activation the costs were priced at. It
    composes the feature codec (0.5 for fp16, 0.25 for int8 — see
    ``repro.core.collab.protocol.CODEC_TX_SCALE``) with the channel-packing
    correction; use ``wire_tx_scale`` to derive the combined factor for a
    concrete deployment. Compute-side memory traffic is unaffected.

    **T_TX is uplink-only by default**: it charges the feature tensor
    (device -> server) plus ONE RTT, matching the paper's Eq. 5 and every
    comparison table in ``benchmarks/``. The socket path is actually
    request/response — logits come back — so ``round_trip=True`` adds the
    return payload (the final layer's output bytes) and a second RTT for
    deployments where the downlink is not negligible. ``tx_bytes`` in the
    returned row stays uplink-only either way (it is what the runtimes
    report as transmitted feature bytes)."""
    n = len(costs)
    assert 0 <= c <= n

    def seg_time(idx, comp, measured):
        if measured is not None:
            return sum(measured[i] for i in idx)
        return _segment_time(costs, idx, comp)

    t_d = seg_time(range(c), profile.device, measured_device_s)
    t_s = seg_time(range(c, n), profile.server, measured_server_s)
    tx_bytes = (input_bytes if c == 0 else costs[c - 1].out_bytes) * tx_scale
    if c == n:
        t_tx = 0.0
    else:
        t_tx = tx_bytes / profile.link.bandwidth + profile.link.rtt_s
        if round_trip:
            # logits downlink: final layer output + its own RTT
            t_tx += (costs[n - 1].out_bytes / profile.link.bandwidth
                     + profile.link.rtt_s)
    return {"T_D": t_d, "T_TX": t_tx, "T_S": t_s,
            "T": t_d + t_tx + t_s, "tx_bytes": 0.0 if c == n else tx_bytes}
