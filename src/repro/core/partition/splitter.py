"""Split-point selection — Algorithm 1, lines 20-27 (greedy argmin), plus a
beyond-paper pipelined variant and the energy-aware objective.

``greedy_split`` is the paper's loop: evaluate T(G', j) for every candidate
j and keep the argmin. ``balanced_split`` (Tier C, DESIGN.md §2) instead
minimizes max(T_D, T_TX, T_S) — the steady-state bottleneck when requests
stream and device/link/server overlap — which the paper's serial model
cannot see. ``energy_aware_split`` minimizes the weighted latency·energy
objective of an ``EnergyPolicy`` (``repro.core.partition.energy_model``):
the paper's motivation names battery-constrained embedded devices, and
the latency optimum is not the joules optimum — ``sweep_splits`` prices
every candidate into a ``(T_total, E_edge)`` pair when handed an
``EnergyProfile``, and ``pareto_front`` reports the non-dominated menu.

``joint_two_stage`` wires the full paper pipeline together: DDPG pruning
first (stage 1), greedy split on the pruned network (stage 2), per Eq. 6's
two-stage decomposition.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Union

from repro.core.partition.energy_model import (EnergyPolicy, EnergyProfile,
                                               pareto_front, price_energy)
from repro.core.partition.latency_model import LayerCost, split_latency
from repro.core.partition.profiles import TwoTierProfile

__all__ = ["SplitDecision", "sweep_splits", "greedy_split",
           "balanced_split", "energy_aware_split", "pareto_front",
           "joint_two_stage"]


@dataclass
class SplitDecision:
    split_point: int
    latency: Dict[str, float]
    table: List[Dict[str, float]]     # per-candidate breakdown (paper Table 2)


def sweep_splits(costs: Sequence[LayerCost], profile: TwoTierProfile,
                 input_bytes: float,
                 measured_device_s: Optional[Sequence[float]] = None,
                 measured_server_s: Optional[Sequence[float]] = None,
                 candidates: Optional[Sequence[int]] = None,
                 tx_scale: Union[float, Callable[[int], float]] = 1.0,
                 round_trip: bool = False,
                 energy: Optional[EnergyProfile] = None
                 ) -> List[Dict[str, float]]:
    """Eq. 5 at every candidate split. ``tx_scale`` may be a callable
    ``split -> scale`` because the channel-packing discount depends on
    which channels survive at each boundary (``wire_tx_scale``).

    With an ``energy`` profile, every row additionally carries the edge
    energy columns ``E_comp``/``E_tx``/``E_wait``/``E_edge`` in joules
    (and ``E_cloud`` when the profile prices the server) — the
    ``(T_total, E_edge)`` pairs the energy-aware objective and the
    Pareto reporter consume."""
    n = len(costs)
    cands = list(candidates) if candidates is not None else list(range(n + 1))
    table = []
    for c in cands:
        scale = tx_scale(c) if callable(tx_scale) else tx_scale
        row = split_latency(costs, c, profile, input_bytes,
                            measured_device_s, measured_server_s,
                            tx_scale=scale, round_trip=round_trip)
        row["split"] = c
        if energy is not None:
            row = price_energy(row, energy, profile.link.rtt_s)
        table.append(row)
    return table


def greedy_split(costs: Sequence[LayerCost], profile: TwoTierProfile,
                 input_bytes: float, **kw) -> SplitDecision:
    """Algorithm 1 lines 20-27: T_min = T(G',1); for j=2..N keep argmin."""
    table = sweep_splits(costs, profile, input_bytes, **kw)
    best = min(table, key=lambda r: r["T"])
    return SplitDecision(int(best["split"]), best, table)


def balanced_split(costs: Sequence[LayerCost], profile: TwoTierProfile,
                   input_bytes: float, **kw) -> SplitDecision:
    """Beyond-paper: minimize the pipeline bottleneck max(T_D, T_TX, T_S)."""
    table = sweep_splits(costs, profile, input_bytes, **kw)
    best = min(table, key=lambda r: max(r["T_D"], r["T_TX"], r["T_S"]))
    return SplitDecision(int(best["split"]), best, table)


def energy_aware_split(costs: Sequence[LayerCost], profile: TwoTierProfile,
                       input_bytes: float, policy: EnergyPolicy,
                       energy_weight: Optional[float] = None,
                       **kw) -> SplitDecision:
    """Argmin of the weighted latency·energy objective
    ``latency_weight * T + energy_weight_s_per_j * E_edge`` over the
    candidate splits (Eq. 5 extended with the device's joules).

    With ``energy_weight_s_per_j == 0`` this degenerates to the paper's
    greedy latency argmin (while still reporting the energy columns);
    ``energy_weight`` overrides the policy's static knob — the
    battery-aware adaptive controller passes its urgency-scaled weight
    here. The decision's ``table`` rows carry both ``T`` (seconds) and
    ``E_edge`` (joules), ready for ``pareto_front``."""
    table = sweep_splits(costs, profile, input_bytes,
                         energy=policy.profile, **kw)
    best = min(table, key=lambda r: policy.score(r, energy_weight))
    return SplitDecision(int(best["split"]), best, table)


def joint_two_stage(search_pruning: Callable[[], Sequence[float]],
                    costs_for_ratios: Callable[[Sequence[float]],
                                               Sequence[LayerCost]],
                    profile: TwoTierProfile, input_bytes: float,
                    mode: str = "greedy") -> Dict:
    """Eq. 6 two-stage solver: S* from DRL, then c* from the split sweep."""
    ratios = list(search_pruning())
    costs = costs_for_ratios(ratios)
    split = (greedy_split if mode == "greedy" else balanced_split)(
        costs, profile, input_bytes)
    return {"ratios": ratios, "split": split}
