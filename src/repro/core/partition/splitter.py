"""Split-point selection — Algorithm 1, lines 20-27 (greedy argmin), plus a
beyond-paper pipelined variant.

``greedy_split`` is the paper's loop: evaluate T(G', j) for every candidate
j and keep the argmin. ``balanced_split`` (Tier C, DESIGN.md §2) instead
minimizes max(T_D, T_TX, T_S) — the steady-state bottleneck when requests
stream and device/link/server overlap — which the paper's serial model
cannot see.

``joint_two_stage`` wires the full paper pipeline together: DDPG pruning
first (stage 1), greedy split on the pruned network (stage 2), per Eq. 6's
two-stage decomposition.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Union

from repro.core.partition.latency_model import LayerCost, split_latency
from repro.core.partition.profiles import TwoTierProfile


@dataclass
class SplitDecision:
    split_point: int
    latency: Dict[str, float]
    table: List[Dict[str, float]]     # per-candidate breakdown (paper Table 2)


def sweep_splits(costs: Sequence[LayerCost], profile: TwoTierProfile,
                 input_bytes: float,
                 measured_device_s: Optional[Sequence[float]] = None,
                 measured_server_s: Optional[Sequence[float]] = None,
                 candidates: Optional[Sequence[int]] = None,
                 tx_scale: Union[float, Callable[[int], float]] = 1.0,
                 round_trip: bool = False
                 ) -> List[Dict[str, float]]:
    """Eq. 5 at every candidate split. ``tx_scale`` may be a callable
    ``split -> scale`` because the channel-packing discount depends on
    which channels survive at each boundary (``wire_tx_scale``)."""
    n = len(costs)
    cands = list(candidates) if candidates is not None else list(range(n + 1))
    table = []
    for c in cands:
        scale = tx_scale(c) if callable(tx_scale) else tx_scale
        row = split_latency(costs, c, profile, input_bytes,
                            measured_device_s, measured_server_s,
                            tx_scale=scale, round_trip=round_trip)
        row["split"] = c
        table.append(row)
    return table


def greedy_split(costs: Sequence[LayerCost], profile: TwoTierProfile,
                 input_bytes: float, **kw) -> SplitDecision:
    """Algorithm 1 lines 20-27: T_min = T(G',1); for j=2..N keep argmin."""
    table = sweep_splits(costs, profile, input_bytes, **kw)
    best = min(table, key=lambda r: r["T"])
    return SplitDecision(int(best["split"]), best, table)


def balanced_split(costs: Sequence[LayerCost], profile: TwoTierProfile,
                   input_bytes: float, **kw) -> SplitDecision:
    """Beyond-paper: minimize the pipeline bottleneck max(T_D, T_TX, T_S)."""
    table = sweep_splits(costs, profile, input_bytes, **kw)
    best = min(table, key=lambda r: max(r["T_D"], r["T_TX"], r["T_S"]))
    return SplitDecision(int(best["split"]), best, table)


def joint_two_stage(search_pruning: Callable[[], Sequence[float]],
                    costs_for_ratios: Callable[[Sequence[float]],
                                               Sequence[LayerCost]],
                    profile: TwoTierProfile, input_bytes: float,
                    mode: str = "greedy") -> Dict:
    """Eq. 6 two-stage solver: S* from DRL, then c* from the split sweep."""
    ratios = list(search_pruning())
    costs = costs_for_ratios(ratios)
    split = (greedy_split if mode == "greedy" else balanced_split)(
        costs, profile, input_bytes)
    return {"ratios": ratios, "split": split}
