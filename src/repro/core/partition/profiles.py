"""Hardware profiles for the latency model.

Tier A (paper-faithful): the paper's own testbed — an i7-6700 edge box, a
Ryzen+RTX-3090 server, ~50 Mbps Wi-Fi (§4.1-4.2). Effective throughputs are
calibrated, not peak: CNN inference on a 4-core desktop CPU sustains a few
tens of GFLOP/s; a 3090 on small-batch CNN inference sustains a low-single-
digit fraction of its 35.6 TFLOP/s peak because AlexNet layers are tiny.

Tier B (TPU-native): v5e chips; the "wireless" hop becomes the inter-pod ICI
link (DESIGN.md §2). Constants per the assignment: 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ComputeProfile:
    name: str
    flops_per_s: float          # sustained
    mem_bw: float               # bytes/s
    overhead_s: float = 0.0     # per-invocation constant (kernel launch etc.)


@dataclass(frozen=True)
class LinkProfile:
    name: str
    bandwidth: float            # bytes/s
    rtt_s: float = 0.0


@dataclass(frozen=True)
class TwoTierProfile:
    device: ComputeProfile
    server: ComputeProfile
    link: LinkProfile


# --- Tier A: the paper's testbed -------------------------------------------
PAPER_EDGE = ComputeProfile("i7-6700 (4c, 3.4GHz)", flops_per_s=45e9,
                            mem_bw=25e9, overhead_s=2e-4)
PAPER_SERVER = ComputeProfile("RTX 3090 (small-batch CNN)",
                              flops_per_s=8e12, mem_bw=936e9,
                              overhead_s=3e-4)
PAPER_WIFI = LinkProfile("Wi-Fi ~50 Mbps", bandwidth=50e6 / 8, rtt_s=4e-3)
PAPER_PROFILE = TwoTierProfile(PAPER_EDGE, PAPER_SERVER, PAPER_WIFI)

# --- Tier B: TPU v5e two-pod deployment -------------------------------------
V5E_CHIP = ComputeProfile("TPU v5e chip", flops_per_s=197e12, mem_bw=819e9)
V5E_POD_256 = ComputeProfile("v5e pod (256 chips)", flops_per_s=256 * 197e12,
                             mem_bw=256 * 819e9)
# inter-pod boundary: activations cross on ICI; a (16,16) pod face has 16
# links of ~50 GB/s toward the neighbouring pod
INTER_POD_ICI = LinkProfile("inter-pod ICI (16 links)", bandwidth=16 * 50e9,
                            rtt_s=1e-6)
TPU_TWO_POD = TwoTierProfile(V5E_POD_256, V5E_POD_256, INTER_POD_ICI)

# An "edge TPU + cloud pod" asymmetric deployment (single v5e host vs pod):
V5E_HOST_8 = ComputeProfile("v5e host (8 chips)", flops_per_s=8 * 197e12,
                            mem_bw=8 * 819e9)
DCN_LINK = LinkProfile("DCN 100 Gbps", bandwidth=100e9 / 8, rtt_s=1e-4)
TPU_EDGE_CLOUD = TwoTierProfile(V5E_HOST_8, V5E_POD_256, DCN_LINK)

PROFILES = {
    "paper": PAPER_PROFILE,
    "tpu_two_pod": TPU_TWO_POD,
    "tpu_edge_cloud": TPU_EDGE_CLOUD,
}
