"""Hardware profiles for the latency model.

Tier A (paper-faithful): the paper's own testbed — an i7-6700 edge box, a
Ryzen+RTX-3090 server, ~50 Mbps Wi-Fi (§4.1-4.2). Effective throughputs are
calibrated, not peak: CNN inference on a 4-core desktop CPU sustains a few
tens of GFLOP/s; a 3090 on small-batch CNN inference sustains a low-single-
digit fraction of its 35.6 TFLOP/s peak because AlexNet layers are tiny.

Tier B (TPU-native): v5e chips; the "wireless" hop becomes the inter-pod ICI
link (DESIGN.md §2). Constants per the assignment: 197 TFLOP/s bf16,
819 GB/s HBM, ~50 GB/s/link ICI.

Time-varying links: a ``LinkProfile`` is a point-in-time snapshot; a
``LinkTrace`` is a piecewise-constant schedule of (bandwidth, RTT) over
elapsed time — the wireless reality the paper's title promises, where the
split picked at deployment time stops being optimal mid-run. The collab
channels (``SimChannel``/``ShapedSocket``) replay a trace per transmitted
byte, and ``repro.core.collab.adaptive`` re-plans the split against the
bandwidth the trace actually delivers. Canned traces live in ``TRACES``.

Fault schedules: a ``LinkTrace`` degrades the link; a ``FaultSchedule``
*breaks* it — deterministic, seedable sequences of frame drops, byte
corruption, stalls, mid-stream disconnects, and cloud-process death,
indexed by transmission-attempt number so every failure mode is exactly
reproducible in tests and benchmarks. The collab channels replay a
schedule through a ``FaultInjector`` (``repro.core.collab.channel``);
the recovery machinery that survives one lives in
``repro.core.collab.faults``. Canned schedules live in
``FAULT_SCHEDULES``.
"""
from __future__ import annotations

import random
from dataclasses import dataclass
from typing import Optional, Tuple


@dataclass(frozen=True)
class ComputeProfile:
    name: str
    flops_per_s: float          # sustained fp32
    mem_bw: float               # bytes/s
    overhead_s: float = 0.0     # per-invocation constant (kernel launch etc.)
    #: sustained int8 MAC throughput (ops/s) for quantized-kernel
    #: roofline pricing; None -> the 4x-fp32 SIMD default
    #: (``int8_ops_per_s``). Edge CPUs gain far more than 4x when their
    #: fp32 path is soft-float (MCU class), so the edge profiles pin it.
    int8_flops_per_s: Optional[float] = None

    @property
    def int8_ops_per_s(self) -> float:
        return self.int8_flops_per_s or 4.0 * self.flops_per_s


@dataclass(frozen=True)
class LinkProfile:
    name: str
    bandwidth: float            # bytes/s
    rtt_s: float = 0.0


@dataclass(frozen=True)
class TraceSegment:
    """One piecewise-constant stretch of a time-varying link."""
    duration_s: float           # use float("inf") for a terminal segment
    bandwidth: float            # bytes/s while this segment is active
    rtt_s: float = 0.0


@dataclass(frozen=True)
class LinkTrace:
    """Piecewise-constant (bandwidth, RTT) schedule over elapsed time.

    ``state_at(t)`` answers "what does the link look like ``t`` seconds
    into the deployment"; ``loop=True`` repeats the schedule forever
    (periodic congestion), otherwise the last segment holds after the
    schedule runs out. ``span_at(t)`` additionally reports how long the
    current segment still lasts, which lets ``SimChannel`` charge a
    transmission that straddles a bandwidth change exactly, segment by
    segment.
    """
    name: str
    segments: Tuple[TraceSegment, ...]
    loop: bool = False

    def __post_init__(self) -> None:
        if not self.segments:
            raise ValueError("LinkTrace needs at least one segment")
        if self.loop and not all(s.duration_s < float("inf")
                                 for s in self.segments):
            raise ValueError("a looping trace cannot contain an infinite "
                             "segment")
        for s in self.segments:
            # a dead link would make byte-draining loops spin forever;
            # model an outage as a very small positive bandwidth instead
            if not (s.bandwidth > 0 and s.duration_s > 0):
                raise ValueError("trace segments need bandwidth > 0 and "
                                 "duration > 0 (model an outage as e.g. "
                                 "1 kbit/s, not 0)")

    @property
    def duration_s(self) -> float:
        return sum(s.duration_s for s in self.segments)

    def span_at(self, t: float) -> Tuple[float, float, float]:
        """(bandwidth, rtt_s, seconds until this segment ends) at time t.

        The remaining span is ``inf`` once a non-looping trace has settled
        into its final segment.
        """
        t = max(0.0, t)
        total = self.duration_s
        if self.loop:
            t = t % total
        elif t >= total:
            last = self.segments[-1]
            return last.bandwidth, last.rtt_s, float("inf")
        for seg in self.segments:
            if t < seg.duration_s:
                return seg.bandwidth, seg.rtt_s, seg.duration_s - t
            t -= seg.duration_s
        last = self.segments[-1]          # t == total on a non-loop trace
        return last.bandwidth, last.rtt_s, float("inf")

    def state_at(self, t: float) -> Tuple[float, float]:
        """(bandwidth bytes/s, rtt_s) in effect ``t`` seconds in."""
        bw, rtt, _ = self.span_at(t)
        return bw, rtt

    def link_at(self, t: float) -> LinkProfile:
        """The trace's link state ``t`` seconds in, as a LinkProfile."""
        bw, rtt = self.state_at(t)
        return LinkProfile(f"{self.name}@{t:.2f}s", bandwidth=bw, rtt_s=rtt)

    @classmethod
    def from_mbps(cls, name: str, spans, rtt_ms: float = 2.0,
                  loop: bool = False) -> "LinkTrace":
        """Build from (duration_s, mbps) or (duration_s, mbps, rtt_ms)
        tuples — the natural units wireless people speak."""
        segs = []
        for span in spans:
            dur, mbps = span[0], span[1]
            rtt = span[2] if len(span) > 2 else rtt_ms
            segs.append(TraceSegment(dur, mbps * 1e6 / 8, rtt * 1e-3))
        return cls(name, tuple(segs), loop=loop)


@dataclass(frozen=True)
class TwoTierProfile:
    device: ComputeProfile
    server: ComputeProfile
    link: LinkProfile


# --- Tier A: the paper's testbed -------------------------------------------
PAPER_EDGE = ComputeProfile("i7-6700 (4c, 3.4GHz)", flops_per_s=45e9,
                            mem_bw=25e9, overhead_s=2e-4)
PAPER_SERVER = ComputeProfile("RTX 3090 (small-batch CNN)",
                              flops_per_s=8e12, mem_bw=936e9,
                              overhead_s=3e-4)
PAPER_WIFI = LinkProfile("Wi-Fi ~50 Mbps", bandwidth=50e6 / 8, rtt_s=4e-3)
PAPER_PROFILE = TwoTierProfile(PAPER_EDGE, PAPER_SERVER, PAPER_WIFI)

# Batched serving: the same 3090 sustains a much larger fraction of peak
# once cross-client dynamic batching keeps its SMs fed — batch-1 AlexNet
# layers are launch-latency-bound (hence the low small-batch calibration
# above), and ``overhead_s`` is amortized across the fused batch (see
# ``latency_model.batched_server_time``). The calibrated sustained
# throughput for bucket-8 CNN batches:
PAPER_SERVER_BATCHED = ComputeProfile("RTX 3090 (batched CNN, bucket 8)",
                                      flops_per_s=24e12, mem_bw=936e9,
                                      overhead_s=3e-4)
#: the heavy-traffic deployment: many edges, one batched cloud GPU
PAPER_FARM_PROFILE = TwoTierProfile(PAPER_EDGE, PAPER_SERVER_BATCHED,
                                    PAPER_WIFI)

# --- battery-constrained edge classes ---------------------------------------
# The embedded devices the paper's motivation names ("resource-limited
# embedded devices", high energy consumption). Their per-state power
# draws live next door in ``repro.core.partition.energy_model``
# (MCU_ENERGY / PI_ENERGY); these are the matching compute throughputs.
#: MCU-class edge (Cortex-M/ESP32 class): reproduces the paper's
#: AlexNet@224-vs-i7 regime — a split optimum that genuinely moves with
#: the link — at benchmark scale.
#: int8 at 8x fp32: the MCU's fp32 path is soft-float while int8 MACs
#: ride the SIMD/DSP extensions (the CMSIS-NN regime)
MCU_EDGE = ComputeProfile("MCU-class edge", flops_per_s=0.15e9,
                          mem_bw=0.5e9, overhead_s=3e-4,
                          int8_flops_per_s=1.2e9)
#: Pi-class single-board edge (quad A72 class, NEON fp32; int8 dot
#: product units give the NEON path ~4x fp32)
PI_EDGE = ComputeProfile("Pi-class edge", flops_per_s=6e9,
                         mem_bw=4e9, overhead_s=2.5e-4,
                         int8_flops_per_s=24e9)
#: Phone-class edge (mid-range smartphone, big.LITTLE A7x SoC).
#: Calibration: sustained fp32 CNN inference on the CPU/NEON path of a
#: 2020s mid-ranger lands at a few tens of GFLOP/s (thermally throttled
#: well below peak; NPU offload would be ~10x but is not the fp32 jnp
#: path this repo deploys), with LPDDR4X delivering ~12 GB/s effective
#: to a single cluster. Sits between PI_EDGE and PAPER_EDGE — the
#: third heterogeneous class the fleet simulator mixes.
PHONE_EDGE = ComputeProfile("phone-class edge", flops_per_s=25e9,
                            mem_bw=12e9, overhead_s=2e-4)
#: Jetson-class cloudlet: the aggregation box the hierarchical-FL plant
#: disease deployments park between the field and the datacenter (an
#: Orin-class module on a pole, not a 3090 in a rack). Calibration:
#: ~1.2 TFLOP/s sustained dense fp32 (ampere-generation embedded GPU,
#: thermally capped), ~60 GB/s LPDDR5, sub-ms launch overhead. Fast
#: enough to absorb a village of edges, slow enough that an
#: under-provisioned fleet genuinely queues — which is what the fleet
#: simulator's cloudlet tier is for.
CLOUDLET_SERVER = ComputeProfile("Jetson-class cloudlet",
                                 flops_per_s=1.2e12, mem_bw=60e9,
                                 overhead_s=1e-4)

# --- Tier B: TPU v5e two-pod deployment -------------------------------------
V5E_CHIP = ComputeProfile("TPU v5e chip", flops_per_s=197e12, mem_bw=819e9)
V5E_POD_256 = ComputeProfile("v5e pod (256 chips)", flops_per_s=256 * 197e12,
                             mem_bw=256 * 819e9)
# inter-pod boundary: activations cross on ICI; a (16,16) pod face has 16
# links of ~50 GB/s toward the neighbouring pod
INTER_POD_ICI = LinkProfile("inter-pod ICI (16 links)", bandwidth=16 * 50e9,
                            rtt_s=1e-6)
TPU_TWO_POD = TwoTierProfile(V5E_POD_256, V5E_POD_256, INTER_POD_ICI)

# An "edge TPU + cloud pod" asymmetric deployment (single v5e host vs pod):
V5E_HOST_8 = ComputeProfile("v5e host (8 chips)", flops_per_s=8 * 197e12,
                            mem_bw=8 * 819e9)
DCN_LINK = LinkProfile("DCN 100 Gbps", bandwidth=100e9 / 8, rtt_s=1e-4)
TPU_EDGE_CLOUD = TwoTierProfile(V5E_HOST_8, V5E_POD_256, DCN_LINK)

PROFILES = {
    "paper": PAPER_PROFILE,
    "paper_farm": PAPER_FARM_PROFILE,
    "tpu_two_pod": TPU_TWO_POD,
    "tpu_edge_cloud": TPU_EDGE_CLOUD,
}

# --- canned time-varying link traces ----------------------------------------
#: the paper's steady testbed link, as a (degenerate) trace
WIFI_STEADY = LinkTrace.from_mbps("wifi_steady",
                                  [(float("inf"), 50.0)], rtt_ms=4.0)
#: edge device walks away from the access point: 50 -> 18 -> 5 Mbps
WIFI_DEGRADING = LinkTrace.from_mbps(
    "wifi_degrading", [(4.0, 50.0), (4.0, 18.0), (float("inf"), 5.0)],
    rtt_ms=4.0)
#: 4G field link with a coverage hole mid-route (handover dip)
LTE_HANDOVER = LinkTrace.from_mbps(
    "lte_handover",
    [(3.0, 30.0, 30.0), (2.0, 2.0, 80.0), (float("inf"), 25.0, 30.0)])
#: shared uplink that sawtooths between free and congested, forever
CONGESTED_SAWTOOTH = LinkTrace.from_mbps(
    "congested_sawtooth", [(2.0, 40.0), (2.0, 6.0)], rtt_ms=10.0, loop=True)

TRACES = {
    "wifi_steady": WIFI_STEADY,
    "wifi_degrading": WIFI_DEGRADING,
    "lte_handover": LTE_HANDOVER,
    "congested_sawtooth": CONGESTED_SAWTOOTH,
}


# --- fault schedules ---------------------------------------------------------
#: failure modes a schedule may inject, in roughly increasing severity
FAULT_KINDS = ("drop", "corrupt", "stall", "disconnect", "die")


@dataclass(frozen=True)
class FaultEvent:
    """One injected failure, pinned to a transmission-attempt index.

    ``attempt`` counts data-frame transmission attempts on the injected
    path (0-based); retries are new attempts, so a schedule that faults
    attempt 3 but not attempt 4 lets the first retry succeed. ``kind``
    is one of ``FAULT_KINDS``:

    - ``drop``: the frame is silently lost (never delivered);
    - ``corrupt``: one payload byte is flipped in flight;
    - ``stall``: delivery is delayed by ``stall_s`` seconds;
    - ``disconnect``: the connection is torn down mid-stream;
    - ``die``: the cloud process itself is killed (server-side only;
      on a client-side injector it behaves like ``disconnect``).
    """
    attempt: int
    kind: str
    stall_s: float = 0.0

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"expected one of {FAULT_KINDS}")
        if self.attempt < 0:
            raise ValueError("fault attempt index must be >= 0")
        if self.kind == "stall" and self.stall_s <= 0:
            raise ValueError("stall events need stall_s > 0")


@dataclass(frozen=True)
class FaultSchedule:
    """A deterministic sequence of injected faults, keyed by attempt.

    A schedule is to failures what a ``LinkTrace`` is to bandwidth: a
    canned, replayable storyline. It is pure data — stateless and
    reusable; the per-run attempt counter lives in the
    ``FaultInjector`` that replays it (``repro.core.collab.channel``),
    so the same schedule object can drive many independent runs.
    """
    name: str
    events: Tuple[FaultEvent, ...]

    def __post_init__(self) -> None:
        seen = set()
        for ev in self.events:
            if ev.attempt in seen:
                raise ValueError(f"schedule {self.name!r} has two events "
                                 f"for attempt {ev.attempt}")
            seen.add(ev.attempt)

    def event_at(self, attempt: int) -> Optional[FaultEvent]:
        """The fault injected at transmission attempt ``attempt``, or
        None for a clean attempt."""
        for ev in self.events:
            if ev.attempt == attempt:
                return ev
        return None

    @property
    def n_events(self) -> int:
        """Total number of injected faults in the schedule."""
        return len(self.events)

    @classmethod
    def seeded(cls, name: str, seed: int, n_attempts: int,
               drop: float = 0.0, corrupt: float = 0.0, stall: float = 0.0,
               stall_s: float = 0.05, disconnect: float = 0.0,
               ) -> "FaultSchedule":
        """Draw a random-but-reproducible schedule over ``n_attempts``.

        Each attempt independently suffers at most one fault, drawn
        with the given per-kind probabilities from ``random.Random
        (seed)`` — same seed, same schedule, forever. Probabilities
        must sum to <= 1.
        """
        p_total = drop + corrupt + stall + disconnect
        if p_total > 1.0:
            raise ValueError("fault probabilities sum to > 1")
        rng = random.Random(seed)
        events = []
        for a in range(n_attempts):
            u = rng.random()
            if u < drop:
                events.append(FaultEvent(a, "drop"))
            elif u < drop + corrupt:
                events.append(FaultEvent(a, "corrupt"))
            elif u < drop + corrupt + stall:
                events.append(FaultEvent(a, "stall", stall_s=stall_s))
            elif u < p_total:
                events.append(FaultEvent(a, "disconnect"))
        return cls(name, tuple(events))


#: lossy uplink: ~6% of frames vanish in flight
FAULT_DROP_BURST = FaultSchedule.seeded("drop_burst", seed=7,
                                        n_attempts=600, drop=0.06)
#: congested AP: ~8% of frames stall for 30 ms, a few are corrupted
FAULT_STALL_STORM = FaultSchedule.seeded("stall_storm", seed=11,
                                         n_attempts=600, corrupt=0.02,
                                         stall=0.08, stall_s=0.03)
#: coverage hole: every attempt in a contiguous window tears the
#: connection down — retries inside the window keep failing
FAULT_OUTAGE = FaultSchedule(
    "outage", tuple(FaultEvent(a, "disconnect") for a in range(12, 18)))
#: the cloud process is killed mid-stream at attempt 8
FAULT_CLOUD_DEATH = FaultSchedule("cloud_death", (FaultEvent(8, "die"),))

FAULT_SCHEDULES = {
    "drop_burst": FAULT_DROP_BURST,
    "stall_storm": FAULT_STALL_STORM,
    "outage": FAULT_OUTAGE,
    "cloud_death": FAULT_CLOUD_DEATH,
}
