"""Resumable driver for the full dry-run matrix.

Runs every (arch x shape x mesh) combination as a SUBPROCESS (so a single
giant compile cannot take down the sweep), smallest-estimated-cost first,
skipping pairs whose JSON already exists. Each subprocess is
``python -m repro.launch.dryrun --arch A --shape S --mesh M``.

    PYTHONPATH=src python -m repro.launch.dryrun_matrix [--mesh pod|multipod|both]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs.registry import ARCH_IDS, get_config
from repro.launch.specs import SHAPES, mode_of, supported

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def est_cost(arch: str, shape: str) -> float:
    """Rough compile-cost order: unrolled instruction count proxy."""
    cfg = get_config(arch)
    per_layer = cfg.d_model / 1024
    if cfg.moe is not None:
        per_layer *= 1 + cfg.moe.num_experts / 16
    mode = mode_of(shape)
    S, B = SHAPES[shape]
    tok = {"train": 3.0 * S * B, "prefill": S * B, "decode": B}[mode]
    return cfg.num_layers * per_layer * (1 + tok / 2**20)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="both")
    ap.add_argument("--timeout", type=int, default=2100)
    ap.add_argument("--scan-fallback", action="store_true", default=True)
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()
    meshes = {"pod": ["pod"], "multipod": ["multipod"],
              "both": ["pod", "multipod"]}[args.mesh]

    todo = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES:
            ok, why = supported(cfg, shape)
            if not ok:
                continue
            for mesh in meshes:
                fn = os.path.join(args.out, f"{arch}_{shape}_{mesh}.json")
                if os.path.exists(fn):
                    try:
                        if json.load(open(fn)).get("status") == "ok":
                            continue
                    except Exception:          # noqa: BLE001
                        pass
                todo.append((est_cost(arch, shape), arch, shape, mesh))
    # single-pod first (roofline baseline), then multipod
    todo.sort(key=lambda t: (t[3] != "pod", t[0]))
    print(f"{len(todo)} runs queued", flush=True)
    failures = []
    for cost, arch, shape, mesh in todo:
        t0 = time.time()
        cmd = [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
               "--shape", shape, "--mesh", mesh, "--out", args.out]
        print(f">>> {arch} {shape} {mesh} (est {cost:.0f})", flush=True)
        try:
            r = subprocess.run(cmd, timeout=args.timeout,
                               capture_output=True, text=True)
            status = "ok" if r.returncode == 0 else "FAIL"
            if status == "FAIL":
                print(r.stdout[-1500:], r.stderr[-3000:], flush=True)
        except subprocess.TimeoutExpired:
            status = "TIMEOUT"
        if status != "ok" and args.scan_fallback:
            print(f"    retrying {arch} {shape} {mesh} with --scan",
                  flush=True)
            try:
                r = subprocess.run(cmd + ["--scan"],
                                   timeout=args.timeout,
                                   capture_output=True, text=True)
                status = ("ok(scan)" if r.returncode == 0
                          else "FAIL(scan)")
                if r.returncode != 0:
                    print(r.stdout[-1500:], r.stderr[-3000:], flush=True)
            except subprocess.TimeoutExpired:
                status = "TIMEOUT(scan)"
        if not status.startswith("ok"):
            failures.append((arch, shape, mesh))
        print(f"<<< {arch} {shape} {mesh}: {status} "
              f"({time.time() - t0:.0f}s)", flush=True)
    print("failures:", failures, flush=True)


if __name__ == "__main__":
    main()
