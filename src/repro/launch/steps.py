"""Jittable step functions (train / prefill / decode) shared by the
launchers, the dry-run, and the benchmarks."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tr
from repro.optim import Optimizer


def make_train_step(cfg: ModelConfig, optimizer: Optimizer, masks=None,
                    grad_accum: int = 1):
    """One optimizer step. ``grad_accum > 1`` scans over microbatches and
    accumulates fp32 grads — divides live activation memory by the factor
    at the cost of one scan (EXPERIMENTS.md §Perf-2 it3: the lever that
    fits qwen2-7b train_4k into 16 GB/chip)."""
    if grad_accum == 1:
        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                tr.loss_fn, has_aux=True)(params, cfg, batch, masks)
            params, opt_state = optimizer.update(grads, opt_state, params)
            return params, opt_state, metrics
        return train_step

    def train_step(params, opt_state, batch):
        def split_mb(path, a):
            from repro.sharding.specs import path_keys
            # mrope_positions is (3, B, S): batch is dim 1
            bdim = 1 if path_keys(path)[-1] == "mrope_positions" else 0
            assert a.shape[bdim] % grad_accum == 0, (path, a.shape)
            if bdim == 0:
                return a.reshape((grad_accum, a.shape[0] // grad_accum)
                                 + a.shape[1:])
            out = a.reshape(a.shape[:1] + (grad_accum,
                                           a.shape[1] // grad_accum)
                            + a.shape[2:])
            return jnp.moveaxis(out, 1, 0)

        mb = jax.tree_util.tree_map_with_path(split_mb, batch)

        def body(gsum, mbatch):
            (_, metrics), g = jax.value_and_grad(
                tr.loss_fn, has_aux=True)(params, cfg, mbatch, masks)
            gsum = jax.tree_util.tree_map(
                lambda acc, gg: acc + gg.astype(jnp.float32), gsum, g)
            return gsum, metrics

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        gsum, ms = jax.lax.scan(body, zeros, mb)
        grads = jax.tree_util.tree_map(
            lambda g, p: (g / grad_accum).astype(p.dtype), gsum, params)
        params, opt_state = optimizer.update(grads, opt_state, params)
        metrics = jax.tree_util.tree_map(lambda a: a.mean(), ms)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig, max_len: Optional[int] = None,
                      masks=None):
    def prefill_step(params, batch):
        return tr.prefill(params, cfg, batch, max_len=max_len, masks=masks)
    return prefill_step


def make_decode_step(cfg: ModelConfig, masks=None):
    def decode_step(params, cache, tokens):
        return tr.decode_step(params, cfg, cache, tokens, masks=masks)
    return decode_step
