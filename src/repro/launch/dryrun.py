import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (architecture x input shape) on
# the production meshes, with zero real allocation (ShapeDtypeStruct inputs).
#
#     PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b \
#         --shape train_4k --mesh pod                    # 16x16 single pod
#     PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multipod
#
# Each run writes experiments/dryrun/<arch>_<shape>_<mesh>.json with
# memory_analysis, cost_analysis, per-collective byte counts, and the three
# roofline terms. Failures (sharding mismatch, OOM at compile, unsupported
# collective) are bugs in the system — the matrix must be green.
#
# NOTE: the two os lines above MUST stay the first statements — jax locks
# the device count on first init.

import argparse
import json
import time
import traceback
from typing import Optional

import jax
import numpy as np

from repro.configs.registry import ARCH_IDS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import SHAPES, input_specs, mode_of, supported
from repro.launch.steps import make_decode_step, make_prefill_step, \
    make_train_step
from repro.models import transformer as tr
from repro.optim import adamw, constant
from repro.roofline import hw
from repro.roofline.analysis import model_flops, terms_from_compiled
from repro.sharding.specs import (batch_specs, cache_specs, mesh_axes,
                                  opt_state_specs, param_specs, to_shardings)

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


def active_params(cfg, params_tree) -> int:
    """Parameter count active per token (MoE: top_k+shared of the experts)."""
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params_tree)[0]:
        from repro.sharding.specs import path_keys
        keys = list(path_keys(path))
        n = int(np.prod(leaf.shape))
        if cfg.moe is not None and "moe" in keys and keys[-1] in (
                "w_up", "w_down", "w_gate"):
            n = n * cfg.moe.top_k // cfg.moe.num_experts
        total += n
    return total


def memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
        if ma is None:
            return {}
        keys = ["argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes"]
        out = {}
        for k in keys:
            if hasattr(ma, k):
                out[k] = int(getattr(ma, k))
        return out or {"repr": str(ma)}
    except Exception as e:                                    # noqa: BLE001
        return {"error": str(e)}


def analytic_memory(cfg, specs, mesh, mode) -> dict:
    """Per-device resident bytes from shardings (params/opt/cache/batch)."""
    from repro.sharding.specs import param_specs as ps
    n_dev = mesh.devices.size

    def tree_bytes(tree):
        return sum(int(np.prod(l.shape)) * l.dtype.itemsize
                   for l in jax.tree_util.tree_leaves(tree))

    params_b = tree_bytes(specs["params"])
    out = {"params_global": params_b, "params_per_device": params_b // n_dev}
    if mode == "train":
        out["opt_state_global"] = 2 * params_b     # m+v same dtypes
        out["batch_global"] = tree_bytes(specs["batch"])
    elif mode == "decode":
        cache_b = tree_bytes(specs["cache"])
        out["cache_global"] = cache_b
        out["cache_per_device"] = cache_b // n_dev
    return out


def run_one(arch: str, shape_name: str, multi_pod: bool,
            out_dir: str = OUT_DIR, save_hlo: bool = False,
            opt_moment_dtype: Optional[str] = None,
            cfg_overrides: Optional[dict] = None,
            grad_accum: int = 1) -> dict:
    # unrolled layers + unrolled attention blocks: HloCostAnalysis counts
    # while bodies once, so roofline numbers need straight-line HLO
    overrides = dict(scan_layers=False, attn_block_unroll=True)
    overrides.update(cfg_overrides or {})
    cfg = get_config(arch).replace(**overrides)
    mesh_name = "multipod" if multi_pod else "pod"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "grad_accum": grad_accum,
           "chips": hw.MULTI_POD_CHIPS if multi_pod else hw.SINGLE_POD_CHIPS}
    ok, why = supported(cfg, shape_name)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec

    mode = mode_of(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    specs = input_specs(cfg, shape_name)
    t0 = time.time()

    import jax.numpy as jnp
    moment_dtype = jnp.bfloat16 if (
        opt_moment_dtype == "bfloat16"
        or (opt_moment_dtype is None and cfg.d_model >= 7168)) else jnp.float32

    with mesh:
        pspecs = param_specs(specs["params"], cfg, mesh)
        pshard = to_shardings(pspecs, mesh)
        if mode == "train":
            optimizer = adamw(constant(1e-4), moment_dtype=moment_dtype)
            opt_sds = jax.eval_shape(optimizer.init, specs["params"])
            ospecs = opt_state_specs(opt_sds, pspecs)
            bspecs = batch_specs(specs["batch"], cfg, mesh)
            step = make_train_step(cfg, optimizer, grad_accum=grad_accum)
            jitted = jax.jit(
                step,
                in_shardings=(pshard, to_shardings(ospecs, mesh),
                              to_shardings(bspecs, mesh)),
                donate_argnums=(0, 1))
            lowered = jitted.lower(specs["params"], opt_sds, specs["batch"])
        elif mode == "prefill":
            S, B = SHAPES[shape_name]
            bspecs = batch_specs(specs["batch"], cfg, mesh)
            step = make_prefill_step(cfg, max_len=S)
            jitted = jax.jit(
                step, in_shardings=(pshard, to_shardings(bspecs, mesh)))
            lowered = jitted.lower(specs["params"], specs["batch"])
        else:
            cspecs = cache_specs(specs["cache"], cfg, mesh)
            step = make_decode_step(cfg)
            jitted = jax.jit(
                step,
                in_shardings=(pshard, to_shardings(cspecs, mesh), None),
                donate_argnums=(1,))
            lowered = jitted.lower(specs["params"], specs["cache"],
                                   specs["tokens"])
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = memory_analysis_dict(compiled)
    print(f"[{arch} {shape_name} {mesh_name}] memory_analysis:", mem)
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    print(f"[{arch} {shape_name} {mesh_name}] cost_analysis: "
          f"flops={cost.get('flops', 0):.3e} "
          f"bytes={cost.get('bytes accessed', 0):.3e}")
    hlo = compiled.as_text()
    terms, coll = terms_from_compiled(compiled, rec["chips"], hlo_text=hlo)

    n_total = tr.param_count(specs["params"])
    n_active = active_params(cfg, specs["params"])
    mf = model_flops(cfg, shape_name, n_params_active=n_active)

    rec.update({
        "status": "ok",
        "scan_counted": bool(cfg.scan_layers),
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": mem,
        "analytic_memory": analytic_memory(cfg, specs, mesh, mode),
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))},
        "collectives": {"bytes_by_op": coll.bytes_by_op,
                        "count_by_op": coll.count_by_op},
        "roofline": terms.as_dict(),
        "params_total": n_total,
        "params_active": n_active,
        "model_flops": mf,
        "useful_flops_ratio": (mf / terms.flops_global)
            if terms.flops else None,
        "moment_dtype": str(moment_dtype.__name__) if mode == "train" else None,
    })
    os.makedirs(out_dir, exist_ok=True)
    fn = os.path.join(out_dir, f"{arch}_{shape_name}_{mesh_name}.json")
    with open(fn, "w") as f:
        json.dump(rec, f, indent=1)
    if save_hlo:
        with open(fn.replace(".json", ".hlo.txt"), "w") as f:
            f.write(hlo)
    return rec


def run_split_serve(arch: str, out_dir: str = OUT_DIR,
                    num_microbatches: int = 8,
                    seq_len: int = 4096, batch: int = 32,
                    cfg_overrides: Optional[dict] = None) -> dict:
    """Tier-B pod-split serving dry-run: lower + compile the 2-pod
    microbatch pipeline (core/partition/pod_pipeline) on the multi-pod
    mesh and extract the T_TX term (collective-permute bytes crossing the
    pod boundary) for comparison against the Eq. 5 latency model."""
    import jax.numpy as jnp

    from repro.core.partition import pod_pipeline as pp
    from repro.core.partition.latency_model import (split_latency,
                                                    transformer_layer_costs)
    from repro.core.partition.profiles import TPU_TWO_POD

    cfg = get_config(arch).replace(scan_layers=False,
                                   **(cfg_overrides or {}))
    assert pp.pipeline_supported(cfg), arch
    n_pods = 2
    mesh = make_production_mesh(multi_pod=True)
    rec = {"arch": arch, "mode": "split_serve", "mesh": "multipod",
           "chips": hw.MULTI_POD_CHIPS, "num_microbatches": num_microbatches,
           "seq_len": seq_len, "batch": batch}
    params = jax.eval_shape(
        lambda: tr.init_params(cfg, jax.random.PRNGKey(0)))
    sp = dict(params)
    sp["runs"] = [jax.eval_shape(
        lambda p: pp.stack_stage_params(p, cfg, n_pods), params)]
    batch_in = {"tokens": jax.ShapeDtypeStruct((batch, seq_len), jnp.int32)}
    if cfg.embeds_input:
        batch_in = {"embeds": jax.ShapeDtypeStruct(
            (batch, seq_len, cfg.d_model), jnp.dtype(cfg.dtype))}

    t0 = time.time()
    with mesh:
        from jax.sharding import PartitionSpec as P
        pspecs = param_specs(sp, cfg, mesh)

        # the stacked stage dim shards over "pod"; inner dims must then
        # drop "pod" from any composite ("pod","data") data-axis entry
        def _stage_spec(spec):
            # specs were computed on the already-stacked tree; dim 0 is the
            # stage dim (always unsharded by the name rules) -> "pod"
            inner = []
            for e in tuple(spec):
                if isinstance(e, tuple) and "pod" in e:
                    rest = tuple(a for a in e if a != "pod")
                    inner.append(rest[0] if len(rest) == 1 else
                                 (rest or None))
                else:
                    inner.append(e)
            assert not inner or inner[0] is None, spec
            return P(*(("pod",) + tuple(inner[1:])))

        pspecs["runs"] = [jax.tree_util.tree_map(
            _stage_spec, pspecs["runs"][0],
            is_leaf=lambda x: isinstance(x, P))]
        step = pp.make_split_serve_step(cfg, n_pods, num_microbatches, mesh)
        lowered = jax.jit(step, in_shardings=(
            to_shardings(pspecs, mesh), None)).lower(sp, batch_in)
        compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 2)
    hlo = compiled.as_text()
    terms, coll = terms_from_compiled(compiled, rec["chips"], hlo_text=hlo)
    rec["memory_analysis"] = memory_analysis_dict(compiled)
    rec["collectives"] = {"bytes_by_op": coll.bytes_by_op,
                          "count_by_op": coll.count_by_op}
    rec["roofline"] = terms.as_dict()
    # Eq. 5 prediction for the same split (layer c = L/2)
    costs = transformer_layer_costs(cfg, seq_len)
    pred = split_latency(costs, cfg.num_layers // 2, TPU_TWO_POD,
                         seq_len * cfg.d_model * 2)
    # per-request boundary bytes: activation (B/M, S, d) x M microbatches
    rec["eq5_prediction"] = {k: v * batch for k, v in pred.items()
                             if k.startswith("T")}
    rec["boundary_bytes_model"] = batch * seq_len * cfg.d_model * 2
    os.makedirs(out_dir, exist_ok=True)
    fn = os.path.join(out_dir, f"{arch}_split_serve_multipod.json")
    with open(fn, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[{arch} split_serve] compile={rec['compile_s']}s "
          f"ppermute_bytes="
          f"{coll.bytes_by_op.get('collective-permute', 0):.3e} "
          f"model_boundary_bytes={rec['boundary_bytes_model']:.3e}")
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"],
                    default="pod")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--split-serve", action="store_true",
                    help="Tier-B pod-split pipeline dry-run (multipod)")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--scan", action="store_true",
                    help="lower with scan-over-layers (fallback for "
                         "compiles too big to unroll on this host; "
                         "cost_analysis counts the loop body once — "
                         "recorded as scan_counted)")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    if args.split_serve:
        run_split_serve(args.arch, args.out)
        return

    meshes = {"pod": [False], "multipod": [True],
              "both": [False, True]}[args.mesh]
    pairs = ([(a, s) for a in ARCH_IDS for s in SHAPES] if args.all
             else [(args.arch, args.shape)])
    failures = []
    for arch, shape in pairs:
        for mp in meshes:
            try:
                over = ({"scan_layers": True, "attn_block_unroll": False}
                        if args.scan else None)
                rec = run_one(arch, shape, mp, args.out,
                              save_hlo=args.save_hlo, cfg_overrides=over)
                status = rec["status"]
                extra = (f" compile={rec.get('compile_s')}s "
                         f"dominant={rec.get('roofline', {}).get('dominant')}"
                         if status == "ok" else f" ({rec.get('reason')})")
                print(f"== {arch} {shape} "
                      f"{'multipod' if mp else 'pod'}: {status}{extra}")
            except Exception:                                 # noqa: BLE001
                failures.append((arch, shape, mp))
                print(f"== {arch} {shape} {'multipod' if mp else 'pod'}: "
                      f"FAILED")
                traceback.print_exc()
    if failures:
        raise SystemExit(f"dry-run failures: {failures}")


if __name__ == "__main__":
    main()
