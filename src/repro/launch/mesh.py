"""Production mesh construction.

Single pod : (16, 16)      axes ("data", "model")        = 256 chips
Multi-pod  : (2, 16, 16)   axes ("pod", "data", "model") = 512 chips

A FUNCTION, not a module constant, so importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before first jax use).

The "pod" axis doubles as the paper's edge/cloud boundary in the Tier-B
split-inference runtime (DESIGN.md §2): pod 0 = edge tier, pod 1 = cloud
tier, and the split activation crosses pods via collective-permute.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host actually has (smoke tests / examples): 1 device."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))
