"""ShapeDtypeStruct stand-ins for every (architecture x input shape) pair —
weak-type-correct, shardable, zero allocation.

Assigned shapes:
    train_4k     seq 4,096    global_batch 256   (training)
    prefill_32k  seq 32,768   global_batch 32    (inference-prefill)
    decode_32k   seq 32,768   global_batch 128   (inference-decode)
    long_500k    seq 524,288  global_batch 1     (long-context decode)

Decode shapes mean: ONE new token against a KV cache of seq_len.
``supported()`` encodes the DESIGN.md skip table (encoder-only has no
decode; long_500k needs sub-quadratic or compressed-cache attention).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tr

SHAPES: Dict[str, Tuple[int, int]] = {
    "train_4k": (4096, 256),
    "prefill_32k": (32768, 32),
    "decode_32k": (32768, 128),
    "long_500k": (524288, 1),
}

LONG_OK = {"mamba2-2.7b", "zamba2-1.2b", "mixtral-8x7b", "deepseek-v3-671b"}


def mode_of(shape_name: str) -> str:
    if shape_name.startswith("train"):
        return "train"
    if shape_name.startswith("prefill"):
        return "prefill"
    return "decode"


def supported(cfg: ModelConfig, shape_name: str) -> Tuple[bool, str]:
    mode = mode_of(shape_name)
    if mode == "decode" and not cfg.causal:
        return False, "encoder-only: no autoregressive decode (DESIGN.md)"
    if shape_name == "long_500k" and cfg.name not in LONG_OK:
        return False, ("full-attention dense arch: 500k decode skipped "
                       "(needs SSM/SWA/MLA-compressed cache; DESIGN.md)")
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs_for(cfg: ModelConfig, shape_name: str,
                    with_labels: bool) -> Dict[str, jax.ShapeDtypeStruct]:
    S, B = SHAPES[shape_name]
    d = jnp.dtype(cfg.dtype)
    batch: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.embeds_input:
        batch["embeds"] = _sds((B, S, cfg.d_model), d)
    elif cfg.vision_tokens:
        V = cfg.vision_tokens
        batch["tokens"] = _sds((B, S - V), jnp.int32)
        batch["vision_embeds"] = _sds((B, V, cfg.d_model), d)
        batch["mrope_positions"] = _sds((3, B, S), jnp.int32)
    else:
        batch["tokens"] = _sds((B, S), jnp.int32)
    if with_labels:
        if cfg.vision_tokens:
            batch["labels"] = _sds((B, S - cfg.vision_tokens), jnp.int32)
        else:
            batch["labels"] = _sds((B, S), jnp.int32)
    return batch


def input_specs(cfg: ModelConfig, shape_name: str) -> Dict:
    """Everything the lowered step consumes, as ShapeDtypeStructs.

    train   -> {params, opt_state, batch}
    prefill -> {params, batch}
    decode  -> {params, cache, tokens}
    """
    mode = mode_of(shape_name)
    S, B = SHAPES[shape_name]
    params = jax.eval_shape(
        lambda: tr.init_params(cfg, jax.random.PRNGKey(0)))
    if mode == "train":
        return {"params": params,
                "batch": batch_specs_for(cfg, shape_name, with_labels=True)}
    if mode == "prefill":
        return {"params": params,
                "batch": batch_specs_for(cfg, shape_name, with_labels=False)}
    cache = jax.eval_shape(lambda: tr.init_cache(cfg, B, S))
    return {"params": params, "cache": cache,
            "tokens": _sds((B, 1), jnp.int32)}
