"""Rotary position embeddings: standard RoPE and Qwen2-VL M-RoPE.

M-RoPE [arXiv:2409.12191] splits the head_dim/2 frequency bands into
(temporal, height, width) sections; text tokens use identical t/h/w position
ids, vision tokens use their 3-D grid coordinates.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    """(head_dim//2,) inverse frequencies."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float) -> jnp.ndarray:
    """positions (..., S) -> angles (..., S, head_dim//2)."""
    inv = rope_freqs(head_dim, theta)
    return positions.astype(jnp.float32)[..., None] * inv


def mrope_angles(positions: jnp.ndarray, head_dim: int, theta: float,
                 sections: Tuple[int, ...]) -> jnp.ndarray:
    """positions (3, B, S) with (t, h, w) ids -> angles (B, S, head_dim//2).

    ``sections`` gives how many frequency bands each of t/h/w owns;
    sum(sections) == head_dim // 2.
    """
    assert positions.shape[0] == 3
    assert sum(sections) == head_dim // 2, (sections, head_dim)
    inv = rope_freqs(head_dim, theta)                     # (half,)
    # angle per axis then select by band-section
    ang = positions.astype(jnp.float32)[..., None] * inv   # (3, B, S, half)
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.array(sections), total_repeat_length=head_dim // 2)
    return _select_sections(ang, sec_id)


def _select_sections(ang: jnp.ndarray, sec_id: jnp.ndarray) -> jnp.ndarray:
    """ang (3, B, S, half), sec_id (half,) in {0,1,2} -> (B, S, half)."""
    onehot = (sec_id[None, :] == jnp.arange(3)[:, None]).astype(ang.dtype)  # (3, half)
    return jnp.einsum("absh,ah->bsh", ang, onehot)


def apply_rope(x: jnp.ndarray, angles: jnp.ndarray) -> jnp.ndarray:
    """x (B, S, H, D), angles (B, S, D//2) -> rotated x (same dtype)."""
    dtype = x.dtype
    half = x.shape[-1] // 2
    x32 = x.astype(jnp.float32)
    x1, x2 = x32[..., :half], x32[..., half:]
    cos = jnp.cos(angles)[..., None, :]   # (B, S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(dtype)


def positions_for(batch: int, seq: int, offset=0) -> jnp.ndarray:
    return jnp.arange(seq, dtype=jnp.int32)[None, :] + jnp.asarray(offset).reshape(-1, 1)


def text_mrope_positions(batch: int, seq: int, offset=0) -> jnp.ndarray:
    """Text-only M-RoPE ids: t == h == w == position. (3, B, S)."""
    p = positions_for(batch, seq, offset)
    p = jnp.broadcast_to(p, (batch, seq))
    return jnp.broadcast_to(p[None], (3, batch, seq))
