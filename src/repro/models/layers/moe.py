"""Mixture-of-Experts layer with sort-based (dropping) token dispatch.

Dispatch strategy: flatten (token, k) assignments, sort by expert id, place
each assignment at its position-within-expert in an (E, C, d) buffer
(assignments beyond capacity C are dropped), run all experts as one batched
einsum over stacked expert weights, then gather+combine weighted by router
probabilities. This is the standard TPU-friendly formulation (cf. MaxText):
no per-expert dynamic shapes, one big MXU-friendly GEMM.

Expert weights are stacked (E, ...) so the "model" mesh axis shards the
expert dimension (expert parallelism). The routing scatter/gather lowers to
all-to-all-style collectives under SPMD — visible in the roofline's
collective term and a target of the §Perf hillclimb.

Pruning hook: ``expert_mask`` (E,) — pruned experts get -inf router logits
(the DDPG pruner's structured axis for MoE layers). Router probabilities are
re-normalized over surviving experts automatically by the softmax/top-k.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers.mlp import GATED, _act


class MoEMetrics(NamedTuple):
    aux_loss: jnp.ndarray       # load-balance auxiliary loss (scalar)
    z_loss: jnp.ndarray         # router z-loss (scalar)
    drop_frac: jnp.ndarray      # fraction of assignments dropped


def _init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32)
            / math.sqrt(shape[-2])).astype(dtype)


def init_moe_params(key, d_model, moe, activation, dtype):
    E, de = moe.num_experts, moe.d_expert
    ks = jax.random.split(key, 7)
    p = {
        "w_router": (jax.random.normal(ks[0], (d_model, E), jnp.float32)
                     / math.sqrt(d_model)).astype(jnp.float32),
        "w_up": _init(ks[1], (E, d_model, de), dtype),
        "w_down": _init(ks[2], (E, de, d_model), dtype),
    }
    if activation in GATED:
        p["w_gate"] = _init(ks[3], (E, d_model, de), dtype)
    if moe.num_shared:
        ds = de * moe.num_shared
        p["w_up_sh"] = _init(ks[4], (d_model, ds), dtype)
        p["w_down_sh"] = _init(ks[5], (ds, d_model), dtype)
        if activation in GATED:
            p["w_gate_sh"] = _init(ks[6], (d_model, ds), dtype)
    return p


def capacity(num_tokens: int, moe) -> int:
    c = int(math.ceil(num_tokens * moe.top_k / moe.num_experts
                      * moe.capacity_factor))
    return max(8, -(-c // 8) * 8)


def route(params, moe, x2d, expert_mask: Optional[jnp.ndarray]):
    """x2d (T, d) -> (probs (T,k), idx (T,k), metrics pieces)."""
    logits = (x2d.astype(jnp.float32) @ params["w_router"])
    if expert_mask is not None:
        logits = jnp.where(expert_mask[None] > 0, logits, -1e30)
    if moe.score_fn == "sigmoid":
        scores = jax.nn.sigmoid(logits)
    else:
        scores = jax.nn.softmax(logits, axis=-1)
    probs, idx = jax.lax.top_k(scores, moe.top_k)
    probs = probs / jnp.maximum(probs.sum(-1, keepdims=True), 1e-9)
    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    E = moe.num_experts
    dense_probs = jax.nn.softmax(logits, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(1), axis=0)
    aux = E * jnp.sum(frac * dense_probs.mean(0)) * moe.router_aux_weight
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2) * moe.router_z_weight
    return probs, idx, aux, z


def moe_forward(params, moe, x, activation, *, expert_mask=None):
    """x (B, S, d) -> (out (B, S, d), MoEMetrics)."""
    B, S, d = x.shape
    T = B * S
    x2d = x.reshape(T, d)
    probs, idx, aux, z = route(params, moe, x2d, expert_mask)
    E, k = moe.num_experts, moe.top_k
    C = capacity(T, moe)

    flat_e = idx.reshape(-1)                                  # (T*k,)
    flat_t = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)    # (T*k,)
    flat_p = probs.reshape(-1)
    order = jnp.argsort(flat_e)
    se, st, sp = flat_e[order], flat_t[order], flat_p[order]
    counts = jnp.bincount(se, length=E)
    starts = jnp.cumsum(counts) - counts
    pos = jnp.arange(T * k, dtype=jnp.int32) - starts[se].astype(jnp.int32)
    keep = pos < C
    slot = jnp.where(keep, se * C + pos, E * C)               # E*C = drop bin

    from jax.sharding import PartitionSpec as P
    from repro.sharding.constraints import data_axes_spec, maybe_constrain
    dspec = data_axes_spec()
    buf = jnp.zeros((E * C + 1, d), x.dtype).at[slot].set(
        x2d[st] * keep[:, None].astype(x.dtype))
    eb = buf[:-1].reshape(E, C, d)
    # expert parallelism: the dispatch buffer lives expert-sharded on
    # "model" so the scatter crossing (data-sharded tokens -> expert
    # buffers) lowers to all-to-all instead of replicated-add all-reduce
    # (EXPERIMENTS.md §Perf-4)
    eb = maybe_constrain(eb, P("model", None, None))

    h = _act(jnp.einsum("ecd,edf->ecf", eb, params["w_up"]), activation)
    if activation in GATED:
        h = h * jnp.einsum("ecd,edf->ecf", eb, params["w_gate"])
    h = maybe_constrain(h, P("model", None, None))
    ob = jnp.einsum("ecf,efd->ecd", h, params["w_down"])
    ob = maybe_constrain(ob, P("model", None, None)).reshape(E * C, d)

    gathered = ob[jnp.minimum(slot, E * C - 1)] * keep[:, None].astype(x.dtype)
    out2d = jnp.zeros((T, d), jnp.float32).at[st].add(
        gathered.astype(jnp.float32) * sp[:, None])
    out = maybe_constrain(out2d, P(dspec, None)).astype(x.dtype)

    if moe.num_shared:
        hs = _act(x2d @ params["w_up_sh"], activation)
        if activation in GATED:
            hs = hs * (x2d @ params["w_gate_sh"])
        out = out + hs @ params["w_down_sh"]

    drop = 1.0 - keep.sum().astype(jnp.float32) / (T * k)
    return out.reshape(B, S, d), MoEMetrics(aux, z, drop)
