"""Attention blocks: GQA (with optional QKV bias / sliding window /
bidirectional), and DeepSeek-style MLA with compressed latent KV cache.

Two execution paths:
  * ``chunked_attention`` — flash-style online-softmax scan over KV blocks in
    pure jnp: O(S * block) live memory instead of O(S^2). Used for long
    prefill and as the oracle the Pallas flash kernel is tested against.
  * naive einsum attention for short sequences (cheaper HLO for smoke tests).

Decode paths take a cache pytree and a single new token per sequence.
Pruning hooks: an optional ``head_mask`` (num_heads,) multiplies attention
output per head — the structured axis the DDPG pruner controls.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.layers.norms import rmsnorm
from repro.models.layers.rope import apply_rope

NEG_INF = -2.0 ** 30


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------
def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_gqa_params(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (cfg.d_model, cfg.q_dim), dtype),
        "wk": _dense_init(ks[1], (cfg.d_model, cfg.kv_dim), dtype),
        "wv": _dense_init(ks[2], (cfg.d_model, cfg.kv_dim), dtype),
        "wo": _dense_init(ks[3], (cfg.q_dim, cfg.d_model), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), dtype)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dtype)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dtype)
    return p


def init_mla_params(key, cfg, dtype):
    m = cfg.mla
    ks = jax.random.split(key, 7)
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "w_dq": _dense_init(ks[0], (cfg.d_model, m.q_lora_rank), dtype),
        "q_norm": jnp.ones((m.q_lora_rank,), dtype),
        "w_uq": _dense_init(ks[1], (m.q_lora_rank, cfg.num_heads * qk_head), dtype),
        # joint KV down-projection + shared rope key
        "w_dkv": _dense_init(ks[2], (cfg.d_model,
                                     m.kv_lora_rank + m.qk_rope_head_dim), dtype),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
        "w_uk": _dense_init(ks[3], (m.kv_lora_rank,
                                    cfg.num_heads * m.qk_nope_head_dim), dtype),
        "w_uv": _dense_init(ks[4], (m.kv_lora_rank,
                                    cfg.num_heads * m.v_head_dim), dtype),
        "wo": _dense_init(ks[5], (cfg.num_heads * m.v_head_dim, cfg.d_model), dtype),
    }


# ---------------------------------------------------------------------------
# masks
# ---------------------------------------------------------------------------
def _band_mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray, causal: bool,
               window: Optional[int]) -> jnp.ndarray:
    """(..., Sq, Sk) boolean allow-mask from position vectors."""
    d = q_pos[..., :, None] - k_pos[..., None, :]
    # sentinel (>= 2**29) marks padded KV slots — always excluded
    ok = (k_pos < 2 ** 29)[..., None, :] & jnp.ones(d.shape, bool)
    if causal:
        ok &= d >= 0
    if window is not None:
        ok &= d < window
    return ok


# ---------------------------------------------------------------------------
# core attention (jnp paths)
# ---------------------------------------------------------------------------
def naive_attention(q, k, v, mask, scale):
    """q (B,Sq,H,D), k/v (B,Sk,Hkv,D); mask (B,Sq,Sk) or (Sq,Sk) boolean."""
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    group = H // Hkv
    qg = q.reshape(B, Sq, Hkv, group, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if mask.ndim == 2:
        mask = mask[None]
    logits = jnp.where(mask[:, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)


def chunked_attention(q, k, v, q_pos, k_pos, causal, window, scale,
                      block_kv: int = 1024, unroll: bool = False):
    """Flash-style attention: scan over KV blocks with online softmax.

    q (B,Sq,H,D); k,v (B,Sk,Hkv,D); q_pos (B,Sq); k_pos (B,Sk).
    Memory: O(Sq * block_kv) logits at a time. ``unroll`` replaces the scan
    with straight-line blocks (for cost-analysis-accurate dry-runs).
    """
    B, Sq, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    group = H // Hkv
    nblk = -(-Sk // block_kv)
    pad = nblk * block_kv - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=2**30)
    qg = (q.astype(jnp.float32) * scale).reshape(B, Sq, Hkv, group, D)
    kb = k.reshape(B, nblk, block_kv, Hkv, D)
    vb = v.reshape(B, nblk, block_kv, Hkv, Dv)
    pb = k_pos.reshape(B, nblk, block_kv)

    def step(carry, blk):
        m, l, acc = carry
        kc, vc, pc = blk      # (B, block, Hkv, D), (B, block)
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kc.astype(jnp.float32))
        ok = _band_mask(q_pos[:, None, None], pc[:, None, None], causal, window)
        logits = jnp.where(ok, logits, NEG_INF)   # ok: (B,1,1,Sq,block)
        m_new = jnp.maximum(m, logits.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Hkv, group, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Hkv, group, Sq), jnp.float32)
    a0 = jnp.zeros((B, Hkv, group, Sq, Dv), jnp.float32)
    if unroll:
        carry = (m0, l0, a0)
        for i in range(nblk):
            carry, _ = step(carry, (kb[:, i], vb[:, i], pb[:, i]))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(
            step, (m0, l0, a0),
            (kb.swapaxes(0, 1), vb.swapaxes(0, 1), pb.swapaxes(0, 1)))
    out = acc / jnp.maximum(l, 1e-37)[..., None]
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, Sq, H, Dv)
    return out.astype(q.dtype)


def chunked_attention_ha(q, k, v, q_pos, k_pos, causal, window, scale,
                         block_kv: int = 1024, unroll: bool = False):
    """Head-atomic variant of chunked_attention: K/V are repeated to the
    full H query heads instead of reshaping H into (Hkv, group).

    Why it exists: splitting H into (Hkv, group) makes the logits tensor
    (B, Hkv, group, Sq, blk) unshardable when the mesh "model" axis divides
    neither factor (e.g. 28 heads = 4 x 7 on a 16-way axis) — GSPMD then
    replicates the biggest intermediate of the whole model and all-reduces
    partial sums (measured: 27 TB/chip on qwen2-7b prefill_32k,
    EXPERIMENTS.md §Perf-1). Keeping H atomic lets "model" shard it
    (unevenly, padded) and kills both. The repeated K/V cost is
    group x the (small) KV tensor, sharded like the logits.
    """
    B, Sq, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    rep = H // Hkv
    from repro.sharding.constraints import data_axes_spec, maybe_constrain
    from jax.sharding import PartitionSpec as P
    dspec = data_axes_spec()
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    k = maybe_constrain(k, P(dspec, None, "model", None))
    v = maybe_constrain(v, P(dspec, None, "model", None))
    nblk = -(-Sk // block_kv)
    pad = nblk * block_kv - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=2**30)
    qh = (q.astype(jnp.float32) * scale)
    kb = k.reshape(B, nblk, block_kv, H, D)
    vb = v.reshape(B, nblk, block_kv, H, Dv)
    pb = k_pos.reshape(B, nblk, block_kv)

    def step(carry, blk):
        m, l, acc = carry
        kc, vc, pc = blk                   # (B, blk, H, D), (B, blk)
        logits = jnp.einsum("bqhd,bkhd->bhqk", qh, kc.astype(jnp.float32))
        logits = maybe_constrain(logits, P(dspec, "model", None, None))
        ok = _band_mask(q_pos, pc, causal, window)      # (B, Sq, blk)
        logits = jnp.where(ok[:, None], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l_new = l * alpha + p.sum(-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vc.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, Dv), jnp.float32)
    if unroll:
        carry = (m0, l0, a0)
        for i in range(nblk):
            carry, _ = step(carry, (kb[:, i], vb[:, i], pb[:, i]))
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(
            step, (m0, l0, a0),
            (kb.swapaxes(0, 1), vb.swapaxes(0, 1), pb.swapaxes(0, 1)))
    out = acc / jnp.maximum(l, 1e-37)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, valid_len, q_pos, window, scale):
    """Single-step decode: q (B,1,H,D) against (B,Smax,Hkv,D) cache.

    ``valid_len`` (B,) — number of filled cache slots; positions are
    0..valid_len-1 (or a rolling window layout handled by the caller via
    k_pos == slot positions).
    """
    B, _, H, D = q.shape
    Smax, Hkv = k_cache.shape[1], k_cache.shape[2]
    group = H // Hkv
    qg = (q.astype(jnp.float32) * scale).reshape(B, Hkv, group, D)
    logits = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache.astype(jnp.float32))
    kpos = jnp.arange(Smax)[None]
    ok = kpos < valid_len[:, None]
    if window is not None:
        ok &= kpos > (q_pos[:, None] - window)
    logits = jnp.where(ok[:, None, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", probs, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------
class KVCache(NamedTuple):
    k: jnp.ndarray          # (B, Smax, Hkv, D)
    v: jnp.ndarray


def init_kv_cache(batch, max_len, num_kv_heads, head_dim, dtype) -> KVCache:
    shape = (batch, max_len, num_kv_heads, head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def gqa_forward(params, cfg, x, angles, *, head_mask=None, chunked=None):
    """Full-sequence forward (train / prefill). Returns (out, (k, v))."""
    B, S, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = k.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, S, cfg.num_kv_heads, cfg.head_dim)
    if angles is not None:
        q = apply_rope(q, angles)
        k = apply_rope(k, angles)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    use_chunked = chunked if chunked is not None else S > cfg.naive_attn_max
    from repro.kernels import dispatch
    if dispatch.enabled():
        from repro.kernels.flash_attention.ops import flash_attention
        out = flash_attention(q, k, v, causal=cfg.causal,
                              window=cfg.sliding_window, scale=scale,
                              interpret=dispatch.interpret())
    elif use_chunked and cfg.attn_head_atomic:
        from jax.sharding import PartitionSpec as P
        from repro.sharding.constraints import (data_axes_spec,
                                                maybe_constrain)
        q = maybe_constrain(q, P(data_axes_spec(), None, "model", None))
        out = chunked_attention_ha(q, k, v, pos, pos, cfg.causal,
                                   cfg.sliding_window, scale,
                                   unroll=cfg.attn_block_unroll)
    elif use_chunked:
        out = chunked_attention(q, k, v, pos, pos, cfg.causal,
                                cfg.sliding_window, scale,
                                unroll=cfg.attn_block_unroll)
    else:
        mask = _band_mask(jnp.arange(S), jnp.arange(S), cfg.causal,
                          cfg.sliding_window)
        out = naive_attention(q, k, v, mask, scale)
    if head_mask is not None:
        out = out * head_mask[None, None, :, None].astype(out.dtype)
    return out.reshape(B, S, cfg.q_dim) @ params["wo"], (k, v)


def gqa_decode(params, cfg, x, angles, cache: KVCache, pos, *, head_mask=None):
    """One-token decode. x (B,1,d_model); pos (B,) absolute position.

    For sliding-window configs the cache is a rolling buffer of size
    min(Smax, window): slot = pos % cache_len.
    """
    B = x.shape[0]
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if cfg.qkv_bias:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    q = q.reshape(B, 1, cfg.num_heads, cfg.head_dim)
    k = k.reshape(B, 1, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(B, 1, cfg.num_kv_heads, cfg.head_dim)
    if angles is not None:
        q = apply_rope(q, angles)
        k = apply_rope(k, angles)
    cache_len = cache.k.shape[1]
    slot = (pos % cache_len).astype(jnp.int32)
    k_cache = jax.vmap(lambda c, u, s: jax.lax.dynamic_update_slice(
        c, u, (s, 0, 0)))(cache.k, k, slot)
    v_cache = jax.vmap(lambda c, u, s: jax.lax.dynamic_update_slice(
        c, u, (s, 0, 0)))(cache.v, v, slot)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    if cfg.sliding_window is not None and cache_len <= cfg.sliding_window:
        # rolling buffer: every slot written within the window is valid
        valid = jnp.minimum(pos + 1, cache_len)
        window = None   # rolling buffer already enforces the window
    else:
        valid = pos + 1
        window = cfg.sliding_window
    out = decode_attention(q, k_cache, v_cache, valid, pos, window, scale)
    if head_mask is not None:
        out = out * head_mask[None, None, :, None].astype(out.dtype)
    out = out.reshape(B, 1, cfg.q_dim) @ params["wo"]
    return out, KVCache(k_cache, v_cache)


# ---------------------------------------------------------------------------
# MLA block (DeepSeek-V3). Prefill materializes K/V; decode uses the
# weight-absorbed latent form so the cache stays (kv_lora_rank + rope_dim)
# floats per token regardless of head count.
# ---------------------------------------------------------------------------
class MLACache(NamedTuple):
    ckv: jnp.ndarray        # (B, Smax, kv_lora_rank)
    krope: jnp.ndarray      # (B, Smax, qk_rope_head_dim)


def init_mla_cache(batch, max_len, mla, dtype) -> MLACache:
    return MLACache(
        jnp.zeros((batch, max_len, mla.kv_lora_rank), dtype),
        jnp.zeros((batch, max_len, mla.qk_rope_head_dim), dtype))


def _mla_qkv(params, cfg, x, angles):
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    q_lat = rmsnorm(x @ params["w_dq"], params["q_norm"], cfg.norm_eps)
    q = (q_lat @ params["w_uq"]).reshape(B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope, q_rope = q[..., :m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = apply_rope(q_rope, angles)
    dkv = x @ params["w_dkv"]
    ckv = rmsnorm(dkv[..., :m.kv_lora_rank], params["kv_norm"], cfg.norm_eps)
    k_rope = apply_rope(dkv[..., None, m.kv_lora_rank:], angles)[:, :, 0]  # shared
    return q_nope, q_rope, ckv, k_rope


def mla_forward(params, cfg, x, angles, *, head_mask=None):
    """Prefill/train path: materialize per-head K/V from the latent."""
    m = cfg.mla
    B, S, _ = x.shape
    H = cfg.num_heads
    q_nope, q_rope, ckv, k_rope = _mla_qkv(params, cfg, x, angles)
    k_nope = (ckv @ params["w_uk"]).reshape(B, S, H, m.qk_nope_head_dim)
    vv = (ckv @ params["w_uv"]).reshape(B, S, H, m.v_head_dim)
    q = jnp.concatenate([q_nope, q_rope], -1)
    k = jnp.concatenate([k_nope,
                         jnp.broadcast_to(k_rope[:, :, None],
                                          (B, S, H, m.qk_rope_head_dim))], -1)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    if S > cfg.naive_attn_max:
        out = chunked_attention(q, k, vv, pos, pos, cfg.causal, None, scale,
                                unroll=cfg.attn_block_unroll)
    else:
        mask = _band_mask(jnp.arange(S), jnp.arange(S), cfg.causal, None)
        out = naive_attention(q, k, vv, mask, scale)
    if head_mask is not None:
        out = out * head_mask[None, None, :, None].astype(out.dtype)
    out = out.reshape(B, S, H * m.v_head_dim) @ params["wo"]
    return out, (ckv, k_rope)


def mla_decode(params, cfg, x, angles, cache: MLACache, pos, *, head_mask=None):
    """Absorbed decode: score/value computed in the latent space.

    scores = (q_nope W_uk^T) . ckv + q_rope . k_rope     -- per head
    out    = softmax(scores) @ ckv  then  W_uv, per head.
    """
    m = cfg.mla
    B = x.shape[0]
    H = cfg.num_heads
    q_nope, q_rope, ckv_new, krope_new = _mla_qkv(params, cfg, x, angles)
    # absorb W_uk: (B,1,H,nope) x (rank, H*nope) -> (B,H,rank)
    wuk = params["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    q_lat = jnp.einsum("bhd,rhd->bhr", q_nope[:, 0].astype(jnp.float32),
                       wuk.astype(jnp.float32))
    cache_len = cache.ckv.shape[1]
    slot = (pos % cache_len).astype(jnp.int32)
    ckv_c = jax.vmap(lambda c, u, s: jax.lax.dynamic_update_slice(
        c, u, (s, 0)))(cache.ckv, ckv_new, slot)
    kr_c = jax.vmap(lambda c, u, s: jax.lax.dynamic_update_slice(
        c, u, (s, 0)))(cache.krope, krope_new, slot)
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    s_lat = jnp.einsum("bhr,bkr->bhk", q_lat, ckv_c.astype(jnp.float32))
    s_rope = jnp.einsum("bhd,bkd->bhk", q_rope[:, 0].astype(jnp.float32),
                        kr_c.astype(jnp.float32))
    logits = (s_lat + s_rope) * scale
    ok = jnp.arange(cache_len)[None] < (pos[:, None] + 1)
    logits = jnp.where(ok[:, None], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    o_lat = jnp.einsum("bhk,bkr->bhr", probs, ckv_c.astype(jnp.float32))
    wuv = params["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    out = jnp.einsum("bhr,rhd->bhd", o_lat, wuv.astype(jnp.float32))
    if head_mask is not None:
        out = out * head_mask[None, :, None]
    out = out.reshape(B, 1, H * m.v_head_dim).astype(x.dtype) @ params["wo"]
    return out, MLACache(ckv_c, kr_c)
