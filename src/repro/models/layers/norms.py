"""Normalization layers (pure-JAX reference path).

The Pallas fused rmsnorm lives in ``repro.kernels.rmsnorm``; model code calls
through :func:`rmsnorm` which dispatches on a module-level flag so the dry-run
and smoke tests use the XLA path while kernel tests exercise Pallas.
"""
from __future__ import annotations

import jax.numpy as jnp


def rmsnorm_ref(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6,
                scale_offset: float = 0.0) -> jnp.ndarray:
    """RMSNorm computed in fp32, cast back to input dtype.

    ``scale_offset=1.0`` gives the gemma convention (weights stored as
    ``scale - 1``).
    """
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * (1.0 / jnp.sqrt(var + eps))
    return (y * (scale.astype(jnp.float32) + scale_offset)).astype(dtype)


def rmsnorm(x, scale, eps: float = 1e-6, scale_offset: float = 0.0):
    from repro.kernels import dispatch
    if dispatch.enabled():
        from repro.kernels.rmsnorm.ops import rmsnorm as rms_pallas
        return rms_pallas(x, scale, eps=eps, scale_offset=scale_offset,
                          interpret=dispatch.interpret())
    return rmsnorm_ref(x, scale, eps, scale_offset)


def layernorm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
              eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) / jnp.sqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dtype)


def gated_rmsnorm(x: jnp.ndarray, z: jnp.ndarray, scale: jnp.ndarray,
                  eps: float = 1e-6) -> jnp.ndarray:
    """Mamba2's norm-then-gate: RMSNorm(x * silu(z))."""
    x32 = x.astype(jnp.float32)
    z32 = z.astype(jnp.float32)
    g = x32 * (z32 * jnp.where(z32 >= 0, 1 / (1 + jnp.exp(-z32)),
                               jnp.exp(z32) / (1 + jnp.exp(z32))))
    var = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    return ((g / jnp.sqrt(var + eps)) * scale.astype(jnp.float32)).astype(x.dtype)
