"""Feed-forward blocks: gated (SiLU-GLU / GeGLU) and non-gated (GELU /
squared-ReLU, the Nemotron-4 variant).

Pruning hook: ``ffn_mask`` (d_ff,) zeroes pruned inner channels — the
structured axis the DDPG pruner controls for FFN layers.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def _init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32)
            / math.sqrt(shape[0])).astype(dtype)


GATED = {"silu_glu", "geglu"}


def init_mlp_params(key, d_model, d_ff, activation, dtype):
    ks = jax.random.split(key, 3)
    p = {"w_up": _init(ks[0], (d_model, d_ff), dtype),
         "w_down": _init(ks[1], (d_ff, d_model), dtype)}
    if activation in GATED:
        p["w_gate"] = _init(ks[2], (d_model, d_ff), dtype)
    return p


def _act(x, activation):
    if activation == "silu_glu":
        return jax.nn.silu(x)
    if activation == "geglu":
        return jax.nn.gelu(x, approximate=True)
    if activation == "gelu":
        return jax.nn.gelu(x, approximate=True)
    if activation == "sq_relu":
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(activation)


def mlp_forward(params, x, activation, *, ffn_mask=None):
    from repro.kernels import dispatch
    if dispatch.enabled() and ffn_mask is not None:
        from repro.kernels.masked_matmul.ops import masked_matmul
        h = _act(masked_matmul(x, params["w_up"], ffn_mask,
                               interpret=dispatch.interpret()), activation)
        if activation in GATED:
            h = h * masked_matmul(x, params["w_gate"], ffn_mask,
                                  interpret=dispatch.interpret())
        return h @ params["w_down"]
    from jax.sharding import PartitionSpec as P
    from repro.sharding.constraints import data_axes_spec, maybe_constrain
    dspec = data_axes_spec()
    h = _act(x @ params["w_up"], activation)
    if activation in GATED:
        h = h * (x @ params["w_gate"])
    # keep batch data-sharded / d_ff model-sharded through the FFN: without
    # this GSPMD reshards the remat-saved hidden to batch-replicated fp32
    # (EXPERIMENTS.md §Perf-2 it2: 3x ~278 GB/chip collective classes)
    if h.ndim == 3:
        h = maybe_constrain(h, P(dspec, None, "model"))
    if ffn_mask is not None:
        h = h * ffn_mask.astype(h.dtype)
    out = h @ params["w_down"]
    if out.ndim == 3:
        out = maybe_constrain(out, P(dspec, None, None))
    return out
