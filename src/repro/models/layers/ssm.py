"""Mamba2 block (state-space duality / SSD, arXiv:2405.21060).

Forward path uses the chunked SSD algorithm: intra-chunk attention-like
dot-products + an inter-chunk linear state recurrence (``lax.scan`` over
chunks). This is the TPU-native formulation — chunk matmuls hit the MXU and
the sequential part is O(S / chunk). The Pallas kernel in
``repro.kernels.ssd_scan`` implements the same math with explicit VMEM
tiling; this file is the pure-jnp reference the kernel is validated against.

Decode: O(1) per token — conv rolling state (d_conv-1 taps) + SSM state
(H, P, N) per layer.

Pruning hook: ``head_mask`` (ssm_heads,) zeroes pruned SSD heads.
"""
from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.layers.norms import gated_rmsnorm


class SSMCache(NamedTuple):
    conv: jnp.ndarray     # (B, d_conv-1, conv_dim)
    state: jnp.ndarray    # (B, H, P, N) fp32


def _init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32)
            / math.sqrt(shape[0])).astype(dtype)


def conv_dim(cfg) -> int:
    s = cfg.ssm
    return cfg.d_inner + 2 * s.n_groups * s.d_state


def init_ssm_params(key, cfg, dtype):
    s = cfg.ssm
    H = cfg.ssm_heads
    d_in = cfg.d_inner
    cdim = conv_dim(cfg)
    proj_out = 2 * d_in + 2 * s.n_groups * s.d_state + H
    ks = jax.random.split(key, 4)
    dt = jnp.exp(jax.random.uniform(ks[2], (H,), jnp.float32,
                                    math.log(1e-3), math.log(1e-1)))
    return {
        "w_in": _init(ks[0], (cfg.d_model, proj_out), dtype),
        "conv_w": (jax.random.normal(ks[1], (s.d_conv, cdim), jnp.float32)
                   / math.sqrt(s.d_conv)).astype(dtype),
        "conv_b": jnp.zeros((cdim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm_scale": jnp.ones((d_in,), dtype),
        "w_out": _init(ks[3], (d_in, cfg.d_model), dtype),
    }


def _split_proj(cfg, proj):
    s = cfg.ssm
    d_in = cfg.d_inner
    gn = s.n_groups * s.d_state
    z = proj[..., :d_in]
    xBC = proj[..., d_in:d_in + d_in + 2 * gn]
    dt = proj[..., d_in + d_in + 2 * gn:]
    return z, xBC, dt


def _causal_conv(xBC, conv_w, conv_b, d_conv):
    """Depthwise causal conv1d. xBC (B,S,Cd), conv_w (K,Cd)."""
    pad = jnp.pad(xBC, ((0, 0), (d_conv - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1]] * conv_w[i] for i in range(d_conv))
    return jax.nn.silu((out + conv_b).astype(jnp.float32)).astype(xBC.dtype)


def _segsum(x):
    """x (..., Q) -> (..., Q, Q) lower-triangular cumulative sums:
    out[i, j] = sum_{j < m <= i} x[m]; -inf above the diagonal."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, -1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, d, -jnp.inf)


def ssd_chunked(xh, dt, A, Bm, Cm, chunk):
    """Chunked SSD scan (fp32 math).

    xh (B,S,H,P); dt (B,S,H) post-softplus; A (H,) negative;
    Bm/Cm (B,S,G,N) broadcastable to heads (G divides H).
    Returns (y (B,S,H,P), final_state (B,H,P,N)).
    """
    Bsz, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    rep = H // G
    f32 = jnp.float32
    xc = xh.reshape(Bsz, nc, chunk, H, P).astype(f32)
    dtc = dt.reshape(Bsz, nc, chunk, H).astype(f32)
    Bc = jnp.repeat(Bm.reshape(Bsz, nc, chunk, G, N), rep, 3).astype(f32)
    Cc = jnp.repeat(Cm.reshape(Bsz, nc, chunk, G, N), rep, 3).astype(f32)

    dA = dtc * A.astype(f32)                       # (B,nc,Q,H)
    dA_cs = jnp.cumsum(dA, axis=2)
    # intra-chunk (diagonal blocks)
    L = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2)))          # (B,nc,H,Q,Q)
    CB = jnp.einsum("bcqhn,bckhn->bchqk", Cc, Bc)
    y_diag = jnp.einsum("bchqk,bckh,bckhp->bcqhp",
                        CB * L, dtc, xc)
    # chunk-final states
    decay_to_end = jnp.exp(dA_cs[:, :, -1:, :] - dA_cs)     # (B,nc,Q,H)
    states = jnp.einsum("bcqhn,bcqh,bcqh,bcqhp->bchpn",
                        Bc, dtc, decay_to_end, xc)
    # inter-chunk recurrence
    chunk_decay = jnp.exp(dA_cs[:, :, -1, :])               # (B,nc,H)

    def step(s_prev, inp):
        st, dec = inp
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    init = jnp.zeros((Bsz, H, P, N), f32)
    final, prev_states = jax.lax.scan(
        step, init,
        (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)))
    prev_states = prev_states.swapaxes(0, 1)                # (B,nc,H,P,N)
    # off-diagonal contribution: carry-in state seen through per-step decay
    state_decay = jnp.exp(dA_cs)                            # (B,nc,Q,H)
    y_off = jnp.einsum("bcqhn,bchpn,bcqh->bcqhp",
                       Cc, prev_states, state_decay)
    y = (y_diag + y_off).reshape(Bsz, nc * chunk, H, P)
    return y[:, :S], final


def ssm_forward(params, cfg, x, *, head_mask=None, return_state=False):
    """Full-sequence Mamba2 block. x (B,S,d_model).

    With ``return_state``, also returns an SSMCache holding the rolling conv
    tail (raw pre-conv inputs) and the final SSD state — exactly what
    ``ssm_decode`` consumes to continue the sequence.
    """
    s = cfg.ssm
    H, P = cfg.ssm_heads, s.head_dim
    proj = x @ params["w_in"]
    z, xBC, dt = _split_proj(cfg, proj)
    xBC_raw = xBC
    xBC = _causal_conv(xBC, params["conv_w"], params["conv_b"], s.d_conv)
    d_in = cfg.d_inner
    gn = s.n_groups * s.d_state
    xs = xBC[..., :d_in].reshape(*x.shape[:2], H, P)
    Bm = xBC[..., d_in:d_in + gn].reshape(*x.shape[:2], s.n_groups, s.d_state)
    Cm = xBC[..., d_in + gn:].reshape(*x.shape[:2], s.n_groups, s.d_state)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    from repro.kernels import dispatch
    if dispatch.enabled():
        from repro.kernels.ssd_scan.ops import ssd_scan
        y, state = ssd_scan(xs, dt, A, Bm, Cm, head_mask=head_mask,
                            chunk=s.chunk_size,
                            interpret=dispatch.interpret())
    else:
        y, state = ssd_chunked(xs, dt, A, Bm, Cm, s.chunk_size)
        if head_mask is not None:
            y = y * head_mask[None, None, :, None]
    skip = params["D"][None, None, :, None] * xs.astype(jnp.float32)
    if head_mask is not None:
        skip = skip * head_mask[None, None, :, None]
    y = y + skip
    y = y.reshape(*x.shape[:2], d_in).astype(x.dtype)
    y = gated_rmsnorm(y, z, params["norm_scale"], cfg.norm_eps)
    out = y @ params["w_out"]
    if return_state:
        K = s.d_conv
        S_len = x.shape[1]
        if S_len >= K - 1:
            tail = xBC_raw[:, S_len - (K - 1):]
        else:
            tail = jnp.pad(xBC_raw, ((0, 0), (K - 1 - S_len, 0), (0, 0)))
        return out, SSMCache(tail.astype(x.dtype), state)
    return out


def init_ssm_cache(cfg, batch, dtype) -> SSMCache:
    s = cfg.ssm
    return SSMCache(
        conv=jnp.zeros((batch, s.d_conv - 1, conv_dim(cfg)), dtype),
        state=jnp.zeros((batch, cfg.ssm_heads, s.head_dim, s.d_state),
                        jnp.float32))


def ssm_decode(params, cfg, x, cache: SSMCache, *, head_mask=None):
    """One-token decode. x (B,1,d_model) -> (out (B,1,d), new cache)."""
    s = cfg.ssm
    H, P = cfg.ssm_heads, s.head_dim
    B = x.shape[0]
    proj = x[:, 0] @ params["w_in"]                  # (B, proj_out)
    z, xBC, dt = _split_proj(cfg, proj)
    # rolling conv state
    hist = jnp.concatenate([cache.conv, xBC[:, None]], axis=1)  # (B,K,Cd)
    conv_out = jnp.einsum("bkc,kc->bc", hist.astype(jnp.float32),
                          params["conv_w"].astype(jnp.float32))
    xBC = jax.nn.silu(conv_out + params["conv_b"].astype(jnp.float32))
    new_conv = hist[:, 1:].astype(cache.conv.dtype)

    d_in = cfg.d_inner
    gn = s.n_groups * s.d_state
    xs = xBC[..., :d_in].reshape(B, H, P)
    Bm = xBC[..., d_in:d_in + gn].reshape(B, s.n_groups, s.d_state)
    Cm = xBC[..., d_in + gn:].reshape(B, s.n_groups, s.d_state)
    rep = H // s.n_groups
    Bh = jnp.repeat(Bm, rep, axis=1)                 # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    A = -jnp.exp(params["A_log"])
    decay = jnp.exp(dt * A)                          # (B,H)
    state = (cache.state * decay[..., None, None]
             + jnp.einsum("bh,bhp,bhn->bhpn", dt, xs, Bh))
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch) + params["D"][None, :, None] * xs
    if head_mask is not None:
        y = y * head_mask[None, :, None]
    y = y.reshape(B, 1, d_in).astype(x.dtype)
    y = gated_rmsnorm(y, z[:, None], params["norm_scale"], cfg.norm_eps)
    return y @ params["w_out"], SSMCache(new_conv, state)
