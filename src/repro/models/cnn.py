"""Generic CNN built from a CNNConfig layer list — AlexNet for the
paper-faithful PlantVillage reproduction.

Every op (conv / relu / pool / flatten / dense) is a *layer* in the paper's
sense: a candidate split point for the partitioner and (for conv/dense) a
prunable unit for the DDPG agent. ``apply`` can return every intermediate
activation so the partitioner can read per-layer output sizes (Fig. 2 / Fig. 4
of the paper).

Channel pruning is mask-based: ``masks[i]`` is a 0/1 vector over layer i's
output channels (conv) or units (dense). Masked channels are zeroed, which is
mathematically identical to removing them; ``compact_params`` additionally
*materializes* the removal (physically smaller weights) for deployment.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import CNNConfig, ConvLayerSpec


def alexnet_config(num_classes: int = 38) -> CNNConfig:
    L = ConvLayerSpec
    return CNNConfig(
        name="alexnet",
        layers=(
            L("conv", out_channels=64, kernel=11, stride=4, padding=2),   # 0
            L("relu"),                                                    # 1
            L("maxpool", kernel=3, stride=2),                             # 2
            L("conv", out_channels=192, kernel=5, stride=1, padding=2),   # 3
            L("relu"),                                                    # 4
            L("maxpool", kernel=3, stride=2),                             # 5
            L("conv", out_channels=384, kernel=3, stride=1, padding=1),   # 6
            L("relu"),                                                    # 7
            L("conv", out_channels=256, kernel=3, stride=1, padding=1),   # 8
            L("relu"),                                                    # 9
            L("conv", out_channels=256, kernel=3, stride=1, padding=1),   # 10
            L("relu"),                                                    # 11
            L("maxpool", kernel=3, stride=2),                             # 12
            L("flatten"),                                                 # 13
            L("dense", features=4096),                                    # 14
            L("relu"),                                                    # 15
            L("dense", features=4096),                                    # 16
            L("relu"),                                                    # 17
            L("dense", features=num_classes),                             # 18
        ),
        num_classes=num_classes,
        input_hw=(224, 224),
        citation="AlexNet (Krizhevsky et al. 2012); layer list per "
                 "torchvision; paper Figs. 2-4 profile this network.",
    )


def tiny_cnn_config(num_classes: int = 38, width: float = 0.25,
                    hw: int = 64) -> CNNConfig:
    """Reduced AlexNet-family CNN for CPU training in tests/examples."""
    L = ConvLayerSpec
    w = lambda c: max(8, int(c * width))
    return CNNConfig(
        name="tiny_alexnet",
        layers=(
            L("conv", out_channels=w(64), kernel=5, stride=2, padding=2),
            L("relu"),
            L("maxpool", kernel=3, stride=2),
            L("conv", out_channels=w(192), kernel=3, stride=1, padding=1),
            L("relu"),
            L("maxpool", kernel=3, stride=2),
            L("conv", out_channels=w(256), kernel=3, stride=1, padding=1),
            L("relu"),
            L("maxpool", kernel=3, stride=2),
            L("flatten"),
            L("dense", features=256),
            L("relu"),
            L("dense", features=num_classes),
        ),
        num_classes=num_classes,
        input_hw=(hw, hw),
        citation="reduced AlexNet-family CNN (this work, CPU smoke scale)",
    )


# ---------------------------------------------------------------------------
def _out_hw(hw: int, k: int, s: int, p: int) -> int:
    return (hw + 2 * p - k) // s + 1


def layer_shapes(cfg: CNNConfig) -> List[Tuple[int, ...]]:
    """Output shape (C, H, W) or (F,) per layer, batch-free."""
    h, w = cfg.input_hw
    c = cfg.input_channels
    shapes: List[Tuple[int, ...]] = []
    flat = None
    for spec in cfg.layers:
        if spec.kind == "conv":
            h = _out_hw(h, spec.kernel, spec.stride, spec.padding)
            w = _out_hw(w, spec.kernel, spec.stride, spec.padding)
            c = spec.out_channels
            shapes.append((c, h, w))
        elif spec.kind == "maxpool":
            h = _out_hw(h, spec.kernel, spec.stride, 0)
            w = _out_hw(w, spec.kernel, spec.stride, 0)
            shapes.append((c, h, w))
        elif spec.kind == "relu":
            shapes.append(shapes[-1] if shapes else (c, h, w))
        elif spec.kind == "flatten":
            flat = c * h * w
            shapes.append((flat,))
        elif spec.kind == "dense":
            flat = spec.features
            shapes.append((flat,))
        else:
            raise ValueError(spec.kind)
    return shapes


def init_cnn_params(key, cfg: CNNConfig) -> Dict[str, Dict[str, jnp.ndarray]]:
    dtype = jnp.dtype(cfg.dtype)
    params: Dict[str, Dict[str, jnp.ndarray]] = {}
    shapes = layer_shapes(cfg)
    c_in = cfg.input_channels
    flat_in = None
    keys = jax.random.split(key, len(cfg.layers))
    for i, spec in enumerate(cfg.layers):
        if spec.kind == "conv":
            fan_in = c_in * spec.kernel * spec.kernel
            wshape = (spec.kernel, spec.kernel, c_in, spec.out_channels)
            params[f"l{i}"] = {
                "w": (jax.random.normal(keys[i], wshape, jnp.float32)
                      * math.sqrt(2.0 / fan_in)).astype(dtype),
                "b": jnp.zeros((spec.out_channels,), dtype),
            }
            c_in = spec.out_channels
        elif spec.kind == "flatten":
            flat_in = shapes[i][0]
        elif spec.kind == "dense":
            d_in = flat_in if flat_in is not None else shapes[i - 1][0]
            params[f"l{i}"] = {
                "w": (jax.random.normal(keys[i], (d_in, spec.features),
                                        jnp.float32)
                      * math.sqrt(2.0 / d_in)).astype(dtype),
                "b": jnp.zeros((spec.features,), dtype),
            }
            flat_in = spec.features
    return params


def cnn_apply(params, cfg: CNNConfig, x: jnp.ndarray,
              masks: Optional[Dict[int, jnp.ndarray]] = None,
              return_intermediates: bool = False,
              start_layer: int = 0, stop_layer: Optional[int] = None):
    """Run layers [start_layer, stop_layer) on x.

    x: (B, H, W, C) for start_layer==0, else whatever that layer expects.
    Split inference runs [0, c) on the edge and [c, N) on the cloud.
    """
    masks = masks or {}
    stop = stop_layer if stop_layer is not None else len(cfg.layers)
    inter = []
    for i in range(start_layer, stop):
        spec = cfg.layers[i]
        if spec.kind == "conv":
            p = params[f"l{i}"]
            x = jax.lax.conv_general_dilated(
                x, p["w"], (spec.stride, spec.stride),
                [(spec.padding, spec.padding)] * 2,
                dimension_numbers=("NHWC", "HWIO", "NHWC")) + p["b"]
            if i in masks:
                x = x * masks[i].astype(x.dtype)
        elif spec.kind == "relu":
            x = jax.nn.relu(x)
        elif spec.kind == "maxpool":
            x = jax.lax.reduce_window(
                x, -jnp.inf, jax.lax.max,
                (1, spec.kernel, spec.kernel, 1),
                (1, spec.stride, spec.stride, 1), "VALID")
        elif spec.kind == "flatten":
            x = x.reshape(x.shape[0], -1)
        elif spec.kind == "dense":
            p = params[f"l{i}"]
            x = x @ p["w"] + p["b"]
            if i in masks:
                x = x * masks[i].astype(x.dtype)
        if return_intermediates:
            inter.append(x)
    if return_intermediates:
        return x, inter
    return x


def prunable_layers(cfg: CNNConfig) -> List[int]:
    """Indices the DDPG agent controls (conv + hidden dense, not the head)."""
    out = [i for i, s in enumerate(cfg.layers) if s.kind == "conv"]
    dense = [i for i, s in enumerate(cfg.layers) if s.kind == "dense"]
    out += dense[:-1]          # never prune the classifier head
    return out


def compact_cnn_config(cfg: CNNConfig,
                       masks: Dict[int, jnp.ndarray]) -> CNNConfig:
    """Shape-only compaction: shrink conv out_channels / dense features to
    the surviving counts, without touching params. The latency model prices
    the *deployed* (physically smaller) network with this config."""
    import dataclasses as _dc
    new_specs = list(cfg.layers)
    for i, spec in enumerate(cfg.layers):
        if i not in masks:
            continue
        kept = int(np.sum(np.asarray(masks[i]) > 0))
        if spec.kind == "conv":
            new_specs[i] = ConvLayerSpec("conv", out_channels=kept,
                                         kernel=spec.kernel,
                                         stride=spec.stride,
                                         padding=spec.padding)
        elif spec.kind == "dense":
            new_specs[i] = ConvLayerSpec("dense", features=kept)
    return _dc.replace(cfg, layers=tuple(new_specs))


def split_keep_indices(cfg: CNNConfig, masks: Optional[Dict[int, jnp.ndarray]],
                       split: int) -> Optional[np.ndarray]:
    """Surviving-unit indices along the LAST axis of the activation that
    crosses split point ``split`` (the output of layer split-1) under masked
    execution, or None when every unit is live.

    Mirrors ``compact_params``'s carry logic: relu/pool inherit the
    producing conv's channel mask, flatten expands it across spatial
    positions, and an *unmasked* conv/dense mixes all inputs so nothing is
    provably zero afterwards. Feeds the codec's channel packing — only
    these slices need to cross the wire.
    """
    if split <= 0 or not masks:
        return None
    shapes = layer_shapes(cfg)
    carry: Optional[np.ndarray] = None
    for i in range(split):
        spec = cfg.layers[i]
        if spec.kind in ("conv", "dense"):
            carry = (np.nonzero(np.asarray(masks[i]) > 0)[0]
                     if i in masks else None)
        elif spec.kind == "flatten" and carry is not None:
            c, h, w = shapes[i - 1]
            carry = (np.arange(h * w)[:, None] * c
                     + carry[None, :]).reshape(-1)
    if carry is None:
        return None
    # layer_shapes stores (C, H, W) for spatial layers and (F,) for flat
    # ones; the runtime NHWC tensor's last axis is C (resp. F) either way.
    n_full = shapes[split - 1][0]
    return None if carry.size == n_full else carry


def compact_params(params, cfg: CNNConfig, masks: Dict[int, jnp.ndarray]):
    """Physically remove pruned channels (deployment-time compaction).

    Returns (new_params, new_cfg) where conv out_channels / dense features
    are shrunk to the surviving counts and downstream input dims follow.
    Conv->flatten->dense transitions expand the conv-channel mask across the
    spatial positions of the flattened activation.
    """
    shapes = layer_shapes(cfg)
    new_specs = list(cfg.layers)
    new_params = {k: dict(v) for k, v in params.items()}
    # keep-index per producing layer
    carry: Optional[jnp.ndarray] = None    # input-dim keep indices
    for i, spec in enumerate(cfg.layers):
        if spec.kind == "conv":
            p = new_params[f"l{i}"]
            w = p["w"]
            if carry is not None:
                w = w[:, :, carry, :]
            if i in masks:
                keep = jnp.nonzero(masks[i] > 0)[0]
            else:
                keep = jnp.arange(w.shape[-1])
            new_params[f"l{i}"] = {"w": w[..., keep], "b": p["b"][keep]}
            new_specs[i] = ConvLayerSpec("conv", out_channels=int(keep.size),
                                         kernel=spec.kernel,
                                         stride=spec.stride,
                                         padding=spec.padding)
            carry = keep
        elif spec.kind == "flatten":
            if carry is not None:
                c, h, w_ = shapes[i - 1]
                # NHWC flatten: index = (h*W + w)*C + c
                hw = h * w_
                grid = (jnp.arange(hw)[:, None] * c + carry[None, :]).reshape(-1)
                carry = grid
        elif spec.kind == "dense":
            p = new_params[f"l{i}"]
            w = p["w"]
            if carry is not None:
                w = w[carry, :]
            if i in masks:
                keep = jnp.nonzero(masks[i] > 0)[0]
            else:
                keep = jnp.arange(w.shape[-1])
            new_params[f"l{i}"] = {"w": w[:, keep], "b": p["b"][keep]}
            new_specs[i] = ConvLayerSpec("dense", features=int(keep.size))
            carry = keep if i in masks else None
    import dataclasses as _dc
    return new_params, _dc.replace(cfg, layers=tuple(new_specs))
