"""Composable decoder/encoder stack covering every assigned family:

  dense   — pre-norm GQA + (gated/non-gated) FFN           [gemma, qwen*, nemotron]
  moe     — GQA or MLA attention + sort-dispatch MoE FFN   [mixtral, deepseek-v3]
  ssm     — Mamba2 (SSD) blocks, attention-free            [mamba2]
  hybrid  — Mamba2 backbone + one SHARED attention block
            applied every ``shared_attn_period`` layers    [zamba2]
  audio   — bidirectional encoder over precomputed frame
            embeddings (stubbed conv frontend)             [hubert]
  vlm     — dense decoder with M-RoPE; vision patch
            embeddings (stubbed ViT) prefix the text       [qwen2-vl]

Layer stacks are grouped into homogeneous *runs* and executed with
``lax.scan`` over stacked per-layer weights: compile cost is O(1) in depth,
which keeps 96-layer dry-run compiles tractable and the production HLO
small. Hybrid stacks scan over (period)-sized groups — inner scan over the
Mamba2 layers of a group, then the shared attention block — so per-group
shared-KV caches have static shapes.

Three execution entry points, all cache-consistent with each other (tested):
  forward      — full sequence, logits for every position (train)
  prefill      — full sequence, last-position logits + decode-ready cache
  decode_step  — one token against the cache

Pruning integration (the paper's technique): ``masks`` mirrors the runs
structure with per-layer structured masks — attention ``head_mask``, FFN
``ffn_mask``, MoE ``expert_mask``, SSD ``ssm_head_mask``.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ssm as ssm_lib
from repro.models.layers.attention import (KVCache, MLACache, gqa_decode,
                                           gqa_forward, init_gqa_params,
                                           init_kv_cache, init_mla_cache,
                                           init_mla_params, mla_decode,
                                           mla_forward)
from repro.models.layers.mlp import init_mlp_params, mlp_forward
from repro.models.layers.moe import init_moe_params, moe_forward
from repro.models.layers.norms import rmsnorm
from repro.models.layers.rope import (mrope_angles, positions_for,
                                      rope_angles, text_mrope_positions)


# ---------------------------------------------------------------------------
# run grouping
# ---------------------------------------------------------------------------
class Run(NamedTuple):
    kind: str      # attn | attn_dense | moe | ssm
    start: int
    count: int


def layer_runs(cfg: ModelConfig) -> List[Run]:
    kinds = cfg.layer_kinds()
    runs: List[Run] = []
    for i, k in enumerate(kinds):
        if runs and runs[-1].kind == k:
            runs[-1] = Run(k, runs[-1].start, runs[-1].count + 1)
        else:
            runs.append(Run(k, i, 1))
    return runs


def hybrid_split(cfg: ModelConfig, count: int) -> Tuple[int, int]:
    """(n_groups, tail) for a hybrid run of ``count`` ssm layers."""
    period = cfg.shared_attn_period
    return count // period, count % period


def _maybe_scan(cfg, body, carry, xs):
    """lax.scan, or an unrolled python loop when cfg.scan_layers=False.

    Unrolling exists for the dry-run: XLA's HloCostAnalysis counts a
    while-loop body once regardless of trip count, so roofline numbers must
    come from straight-line HLO. Results are identical either way (tested).
    """
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        inp = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body(carry, inp)
        ys.append(y)
    if not ys or all(
            not jax.tree_util.tree_leaves(y) for y in ys):
        # preserve the ys tree structure (all-None) for caller unpacking
        return carry, (ys[0] if ys else None)
    stacked = jax.tree_util.tree_map(lambda *zs: jnp.stack(zs), *ys)
    return carry, stacked


def _group_tree(tree, n_groups: int, period: int):
    main = jax.tree_util.tree_map(
        lambda a: a[:n_groups * period].reshape(
            (n_groups, period) + a.shape[1:]), tree)
    tail = jax.tree_util.tree_map(lambda a: a[n_groups * period:], tree)
    return main, tail


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_attn_layer(cfg: ModelConfig, dtype):
    def init(key):
        ks = jax.random.split(key, 4)
        if cfg.attention == "mla":
            att = init_mla_params(ks[0], cfg, dtype)
        else:
            att = init_gqa_params(ks[0], cfg, dtype)
        return {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "attn": att,
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "mlp": init_mlp_params(ks[1], cfg.d_model, cfg.d_ff,
                                   cfg.activation, dtype),
        }
    return init


def _init_moe_layer(cfg: ModelConfig, dtype):
    def init(key):
        ks = jax.random.split(key, 2)
        if cfg.attention == "mla":
            att = init_mla_params(ks[0], cfg, dtype)
        else:
            att = init_gqa_params(ks[0], cfg, dtype)
        return {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "attn": att,
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "moe": init_moe_params(ks[1], cfg.d_model, cfg.moe,
                                   cfg.activation, dtype),
        }
    return init


def _init_ssm_layer(cfg: ModelConfig, dtype):
    def init(key):
        return {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "ssm": ssm_lib.init_ssm_params(key, cfg, dtype),
        }
    return init


_RUN_INIT = {"attn": _init_attn_layer, "attn_dense": _init_attn_layer,
             "moe": _init_moe_layer, "ssm": _init_ssm_layer}


def init_params(cfg: ModelConfig, key) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    V = cfg.padded_vocab
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(keys[0], (V, cfg.d_model), jnp.float32)
                  * 0.02).astype(dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(
            keys[1], (cfg.d_model, V), jnp.float32)
            / math.sqrt(cfg.d_model)).astype(dtype)
    runs = layer_runs(cfg)
    run_keys = jax.random.split(keys[2], max(len(runs), 1))
    params["runs"] = []
    for r, rk in zip(runs, run_keys):
        layer_keys = jax.random.split(rk, r.count)
        params["runs"].append(jax.vmap(_RUN_INIT[r.kind](cfg, dtype))(layer_keys))
    if cfg.shared_attn_period:
        ks = jax.random.split(keys[3], 2)
        params["shared"] = {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "attn": init_gqa_params(ks[0], cfg, dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "mlp": init_mlp_params(ks[1], cfg.d_model,
                                   cfg.d_ff or 4 * cfg.d_model,
                                   cfg.activation, dtype),
        }
    if cfg.mtp_depth:
        ks = jax.random.split(keys[4], 2)
        mtp_cfg = (cfg.replace(attention="gqa") if cfg.attention == "mla"
                   else cfg)
        params["mtp"] = {
            "proj": (jax.random.normal(
                keys[5], (2 * cfg.d_model, cfg.d_model), jnp.float32)
                / math.sqrt(2 * cfg.d_model)).astype(dtype),
            "block": _init_attn_layer(mtp_cfg, dtype)(ks[0]),
            "ln": jnp.ones((cfg.d_model,), dtype),
        }
    return params


def param_count(params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# rope angles
# ---------------------------------------------------------------------------
def _rope_dim(cfg: ModelConfig) -> int:
    return (cfg.mla.qk_rope_head_dim if cfg.attention == "mla"
            else cfg.head_dim)


def _angles_for(cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
                B: int, S: int, offset=0):
    if cfg.rope_mode == "none":
        return None
    if cfg.rope_mode == "mrope":
        pos = batch.get("mrope_positions")
        if pos is None:
            pos = text_mrope_positions(B, S, offset)
        return mrope_angles(pos, _rope_dim(cfg), cfg.rope_theta,
                            cfg.mrope_sections)
    pos = positions_for(B, S, offset)
    pos = jnp.broadcast_to(pos, (B, S))
    return rope_angles(pos, _rope_dim(cfg), cfg.rope_theta)


# ---------------------------------------------------------------------------
# block bodies
# ---------------------------------------------------------------------------
def _attn_block(cfg, lp, x, angles, mask, collect_kv=False):
    head_mask = None if mask is None else mask.get("head_mask")
    ffn_mask = None if mask is None else mask.get("ffn_mask")
    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    if cfg.attention == "mla":
        a, kv = mla_forward(lp["attn"], cfg, h, angles, head_mask=head_mask)
    else:
        a, kv = gqa_forward(lp["attn"], cfg, h, angles, head_mask=head_mask)
    x = x + a
    h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    x = x + mlp_forward(lp["mlp"], h, cfg.activation, ffn_mask=ffn_mask)
    return (x, kv) if collect_kv else (x, None)


def _moe_block(cfg, lp, x, angles, mask, collect_kv=False):
    head_mask = None if mask is None else mask.get("head_mask")
    expert_mask = None if mask is None else mask.get("expert_mask")
    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    if cfg.attention == "mla":
        a, kv = mla_forward(lp["attn"], cfg, h, angles, head_mask=head_mask)
    else:
        a, kv = gqa_forward(lp["attn"], cfg, h, angles, head_mask=head_mask)
    x = x + a
    h = rmsnorm(x, lp["ln2"], cfg.norm_eps)
    m, metrics = moe_forward(lp["moe"], cfg.moe, h, cfg.activation,
                             expert_mask=expert_mask)
    return x + m, metrics, (kv if collect_kv else None)


def _ssm_block(cfg, lp, x, mask, collect_state=False):
    head_mask = None if mask is None else mask.get("ssm_head_mask")
    h = rmsnorm(x, lp["ln1"], cfg.norm_eps)
    if collect_state:
        o, st = ssm_lib.ssm_forward(lp["ssm"], cfg, h, head_mask=head_mask,
                                    return_state=True)
        return x + o, st
    return x + ssm_lib.ssm_forward(lp["ssm"], cfg, h, head_mask=head_mask), None


def _shared_block(cfg, sp, x, angles, collect_kv=False):
    h = rmsnorm(x, sp["ln1"], cfg.norm_eps)
    a, kv = gqa_forward(sp["attn"], cfg, h, angles)
    x = x + a
    h = rmsnorm(x, sp["ln2"], cfg.norm_eps)
    x = x + mlp_forward(sp["mlp"], h, cfg.activation)
    return (x, kv) if collect_kv else (x, None)


# ---------------------------------------------------------------------------
# embedding & head
# ---------------------------------------------------------------------------
def embed_inputs(params, cfg: ModelConfig, batch) -> Tuple[jnp.ndarray, int, int]:
    if cfg.embeds_input:                       # audio: stubbed conv frontend
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
        B, S = x.shape[:2]
    elif cfg.vision_tokens:                    # vlm: vision prefix + text
        tok = batch["tokens"]
        B = tok.shape[0]
        emb = params["embed"][tok]
        vis = batch["vision_embeds"].astype(emb.dtype)   # (B, V, d)
        x = jnp.concatenate([vis, emb], axis=1)
        S = x.shape[1]
    else:
        tok = batch["tokens"]
        B, S = tok.shape
        x = params["embed"][tok]
    if cfg.scale_embeddings:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    return x, B, S


def _lm_logits(params, cfg, x):
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = x @ head
    if cfg.logit_softcap:
        cap = cfg.logit_softcap
        logits = jnp.tanh(logits / cap) * cap
    return logits


# ---------------------------------------------------------------------------
# stack walker (shared by forward & prefill)
# ---------------------------------------------------------------------------
def _run_stack(params, cfg: ModelConfig, x, angles, masks, collect: bool):
    """Run all layer runs over x. Returns (x, aux, caches or None)."""
    runs = layer_runs(cfg)
    masks = masks if masks is not None else [None] * len(runs)
    aux = jnp.zeros((), jnp.float32)
    zl = jnp.zeros((), jnp.float32)
    caches: List[Any] = []
    shared_kv = None
    shared = params.get("shared")
    period = cfg.shared_attn_period

    for run, rp, rmask in zip(runs, params["runs"], masks):
        xs = (rp, rmask) if rmask is not None else (rp,)

        def unpack(inp):
            return inp if len(inp) == 2 else (inp[0], None)

        if run.kind in ("attn", "attn_dense"):
            def body(carry, inp):
                lp, mk = unpack(inp)
                h, kv = _attn_block(cfg, lp, carry, angles, mk,
                                    collect_kv=collect)
                return h, kv
            if cfg.remat:
                body = jax.checkpoint(body)
            x, kv = _maybe_scan(cfg, body, x, xs)
            caches.append(kv)
        elif run.kind == "moe":
            def body(carry, inp):
                lp, mk = unpack(inp)
                h, a, z = carry
                h, metrics, kv = _moe_block(cfg, lp, h, angles, mk,
                                            collect_kv=collect)
                return (h, a + metrics.aux_loss, z + metrics.z_loss), kv
            if cfg.remat:
                body = jax.checkpoint(body)
            (x, aux, zl), kv = _maybe_scan(cfg, body, (x, aux, zl), xs)
            caches.append(kv)
        elif run.kind == "ssm" and not period:
            def body(carry, inp):
                lp, mk = unpack(inp)
                h, st = _ssm_block(cfg, lp, carry, mk, collect_state=collect)
                return h, st
            if cfg.remat:
                body = jax.checkpoint(body)
            x, st = _maybe_scan(cfg, body, x, xs)
            caches.append(st)
        else:  # hybrid: groups of `period` ssm layers + shared attn block
            n_groups, tail = hybrid_split(cfg, run.count)
            xs_main, xs_tail = _group_tree(xs, n_groups, period)

            def inner(carry, inp):
                lp, mk = unpack(inp)
                h, st = _ssm_block(cfg, lp, carry, mk, collect_state=collect)
                return h, st

            def group_body(carry, ginp):
                h, st = _maybe_scan(cfg, inner, carry, ginp)
                h, kv = _shared_block(cfg, shared, h, angles,
                                      collect_kv=collect)
                return h, (st, kv)
            if cfg.remat:
                group_body = jax.checkpoint(group_body)
            if n_groups:
                x, (st_main, skv) = _maybe_scan(cfg, group_body, x, xs_main)
            else:
                st_main, skv = None, None
            st_tail = None
            if tail:
                inner_t = jax.checkpoint(inner) if cfg.remat else inner
                x, st_tail = _maybe_scan(cfg, inner_t, x, xs_tail)
            caches.append((st_main, st_tail))
            shared_kv = skv
    return x, {"moe_aux": aux, "moe_z": zl}, caches, shared_kv


# ---------------------------------------------------------------------------
# full-sequence forward (train)
# ---------------------------------------------------------------------------
def forward(params, cfg: ModelConfig, batch,
            masks: Optional[List[Optional[Dict[str, jnp.ndarray]]]] = None):
    x, B, S = embed_inputs(params, cfg, batch)
    angles = _angles_for(cfg, batch, B, S)
    x, aux, _, _ = _run_stack(params, cfg, x, angles, masks, collect=False)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = _lm_logits(params, cfg, x)
    return logits, {"moe_aux": aux["moe_aux"], "moe_z": aux["moe_z"],
                    "hidden": x}


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------
def softmax_xent(logits, labels, vocab_size=None):
    """Mean xent; labels < 0 are masked out. fp32 math."""
    logits = logits.astype(jnp.float32)
    mask = labels >= 0
    safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1)


def loss_fn(params, cfg: ModelConfig, batch, masks=None):
    logits, aux = forward(params, cfg, batch, masks)
    labels = batch["labels"]
    if cfg.vision_tokens:
        pad = -jnp.ones((labels.shape[0], cfg.vision_tokens), labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    loss = softmax_xent(logits, labels)
    total = loss + aux["moe_aux"] + aux["moe_z"]
    metrics = {"xent": loss, "moe_aux": aux["moe_aux"], "moe_z": aux["moe_z"]}
    if cfg.mtp_depth and "mtp" in params:
        h = aux["hidden"]
        emb_next = params["embed"][jnp.maximum(batch["tokens"], 0)]
        if cfg.scale_embeddings:
            emb_next = emb_next * jnp.asarray(
                math.sqrt(cfg.d_model), emb_next.dtype)
        if cfg.vision_tokens:
            h = h[:, cfg.vision_tokens:]
        hcat = jnp.concatenate(
            [h[:, :-1], emb_next[:, 1:]], axis=-1) @ params["mtp"]["proj"]
        B2, S2 = hcat.shape[:2]
        mtp_cfg = (cfg.replace(attention="gqa") if cfg.attention == "mla"
                   else cfg)
        if mtp_cfg.rope_mode == "mrope":
            mtp_cfg = mtp_cfg.replace(rope_mode="standard")
        ang = _angles_for(mtp_cfg, {}, B2, S2)
        hcat = _attn_block(mtp_cfg, params["mtp"]["block"], hcat, ang, None)[0]
        hcat = rmsnorm(hcat, params["mtp"]["ln"], cfg.norm_eps)
        mtp_logits = _lm_logits(params, cfg, hcat)
        lm_labels = batch["labels"]
        mtp_labels = jnp.pad(lm_labels[:, 2:], ((0, 0), (0, 1)),
                             constant_values=-1)[:, :S2]
        mtp_loss = softmax_xent(mtp_logits, mtp_labels)
        total = total + 0.1 * mtp_loss
        metrics["mtp"] = mtp_loss
    metrics["loss"] = total
    return total, metrics


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------
def cache_len_for(cfg: ModelConfig, max_len: int) -> int:
    if cfg.sliding_window is not None:
        return min(max_len, cfg.sliding_window)
    return max_len


def _stack_zeros(c, n):
    return jax.tree_util.tree_map(
        lambda a: jnp.zeros((n,) + a.shape, a.dtype), c)


def init_cache(cfg: ModelConfig, batch_size: int, max_len: int):
    dtype = jnp.dtype(cfg.dtype)
    runs = layer_runs(cfg)
    clen = cache_len_for(cfg, max_len)
    period = cfg.shared_attn_period
    caches = []
    for run in runs:
        if run.kind == "ssm":
            base = ssm_lib.init_ssm_cache(cfg, batch_size, dtype)
            if period:
                n_groups, tail = hybrid_split(cfg, run.count)
                main = jax.tree_util.tree_map(
                    lambda a: jnp.zeros((n_groups, period) + a.shape,
                                        a.dtype), base)
                tl = _stack_zeros(base, tail) if tail else None
                caches.append((main, tl))
            else:
                caches.append(_stack_zeros(base, run.count))
        elif cfg.attention == "mla":
            caches.append(_stack_zeros(
                init_mla_cache(batch_size, max_len, cfg.mla, dtype),
                run.count))
        else:
            caches.append(_stack_zeros(
                init_kv_cache(batch_size, clen, cfg.num_kv_heads,
                              cfg.head_dim, dtype), run.count))
    out = {"runs": caches, "pos": jnp.zeros((batch_size,), jnp.int32)}
    if period:
        ninv = cfg.num_layers // period
        out["shared"] = _stack_zeros(
            init_kv_cache(batch_size, max_len, cfg.num_kv_heads,
                          cfg.head_dim, dtype), max(ninv, 1))
    return out


# ---------------------------------------------------------------------------
# prefill: full sequence -> (last logits, decode-ready cache)
# ---------------------------------------------------------------------------
def _kv_to_cache(cfg, k, v, max_len):
    """k/v (..., S, Hkv, D) -> rolling/padded cache of cache_len_for()."""
    S = k.shape[-3]
    clen = cache_len_for(cfg, max_len)
    if clen == S:
        return k, v
    if clen < S and cfg.sliding_window is None:
        raise ValueError(
            f"prefill max_len={max_len} < prefill length {S} "
            "(remember vision/audio prefix tokens count toward max_len)")
    if clen < S:     # sliding window rolling buffer: slot = pos % clen
        k = jnp.roll(k[..., S - clen:, :, :], S % clen, axis=-3)
        v = jnp.roll(v[..., S - clen:, :, :], S % clen, axis=-3)
        return k, v
    pad = [(0, 0)] * (k.ndim - 3) + [(0, clen - S), (0, 0), (0, 0)]
    return jnp.pad(k, pad), jnp.pad(v, pad)


def prefill(params, cfg: ModelConfig, batch, max_len: Optional[int] = None,
            masks=None):
    """Returns (last_logits (B,V), cache) — or (all_logits, None) for
    encoder-only configs (no decode)."""
    x, B, S = embed_inputs(params, cfg, batch)
    angles = _angles_for(cfg, batch, B, S)
    max_len = max_len or S
    x, _, raw, shared_kv = _run_stack(params, cfg, x, angles, masks,
                                      collect=True)
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if not cfg.causal:
        return _lm_logits(params, cfg, x), None
    logits = _lm_logits(params, cfg, x[:, -1])

    runs = layer_runs(cfg)
    caches = []
    for run, rc in zip(runs, raw):
        if run.kind == "ssm":
            if cfg.shared_attn_period:
                caches.append(rc)     # ((groups, period, ...), tail)
            else:
                caches.append(rc)
        elif cfg.attention == "mla":
            ckv, krope = rc           # (count, B, S, rank/ropedim)
            clen = max_len
            if clen > S:
                pad = [(0, 0), (0, 0), (0, clen - S), (0, 0)]
                ckv, krope = jnp.pad(ckv, pad), jnp.pad(krope, pad)
            caches.append(MLACache(ckv, krope))
        else:
            k, v = rc                 # (count, B, S, Hkv, D)
            k, v = _kv_to_cache(cfg, k, v, max_len)
            caches.append(KVCache(k, v))
    cache = {"runs": caches,
             "pos": jnp.full((B,), S, jnp.int32)}
    if cfg.shared_attn_period:
        k, v = shared_kv              # (ninv, B, S, Hkv, D)
        if max_len > S:
            pad = [(0, 0)] * (k.ndim - 3) + [(0, max_len - S), (0, 0),
                                             (0, 0)]
            k, v = jnp.pad(k, pad), jnp.pad(v, pad)
        cache["shared"] = KVCache(k, v)
    return logits, cache


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def decode_step(params, cfg: ModelConfig, cache, tokens,
                masks: Optional[List] = None):
    """tokens (B,1) int32 -> (logits (B,V), new cache)."""
    pos = cache["pos"]
    B = tokens.shape[0]
    x = params["embed"][tokens[:, 0]][:, None]
    if cfg.scale_embeddings:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if cfg.rope_mode == "mrope":
        p3 = jnp.broadcast_to(pos[None, :, None], (3, B, 1))
        angles = mrope_angles(p3, _rope_dim(cfg), cfg.rope_theta,
                              cfg.mrope_sections)
    elif cfg.rope_mode == "none":
        angles = None
    else:
        angles = rope_angles(pos[:, None], _rope_dim(cfg), cfg.rope_theta)

    runs = layer_runs(cfg)
    masks = masks if masks is not None else [None] * len(runs)
    new_caches = []
    shared = params.get("shared")
    period = cfg.shared_attn_period
    new_shared = cache.get("shared")

    def unpack(inp, n):
        return (inp[:n], inp[n] if len(inp) > n else None)

    for run, rp, rc, rmask in zip(runs, params["runs"], cache["runs"], masks):
        if run.kind == "ssm" and period:
            n_groups, tail = hybrid_split(cfg, run.count)
            rc_main, rc_tail = rc
            xs_p, xs_t = _group_tree(
                (rp, rmask) if rmask is not None else (rp,), n_groups, period)

            def inner(carry, inp):
                (lp, lc), mk = unpack(inp, 2)
                hm = None if mk is None else mk.get("ssm_head_mask")
                hn = rmsnorm(carry, lp["ln1"], cfg.norm_eps)
                o, nc = ssm_lib.ssm_decode(lp["ssm"], cfg, hn,
                                           ssm_lib.SSMCache(*lc),
                                           head_mask=hm)
                return carry + o, nc

            def group_body(carry, ginp):
                h, skv, g = carry
                gp_and_mask, glc = ginp[:-1], ginp[-1]
                inner_xs = (gp_and_mask[0], glc) + (
                    (gp_and_mask[1],) if len(gp_and_mask) > 1 else ())
                # reorder xs for inner: (lp, lc, mk?)
                h, nc = _maybe_scan(cfg, inner, h, inner_xs)
                kv_g = jax.tree_util.tree_map(lambda a: a[g], skv)
                hn = rmsnorm(h, shared["ln1"], cfg.norm_eps)
                a, kv_new = gqa_decode(shared["attn"], cfg, hn, angles,
                                       KVCache(*kv_g), pos)
                h = h + a
                hn = rmsnorm(h, shared["ln2"], cfg.norm_eps)
                h = h + mlp_forward(shared["mlp"], hn, cfg.activation)
                skv = jax.tree_util.tree_map(
                    lambda full, new, idx=g: full.at[idx].set(new),
                    skv, kv_new)
                return (h, skv, g + 1), nc

            if n_groups:
                (x, new_shared, _), nc_main = jax.lax.scan(
                    group_body, (x, new_shared, 0), xs_p + (rc_main,))
            else:
                nc_main = None
            nc_tail = None
            if tail:
                t_xs = (xs_t[0], rc_tail) + ((xs_t[1],) if len(xs_t) > 1
                                             else ())
                x, nc_tail = _maybe_scan(cfg, inner, x, t_xs)
            new_caches.append((nc_main, nc_tail))
        elif run.kind == "ssm":
            def body(carry, inp):
                (lp, lc), mk = unpack(inp, 2)
                hm = None if mk is None else mk.get("ssm_head_mask")
                hn = rmsnorm(carry, lp["ln1"], cfg.norm_eps)
                o, nc = ssm_lib.ssm_decode(lp["ssm"], cfg, hn,
                                           ssm_lib.SSMCache(*lc),
                                           head_mask=hm)
                return carry + o, nc
            xs = (rp, rc) + ((rmask,) if rmask is not None else ())
            x, nc = _maybe_scan(cfg, body, x, xs)
            new_caches.append(nc)
        else:
            is_moe = run.kind == "moe"

            def body(carry, inp):
                (lp, lc), mk = unpack(inp, 2)
                hm = None if mk is None else mk.get("head_mask")
                h = rmsnorm(carry, lp["ln1"], cfg.norm_eps)
                if cfg.attention == "mla":
                    a, nc = mla_decode(lp["attn"], cfg, h, angles,
                                       MLACache(*lc), pos, head_mask=hm)
                else:
                    a, nc = gqa_decode(lp["attn"], cfg, h, angles,
                                       KVCache(*lc), pos, head_mask=hm)
                h = carry + a
                hn = rmsnorm(h, lp["ln2"], cfg.norm_eps)
                if is_moe:
                    em = None if mk is None else mk.get("expert_mask")
                    m, _ = moe_forward(lp["moe"], cfg.moe, hn, cfg.activation,
                                       expert_mask=em)
                else:
                    fm = None if mk is None else mk.get("ffn_mask")
                    m = mlp_forward(lp["mlp"], hn, cfg.activation,
                                    ffn_mask=fm)
                return h + m, nc

            xs = (rp, rc) + ((rmask,) if rmask is not None else ())
            x, nc = _maybe_scan(cfg, body, x, xs)
            new_caches.append(nc)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = _lm_logits(params, cfg, x[:, 0])
    new_cache = dict(cache, runs=new_caches, pos=pos + 1)
    if period:
        new_cache["shared"] = new_shared
    return logits, new_cache
