"""``DeploymentPlan`` — the serializable deployment contract (paper §3.3).

The paper's deployment is one logical object: a (possibly pruned) model, a
split point, and a wire encoding shared by an edge and a cloud peer. This
module captures that object as a single artifact instead of loose
positional knobs smeared across constructors:

  * ``DeploymentPlan.from_pipeline(result)`` packages what
    ``run_paper_pipeline`` produced (fine-tuned params, masks, re-priced
    deploy split, codec, hardware profile);
  * ``DeploymentPlan.from_args(...)`` builds one from explicit pieces,
    auto-picking the greedy split when ``split=None``;
  * ``save(dir)`` / ``DeploymentPlan.load(dir)`` persist the plan — params
    through ``repro.checkpoint.store`` (.npz + treedef JSON), masks as an
    .npz, and the contract as ``plan.json`` — so a plan exported once can
    be deployed anywhere with no access to the original pipeline objects;
  * ``plan.digest`` is a stable hash of the *contract* (architecture,
    split, masks, compact, codec, pack, version, adaptive section): the
    HELLO handshake compares the two peers' digests on connect and
    rejects a mismatch before any feature tensor is exchanged. Weights
    are deliberately not part of the digest — a weight mismatch yields
    wrong predictions, not undecodable tensors; the digest guards the
    frame/shape contract.

**Adaptive plans**: setting ``adaptive=AdaptivePolicy(candidates=...)``
declares the deployment *re-plannable* — both peers pre-arm jitted
sub-models for every candidate split (``SplitFnBank``), the session
estimates the live uplink bandwidth from each request's
``tx_bytes``/``t_tx``, re-runs the Eq. 5 greedy sweep on the measured
link, and switches the split through the RESPLIT control frame without
reconnecting (hysteresis + dwell guard against flapping; see
``repro.core.collab.adaptive``). The adaptive section is folded into the
digest — the candidate set is part of the contract, since the cloud must
be willing to serve any split the edge may announce. Plans without an
``adaptive`` section keep their pre-adaptive digests. Time-varying link
*traces* (``repro.core.partition.profiles.LinkTrace``) are an
environment/simulation knob, not part of the contract: pass them to the
session/server (``connect(plan, trace=...)``), not the plan.

**Batched plans**: setting ``batching=BatchingPolicy(...)`` arms the
cloud peer's cross-client dynamic batching engine
(``repro.core.collab.batching``): connection handlers submit decoded
feature tensors to per-lane queues (keyed by split x wire encoding),
a scheduler fuses concurrent requests within ``max_wait_ms`` up to
``max_batch`` rows, pads to power-of-two bucket shapes to bound
recompilation, and answers each fused batch with ONE jitted cloud call —
logits bit-identical to sequential serving. Like ``adaptive``, the
``batching`` section is folded into the digest **only when set** (plans
without one keep their pre-batching digests): the bucket/warm set and
the server's in-order response pipelining are deployment-contract-level
behaviour both peers arm for (the edge's pipelined ``infer_many``
assumes a server that reads ahead while batches are in flight).

**Energy-metered plans**: setting ``energy=EnergyPolicy(profile=...)``
attaches the edge device's power model
(``repro.core.partition.energy_model``) to the deployment: every
session result reports ``e_edge_j`` (joules the edge spent on that
request) next to ``t_total``/``tx_bytes``, ``from_args(split=None)``
picks the split by the weighted latency·energy objective instead of raw
Eq. 5 latency, and — on an adaptive plan — a ``battery_j`` budget makes
the controller walk the partition toward the low-energy splits as the
budget drains. Like ``adaptive``/``batching``, the ``energy`` section
is folded into the digest **only when set** (un-metered plans keep
their digests): metering changes which split both peers deploy and may
re-plan to, so peers must agree on it.

**Fault-tolerant plans**: setting ``faults=FaultPolicy(...)`` arms the
recovery machinery (``repro.core.collab.faults``): the edge client
applies the per-request deadline to every socket read (a dead cloud
raises ``RequestTimeout`` instead of hanging), retries transient
failures with exponential backoff + deterministic jitter (reconnect,
re-HELLO, re-RESPLIT to the controller's current split, replay by
sequence number), and — when the retry budget exhausts and
``fallback="edge"`` — serves the request locally from the bank's c=N
pair, bit-identical to an all-edge deployment; the cloud reaps clients
silent for ``3 * heartbeat_s``. Like the other optional sections,
``faults`` folds into the digest **only when set**, so pre-fault plans
keep their digests byte-for-byte.

**Fleet-routed plans**: setting ``routing=RoutingPolicy(ports=...)``
declares the cloud tier to be a *fleet* of servers instead of one: the
socket backend builds a ``FleetRouter`` (``repro.core.collab.cluster``)
that rendezvous-hashes the edge's wire-lane key over the member ports
(batching lanes stay hot on one server), tracks member health from
transport outcomes (miss-count → suspect → dead), reroutes the recovery
loop to the next healthy member on server death, migrates on DRAIN
(rolling restart, zero failed requests), redirects on BUSY
(bounded-lane backpressure), and degrades to edge-only inference only
when the whole fleet is gone. Folded into the digest **only when set**
(single-server plans keep their digests): both peers must agree on the
membership for the reroute-then-replay contract to hold.

**Fleet plans**: setting ``fleet=FleetScenario(...)`` attaches the
simulated deployment context (``repro.core.fleet``) the plan is being
evaluated for: fleet size, heterogeneous device/trace mixes, battery
budgets, SLO classes, and the cloudlet tier's shape. The section is
descriptive — it configures the fleet simulator, not the socket peers —
but it follows the same only-when-set digest rule as the other
sections: a plan exported *for* a specific fleet study pins that
scenario in its contract (so two artifacts claiming the same study are
comparable), while plans without one keep their digests byte-for-byte.

**Quantized-edge plans**: setting ``quant=QuantPolicy(...)`` switches
the EDGE submodel's conv/dense layers onto the masked-GEMM kernel path
(``repro.core.collab.quant``): the deployed (post-compaction) weights
are affine-quantized per output channel to int8/int4 (or kept fp32
with ``weight_bits=None`` — kernel dispatch only), and every
``SplitFnBank`` edge closure — across all three backends, every
candidate split, the batched row-mapped variants, and the edge-only
fault fallback — runs the quantized kernel forward while cloud halves
stay fp32 dense. Folded into the digest **only when set** (un-quantized
plans keep their digests byte-for-byte): the edge's numerics are part
of what both peers deploy and compare golden logits against. See
``docs/quantized-edge.md`` for the error-bound and dispatch contracts.

Serve a plan through ``repro.serving.connect`` (see ``session.py``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

import jax
import numpy as np

from repro.checkpoint import store
from repro.configs.base import CNNConfig, ConvLayerSpec
from repro.core.collab.adaptive import AdaptivePolicy
from repro.core.collab.batching import BatchingPolicy
from repro.core.collab.cluster import RoutingPolicy
from repro.core.collab.faults import FaultPolicy
from repro.core.collab.protocol import CODEC_TX_SCALE
from repro.core.collab.quant import QuantPolicy
from repro.core.fleet.scenario import FleetScenario
from repro.core.partition.energy_model import EnergyPolicy
from repro.core.partition.latency_model import (cnn_input_bytes,
                                                cnn_layer_costs,
                                                compacted_cnn_layer_costs,
                                                wire_tx_scale)
from repro.core.partition.profiles import (ComputeProfile, LinkProfile,
                                           PAPER_PROFILE, TwoTierProfile)
from repro.core.partition.splitter import energy_aware_split, greedy_split
from repro.models.cnn import init_cnn_params

PLAN_VERSION = 1


def _cfg_to_json(cfg: CNNConfig) -> Dict[str, Any]:
    d = dataclasses.asdict(cfg)
    d["layers"] = [dataclasses.asdict(s) for s in cfg.layers]
    return d


def _cfg_from_json(d: Dict[str, Any]) -> CNNConfig:
    layers = tuple(ConvLayerSpec(**s) for s in d["layers"])
    return CNNConfig(**{**d, "layers": layers,
                        "input_hw": tuple(d["input_hw"])})


def _profile_to_json(p: TwoTierProfile) -> Dict[str, Any]:
    return {"device": dataclasses.asdict(p.device),
            "server": dataclasses.asdict(p.server),
            "link": dataclasses.asdict(p.link)}


def _profile_from_json(d: Dict[str, Any]) -> TwoTierProfile:
    return TwoTierProfile(ComputeProfile(**d["device"]),
                          ComputeProfile(**d["server"]),
                          LinkProfile(**d["link"]))


@dataclass
class DeploymentPlan:
    """One deployment contract: model + split + wire encoding + link.

    ``cfg``/``params``/``masks`` are the *logical* (pre-compaction)
    network; ``compact=True`` materializes the masks at deploy time on
    both peers (``deploy_submodels``). ``codec``/``pack`` pick the wire
    encoding of the split-boundary feature tensor. ``profile`` is the
    two-tier hardware model used for analytic timing (local backend) and
    socket shaping; ``host``/``port``/``connect_timeout_s``/``shape_link``
    are the transport (link) section.
    """
    cfg: CNNConfig
    params: Dict
    split: int
    masks: Optional[Dict[int, np.ndarray]] = None
    compact: bool = False
    codec: str = "fp32"
    pack: bool = False
    profile: TwoTierProfile = PAPER_PROFILE
    host: str = "127.0.0.1"
    port: int = 29500
    connect_timeout_s: float = 30.0
    shape_link: bool = True
    adaptive: Optional[AdaptivePolicy] = None
    batching: Optional[BatchingPolicy] = None
    energy: Optional[EnergyPolicy] = None
    faults: Optional[FaultPolicy] = None
    fleet: Optional[FleetScenario] = None
    routing: Optional[RoutingPolicy] = None
    quant: Optional[QuantPolicy] = None
    version: int = PLAN_VERSION

    def __post_init__(self) -> None:
        n = len(self.cfg.layers)
        if not 0 <= self.split <= n:
            raise ValueError(f"split {self.split} outside [0, {n}]")
        if self.codec not in CODEC_TX_SCALE:
            raise ValueError(f"unknown codec {self.codec!r} "
                             f"(use {list(CODEC_TX_SCALE)})")
        if self.compact and not self.masks:
            raise ValueError("compact=True requires pruning masks "
                             "(a dense model has nothing to compact)")
        if self.masks is not None:
            self.masks = {int(i): np.asarray(m) for i, m in
                          sorted(self.masks.items())}
        if self.adaptive is not None:
            cands = sorted({int(c) for c in self.adaptive.candidates}
                           | {self.split})
            bad = [c for c in cands if not 0 <= c <= n]
            if bad:
                raise ValueError(f"adaptive candidates {bad} outside "
                                 f"[0, {n}]")
            # normalized: sorted, unique, always containing the initial
            # split (so the controller's current point stays sweepable)
            self.adaptive = dataclasses.replace(self.adaptive,
                                                candidates=tuple(cands))

    # -- constructors -------------------------------------------------------
    @classmethod
    def from_args(cls, params, cfg: CNNConfig, split: Optional[int] = None,
                  *, masks=None, compact: bool = False, codec: str = "fp32",
                  pack: bool = False,
                  profile: TwoTierProfile = PAPER_PROFILE,
                  **transport) -> "DeploymentPlan":
        """Build a plan from explicit pieces. ``split=None`` runs the
        greedy split sweep (Algorithm 1) on the deployed shapes —
        compacted when ``compact``, masked otherwise — with the true wire
        cost per candidate priced in (``wire_tx_scale``: codec bytes per
        element x channel packing, the same model the runtimes and the
        adaptive controller use). On a plan with an ``energy`` section
        the auto-pick minimizes that policy's weighted latency·energy
        objective instead of raw latency (identical splits when the
        energy weight is 0)."""
        if split is None:
            deploy_compact = compact and bool(masks)
            costs = (compacted_cnn_layer_costs(cfg, masks)
                     if deploy_compact else cnn_layer_costs(cfg, masks))
            scale = lambda c: wire_tx_scale(    # noqa: E731
                cfg, masks, c, codec=codec, pack=pack,
                compact=deploy_compact)
            energy = transport.get("energy")
            if energy is not None:
                split = energy_aware_split(
                    costs, profile, cnn_input_bytes(cfg), energy,
                    tx_scale=scale).split_point
            else:
                split = greedy_split(costs, profile, cnn_input_bytes(cfg),
                                     tx_scale=scale).split_point
        return cls(cfg=cfg, params=params, split=int(split), masks=masks,
                   compact=compact, codec=codec, pack=pack, profile=profile,
                   **transport)

    @classmethod
    def from_pipeline(cls, result, *, compact: bool = True,
                      codec: Optional[str] = None,
                      **transport) -> "DeploymentPlan":
        """Package a ``PaperPipelineResult``: fine-tuned params + masks,
        the stage-6 re-priced deploy split (falling back to the stage-5
        split for non-compact deployment), and the pipeline's profile."""
        compact = compact and bool(result.masks)
        dec = (result.deploy_split
               if compact and result.deploy_split is not None
               else result.split)
        return cls.from_args(
            result.params, result.cfg, dec.split_point, masks=result.masks,
            compact=compact, codec=codec or result.deploy_codec,
            pack=not compact and bool(result.masks),
            profile=result.profile, **transport)

    # -- contract digest ----------------------------------------------------
    def contract(self) -> Dict[str, Any]:
        """What both peers must agree on for frames to decode correctly.

        The adaptive section is part of the contract (the cloud must be
        willing to serve any candidate split the edge may RESPLIT to),
        but the key is only present when set, so pre-adaptive plans keep
        their digests. The batching section follows the same rule: only
        present when set (pre-batching digests stable), and folded in
        because the bucket/warm set and the server's pipelined in-order
        response behaviour are part of what the peers arm for. The
        energy section likewise: only present when set (un-metered plans
        keep their digests), folded in because metering changes which
        split the deployment picks and may re-plan to under a battery
        budget. The faults section follows the same only-when-set rule
        (pre-fault plans keep their digests byte-for-byte): the retry /
        heartbeat / fallback contract changes how both peers behave on
        the wire — a heartbeat-reaping cloud against a non-heartbeating
        edge would sever healthy clients — so peers must agree on it.
        The routing section (fleet membership + health thresholds) is
        likewise only-when-set: single-server plans keep their digests,
        while fleet peers must agree on the member ring for the
        reroute-then-replay contract to hold."""
        masks = None
        if self.masks:
            masks = {str(i): np.nonzero(np.asarray(m) > 0)[0].tolist()
                     for i, m in self.masks.items()}
        doc = {"version": self.version, "cfg": _cfg_to_json(self.cfg),
               "split": self.split, "masks": masks,
               "compact": self.compact, "codec": self.codec,
               "pack": self.pack}
        if self.adaptive is not None:
            doc["adaptive"] = self.adaptive.to_json()
        if self.batching is not None:
            doc["batching"] = self.batching.to_json()
        if self.energy is not None:
            doc["energy"] = self.energy.to_json()
        if self.faults is not None:
            doc["faults"] = self.faults.to_json()
        if self.fleet is not None:
            doc["fleet"] = self.fleet.to_json()
        if self.routing is not None:
            doc["routing"] = self.routing.to_json()
        if self.quant is not None:
            doc["quant"] = self.quant.to_json()
        return doc

    @property
    def digest(self) -> str:
        blob = json.dumps(self.contract(), sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()[:16]

    # -- persistence --------------------------------------------------------
    def save(self, path: str) -> str:
        """Write the plan into directory ``path`` (created if missing):
        ``plan.json`` + ``params.npz``/``params.json`` (checkpoint.store)
        + ``masks.npz``. Returns ``path``."""
        os.makedirs(path, exist_ok=True)
        store.save(os.path.join(path, "params"), self.params,
                   metadata={"digest": self.digest})
        if self.masks:
            np.savez(os.path.join(path, "masks.npz"),
                     **{str(i): np.asarray(m)
                        for i, m in self.masks.items()})
        doc = {"version": self.version, "digest": self.digest,
               "cfg": _cfg_to_json(self.cfg), "split": self.split,
               "compact": self.compact, "codec": self.codec,
               "pack": self.pack, "profile": _profile_to_json(self.profile),
               "link": {"host": self.host, "port": self.port,
                        "connect_timeout_s": self.connect_timeout_s,
                        "shape_link": self.shape_link},
               "adaptive": (self.adaptive.to_json()
                            if self.adaptive else None),
               "batching": (self.batching.to_json()
                            if self.batching else None),
               "energy": (self.energy.to_json()
                          if self.energy else None),
               "faults": (self.faults.to_json()
                          if self.faults else None),
               "fleet": (self.fleet.to_json()
                         if self.fleet else None),
               "routing": (self.routing.to_json()
                           if self.routing else None),
               "quant": (self.quant.to_json()
                         if self.quant else None),
               "has_masks": bool(self.masks)}
        with open(os.path.join(path, "plan.json"), "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
        return path

    @classmethod
    def load(cls, path: str) -> "DeploymentPlan":
        """Reconstruct a saved plan; verifies the stored digest still
        matches the reconstructed contract (catches version drift or a
        hand-edited plan.json)."""
        with open(os.path.join(path, "plan.json")) as f:
            doc = json.load(f)
        cfg = _cfg_from_json(doc["cfg"])
        template = init_cnn_params(jax.random.PRNGKey(0), cfg)
        params = store.restore(os.path.join(path, "params"), template)
        masks = None
        if doc.get("has_masks"):
            with np.load(os.path.join(path, "masks.npz")) as data:
                masks = {int(k): data[k] for k in data.files}
        link = doc["link"]
        adaptive = (AdaptivePolicy.from_json(doc["adaptive"])
                    if doc.get("adaptive") else None)
        batching = (BatchingPolicy.from_json(doc["batching"])
                    if doc.get("batching") else None)
        energy = (EnergyPolicy.from_json(doc["energy"])
                  if doc.get("energy") else None)
        faults = (FaultPolicy.from_json(doc["faults"])
                  if doc.get("faults") else None)
        fleet = (FleetScenario.from_json(doc["fleet"])
                 if doc.get("fleet") else None)
        routing = (RoutingPolicy.from_json(doc["routing"])
                   if doc.get("routing") else None)
        quant = (QuantPolicy.from_json(doc["quant"])
                 if doc.get("quant") else None)
        plan = cls(cfg=cfg, params=params, split=doc["split"], masks=masks,
                   compact=doc["compact"], codec=doc["codec"],
                   pack=doc["pack"],
                   profile=_profile_from_json(doc["profile"]),
                   host=link["host"], port=link["port"],
                   connect_timeout_s=link["connect_timeout_s"],
                   shape_link=link["shape_link"], adaptive=adaptive,
                   batching=batching, energy=energy, faults=faults,
                   fleet=fleet, routing=routing, quant=quant,
                   version=doc["version"])
        if plan.digest != doc["digest"]:
            raise ValueError(
                f"plan digest mismatch after load: stored {doc['digest']}, "
                f"reconstructed {plan.digest} — the artifact was edited or "
                f"written by an incompatible plan version")
        return plan

    # -- convenience --------------------------------------------------------
    def describe(self) -> str:
        """One-line human summary of the deployment contract (digest,
        split, pruning, wire encoding, link endpoint, armed sections)."""
        n = len(self.cfg.layers)
        prune = (f"{len(self.masks)} masked layers" if self.masks
                 else "dense")
        adapt = (f", adaptive over {list(self.adaptive.candidates)}"
                 if self.adaptive else "")
        batch = (f", batched<= {self.batching.max_batch}"
                 f"@{self.batching.max_wait_ms}ms"
                 if self.batching else "")
        joule = ""
        if self.energy is not None:
            joule = (f", energy={self.energy.profile.name}"
                     f"@{self.energy.energy_weight_s_per_j:g}s/J")
            if self.energy.battery_j is not None:
                joule += f" battery={self.energy.battery_j:g}J"
        tol = (f", faults: retries<={self.faults.max_retries}"
               f" fallback={self.faults.fallback}"
               if self.faults else "")
        flt = (f", fleet={self.fleet.name}"
               f"({self.fleet.n_edges}x{self.fleet.n_cloudlets})"
               if self.fleet else "")
        rte = (f", routed over {len(self.routing.ports)} servers"
               if self.routing else "")
        qnt = (f", quant={self.quant.describe()}" if self.quant else "")
        return (f"DeploymentPlan[{self.digest}] {self.cfg.name}: "
                f"split c={self.split}/{n}, {prune}, "
                f"compact={self.compact}, codec={self.codec}"
                f"{'+packed' if self.pack and not self.compact else ''}, "
                f"link={self.host}:{self.port} "
                f"({self.profile.link.name})"
                f"{adapt}{batch}{joule}{tol}{flt}{rte}{qnt}")
