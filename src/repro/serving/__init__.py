"""``repro.serving`` — the deployment front door (one contract, any
backend).

The paper's deployment (§3.3/§4.3) is captured as a single serializable
artifact, ``DeploymentPlan`` (model + masks + split + wire codec + link),
and served through one session interface::

    from repro import serving

    plan = serving.DeploymentPlan.from_pipeline(run_paper_pipeline(...))
    plan.save("artifacts/deploy")                  # export once ...

    plan = serving.DeploymentPlan.load("artifacts/deploy")   # ... anywhere
    with serving.CloudServer(plan):                          # cloud peer
        with serving.connect(plan, backend="socket") as sess:  # edge peer
            out = sess.infer(image)                # {"logits", "t_edge", ...}

Backends: ``local`` (in-process CollabRunner), ``socket`` (real TCP
EdgeClient/serve_cloud with the HELLO digest handshake), ``streaming``
(3-stage pipelined runtime). All take the full deployment contract from
the plan and return the same result shape — ``t_*`` keys in seconds,
``tx_bytes`` in bytes, ``e_edge_j`` in joules.

Energy metering: attach ``EnergyPolicy(profile=MCU_ENERGY, ...)`` as the
plan's ``energy`` section to price every request's edge joules
(``e_edge_j`` in each result), pick the split by the weighted
latency·energy objective (``from_args(split=None)``), and — combined
with an ``adaptive`` section and a ``battery_j`` budget — have the
session re-split toward the low-energy end of the Pareto front as the
battery drains. See ``docs/architecture.md`` and
``docs/deployment-plan.md`` for the full serving contract.
"""
from repro.core.collab.adaptive import (AdaptivePolicy,
                                        AdaptiveSplitController,
                                        BandwidthEstimator, SplitSwitch)
from repro.core.collab.batching import BatchingPolicy, LaneStats
from repro.core.collab.protocol import PlanMismatchError
from repro.core.partition.energy_model import (ENERGY_PROFILES, MCU_ENERGY,
                                               PAPER_EDGE_ENERGY, PI_ENERGY,
                                               EnergyPolicy, EnergyProfile,
                                               RadioProfile, pareto_front)
from repro.core.partition.profiles import TRACES, LinkTrace, TraceSegment
from repro.serving.plan import PLAN_VERSION, DeploymentPlan
from repro.serving.session import (BACKENDS, CloudServer, InferenceSession,
                                   LocalSession, SocketSession,
                                   StreamingSession, connect, serve)

__all__ = [
    "BACKENDS", "PLAN_VERSION", "DeploymentPlan", "InferenceSession",
    "LocalSession", "SocketSession", "StreamingSession", "CloudServer",
    "PlanMismatchError", "connect", "serve",
    "AdaptivePolicy", "AdaptiveSplitController", "BandwidthEstimator",
    "SplitSwitch", "LinkTrace", "TraceSegment", "TRACES",
    "BatchingPolicy", "LaneStats",
    "EnergyPolicy", "EnergyProfile", "RadioProfile", "pareto_front",
    "ENERGY_PROFILES", "MCU_ENERGY", "PI_ENERGY", "PAPER_EDGE_ENERGY",
]
