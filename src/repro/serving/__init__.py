"""``repro.serving`` — the deployment front door (one contract, any
backend).

The paper's deployment (§3.3/§4.3) is captured as a single serializable
artifact, ``DeploymentPlan`` (model + masks + split + wire codec + link),
and served through one session interface::

    from repro import serving

    plan = serving.DeploymentPlan.from_pipeline(run_paper_pipeline(...))
    plan.save("artifacts/deploy")                  # export once ...

    plan = serving.DeploymentPlan.load("artifacts/deploy")   # ... anywhere
    with serving.CloudServer(plan):                          # cloud peer
        with serving.connect(plan, backend="socket") as sess:  # edge peer
            out = sess.infer(image)                # {"logits", "t_edge", ...}

Backends: ``local`` (in-process CollabRunner), ``socket`` (real TCP
EdgeClient/serve_cloud with the HELLO digest handshake), ``streaming``
(3-stage pipelined runtime). All take the full deployment contract from
the plan and return the same result shape — ``t_*`` keys in seconds,
``tx_bytes`` in bytes, ``e_edge_j`` in joules.

Energy metering: attach ``EnergyPolicy(profile=MCU_ENERGY, ...)`` as the
plan's ``energy`` section to price every request's edge joules
(``e_edge_j`` in each result), pick the split by the weighted
latency·energy objective (``from_args(split=None)``), and — combined
with an ``adaptive`` section and a ``battery_j`` budget — have the
session re-split toward the low-energy end of the Pareto front as the
battery drains.

Fault tolerance: attach ``FaultPolicy(...)`` as the plan's ``faults``
section to arm the recovery machinery — per-frame CRC + sequence
numbers (negotiated via the HELLO caps byte, so legacy peers still
interoperate), a per-request deadline (``RequestTimeout`` instead of a
hang on a dead cloud), retries with exponential backoff + jitter
(reconnect, re-HELLO, re-RESPLIT, replay by sequence), and edge-only
graceful degradation (bit-identical to an all-edge split) when the
budget exhausts. Deterministic fault *injection* for tests and
benchmarks comes from ``FaultSchedule``/``FaultInjector``
(``FAULT_SCHEDULES`` has the canned storms). See
``docs/architecture.md`` and ``docs/deployment-plan.md`` for the full
serving contract and ``docs/wire-protocol.md`` for the fault-tolerant
framing.

High availability: attach ``RoutingPolicy(ports=(...), ...)`` as the
plan's ``routing`` section to spread edges across a multi-server cloud
fleet — ``CloudFleet`` starts one ``CloudServer`` per member port, the
socket session's ``FleetRouter`` assigns each edge to a member by
rendezvous hashing over its wire-lane key (batching lanes stay hot on
one server), and the recovery ladder extends fleet-wide: a crashed
member's edges re-route to the next healthy server (``ServerDraining``
/ ``ServerBusy`` migrations spend no fault budget; bit-identical
logits), a rolling drain (the DRAIN frame) migrates with zero failed
requests, a saturated batching lane (``BatchingPolicy.max_queue``)
answers BUSY instead of stalling, and edge-only fallback engages only
when the whole fleet is gone (``FleetExhaustedError``).

Fleet studies: attach ``FleetScenario(...)`` as the plan's ``fleet``
section to pin the simulated deployment context — fleet size, device /
trace mixes, SLO classes (each an ``SLOClass`` over a ``FaultPolicy``),
battery budgets, diurnal ``ArrivalPattern``, cloudlet tier shape — and
run it with ``simulate_fleet`` (``repro.core.fleet``); see
``docs/fleet-sim.md``.
"""
from repro.core.collab.adaptive import (AdaptivePolicy,
                                        AdaptiveSplitController,
                                        BandwidthEstimator, SplitSwitch)
from repro.core.collab.batching import (BatchingPolicy, LaneSaturated,
                                        LaneStats)
from repro.core.collab.channel import FaultInjector
from repro.core.collab.cluster import (FleetExhaustedError, FleetRouter,
                                       RoutingPolicy)
from repro.core.collab.faults import (FaultPolicy, RequestTimeout,
                                      ServerBusy, ServerDraining,
                                      fault_record)
from repro.core.collab.protocol import (FrameIntegrityError,
                                        PlanMismatchError)
from repro.core.collab.quant import QuantPolicy
from repro.core.fleet import (ArrivalPattern, FleetScenario, FleetSimulator,
                              SLOClass, simulate_fleet)
from repro.core.partition.energy_model import (ENERGY_PROFILES, MCU_ENERGY,
                                               PAPER_EDGE_ENERGY, PI_ENERGY,
                                               EnergyPolicy, EnergyProfile,
                                               RadioProfile, pareto_front)
from repro.core.partition.profiles import (FAULT_SCHEDULES, TRACES,
                                           FaultEvent, FaultSchedule,
                                           LinkTrace, TraceSegment)
from repro.serving.plan import PLAN_VERSION, DeploymentPlan
from repro.serving.session import (BACKENDS, CloudFleet, CloudServer,
                                   InferenceSession, LocalSession,
                                   SocketSession, StreamingSession, connect,
                                   serve)

__all__ = [
    "BACKENDS", "PLAN_VERSION", "DeploymentPlan", "InferenceSession",
    "LocalSession", "SocketSession", "StreamingSession", "CloudServer",
    "CloudFleet", "PlanMismatchError", "connect", "serve",
    "AdaptivePolicy", "AdaptiveSplitController", "BandwidthEstimator",
    "SplitSwitch", "LinkTrace", "TraceSegment", "TRACES",
    "BatchingPolicy", "LaneStats", "LaneSaturated",
    "EnergyPolicy", "EnergyProfile", "RadioProfile", "pareto_front",
    "ENERGY_PROFILES", "MCU_ENERGY", "PI_ENERGY", "PAPER_EDGE_ENERGY",
    "FaultPolicy", "FaultSchedule", "FaultEvent", "FaultInjector",
    "RequestTimeout", "FrameIntegrityError", "fault_record",
    "FAULT_SCHEDULES",
    "RoutingPolicy", "FleetRouter", "FleetExhaustedError",
    "ServerDraining", "ServerBusy", "QuantPolicy",
    "ArrivalPattern", "FleetScenario", "FleetSimulator", "SLOClass",
    "simulate_fleet",
]
