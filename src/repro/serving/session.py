"""``InferenceSession`` — one session interface over three deployment
backends, all constructed from the same ``DeploymentPlan``:

  * ``connect(plan, backend="local")`` — in-process split executor
    (``CollabRunner``): real compute, byte-accurate simulated channel,
    analytic Eq. 5 timing. The default for benchmarks and quick checks.
  * ``connect(plan, backend="socket")`` — a real TCP edge client
    (``EdgeClient``) against a cloud peer started with ``serve(plan)`` /
    ``CloudServer(plan)``. The connection opens with the HELLO handshake:
    both peers must present the same plan digest or the session fails
    fast with ``PlanMismatchError``.
  * ``connect(plan, backend="streaming")`` — the 3-stage pipelined
    in-process runtime (``StreamingCollabRunner``) for overlapped
    service of request streams.

Every backend returns the same result shape from ``infer`` /
``infer_many``::

    {"logits": np.ndarray, "t_edge": float|None, "t_upstream": float|None,
     "t_total": float|None, "tx_bytes": int|None, "e_edge_j": float|None,
     "fault": {"faults": int, "retries": int, "migrations": int,
               "fallback": bool}}

with uniform key semantics across the three backends: ``t_*`` are
seconds, ``tx_bytes`` is bytes, ``e_*`` are joules. ``t_upstream`` is
everything past the edge (network + cloud) and a ``None`` marks a
quantity the backend cannot attribute per request (e.g. per-request
wall time inside the pipelined backends). ``tx_bytes`` is the
transmitted frame *payload* — identical across backends for the same plan
(the socket path's 8-byte length prefix is framing, not payload).
``e_edge_j`` is the edge device's energy for the request, priced by the
plan's ``energy`` section (``None`` on an un-metered plan, and on the
socket backend's pipelined ``infer_many`` where the uplink time cannot
be attributed per request). ``fault`` is the uniform per-request fault
accounting (``repro.core.collab.faults.fault_record``) — all-zero on a
clean request, and the socket backend reports the faults survived, the
recovery attempts spent, and whether the request was served by the
edge-only fallback.

**Fault-tolerant plans** (``plan.faults`` set): the socket session's
``EdgeClient`` retries transient failures (reconnect + re-HELLO +
re-RESPLIT + replay by sequence number) under the policy's backoff and
deadline, and falls back to edge-only inference when the budget
exhausts. On a fallback with an adaptive plan the session reports the
outage to the controller (``note_outage`` — bandwidth collapses to ~0,
so the decision is an immediate re-split to the latest candidate,
typically c=N) and adopts the new split *locally* (``adopt_split`` —
the wire is down; the next successful reconnect re-RESPLITs to it);
once requests flow again, healthy uplink observations pull the
estimate back up and the controller re-splits toward offloading.

**Adaptive plans** (``plan.adaptive`` set): the ``local`` and ``socket``
sessions close the control loop per request — each ``infer`` feeds its
uplink observation to an ``AdaptiveSplitController``, and when the
measured link has drifted past the hysteresis margin the session switches
the split in place (``CollabRunner.set_split`` locally; the RESPLIT
control frame on the live socket). ``session.split`` is the current
partition and ``session.switches`` the decision log. Pass a ``LinkTrace``
via ``connect(plan, trace=...)`` (and ``serve(plan, trace=...)``) to
replay a time-varying link.

**Fleet-routed plans** (``plan.routing`` set): the socket session builds
a ``FleetRouter`` over the plan's fleet member ports and the edge client
routes by its wire-lane key (rendezvous hashing — one lane stays hot on
one server). ``CloudFleet`` starts one ``CloudServer`` per member port
and drives the chaos drills: ``kill`` (crash), ``drain`` (rolling
restart — victims answer new requests with the DRAIN frame and edges
migrate with zero failed requests), ``restart`` (heal back into the
ring). When every member is gone the edge degrades to the bit-identical
edge-only fallback, exactly as for a single-server cloud death.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.collab.adaptive import (AdaptiveSplitController,
                                        SplitSwitch)
from repro.core.collab.batching import bucket_for
from repro.core.collab.channel import FaultInjector
from repro.core.collab.cluster import FleetRouter
from repro.core.collab.faults import fault_record
from repro.core.collab.protocol import PlanMismatchError  # re-export  # noqa: F401
from repro.core.collab.runtime import (CollabRunner, EdgeClient,
                                       serve_cloud)
from repro.core.collab.streaming import StreamingCollabRunner, StreamReport
from repro.core.partition.profiles import LinkTrace
from repro.serving.plan import DeploymentPlan

BACKENDS = ("local", "socket", "streaming")


def _controller_for(plan: DeploymentPlan) -> Optional[AdaptiveSplitController]:
    if plan.adaptive is None:
        return None
    return AdaptiveSplitController.for_deployment(
        plan.cfg, plan.adaptive, plan.split, plan.profile, masks=plan.masks,
        compact=plan.compact, codec=plan.codec, pack=plan.pack,
        energy=plan.energy)


def _result(logits, t_edge: Optional[float], t_upstream: Optional[float],
            tx_bytes: Optional[int],
            e_edge_j: Optional[float] = None,
            fault: Optional[Dict] = None) -> Dict:
    """The one result shape every backend returns: ``t_*`` seconds,
    ``tx_bytes`` bytes, ``e_edge_j`` joules (None = unattributable or
    un-metered), ``fault`` the uniform ``{faults, retries, migrations,
    fallback}`` accounting (all-zero when the backend reports none)."""
    total = (None if t_edge is None or t_upstream is None
             else t_edge + t_upstream)
    return {"logits": np.asarray(logits), "t_edge": t_edge,
            "t_upstream": t_upstream, "t_total": total,
            "tx_bytes": tx_bytes, "e_edge_j": e_edge_j,
            "fault": dict(fault) if fault else fault_record()}


class InferenceSession:
    """Base session: one deployed plan, uniform request interface.

    ``split`` is the *current* partition point (it moves under an
    adaptive plan); ``switches`` logs every ``SplitSwitch`` the adaptive
    controller executed on this session.
    """

    backend: str = "?"

    def __init__(self, plan: DeploymentPlan):
        self.plan = plan
        self.split: int = plan.split
        self.switches: List[SplitSwitch] = []

    def infer(self, image: np.ndarray) -> Dict:
        """Serve one request (image ``(B, H, W, C)`` float32); returns
        the uniform result dict (``t_*`` seconds, ``tx_bytes`` bytes,
        ``e_edge_j`` joules)."""
        raise NotImplementedError

    def infer_many(self, images: Sequence[np.ndarray]) -> List[Dict]:
        """Serve a batch of requests; pipelined backends overlap them."""
        return [self.infer(img) for img in images]

    def close(self) -> None:
        """Release the backend's resources (sockets, worker threads);
        in-process backends need no teardown."""
        pass

    def __enter__(self) -> "InferenceSession":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


class LocalSession(InferenceSession):
    """In-process split executor. ``t_edge``/``t_upstream`` come from the
    analytic hardware profile when ``simulate_compute`` (the default —
    this container is not an i7/3090 pair); the channel term is always
    charged per transmitted byte. A ``trace`` replays a time-varying
    link on the simulated channel; with an adaptive plan the session
    re-splits itself as the charged per-send costs reveal the drift."""

    backend = "local"

    def __init__(self, plan: DeploymentPlan, *,
                 realtime_channel: bool = False,
                 simulate_compute: bool = True,
                 trace: Optional[LinkTrace] = None,
                 faults: Optional[FaultInjector] = None):
        super().__init__(plan)
        self._runner = CollabRunner(
            plan.params, plan.cfg, plan.split, plan.profile,
            masks=plan.masks, realtime_channel=realtime_channel,
            simulate_compute=simulate_compute, compact=plan.compact,
            codec=plan.codec, pack=plan.pack, trace=trace,
            energy=plan.energy.profile if plan.energy else None,
            faults=faults, quant=plan.quant)
        self._controller = _controller_for(plan)
        if self._controller is not None:
            # pre-jit every candidate so a switch doesn't stall a request
            self._runner.warm(plan.adaptive.candidates)

    def infer(self, image: np.ndarray) -> Dict:
        """One in-process request: device/server terms from the analytic
        profile (seconds), channel charged per byte, ``e_edge_j`` priced
        by the plan's energy section; feeds the adaptive controller."""
        res = self._runner.infer(image)
        t = res["timing"]
        if self._controller is not None:
            sw = self._controller.step(t.tx_bytes, t.t_tx, t.e_edge_j)
            if sw is not None:
                self._runner.set_split(sw.new_split)
                self.split = sw.new_split
                self.switches.append(sw)
        return _result(res["logits"], t.t_device, t.t_tx + t.t_server,
                       t.tx_bytes, t.e_edge_j, fault=res.get("fault"))

    def infer_many(self, images: Sequence[np.ndarray]) -> List[Dict]:
        """Batched fast path when the plan carries a ``batching`` section
        (and no adaptive controller needs per-request observations):
        requests are fused up to ``max_batch`` ROWS at a time through ONE
        edge call and ONE bucketed cloud call
        (``CollabRunner.infer_batch``), with logits bit-identical to the
        sequential loop. A single request wider than ``max_batch`` rows
        falls back to the sequential path (which accepts any batch)."""
        if self.plan.batching is None or self._controller is not None:
            return super().infer_many(images)
        mb = self.plan.batching.max_batch
        buckets = self.plan.batching.resolved_buckets
        out: List[Dict] = []
        chunk: List[np.ndarray] = []
        chunk_rows = 0

        def flush():
            nonlocal chunk, chunk_rows
            for r in self._runner.infer_batch(
                    chunk, bucket=bucket_for(chunk_rows, buckets)):
                t = r["timing"]
                out.append(_result(r["logits"], t.t_device,
                                   t.t_tx + t.t_server, t.tx_bytes,
                                   t.e_edge_j, fault=r.get("fault")))
            chunk, chunk_rows = [], 0

        for img in images:
            rows = int(np.asarray(img).shape[0])
            if rows > mb:                # wider than any bucket
                if chunk:
                    flush()
                out.append(self.infer(img))
                continue
            if chunk_rows + rows > mb:
                flush()
            chunk.append(img)
            chunk_rows += rows
        if chunk:
            flush()
        return out


class SocketSession(InferenceSession):
    """Edge side of the real-socket deployment. Requires a cloud peer
    (``serve``/``CloudServer``) listening at the plan's link endpoint;
    ``verify=True`` (default) runs the HELLO digest handshake.

    With an adaptive plan, each synchronous ``infer`` feeds the measured
    send wall-clock to the controller and executes any decided switch via
    the RESPLIT frame — same connection, no re-handshake. ``resplit``
    forces a switch manually. A ``trace`` shapes the edge's uplink
    against a time-varying link (pair it with ``serve(plan, trace=...)``
    for the downlink).

    With a fleet-routed plan (``plan.routing`` set) the session builds a
    ``FleetRouter`` over the fleet member ports (or adopts a shared one
    passed as ``router``) and the client picks its server per connect by
    lane key; ``session.router`` exposes the health/reroute stats.
    ``sleep_fn`` replaces the retry-backoff sleep (tests inject a no-op
    to run failover drills in milliseconds)."""

    backend = "socket"

    def __init__(self, plan: DeploymentPlan, *, verify: bool = True,
                 host: Optional[str] = None, port: Optional[int] = None,
                 trace: Optional[LinkTrace] = None,
                 faults: Optional[FaultInjector] = None,
                 router: Optional[FleetRouter] = None,
                 sleep_fn=None):
        super().__init__(plan)
        if router is None and plan.routing is not None:
            router = FleetRouter(plan.routing, host=host or plan.host)
        #: the fleet router steering this session's connects (None on a
        #: single-server plan) — shared health state if passed in
        self.router = router
        self._client = EdgeClient(
            plan.params, plan.cfg, plan.split, port or plan.port,
            masks=plan.masks,
            link=plan.profile.link if plan.shape_link else None,
            compact=plan.compact, codec=plan.codec, pack=plan.pack,
            host=host or plan.host, timeout=plan.connect_timeout_s,
            plan_digest=plan.digest if verify else None, trace=trace,
            fault_policy=plan.faults, faults=faults, router=router,
            quant=plan.quant,
            **({"sleep_fn": sleep_fn} if sleep_fn is not None else {}))
        self._controller = _controller_for(plan)
        if self._controller is not None:
            # pre-jit the edge half of every candidate (the cloud peer
            # warms its own halves when it arms RESPLIT)
            self._client.warm(plan.adaptive.candidates)
        if plan.faults is not None and plan.faults.fallback == "edge":
            # pre-jit the c=N pair so the first edge-only fallback does
            # not pay an XLA trace in the middle of an outage
            self._client.warm([len(plan.cfg.layers)])

    def resplit(self, split: int) -> None:
        """Move the partition on the live connection (RESPLIT + ack).
        With an adaptive plan the controller adopts the override and its
        dwell window restarts (it won't overrule it on the next infer)."""
        self._client.resplit(split)
        self.split = split
        if self._controller is not None:
            self._controller.note_external_switch(split)

    def _energy(self, res: Dict) -> Optional[float]:
        """Price one synchronous request's edge joules from its measured
        breakdown: edge compute wall-clock, the channel's modeled uplink
        cost, and the remaining wait (cloud + downlink)."""
        if self.plan.energy is None:
            return None
        t_wait = max(res["t_net_and_cloud"] - res["t_tx"], 0.0)
        return self.plan.energy.profile.request_energy(
            res["t_edge"], res["t_tx"], t_wait,
            rtt_s=self.plan.profile.link.rtt_s)

    def infer(self, image: np.ndarray) -> Dict:
        """One synchronous request/response on the live socket; measured
        wall-clock timing (seconds), modeled uplink cost as ``t_tx``,
        ``e_edge_j`` joules when metered; feeds the adaptive controller
        and executes any decided RESPLIT."""
        res = self._client.infer(image)
        e = self._energy(res)
        rec = res.get("fault")
        if self._controller is not None:
            if rec and rec["fallback"]:
                # outage: the cloud is unreachable, so the switch (if
                # any) is adopted locally — the client re-RESPLITs the
                # wire on its next successful reconnect
                sw = self._controller.note_outage()
                if sw is not None:
                    self._client.adopt_split(sw.new_split)
                    self.split = sw.new_split
                    self.switches.append(sw)
            else:
                sw = self._controller.step(res["tx_bytes"], res["t_tx"], e)
                if sw is None and rec and rec["migrations"]:
                    # fleet backpressure: let the controller answer the
                    # congestion signal without waiting out the dwell
                    sw = self._controller.note_congestion()
                if sw is not None:
                    self._client.resplit(sw.new_split)
                    self.split = sw.new_split
                    self.switches.append(sw)
        return _result(res["logits"], res["t_edge"],
                       res["t_net_and_cloud"], res["tx_bytes"], e,
                       fault=rec)

    def infer_many(self, images: Sequence[np.ndarray]) -> List[Dict]:
        """Pipelined submit/collect: edge compute of request i+1 overlaps
        network + cloud time of request i. Results in submission order.

        With an adaptive plan this falls back to the sequential per-request
        loop: the control loop needs a per-request uplink observation and a
        quiesced connection to switch on, neither of which the async
        pipeline provides (a RESPLIT cannot interleave with in-flight
        frames)."""
        if self._controller is not None:
            return [self.infer(img) for img in images]
        for img in images:
            self._client.submit(img)
        out = self._client.collect(len(images))
        return [_result(r["logits"], r["t_edge"], None, r["tx_bytes"],
                        fault=r.get("fault"))
                for r in out]

    def close(self) -> None:
        """Drain any pipelined requests and close the TCP connection."""
        self._client.close()


class StreamingSession(InferenceSession):
    """3-stage pipelined in-process backend (edge ∥ link ∥ cloud).
    ``infer_many`` is the native call; the full ``StreamReport`` of the
    last run (occupancy, throughput, wire bytes) is on ``last_report``."""

    backend = "streaming"

    def __init__(self, plan: DeploymentPlan, *, queue_depth: int = 4,
                 microbatch: int = 1, realtime_channel: bool = True,
                 trace: Optional[LinkTrace] = None):
        super().__init__(plan)
        self._runner = StreamingCollabRunner(
            plan.params, plan.cfg, plan.split, plan.profile,
            masks=plan.masks, compact=plan.compact, codec=plan.codec,
            pack=plan.pack, queue_depth=queue_depth, microbatch=microbatch,
            realtime_channel=realtime_channel, trace=trace,
            quant=plan.quant)
        self.last_report: Optional[StreamReport] = None

    def infer(self, image: np.ndarray) -> Dict:
        """Serve one request through the pipeline (prefer ``infer_many``
        — a single request cannot overlap anything)."""
        return self.infer_many([image])[0]

    def infer_many(self, images: Sequence[np.ndarray]) -> List[Dict]:
        rep = self._runner.run(list(images))
        self.last_report = rep
        energy = self.plan.energy.profile if self.plan.energy else None
        n = max(len(rep.results), 1)
        # per-request stage attribution: measured busy wall-clock of the
        # edge/cloud stages amortized over the stream, plus the channel's
        # *modeled* per-request uplink cost (the pipelined wall-clock of
        # an individual request is not observable, which is why t_* stay
        # None below — but the energy integral over the stream is)
        t_edge_amort = rep.stages["edge"].busy_s / n
        t_cloud_amort = rep.stages["cloud"].busy_s / n
        out = []
        for r in rep.results:
            # a micro-batched frame pays ONE RTT shared by its requests,
            # and t_tx_model above is that frame's cost split evenly —
            # so the RTT peeled off in the energy formula must be split
            # the same way or multi-request frames would zero their
            # radio-active TX time
            e = (energy.request_energy(
                    t_edge_amort, r["t_tx_model"], t_cloud_amort,
                    rtt_s=self.plan.profile.link.rtt_s / r["frame_n"])
                 if energy is not None else None)
            out.append(_result(r["logits"], None, None,
                               int(r["tx_bytes"]), e))
        return out


def connect(plan: DeploymentPlan, backend: str = "local",
            **opts) -> InferenceSession:
    """Open an ``InferenceSession`` on ``plan`` with the chosen backend.
    All backends serve the same contract and return the same result
    shape; extra ``opts`` are backend-specific (see each session class).
    """
    if backend == "local":
        return LocalSession(plan, **opts)
    if backend == "socket":
        return SocketSession(plan, **opts)
    if backend == "streaming":
        return StreamingSession(plan, **opts)
    raise ValueError(f"unknown backend {backend!r} (use {BACKENDS})")


def serve(plan: DeploymentPlan, *, port: Optional[int] = None,
          host: Optional[str] = None, max_requests: Optional[int] = None,
          max_clients: Optional[int] = 1,
          ready: Optional[threading.Event] = None,
          stop: Optional[threading.Event] = None,
          verify: bool = True,
          trace: Optional[LinkTrace] = None,
          batch_stats: Optional[Dict] = None,
          simulate_server=None,
          faults: Optional[FaultInjector] = None,
          fault_stats: Optional[Dict] = None,
          die: Optional[threading.Event] = None,
          drain: Optional[threading.Event] = None) -> None:
    """Cloud-side entry point: serve ``plan`` on its link endpoint
    (blocking). ``max_clients=None`` + a ``stop`` event serves many edges
    until told to quit; ``verify`` arms the HELLO digest check. An
    adaptive plan arms the RESPLIT path, restricted to the plan's
    candidate splits; a non-adaptive plan still answers RESPLIT for any
    split valid on the deployed network (manual ``resplit``). A plan with
    a ``batching`` section serves through the cross-client dynamic
    batching engine; pass a dict as ``batch_stats`` to receive its
    per-lane accounting (fill rate, padding waste) on shutdown.
    ``simulate_server`` (a ``ComputeProfile``) additionally charges each
    cloud invocation its analytic device time on that hardware,
    serialized server-wide (see ``serve_cloud``) — the benchmark knob for
    measuring the engine against the paper's 3090 on this container.

    A plan with a ``faults`` section arms the server's recovery side:
    sealed (CRC + sequence) frames are negotiated per connection via the
    HELLO caps byte, clients silent for ``3 * heartbeat_s`` are reaped,
    and a ``stop`` becomes a graceful drain (in-flight batched requests
    flush before the listener exits). ``faults`` (a ``FaultInjector``)
    injects the schedule into the server's response path; ``fault_stats``
    (a dict) receives classified error counts on shutdown; ``die`` is
    the crash switch — setting it kills every connection without drain
    (what ``CloudServer.kill`` uses to simulate cloud death); ``drain``
    is the rolling-restart switch — while set, new data requests are
    answered with the versioned DRAIN control frame (fleet-routed edges
    migrate to another member, zero failed requests) while handshakes
    and in-flight work still complete (what ``CloudServer.drain``
    sets)."""
    serve_cloud(plan.params, plan.cfg, plan.split, port or plan.port,
                masks=plan.masks,
                link=plan.profile.link if plan.shape_link else None,
                max_requests=max_requests, ready=ready,
                compact=plan.compact, host=host or plan.host,
                max_clients=max_clients, stop=stop,
                plan_digest=plan.digest if verify else None,
                resplit_candidates=(plan.adaptive.candidates
                                    if plan.adaptive else None),
                trace=trace, batching=plan.batching,
                batch_stats=batch_stats, simulate_server=simulate_server,
                fault_policy=plan.faults, faults=faults,
                fault_stats=fault_stats, die=die, drain=drain,
                quant=plan.quant)


class CloudServer:
    """Background cloud peer for a plan (thread wrapper around ``serve``).

    >>> with CloudServer(plan, max_clients=None) as srv:
    ...     sess = connect(plan, backend="socket")
    """

    def __init__(self, plan: DeploymentPlan, *,
                 port: Optional[int] = None, host: Optional[str] = None,
                 max_requests: Optional[int] = None,
                 max_clients: Optional[int] = None, verify: bool = True,
                 start_timeout: float = 10.0,
                 trace: Optional[LinkTrace] = None,
                 simulate_server=None,
                 faults: Optional[FaultInjector] = None):
        self.plan = plan
        #: per-lane dynamic-batching accounting (filled on shutdown when
        #: the plan carries a ``batching`` section)
        self.batch_stats: Dict = {}
        #: classified server-side error counts (filled on shutdown)
        self.fault_stats: Dict = {}
        self._stop = threading.Event()
        self._die = threading.Event()
        self._drain = threading.Event()
        ready = threading.Event()
        self._thread = threading.Thread(
            target=serve, args=(plan,),
            kwargs=dict(port=port, host=host, max_requests=max_requests,
                        max_clients=max_clients, ready=ready,
                        stop=self._stop, verify=verify, trace=trace,
                        batch_stats=self.batch_stats,
                        simulate_server=simulate_server, faults=faults,
                        fault_stats=self.fault_stats, die=self._die,
                        drain=self._drain),
            daemon=True)
        self._thread.start()
        if not ready.wait(start_timeout):
            raise TimeoutError("cloud server failed to start listening")

    def stop(self, timeout: float = 10.0) -> None:
        """Signal the serve loop to quit and join its thread (seconds):
        a *graceful drain* — in-flight batched requests flush before the
        listener exits; fills ``batch_stats`` when the plan batches."""
        self._stop.set()
        self._thread.join(timeout)

    def drain(self) -> None:
        """Start a rolling-restart drain: stop admitting new data
        requests — each gets the DRAIN control frame so fleet-routed
        edges migrate to another member — while handshakes and in-flight
        batched work still complete. Returns immediately; call ``stop``
        once the edges have moved (``CloudFleet.restart`` sequences
        this)."""
        self._drain.set()

    @property
    def draining(self) -> bool:
        """True once a rolling-restart drain has been started."""
        return self._drain.is_set()

    def kill(self, timeout: float = 10.0) -> None:
        """Simulated cloud death: hard-close every connection (no drain,
        no goodbye — clients see a reset mid-stream) and join the serve
        thread. The fault-injection benchmark's 'cloud process dies'
        event; a fault-tolerant edge recovers by reconnecting to a fresh
        server, everyone else gets a ``ConnectionError``."""
        self._die.set()
        self._stop.set()
        self._thread.join(timeout)

    def join(self, timeout: float = 30.0) -> None:
        """Wait for a bounded server (``max_clients`` set) to drain."""
        self._thread.join(timeout)

    @property
    def alive(self) -> bool:
        return self._thread.is_alive()

    def __enter__(self) -> "CloudServer":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()


class CloudFleet:
    """The high-availability cloud tier: one background ``CloudServer``
    per fleet member port in ``plan.routing``, plus the chaos controls
    the failover drills drive — ``kill`` (crash a member), ``drain``
    (rolling restart: the member answers new requests with DRAIN and
    fleet-routed edges migrate with zero failed requests), ``restart``
    (heal a member back into the ring).

    >>> with CloudFleet(plan) as fleet:
    ...     sess = connect(plan, backend="socket")   # routes by lane
    ...     fleet.kill(plan.routing.ports[0])        # edges re-route
    """

    def __init__(self, plan: DeploymentPlan, *, verify: bool = True,
                 max_clients: Optional[int] = None,
                 simulate_server=None, start_timeout: float = 10.0):
        if plan.routing is None or not plan.routing.ports:
            raise ValueError(
                "CloudFleet needs a plan with a routing section "
                "(fleet member ports)")
        self.plan = plan
        self._verify = verify
        self._max_clients = max_clients
        self._simulate_server = simulate_server
        self._start_timeout = start_timeout
        self._lock = threading.Lock()
        self._servers: Dict[int, CloudServer] = {}
        for p in plan.routing.ports:
            self._servers[p] = self._spawn(p)

    def _spawn(self, port: int) -> CloudServer:
        return CloudServer(
            self.plan, port=port, max_clients=self._max_clients,
            verify=self._verify, simulate_server=self._simulate_server,
            start_timeout=self._start_timeout)

    @property
    def ports(self) -> tuple:
        """The fleet member ports (the plan's routing section)."""
        return self.plan.routing.ports

    def server(self, port: int) -> CloudServer:
        """The current ``CloudServer`` for one member port."""
        with self._lock:
            return self._servers[port]

    def kill(self, port: int, timeout: float = 10.0) -> None:
        """Crash one member: hard-close its connections (no drain, no
        goodbye). Fleet-routed edges see the reset, mark the member
        dead, and re-route the replayed request to the next healthy
        server."""
        self.server(port).kill(timeout)

    def drain(self, port: int) -> None:
        """Start a rolling-restart drain on one member (see
        ``CloudServer.drain``); returns immediately while edges
        migrate."""
        self.server(port).drain()

    def stop(self, port: int, timeout: float = 10.0) -> None:
        """Gracefully stop one member (in-flight work flushes)."""
        self.server(port).stop(timeout)

    def restart(self, port: int, timeout: float = 10.0) -> CloudServer:
        """Bring a killed/drained member back: stop whatever is left on
        the port and start a fresh ``CloudServer`` there. The routers'
        dead-member probe (``retry_dead_s``) heals it back into the
        ring; a drill can also call ``router.revive(port)`` directly."""
        old = self.server(port)
        if old.alive:
            old.stop(timeout)
        srv = self._spawn(port)
        with self._lock:
            self._servers[port] = srv
        return srv

    def stop_all(self, timeout: float = 10.0) -> None:
        """Gracefully stop every member of the fleet."""
        with self._lock:
            servers = list(self._servers.values())
        for srv in servers:
            srv.stop(timeout)

    def __enter__(self) -> "CloudFleet":
        return self

    def __exit__(self, *exc) -> None:
        self.stop_all()
