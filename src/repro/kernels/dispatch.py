"""Global Pallas dispatch switch.

    from repro.kernels import dispatch
    with dispatch.use_pallas(interpret=True):   # CPU validation
        logits, _ = transformer.forward(...)

Model layers consult ``enabled()`` / ``interpret()``; default off so every
other path (dry-run, smoke tests, benchmarks) lowers the pure-XLA graph.
"""
from __future__ import annotations

import contextlib
import threading

_state = threading.local()


def enabled() -> bool:
    return getattr(_state, "enabled", False)


def interpret() -> bool:
    return getattr(_state, "interpret", False)


@contextlib.contextmanager
def use_pallas(interpret: bool = False):
    prev = (enabled(), globals()["interpret"]())
    _state.enabled, _state.interpret = True, interpret
    try:
        yield
    finally:
        _state.enabled, _state.interpret = prev
