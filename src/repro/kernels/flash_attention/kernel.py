"""Flash attention Pallas kernel (TPU target): online-softmax over KV
blocks, causal / sliding-window masks, GQA (grouped KV heads) native.

Tiling: grid (B, H, nq, nk) with the KV-block dim innermost ("arbitrary" —
the running max / denominator / output accumulator carry across it in VMEM
scratch). Per step the working set is

    q tile (block_q, D) + k/v tiles (block_k, D) + acc (block_q, D)

which for block_q = block_k = 512, D = 128, fp32 accumulation is ~1.5 MB —
comfortably inside a v5e core's 128 MB VMEM, leaving room for the scheduler
to double-buffer the HBM streams. Q/K tile dims are 128-aligned for the MXU.

GQA is handled in the index maps: query head h reads KV head h // group, so
KV tiles are fetched once per group position without a materialized repeat.

Non-contributing KV blocks (fully above the causal diagonal or outside the
sliding window) are skipped with pl.when — for causal prefill that halves
the work, and for sliding-window it makes long-S attention O(S * window).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; accept both
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -2.0 ** 30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, window: Optional[int],
                  block_q: int, block_k: int, seq_k: int):
    ki = pl.program_id(3)
    nk = pl.num_programs(3)
    qi = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k
    # block-level skip: causal => KV block entirely in the future;
    # window  => KV block entirely behind every query's window
    relevant = True
    if causal:
        relevant = k_start <= q_start + block_q - 1
    if window is not None:
        relevant = jnp.logical_and(
            relevant, k_start + block_k - 1 > q_start - window)

    @pl.when(relevant)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32) * scale      # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
        ok = k_pos < seq_k                                # tail padding
        if causal:
            ok &= q_pos >= k_pos
        if window is not None:
            ok &= q_pos - k_pos < window
        s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(-1)
        v = v_ref[0, 0].astype(jnp.float32)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _done():
        l = jnp.maximum(l_ref[...], 1e-37)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           causal: bool = True,
                           window: Optional[int] = None,
                           scale: Optional[float] = None,
                           block_q: int = 512, block_k: int = 512,
                           seq_k: Optional[int] = None,
                           interpret: bool = False) -> jnp.ndarray:
    """q (B,H,Sq,D), k/v (B,Hkv,Sk,D) -> (B,H,Sq,D). Sq % block_q == 0,
    Sk % block_k == 0 (ops.py pads; ``seq_k`` = the TRUE key length so the
    padded tail is masked out)."""
    B, H, Sq, D = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    assert H % Hkv == 0
    group = H // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    nq, nk = Sq // block_q, Sk // block_k
    grid = (B, H, nq, nk)
    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k,
        seq_k=seq_k if seq_k is not None else Sk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D),
                         lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
