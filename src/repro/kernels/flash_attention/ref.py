"""Pure-jnp oracle for the flash-attention kernel: materializing softmax
attention with causal / sliding-window masks and GQA head grouping."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  causal: bool = True,
                  window: Optional[int] = None,
                  scale: Optional[float] = None) -> jnp.ndarray:
    """q (B,Sq,H,D), k/v (B,Sk,Hkv,D) with H % Hkv == 0 -> (B,Sq,H,D).

    Positions are 0..S-1 on both sides (self-attention; Sq == Sk assumed for
    the masked cases)."""
    B, Sq, H, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    group = H // Hkv
    scale = scale if scale is not None else 1.0 / (D ** 0.5)
    qg = q.astype(jnp.float32).reshape(B, Sq, Hkv, group, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg,
                        k.astype(jnp.float32)) * scale
    d = jnp.arange(Sq)[:, None] - jnp.arange(Sk)[None, :]
    ok = jnp.ones((Sq, Sk), bool)
    if causal:
        ok &= d >= 0
    if window is not None:
        ok &= d < window
    logits = jnp.where(ok, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, D).astype(q.dtype)
