"""Jitted wrapper for the flash kernel: (B,S,H,D) layout conversion,
sequence padding, block-size clamping."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_pallas


@functools.partial(jax.jit, static_argnames=("causal", "window", "scale",
                                             "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, window: Optional[int] = None,
                    scale: Optional[float] = None,
                    block_q: int = 512, block_k: int = 512,
                    interpret: bool = False) -> jnp.ndarray:
    """Model layout in/out: q (B,Sq,H,D), k/v (B,Sk,Hkv,D) -> (B,Sq,H,D)."""
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    pq, pk = (-Sq) % bq, (-Sk) % bk
    if pq:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pk), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pk), (0, 0)))
    out = flash_attention_pallas(qt, kt, vt, causal=causal, window=window,
                                 scale=scale, block_q=bq, block_k=bk,
                                 seq_k=Sk, interpret=interpret)
    if pq:
        out = out[:, :, :Sq]
    return out.transpose(0, 2, 1, 3)
