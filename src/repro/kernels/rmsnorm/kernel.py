"""Fused RMSNorm Pallas kernel.

RMSNorm is memory-bound (one read + one write of the activation, a handful
of FLOPs per element); the payoff of the kernel is a single HBM->VMEM->HBM
pass with the reduce, rsqrt, and scale fused. Rows are tiled
(block_rows, d): the full feature dim lives in VMEM so the reduction never
leaves the core, and block_rows amortizes grid overhead.

TPU is the target; CPU validation runs the same body with interpret=True.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, scale_ref, o_ref, *, eps: float,
                    scale_offset: float):
    x = x_ref[...].astype(jnp.float32)                  # (block_rows, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    y = y * (scale_ref[...].astype(jnp.float32) + scale_offset)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_pallas(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6,
                   scale_offset: float = 0.0, block_rows: int = 256,
                   interpret: bool = False) -> jnp.ndarray:
    """x (rows, d) -> (rows, d). rows must divide by block_rows (ops.py pads)."""
    rows, d = x.shape
    assert rows % block_rows == 0, (rows, block_rows)
    grid = (rows // block_rows,)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps,
                          scale_offset=scale_offset),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
            pl.BlockSpec((d,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        interpret=interpret,
    )(x, scale)
