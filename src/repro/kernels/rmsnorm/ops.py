"""Jitted public wrapper for the fused RMSNorm kernel: arbitrary leading
dims, row padding to the block size."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.kernel import rmsnorm_pallas


@functools.partial(jax.jit, static_argnames=("eps", "scale_offset",
                                             "block_rows", "interpret"))
def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6,
            scale_offset: float = 0.0, block_rows: int = 256,
            interpret: bool = False) -> jnp.ndarray:
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    block = min(block_rows, rows) if rows else 1
    pad = (-rows) % block
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    out = rmsnorm_pallas(x2, scale, eps=eps, scale_offset=scale_offset,
                         block_rows=block, interpret=interpret)
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
