"""Pure-jnp oracle for the fused RMSNorm kernel."""
from __future__ import annotations

import jax.numpy as jnp


def rmsnorm_ref(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6,
                scale_offset: float = 0.0) -> jnp.ndarray:
    """x (..., d), scale (d,). fp32 math, cast back to x.dtype."""
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * (1.0 / jnp.sqrt(var + eps))
    return (y * (scale.astype(jnp.float32) + scale_offset)).astype(dtype)
