"""Pallas TPU kernels for the framework's compute hot-spots.

Each subpackage has:
    kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
    ops.py    — jitted public wrapper (padding, layout, defaults)
    ref.py    — pure-jnp oracle the kernel is tested against

``dispatch`` holds the global switch that routes model layers through the
Pallas paths (interpret=True on CPU). Off by default: the XLA paths are the
production fallback and what the dry-run lowers.
"""
from repro.kernels import dispatch
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.masked_matmul.ops import masked_matmul
from repro.kernels.rmsnorm.ops import rmsnorm as rmsnorm_op
from repro.kernels.ssd_scan.ops import ssd_scan

__all__ = ["dispatch", "flash_attention", "masked_matmul", "rmsnorm_op",
           "ssd_scan"]
