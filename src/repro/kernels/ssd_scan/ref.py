"""Pure-jnp oracle for the SSD (Mamba2) chunked-scan kernel — re-exports the
model's reference implementation so the kernel is validated against exactly
what the model computes."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.layers.ssm import ssd_chunked


def ssd_ref(xh: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
            Bm: jnp.ndarray, Cm: jnp.ndarray, chunk: int):
    """xh (B,S,H,P); dt (B,S,H) post-softplus; A (H,) negative;
    Bm/Cm (B,S,G,N). Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    return ssd_chunked(xh, dt, A, Bm, Cm, chunk)
