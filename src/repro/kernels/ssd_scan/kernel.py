"""Chunked SSD (Mamba2 state-space duality) Pallas kernel.

The SSD layer is the dominant op of the attention-free architectures
(mamba2-2.7b, zamba2-1.2b). Its TPU-native form is exactly the chunked
algorithm: per chunk a handful of (Q x Q) / (Q x N) / (Q x P) matmuls that
hit the MXU, plus an O(S/Q) sequential state pass.

Tiling: grid (B, H, n_chunks), chunk dim innermost/"arbitrary" — the
(P, N) state carries across chunks in fp32 VMEM scratch (the recurrence
s_c = decay * s_{c-1} + B^T (dt . decay_to_end . x) is associative in c but
cheap enough that a serial carry wastes nothing at Q = 256).

Per-step working set for Q=256, P=64, N=128:
    x (Q,P) + B,C (Q,N) + L (Q,Q) + state (P,N) fp32  ~ 0.5 MB << VMEM.

GQA-style group sharing (G groups of heads share B/C) is handled in the
index map: head h reads group h // (H/G).

Head masking (the DDPG pruner's axis, paper §3.2) multiplies y per head —
folded into the epilogue here so a pruned head never writes to HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams around 0.5; accept both
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, hm_ref,
                y_ref, fs_ref, state_ref, *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, :, 0].astype(jnp.float32)            # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)          # (Q,)
    a = a_ref[0].astype(jnp.float32)                  # scalar A_h (negative)
    b = b_ref[0, :, 0].astype(jnp.float32)            # (Q, N)
    c = c_ref[0, :, 0].astype(jnp.float32)            # (Q, N)

    dA = dt * a                                       # (Q,)
    cs = jnp.cumsum(dA)                               # (Q,)
    # intra-chunk decay matrix L[i,j] = exp(cs_i - cs_j) for i >= j
    d = cs[:, None] - cs[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(ii >= jj, jnp.exp(d), 0.0)          # (Q, Q)

    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q, Q)
    w = cb * L * dt[None, :]
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q, P)

    # carry-in contribution: C_q . state^T, decayed to step q
    state = state_ref[...]                            # (P, N)
    y += jnp.exp(cs)[:, None] * jax.lax.dot_general(
        c, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)

    # chunk-final state update
    decay_to_end = jnp.exp(cs[-1] - cs)               # (Q,)
    xw = x * (dt * decay_to_end)[:, None]             # (Q, P)
    new_contrib = jax.lax.dot_general(
        xw, b, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)           # (P, N)
    state_ref[...] = state * jnp.exp(cs[-1]) + new_contrib

    y = y * hm_ref[0].astype(jnp.float32)             # pruning epilogue
    y_ref[0, :, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _done():
        fs_ref[0, 0] = state_ref[...].astype(fs_ref.dtype)


def ssd_scan_pallas(xh: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
                    Bm: jnp.ndarray, Cm: jnp.ndarray,
                    head_mask: jnp.ndarray,
                    chunk: int = 256, interpret: bool = False):
    """xh (B,S,H,P); dt (B,S,H); A (H,); Bm/Cm (B,S,G,N); head_mask (H,).
    S % chunk == 0 (ops.py pads). Returns (y (B,S,H,P), state (B,H,P,N))."""
    B, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert S % chunk == 0 and H % G == 0
    rep = H // G
    nc = S // chunk
    grid = (B, H, nc)
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    y, fs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, 1, N),
                         lambda b, h, c, r=rep: (b, c, h // r, 0)),
            pl.BlockSpec((1, chunk, 1, N),
                         lambda b, h, c, r=rep: (b, c, h // r, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), xh.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(xh, dt, A, Bm, Cm, head_mask)
    return y, fs
