"""Jitted wrapper for the SSD kernel: sequence padding to the chunk size,
default all-ones head mask, fp32 output state."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.ssd_scan.kernel import ssd_scan_pallas


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(xh: jnp.ndarray, dt: jnp.ndarray, A: jnp.ndarray,
             Bm: jnp.ndarray, Cm: jnp.ndarray,
             head_mask: Optional[jnp.ndarray] = None,
             chunk: int = 256, interpret: bool = False):
    """Same contract as repro.kernels.ssd_scan.ref.ssd_ref, plus the
    pruning head_mask epilogue."""
    B, S, H, P = xh.shape
    if head_mask is None:
        head_mask = jnp.ones((H,), jnp.float32)
    ch = min(chunk, S)
    pad = (-S) % ch
    if pad:
        widths4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        xh = jnp.pad(xh, widths4)
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, widths4)
        Cm = jnp.pad(Cm, widths4)
    y, fs = ssd_scan_pallas(xh, dt, A, Bm, Cm, head_mask, chunk=ch,
                            interpret=interpret)
    return y[:, :S], fs
