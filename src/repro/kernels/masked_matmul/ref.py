"""Pure-jnp oracle for the column-masked GEMM."""
from __future__ import annotations

import jax.numpy as jnp


def masked_matmul_ref(a: jnp.ndarray, b: jnp.ndarray,
                      col_mask: jnp.ndarray) -> jnp.ndarray:
    """a (M, K) @ b (K, N), output columns multiplied by col_mask (N,).

    This is the semantics of a channel-pruned layer under masked execution:
    pruned output channels are exactly zero (fp32 accumulation)."""
    out = a.astype(jnp.float32) @ b.astype(jnp.float32)
    return (out * col_mask.astype(jnp.float32)[None, :]).astype(a.dtype)
