"""Jitted wrapper: shape padding + batch-dim flattening for the masked GEMM."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.masked_matmul.kernel import masked_matmul_pallas


def _pad_to(x, mult, axis):
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def masked_matmul(a: jnp.ndarray, b: jnp.ndarray, col_mask: jnp.ndarray,
                  block_m: int = 128, block_n: int = 128, block_k: int = 128,
                  interpret: bool = False) -> jnp.ndarray:
    """a (..., K) @ b (K, N) * col_mask (N,) -> (..., N)."""
    lead = a.shape[:-1]
    K = a.shape[-1]
    N = b.shape[1]
    M = 1
    for s in lead:
        M *= s
    if M == 0 or N == 0 or K == 0:
        # Degenerate dims never reach the kernel: an empty M or N yields an
        # empty output, and K == 0 is an empty contraction — exact zeros,
        # matching masked_matmul_ref (zeros * mask == zeros).
        return jnp.zeros((*lead, N), a.dtype)
    a2 = a.reshape(M, K)
    bm = min(block_m, max(M, 1))
    bn = min(block_n, N)
    bk = min(block_k, K)
    a2 = _pad_to(_pad_to(a2, bm, 0), bk, 1)
    b2 = _pad_to(_pad_to(b, bk, 0), bn, 1)
    m2 = _pad_to(col_mask, bn, 0)
    out = masked_matmul_pallas(a2, b2, m2, block_m=bm, block_n=bn,
                               block_k=bk, interpret=interpret)
    return out[:M, :N].reshape(*lead, N)
