"""Column-masked GEMM Pallas kernel — the compute hot-spot of the paper's
pruning payoff (§3.2): a pruned layer's surviving channels as a masked
matmul, with the mask folded into the epilogue so pruned output channels
never touch HBM as garbage.

Tiling: (block_m, block_n) output tiles, fp32 VMEM accumulator, K streamed
in block_k slices (grid K-dim innermost / "arbitrary" so the accumulator
carries). All block dims should be multiples of the MXU native 128 on real
TPU; interpret=True relaxes this for CPU validation.

On TPU, masked columns still occupy MXU cycles (structured-sparse skip would
need compaction — see repro.core.pruning.masks.compact_* which physically
shrinks weights instead); the kernel's win is the fused epilogue and the
guarantee that downstream layers see exact zeros.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(a_ref, b_ref, m_ref, o_ref, acc_ref):
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _done():
        mask = m_ref[...].astype(jnp.float32)          # (block_n,)
        o_ref[...] = (acc_ref[...] * mask[None, :]).astype(o_ref.dtype)


def masked_matmul_pallas(a: jnp.ndarray, b: jnp.ndarray,
                         col_mask: jnp.ndarray,
                         block_m: int = 128, block_n: int = 128,
                         block_k: int = 128,
                         interpret: bool = False) -> jnp.ndarray:
    """a (M, K) @ b (K, N) with output-column mask (N,). Dims must divide
    by their blocks (ops.py pads)."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2 and col_mask.shape == (N,)
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0
    grid = (M // block_m, N // block_n, K // block_k)
    return pl.pallas_call(
        _mm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda mi, ni, ki: (mi, ki)),
            pl.BlockSpec((block_k, block_n), lambda mi, ni, ki: (ki, ni)),
            pl.BlockSpec((block_n,), lambda mi, ni, ki: (ni,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((M, N), a.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        interpret=interpret,
    )(a, b, col_mask)
