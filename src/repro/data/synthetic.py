"""Synthetic PlantVillage-38 stand-in (offline container — see DESIGN.md §7).

The real PlantVillage dataset [arXiv:1511.08060] has 54,305 leaf images,
38 classes, 256x256 JPG. We synthesize a class-separable workload with the
same tensor interface: each class is a distinct procedural texture (a
class-keyed mixture of oriented sinusoidal gratings + class-colored blobs on
a leaf-green base, plus per-sample noise/brightness jitter). A small CNN
reaches high accuracy on it, which is what the reproduction needs: the
paper's claims under test are *relative* (prune -> small drop, fine-tune ->
recover; split-point latency curve), not an absolute ImageNet-style score.

Deterministic: image i of class c depends only on (seed, c, i).
"""
from __future__ import annotations

from typing import Dict, Iterator, Tuple

import numpy as np

NUM_CLASSES = 38
IMAGE_HW = 256
CROP_HW = 224


def _class_params(c: int, seed: int) -> np.random.RandomState:
    return np.random.RandomState(seed * 1000003 + c)


def make_image(c: int, i: int, seed: int = 0, hw: int = IMAGE_HW) -> np.ndarray:
    """One (hw, hw, 3) float32 image in [0, 1] for class c, sample i."""
    crs = _class_params(c, seed)
    # class signature: 3 gratings + 2 blob colors
    freqs = crs.uniform(2, 12, size=3)
    orients = crs.uniform(0, np.pi, size=3)
    phases_w = crs.uniform(0.3, 1.0, size=3)
    blob_color = crs.uniform(0, 1, size=(2, 3))
    # per-class mean tint: a strong, linearly-separable disease signature
    # (real PlantVillage classes differ in lesion color statistics too)
    tint = crs.uniform(-1, 1, size=3)
    base_green = np.array([0.18, 0.42, 0.12]) + crs.uniform(-0.05, 0.05, 3)

    srs = np.random.RandomState((seed * 7 + c) * 2654435761 % (2**31) + i)
    yy, xx = np.mgrid[0:hw, 0:hw].astype(np.float32) / hw
    img = np.broadcast_to(base_green, (hw, hw, 3)).astype(np.float32).copy()
    img += 0.12 * tint
    for f, o, w in zip(freqs, orients, phases_w):
        ph = srs.uniform(0, 2 * np.pi)
        g = np.sin(2 * np.pi * f * (xx * np.cos(o) + yy * np.sin(o)) + ph)
        img += 0.12 * w * g[..., None]
    # class-colored lesion blobs (disease spots)
    n_blobs = 2 + (c % 3)
    for b in range(n_blobs):
        cy, cx = srs.uniform(0.15, 0.85, 2)
        r = srs.uniform(0.05, 0.15)
        d2 = (yy - cy) ** 2 + (xx - cx) ** 2
        m = np.exp(-d2 / (2 * r * r))
        img += 0.5 * m[..., None] * (blob_color[b % 2] - img)
    img += srs.normal(0, 0.02, img.shape)
    img *= srs.uniform(0.85, 1.15)
    return np.clip(img, 0, 1).astype(np.float32)


def stratified_split(n_per_class: int, train_frac: float = 0.8,
                     seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Per-class index split (paper §4.1: intra-class stratification, 80/20)."""
    rs = np.random.RandomState(seed)
    tr_idx, te_idx = [], []
    for c in range(NUM_CLASSES):
        perm = rs.permutation(n_per_class)
        k = int(round(train_frac * n_per_class))
        tr_idx.append(np.stack([np.full(k, c), perm[:k]], 1))
        te_idx.append(np.stack([np.full(n_per_class - k, c), perm[k:]], 1))
    return np.concatenate(tr_idx), np.concatenate(te_idx)


class PlantVillageSynthetic:
    """Array-backed dataset (materialized once; tiny at smoke scale)."""

    def __init__(self, n_per_class: int = 40, hw: int = 64, seed: int = 0):
        self.hw = hw
        self.n_per_class = n_per_class
        self.train_ids, self.test_ids = stratified_split(n_per_class, 0.8, seed)
        self.seed = seed
        self._cache: Dict[Tuple[int, int], np.ndarray] = {}

    def _img(self, c: int, i: int) -> np.ndarray:
        k = (c, i)
        if k not in self._cache:
            self._cache[k] = make_image(c, i, self.seed, self.hw)
        return self._cache[k]

    def _batch(self, ids: np.ndarray) -> Dict[str, np.ndarray]:
        x = np.stack([self._img(int(c), int(i)) for c, i in ids])
        y = ids[:, 0].astype(np.int32)
        return {"image": x, "label": y}

    def iter_train(self, batch_size: int, epochs: int = 1,
                   seed: int = 1) -> Iterator[Dict[str, np.ndarray]]:
        rs = np.random.RandomState(seed)
        for _ in range(epochs):
            perm = rs.permutation(len(self.train_ids))
            for s in range(0, len(perm) - batch_size + 1, batch_size):
                yield self._batch(self.train_ids[perm[s:s + batch_size]])

    def test_batches(self, batch_size: int) -> Iterator[Dict[str, np.ndarray]]:
        for s in range(0, len(self.test_ids), batch_size):
            yield self._batch(self.test_ids[s:s + batch_size])
