"""Synthetic LM token streams for transformer training/serving drivers.

Markov-chain token generator: deterministic per (seed, step), with enough
sequential structure that a small LM's loss visibly decreases — good enough
to exercise every substrate layer (pipeline, optimizer, checkpoint, mesh)
without a real corpus in the offline container.
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np


class MarkovTokens:
    def __init__(self, vocab_size: int, order_states: int = 64, seed: int = 0):
        self.vocab = vocab_size
        rs = np.random.RandomState(seed)
        self.n_states = min(order_states, vocab_size)
        # sparse-ish transition structure: each state strongly prefers 4 tokens
        probs = np.full((self.n_states, vocab_size), 0.1 / vocab_size)
        for s in range(self.n_states):
            fav = rs.choice(vocab_size, size=4, replace=False)
            probs[s, fav] += 0.9 / 4
        self.probs = probs / probs.sum(1, keepdims=True)

    def batch(self, batch_size: int, seq_len: int, step: int) -> Dict[str, np.ndarray]:
        rs = np.random.RandomState(step * 9176 + 17)
        out = np.zeros((batch_size, seq_len + 1), np.int32)
        state = rs.randint(0, self.n_states, batch_size)
        for t in range(seq_len + 1):
            u = rs.rand(batch_size, 1)
            cdf = np.cumsum(self.probs[state], 1)
            out[:, t] = (u < cdf).argmax(1)
            state = out[:, t] % self.n_states
        return {"tokens": out[:, :-1], "labels": out[:, 1:].astype(np.int32)}

    def stream(self, batch_size: int, seq_len: int,
               start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        step = start_step
        while True:
            yield self.batch(batch_size, seq_len, step)
            step += 1
