"""Pallas kernel sweeps: shapes x dtypes, assert_allclose vs the pure-jnp
ref.py oracles, interpret=True on CPU."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.masked_matmul.ops import masked_matmul
from repro.kernels.masked_matmul.ref import masked_matmul_ref
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_ref

TOLS = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
        jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _tol(dtype):
    return TOLS[jnp.bfloat16 if dtype == jnp.bfloat16 else jnp.float32]


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(8, 64), (3, 5, 128), (1, 1, 1, 256),
                                   (300, 96)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), shape, dtype)
    s = jax.random.normal(jax.random.PRNGKey(1), shape[-1:], jnp.float32)
    got = rmsnorm(x, s, interpret=True)
    want = rmsnorm_ref(x, s)
    assert got.dtype == x.dtype
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("offset", [0.0, 1.0])
def test_rmsnorm_scale_offset(offset):
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 32))
    s = jax.random.normal(jax.random.PRNGKey(1), (32,))
    np.testing.assert_allclose(
        np.asarray(rmsnorm(x, s, scale_offset=offset, interpret=True)),
        np.asarray(rmsnorm_ref(x, s, scale_offset=offset)),
        rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("M,K,N", [(16, 16, 16), (70, 100, 130),
                                   (128, 256, 64), (1, 512, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_masked_matmul_sweep(M, K, N, dtype):
    a = jax.random.normal(jax.random.PRNGKey(0), (M, K), dtype)
    b = jax.random.normal(jax.random.PRNGKey(1), (K, N), dtype)
    m = (jax.random.uniform(jax.random.PRNGKey(2), (N,)) > 0.4).astype(
        jnp.float32)
    got = masked_matmul(a, b, m, block_m=32, block_n=32, block_k=64,
                        interpret=True)
    want = masked_matmul_ref(a, b, m)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_masked_matmul_pruned_columns_exact_zero():
    a = jax.random.normal(jax.random.PRNGKey(0), (32, 32))
    b = jax.random.normal(jax.random.PRNGKey(1), (32, 48))
    m = jnp.zeros((48,)).at[::2].set(1.0)
    out = np.asarray(masked_matmul(a, b, m, interpret=True))
    assert (out[:, 1::2] == 0.0).all()


@pytest.mark.parametrize("M,K,N", [(0, 4, 5), (3, 0, 5), (3, 4, 0),
                                   (1, 1, 1)])
def test_masked_matmul_degenerate_dims(M, K, N):
    """Empty M/N and the empty contraction (K=0) return exact zeros of
    the right shape instead of reaching the kernel (or dividing by a
    zero grid)."""
    a = jnp.zeros((M, K)) + 1.0
    b = jnp.zeros((K, N)) + 2.0
    m = jnp.ones((N,))
    got = masked_matmul(a, b, m, interpret=True)
    want = masked_matmul_ref(a, b, m)
    assert got.shape == want.shape == (M, N)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("M,K,N", [(1, 7, 5), (1, 200, 3), (2, 3, 130),
                                   (5, 300, 2)])
def test_masked_matmul_dims_smaller_than_block(M, K, N):
    """M=1 rows and K/N far below the default 128 blocks exercise the
    padding path: ops.py clamps each block to the dim, so the pad rows/
    cols the kernel sees are zeros that cannot leak into the output."""
    a = jax.random.normal(jax.random.PRNGKey(0), (M, K))
    b = jax.random.normal(jax.random.PRNGKey(1), (K, N))
    m = (jax.random.uniform(jax.random.PRNGKey(2), (N,)) > 0.3).astype(
        jnp.float32)
    got = masked_matmul(a, b, m, interpret=True)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(masked_matmul_ref(a, b, m)),
                               rtol=2e-5, atol=2e-5)


def test_masked_matmul_all_pruned_mask_exact_zero():
    """A fully pruned column mask (every channel dropped) zeroes the
    whole output exactly — the epilogue multiply, not an approximation."""
    a = jax.random.normal(jax.random.PRNGKey(0), (16, 24))
    b = jax.random.normal(jax.random.PRNGKey(1), (24, 40))
    out = np.asarray(masked_matmul(a, b, jnp.zeros((40,)), interpret=True))
    assert (out == 0.0).all()


def test_masked_matmul_batched_leading_dims():
    a = jax.random.normal(jax.random.PRNGKey(0), (2, 5, 24))
    b = jax.random.normal(jax.random.PRNGKey(1), (24, 16))
    m = jnp.ones((16,))
    got = masked_matmul(a, b, m, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(a @ b),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,H,Hkv,D", [(2, 128, 4, 2, 64),
                                         (1, 100, 4, 4, 32),
                                         (1, 64, 8, 1, 64)])
@pytest.mark.parametrize("causal,window", [(True, None), (True, 16),
                                           (False, None)])
def test_flash_attention_sweep(B, S, H, Hkv, D, causal, window):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=32, block_k=32, interpret=True)
    want = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-5, atol=3e-5)


def test_flash_attention_bf16():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 64, 4, 32), jnp.bfloat16)
    k = jax.random.normal(ks[1], (1, 64, 2, 32), jnp.bfloat16)
    v = jax.random.normal(ks[2], (1, 64, 2, 32), jnp.bfloat16)
    got = flash_attention(q, k, v, block_q=32, block_k=32, interpret=True)
    want = attention_ref(q, k, v)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)


# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,S,H,G,P,N,chunk", [
    (2, 64, 4, 1, 16, 32, 16),
    (1, 100, 4, 2, 32, 16, 32),          # ragged tail
    (2, 128, 8, 8, 16, 16, 64),
])
def test_ssd_scan_sweep(B, S, H, G, P, N, chunk):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    xh = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.5
    y, fs = ssd_scan(xh, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    yr, fsr = ssd_ref(xh, dt, A, Bm, Cm, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(fs), np.asarray(fsr),
                               rtol=2e-3, atol=2e-3)


def test_ssd_scan_head_mask_zeroes_heads():
    B, S, H, G, P, N = 1, 32, 4, 1, 8, 8
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    xh = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.5
    hm = jnp.array([1.0, 0.0, 1.0, 0.0])
    y, _ = ssd_scan(xh, dt, A, Bm, Cm, head_mask=hm, chunk=16,
                    interpret=True)
    y = np.asarray(y)
    assert (y[:, :, 1] == 0).all() and (y[:, :, 3] == 0).all()
    assert np.abs(y[:, :, 0]).max() > 0


# ---------------------------------------------------------------------------
def test_model_forward_with_pallas_dispatch():
    """End-to-end: model logits identical with kernels routed through
    Pallas (interpret) vs pure XLA."""
    from repro.configs.registry import get_smoke_config
    from repro.kernels import dispatch
    from repro.models import transformer as tr
    for arch in ["gemma-7b", "mamba2-2.7b"]:
        cfg = get_smoke_config(arch).replace(dtype="float32",
                                             naive_attn_max=0)
        params = tr.init_params(cfg, jax.random.PRNGKey(0))
        tok = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0,
                                 cfg.vocab_size)
        ref, _ = tr.forward(params, cfg, {"tokens": tok})
        with dispatch.use_pallas(interpret=True):
            got, _ = tr.forward(params, cfg, {"tokens": tok})
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=1e-4, atol=1e-4)
