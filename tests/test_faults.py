"""Fault-tolerant collaborative serving: deterministic fault injection
(seeded schedules), CRC + sequence-number frame integrity, the HELLO
capability negotiation (legacy no-CRC peers interoperate), the retry /
backoff / deadline recovery loop, edge-only graceful degradation
(bit-identical to an all-edge split), outage-aware adaptive re-splitting,
heartbeat reaping, and graceful server drain.

All socket tests run against seeded ``FaultSchedule``s — the same storm
replays identically — and no assertion depends on a wall-clock sleep.
"""
from __future__ import annotations

import socket
import struct
import threading
import time

import jax
import numpy as np
import pytest

from repro import serving
from repro.core.collab.channel import corrupt_bytes
from repro.core.collab.protocol import (CAP_CRC, decode_hello,
                                        decode_sealed, decode_tensor,
                                        encode_hello, encode_sealed,
                                        encode_tensor, hello_caps,
                                        is_sealed)
from repro.core.collab.runtime import EdgeClient
from repro.core.partition.profiles import (PAPER_SERVER, ComputeProfile,
                                           FaultEvent, LinkProfile,
                                           TwoTierProfile)
from repro.core.pruning.masks import cnn_masks_from_ratios
from repro.models.cnn import (cnn_apply, init_cnn_params, prunable_layers,
                              tiny_cnn_config)

SPLIT = 6


@pytest.fixture(scope="module")
def plan_setup():
    cfg = tiny_cnn_config(num_classes=7, hw=32)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    masks = cnn_masks_from_ratios(
        params, cfg, {i: 0.5 for i in prunable_layers(cfg)})
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32, 3)),
                   np.float32)
    want = np.asarray(cnn_apply(params, cfg, x, masks=masks))
    return cfg, params, masks, x, want


def make_plan(plan_setup, port, **kw):
    cfg, params, masks, _, _ = plan_setup
    kw.setdefault("split", SPLIT)
    kw.setdefault("masks", masks)
    kw.setdefault("compact", True)
    kw.setdefault("codec", "fp32")
    kw.setdefault("shape_link", False)
    return serving.DeploymentPlan.from_args(params, cfg, port=port, **kw)


def fast_policy(**kw):
    """Milliseconds-scale recovery knobs so tests never idle."""
    kw.setdefault("max_retries", 3)
    kw.setdefault("backoff_base_s", 0.01)
    kw.setdefault("backoff_max_s", 0.05)
    kw.setdefault("backoff_jitter", 0.0)
    kw.setdefault("request_deadline_s", 5.0)
    return serving.FaultPolicy(**kw)


# ---------------------------------------------------------------------------
# schedules + policy (pure data, no sockets)
# ---------------------------------------------------------------------------
def test_fault_schedule_seeded_deterministic():
    a = serving.FaultSchedule.seeded("s", seed=7, n_attempts=300, drop=0.1,
                                     corrupt=0.05, stall=0.05)
    b = serving.FaultSchedule.seeded("s", seed=7, n_attempts=300, drop=0.1,
                                     corrupt=0.05, stall=0.05)
    assert a.events == b.events and a.n_events > 0
    c = serving.FaultSchedule.seeded("s", seed=8, n_attempts=300, drop=0.1)
    assert a.events != c.events            # the seed IS the storm
    for name, sched in serving.FAULT_SCHEDULES.items():
        assert sched.n_events > 0, name


def test_fault_injector_consumes_attempts():
    sched = serving.FaultSchedule(
        "two", (FaultEvent(0, "drop"), FaultEvent(2, "corrupt")))
    inj = serving.FaultInjector(sched)
    kinds = [getattr(inj.next_event(), "kind", None) for _ in range(4)]
    assert kinds == ["drop", None, "corrupt", None]
    assert inj.attempts == 4 and inj.injected == 2
    inj.reset()
    assert inj.attempts == 0 and inj.next_event().kind == "drop"


def test_fault_policy_backoff_and_roundtrip():
    p = fast_policy(backoff_jitter=0.5, seed=3)
    assert p.backoff_s(0) == 0.01          # jitter-free without an rng
    assert p.backoff_s(10) == 0.05         # capped
    r1 = [p.backoff_s(i, p.make_rng()) for i in range(3)]
    r2 = [p.backoff_s(i, p.make_rng()) for i in range(3)]
    assert r1 == r2                        # deterministic jitter
    assert serving.FaultPolicy.from_json(p.to_json()) == p
    with pytest.raises(ValueError, match="fallback"):
        serving.FaultPolicy(fallback="panic")
    with pytest.raises(ValueError, match="deadline"):
        serving.FaultPolicy(request_deadline_s=0)


def test_plan_digest_stable_without_faults_section(plan_setup):
    base = make_plan(plan_setup, 29520)
    assert "faults" not in base.contract()     # only-when-set fold
    armed = make_plan(plan_setup, 29520, faults=fast_policy())
    assert "faults" in armed.contract()
    assert base.digest != armed.digest
    # transport-identical plan without the section: digest unchanged
    assert base.digest == make_plan(plan_setup, 29999).digest


def test_plan_save_load_roundtrips_fault_policy(plan_setup, tmp_path):
    plan = make_plan(plan_setup, 29520, faults=fast_policy(heartbeat_s=1.0))
    loaded = serving.DeploymentPlan.load(plan.save(str(tmp_path / "d")))
    assert loaded.faults == plan.faults
    assert loaded.digest == plan.digest
    assert "faults" in plan.describe()


# ---------------------------------------------------------------------------
# sealed frames: CRC32 + sequence numbers
# ---------------------------------------------------------------------------
def test_sealed_frame_roundtrip_and_corruption_rejected():
    inner = encode_tensor(np.arange(12, dtype=np.float32))
    frame = encode_sealed(41, inner)
    assert is_sealed(frame)
    seq, back = decode_sealed(frame)
    assert seq == 41 and back == inner
    with pytest.raises(serving.FrameIntegrityError):
        decode_sealed(corrupt_bytes(frame))          # one flipped byte
    with pytest.raises(serving.FrameIntegrityError):
        decode_sealed(frame[:-3])                    # truncated in flight


def test_hello_caps_negotiation():
    plain = encode_hello("ab" * 8)
    assert hello_caps(plain) == 0                    # legacy: no caps byte
    capped = encode_hello("ab" * 8, caps=CAP_CRC)
    assert hello_caps(capped) & CAP_CRC
    # legacy decoder slices the digest by dlen: trailing caps byte ignored
    assert decode_hello(capped) == decode_hello(plain)


# ---------------------------------------------------------------------------
# socket recovery ladder
# ---------------------------------------------------------------------------
def test_socket_session_negotiates_crc(plan_setup):
    plan = make_plan(plan_setup, 29521, faults=fast_policy())
    _, _, _, x, want = plan_setup
    with serving.CloudServer(plan):
        with serving.connect(plan, backend="socket") as sess:
            assert sess._client.use_crc          # both peers advertised
            res = sess.infer(x)
    np.testing.assert_allclose(res["logits"], want, rtol=1e-4, atol=1e-4)
    assert res["fault"] == {"faults": 0, "retries": 0, "migrations": 0,
                            "fallback": False}


def test_legacy_no_crc_peer_interoperates(plan_setup):
    """A legacy edge (no caps byte in HELLO, unsealed frames) is served
    by a fault-aware cloud on the plain wire format."""
    cfg, _, _, _, _ = plan_setup
    n = len(cfg.layers)
    plan = make_plan(plan_setup, 29522, split=n,   # c=N: logits passthrough
                     faults=fast_policy())
    logits = np.arange(7, dtype=np.float32)[None]
    with serving.CloudServer(plan):
        with socket.create_connection(("127.0.0.1", plan.port), 5) as s:
            s.settimeout(5)
            hello = encode_hello(plan.digest)        # NO caps byte
            s.sendall(struct.pack("<Q", len(hello)) + hello)
            (m,) = struct.unpack("<Q", s.recv(8, socket.MSG_WAITALL))
            reply = s.recv(m, socket.MSG_WAITALL)
            _, status, _ = decode_hello(reply)
            assert status == 0 and hello_caps(reply) == 0  # echo: no CRC
            frame = encode_tensor(logits)            # unsealed request
            s.sendall(struct.pack("<Q", len(frame)) + frame)
            (m,) = struct.unpack("<Q", s.recv(8, socket.MSG_WAITALL))
            resp = s.recv(m, socket.MSG_WAITALL)
    assert not is_sealed(resp)                       # unsealed response
    out, _ = decode_tensor(resp)
    np.testing.assert_array_equal(out, logits)


def test_corrupted_request_retried_bit_identical(plan_setup):
    """Client-side injector corrupts the first data frame: the cloud's
    CRC rejects it, the client reconnects and replays — logits
    bit-identical to the fault-free run, one fault + one retry billed."""
    _, _, _, x, _ = plan_setup
    plan = make_plan(plan_setup, 29523, faults=fast_policy())
    with serving.CloudServer(plan) as srv:
        with serving.connect(plan, backend="socket") as sess:
            clean = sess.infer(x)["logits"]
        inj = serving.FaultInjector(
            serving.FaultSchedule("c0", (FaultEvent(0, "corrupt"),)))
        with serving.connect(plan, backend="socket",
                             faults=inj) as sess:
            res = sess.infer(x)
        np.testing.assert_array_equal(res["logits"], clean)
        assert res["fault"]["faults"] == 1
        assert res["fault"]["retries"] == 1
        assert res["fault"]["fallback"] is False
        assert srv.fault_stats.get("integrity_errors", 0) >= 1


def test_dropped_response_recovers_by_replay(plan_setup):
    """Server-side injector drops a response mid-stream: the client hits
    its deadline, reconnects, replays under the same sequence number,
    and the fresh handler answers — bit-identical, no fallback."""
    _, _, _, x, _ = plan_setup
    plan = make_plan(plan_setup, 29524,
                     faults=fast_policy(request_deadline_s=1.0))
    # attempt 0 (warm-up response) clean, attempt 1 dropped
    inj = serving.FaultInjector(
        serving.FaultSchedule("d1", (FaultEvent(1, "drop"),)))
    with serving.CloudServer(plan, faults=inj):
        with serving.connect(plan, backend="socket") as sess:
            clean = sess.infer(x)["logits"]          # warm-up (attempt 0)
            res = sess.infer(x)                      # response dropped
    np.testing.assert_array_equal(res["logits"], clean)
    assert res["fault"]["faults"] >= 1
    assert res["fault"]["retries"] >= 1
    assert res["fault"]["fallback"] is False


def test_cloud_death_reconnect_bit_identical(plan_setup):
    """Kill the cloud process mid-session, bring up a fresh one on the
    same port: the client's retry loop reconnects (re-HELLO) and the
    recovered logits are bit-identical to the pre-death run."""
    _, _, _, x, _ = plan_setup
    plan = make_plan(plan_setup, 29525, faults=fast_policy())
    srv = serving.CloudServer(plan)
    with serving.connect(plan, backend="socket") as sess:
        clean = sess.infer(x)["logits"]
        srv.kill()                                   # hard mid-stream death
        with serving.CloudServer(plan):              # replacement process
            res = sess.infer(x)
        np.testing.assert_array_equal(res["logits"], clean)
        assert res["fault"]["faults"] >= 1           # death was observed
        assert res["fault"]["fallback"] is False


def test_retry_exhaustion_falls_back_edge_only(plan_setup):
    """No cloud left and the budget exhausted: the request is served
    edge-only from the bank's c=N pair — logits bit-identical to a local
    all-edge (c=N) deployment — and billed as a fallback."""
    cfg, _, _, x, _ = plan_setup
    n = len(cfg.layers)
    plan = make_plan(plan_setup, 29526,
                     faults=fast_policy(max_retries=1))
    all_edge = serving.connect(
        make_plan(plan_setup, 29526, split=n), backend="local").infer(x)
    srv = serving.CloudServer(plan)
    with serving.connect(plan, backend="socket") as sess:
        srv.kill()
        res = sess.infer(x)
    np.testing.assert_array_equal(res["logits"], all_edge["logits"])
    assert res["fault"]["fallback"] is True
    assert res["fault"]["retries"] == 1              # budget fully spent
    assert res["tx_bytes"] == 0                      # nothing on the wire
    assert res["t_total"] is not None


def test_fallback_fail_mode_raises(plan_setup):
    _, _, _, x, _ = plan_setup
    plan = make_plan(plan_setup, 29527,
                     faults=fast_policy(max_retries=0, fallback="fail"))
    srv = serving.CloudServer(plan)
    with serving.connect(plan, backend="socket") as sess:
        srv.kill()
        with pytest.raises(OSError):
            sess.infer(x)


def test_dead_cloud_read_raises_typed_timeout(plan_setup):
    """The historical bug: a cloud that accepts but never answers used
    to block ``infer`` forever. The deadline now surfaces it as
    ``RequestTimeout``."""
    cfg, params, masks, x, _ = plan_setup

    def black_hole(srv, stop):
        conn, _ = srv.accept()
        stop.wait(10)                      # read nothing, answer nothing
        conn.close()

    srv = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    srv.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
    srv.bind(("127.0.0.1", 29528))
    srv.listen(1)
    stop = threading.Event()
    t = threading.Thread(target=black_hole, args=(srv, stop), daemon=True)
    t.start()
    try:
        client = EdgeClient(
            params, cfg, SPLIT, 29528, masks=masks, compact=True,
            codec="fp32",
            fault_policy=fast_policy(max_retries=0, fallback="fail",
                                     request_deadline_s=0.3))
        with pytest.raises(serving.RequestTimeout):
            client.infer(x)
    finally:
        stop.set()
        srv.close()
        t.join(timeout=5)


# ---------------------------------------------------------------------------
# outage-aware adaptive control
# ---------------------------------------------------------------------------
def test_outage_resplits_to_edge_and_heals_back(plan_setup):
    """The degradation ladder end-to-end: an outage collapses the
    bandwidth estimate, the controller re-splits to c=N (adopted locally
    — the wire is down), and once a fresh cloud serves again the healthy
    observations pull the partition back toward offloading."""
    cfg, _, _, x, want = plan_setup
    n = len(cfg.layers)
    pol = serving.AdaptivePolicy(candidates=(SPLIT, n), ewma_alpha=1.0,
                                 min_samples=1, hysteresis=0.0, dwell=1)
    # a device slow enough (and an RTT small enough) that offloading at
    # SPLIT beats all-edge whenever the link is healthy — so heal-back
    # is the provably optimal decision, not a coin flip on a tiny net
    weak_edge = TwoTierProfile(
        ComputeProfile("weak edge", flops_per_s=1e8, mem_bw=1e8,
                       overhead_s=1e-3),
        PAPER_SERVER, LinkProfile("lan", bandwidth=100e6 / 8, rtt_s=1e-4))
    plan = make_plan(plan_setup, 29529, adaptive=pol, profile=weak_edge,
                     faults=fast_policy(max_retries=1))
    srv = serving.CloudServer(plan)
    sess = serving.connect(plan, backend="socket")
    try:
        assert sess.infer(x)["fault"]["fallback"] is False
        srv.kill()
        res = sess.infer(x)                          # outage: edge-only
        assert res["fault"]["fallback"] is True
        np.testing.assert_allclose(res["logits"], want,
                                   rtol=1e-4, atol=1e-4)
        assert sess.split == n                       # bandwidth→0 decision
        assert sess.switches and sess.switches[-1].new_split == n
        with serving.CloudServer(plan):              # the link heals
            healed = sess.infer(x)                   # reconnect + re-RESPLIT
            assert healed["fault"]["fallback"] is False
            again = sess.infer(x)                    # healthy observation in
            assert again["fault"] == {"faults": 0, "retries": 0,
                                      "migrations": 0, "fallback": False}
            assert sess.split == SPLIT               # healed back
            np.testing.assert_allclose(again["logits"], want,
                                       rtol=1e-4, atol=1e-4)
    finally:
        sess.close()


# ---------------------------------------------------------------------------
# heartbeats, reaping, graceful drain
# ---------------------------------------------------------------------------
def test_heartbeat_keeps_idle_client_alive_and_silence_reaps(plan_setup):
    _, _, _, x, _ = plan_setup
    hb = 0.05
    plan = make_plan(plan_setup, 29530,
                     faults=fast_policy(heartbeat_s=hb))
    with serving.CloudServer(plan) as srv:
        with serving.connect(plan, backend="socket") as sess:
            sess.infer(x)
            for _ in range(4):                  # idle, but heartbeating
                time.sleep(hb)
                sess._client.heartbeat()
            res = sess.infer(x)                 # connection still alive
            assert res["fault"]["faults"] == 0
            assert srv.fault_stats.get("heartbeats", 0) >= 4
            # now go silent past the 3*heartbeat window: the cloud reaps
            # the connection and the next request recovers on a fresh
            # one (baseline-relative: the first request's jit compile
            # may already have cost an earlier connection its slot)
            base = srv.fault_stats.get("reaped_conns", 0)
            deadline = time.monotonic() + 5.0
            while (srv.fault_stats.get("reaped_conns", 0) <= base
                   and time.monotonic() < deadline):
                time.sleep(hb)
            assert srv.fault_stats.get("reaped_conns", 0) > base
            res = sess.infer(x)
            assert res["fault"]["faults"] >= 1      # reap observed, retried
            assert res["fault"]["fallback"] is False


def test_graceful_drain_flushes_batched_requests(plan_setup):
    """Stopping a batching cloud is a drain, not a crash: every in-flight
    batched response is flushed (correct logits), no future is abandoned,
    and every lane queue ends empty."""
    _, _, _, x, want = plan_setup
    plan = make_plan(plan_setup, 29531,
                     batching=serving.BatchingPolicy(max_batch=4,
                                                     max_wait_ms=2.0),
                     faults=fast_policy())
    srv = serving.CloudServer(plan)
    sess = serving.connect(plan, backend="socket")
    try:
        out = sess.infer_many([x] * 8)          # pipelined through batcher
        assert len(out) == 8
        for r in out:
            np.testing.assert_allclose(r["logits"], want,
                                       rtol=1e-4, atol=1e-4)
    finally:
        sess.close()
        srv.stop()
    assert srv.fault_stats.get("abandoned_futures", 0) == 0
    lanes = {k: v for k, v in srv.batch_stats.items()
             if isinstance(v, dict) and "pending" in v}
    assert lanes                                 # the engine really served
    for k, stats in lanes.items():
        assert stats["pending"] == 0, k
        assert stats.get("failed_rows", 0) == 0, k
