"""High-availability cloud tier: fleet routing (rendezvous hashing),
health-checked failover, graceful drain/rolling restart, overload
backpressure (BUSY), and the edge-only bottom rung when the whole
fleet is gone.

The e2e drills run a real ``CloudFleet`` (one ``CloudServer`` per fleet
member port) against fleet-routed ``SocketSession``s with a no-op
``sleep_fn``, so every recovery path executes in milliseconds of
wall-clock; all logits assertions are bit-exact against the same
deployed network.
"""
from __future__ import annotations

import socket
import struct
import threading
import time

import jax
import numpy as np
import pytest

from repro import serving
from repro.core.collab.batching import (BatchingPolicy, DynamicBatcher,
                                        LaneSaturated)
from repro.core.collab.cluster import (FleetExhaustedError, FleetRouter,
                                       RoutingPolicy, _rendezvous_score)
from repro.core.collab.protocol import (decode_busy, decode_drain,
                                        decode_tensor, encode_busy,
                                        encode_drain, encode_feature,
                                        encode_heartbeat, is_busy, is_drain)
from repro.core.collab.runtime import SplitFnBank
from repro.core.fleet import ChaosEvent, FleetScenario, simulate_fleet
from repro.core.partition.profiles import ComputeProfile, FaultEvent
from repro.core.pruning.masks import cnn_masks_from_ratios
from repro.models.cnn import (cnn_apply, init_cnn_params, prunable_layers,
                              tiny_cnn_config)

SPLIT = 6
LANE = "fp32"        # the wire lane of a compact/fp32/unpacked plan


@pytest.fixture(scope="module")
def plan_setup():
    cfg = tiny_cnn_config(num_classes=7, hw=32)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    masks = cnn_masks_from_ratios(
        params, cfg, {i: 0.5 for i in prunable_layers(cfg)})
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32, 3)),
                   np.float32)
    want = np.asarray(cnn_apply(params, cfg, x, masks=masks))
    return cfg, params, masks, x, want


def make_plan(plan_setup, port, **kw):
    cfg, params, masks, _, _ = plan_setup
    kw.setdefault("split", SPLIT)
    kw.setdefault("masks", masks)
    kw.setdefault("compact", True)
    kw.setdefault("codec", "fp32")
    kw.setdefault("shape_link", False)
    return serving.DeploymentPlan.from_args(params, cfg, port=port, **kw)


def fast_policy(**kw):
    """Milliseconds-scale recovery knobs so drills never idle."""
    kw.setdefault("max_retries", 3)
    kw.setdefault("backoff_base_s", 0.01)
    kw.setdefault("backoff_max_s", 0.05)
    kw.setdefault("backoff_jitter", 0.0)
    kw.setdefault("request_deadline_s", 5.0)
    kw.setdefault("fallback", "edge")
    return serving.FaultPolicy(**kw)


def winner(ports, key=LANE, exclude=()):
    """The rendezvous pick for ``key`` among ``ports`` (test oracle)."""
    cands = [p for p in ports if p not in exclude] or list(ports)
    return max(cands, key=lambda p: (_rendezvous_score(key, p), p))


# ---------------------------------------------------------------------------
# RoutingPolicy (pure data)
# ---------------------------------------------------------------------------
def test_routing_policy_roundtrip_and_validation():
    p = RoutingPolicy(ports=(29540, 29541, 29542), suspect_after_count=2,
                      dead_after_count=4, retry_dead_s=1.5)
    assert RoutingPolicy.from_json(p.to_json()) == p
    with pytest.raises(ValueError, match="duplicate"):
        RoutingPolicy(ports=(1, 1))
    with pytest.raises(ValueError, match="suspect_after_count"):
        RoutingPolicy(ports=(1,), suspect_after_count=0)
    with pytest.raises(ValueError, match="dead_after_count"):
        RoutingPolicy(ports=(1,), suspect_after_count=3, dead_after_count=2)
    with pytest.raises(ValueError, match="retry_dead_s"):
        RoutingPolicy(ports=(1,), retry_dead_s=0)


def test_plan_routing_section_folds_into_digest_only_when_set(
        plan_setup, tmp_path):
    base = make_plan(plan_setup, 29540)
    assert "routing" not in base.contract()      # only-when-set fold
    rp = RoutingPolicy(ports=(29540, 29541, 29542))
    routed = make_plan(plan_setup, 29540, routing=rp)
    assert routed.contract()["routing"] == rp.to_json()
    assert base.digest != routed.digest
    path = routed.save(str(tmp_path / "deploy"))
    reloaded = serving.DeploymentPlan.load(path)
    assert reloaded.routing == rp
    assert reloaded.digest == routed.digest


# ---------------------------------------------------------------------------
# FleetRouter (unit, fake clock)
# ---------------------------------------------------------------------------
def test_rendezvous_routing_is_stable_and_minimally_disruptive():
    ports = (29540, 29541, 29542)
    t = [0.0]
    r = FleetRouter(RoutingPolicy(ports=ports), clock=lambda: t[0])
    host, p1 = r.route(LANE)
    assert host == "127.0.0.1" and p1 == winner(ports)
    assert r.route(LANE)[1] == p1            # same key -> same member
    # losing a NON-winning member must not remap the lane (the
    # rendezvous property a mod-N ring does not have)
    loser = next(q for q in ports if q != p1)
    r.note_miss(loser)
    r.note_miss(loser)                       # dead at the default ladder
    assert r.route(LANE)[1] == p1
    assert set(r.healthy_ports()) == set(ports) - {loser}


def test_route_exclusion_is_a_preference_not_a_filter():
    ports = (29540, 29541, 29542)
    r = FleetRouter(RoutingPolicy(ports=ports))
    p1 = r.route(LANE)[1]
    p2 = r.route(LANE, exclude=(p1,))[1]
    assert p2 != p1 and p2 == winner(ports, exclude=(p1,))
    assert r.stats()["reroutes_count"] == 1
    # excluding everything still hands out a member: a lone server is
    # retried, never silently dropped (and that is not a reroute)
    p3 = r.route(LANE, exclude=ports)[1]
    assert p3 in ports
    assert r.stats()["reroutes_count"] == 1


def test_health_ladder_miss_suspect_dead_and_timed_reprobe():
    t = [0.0]
    r = FleetRouter(RoutingPolicy(ports=(1, 2), suspect_after_count=1,
                                  dead_after_count=2, retry_dead_s=5.0),
                    clock=lambda: t[0])
    assert r.state(1) == "healthy"
    assert r.note_miss(1) == "suspect"
    assert 1 in r.healthy_ports()            # suspect is still routable
    assert r.note_miss(1) == "dead"
    assert r.healthy_ports() == (2,)
    with pytest.raises(FleetExhaustedError):
        r.note_miss(2), r.note_miss(2)
        r.route(LANE)
    t[0] = 4.9
    assert r.healthy_ports() == ()
    t[0] = 5.0                               # retry window: dead -> probe
    assert set(r.healthy_ports()) == {1, 2}
    r.note_ok(1)                             # a probe success heals it
    assert r.state(1) == "healthy"
    # drain is sticky: not routable, immune to note_ok, until revive
    r.note_drain(1)
    r.note_ok(1)
    assert r.state(1) == "draining" and 1 not in r.healthy_ports()
    r.revive(1)
    assert r.state(1) == "healthy"
    st = r.stats()["servers"]
    assert st[2]["state"] == "dead" and st[2]["miss_count"] == 2


# ---------------------------------------------------------------------------
# DRAIN / BUSY control frames
# ---------------------------------------------------------------------------
def test_drain_and_busy_frame_roundtrips():
    d = encode_drain()
    assert is_drain(d) and not is_busy(d)
    assert decode_drain(d) == (0, 1)
    b = encode_busy("queue", redirect=False)
    assert is_busy(b) and not is_drain(b)
    assert decode_busy(b) == ("queue", False, 1)
    assert decode_busy(encode_busy("queue"))[1] is True
    with pytest.raises(ValueError, match="BUSY reason"):
        encode_busy("martians")
    with pytest.raises(ValueError, match="magic"):
        decode_drain(b)
    with pytest.raises(ValueError, match="magic"):
        decode_busy(d)
    assert not is_drain(encode_heartbeat())
    assert not is_busy(b"")


# ---------------------------------------------------------------------------
# bounded lanes (unit)
# ---------------------------------------------------------------------------
def test_batching_policy_max_queue_roundtrip_and_validation():
    p = BatchingPolicy(max_batch=4, max_queue=2)
    assert p.to_json()["max_queue"] == 2
    assert BatchingPolicy.from_json(p.to_json()) == p
    # unbounded lanes serialize WITHOUT the key: pre-HA plan digests
    # must stay byte-for-byte unchanged
    assert "max_queue" not in BatchingPolicy(max_batch=4).to_json()
    with pytest.raises(ValueError, match="max_queue"):
        BatchingPolicy(max_batch=4, max_queue=0)


def test_bounded_lane_raises_lane_saturated(plan_setup):
    cfg, params, masks, x, _ = plan_setup
    bank = SplitFnBank(params, cfg, masks, True)
    edge_fn, cloud_fn, _ = bank.get(SPLIT)
    feat = np.asarray(edge_fn(jax.numpy.asarray(x)))
    ref = np.asarray(cloud_fn(feat))     # the engine's bit-identity oracle
    started, gate = threading.Event(), threading.Event()

    def hold(c, rows):
        started.set()
        gate.wait(10.0)

    engine = DynamicBatcher(bank,
                            BatchingPolicy(max_batch=1, max_wait_ms=1.0,
                                           max_queue=1),
                            invoke_cost=hold)
    try:
        f1 = engine.submit(SPLIT, LANE, feat)
        assert started.wait(10.0)            # batch 1 holds the lane
        f2 = engine.submit(SPLIT, LANE, feat)     # fills the bounded queue
        with pytest.raises(LaneSaturated):
            engine.submit(SPLIT, LANE, feat)
        gate.set()
        for f in (f1, f2):
            assert np.array_equal(np.asarray(f.result(timeout=10.0)), ref)
    finally:
        gate.set()
        engine.stop()


# ---------------------------------------------------------------------------
# e2e: kill a member
# ---------------------------------------------------------------------------
def test_kill_one_of_three_reroutes_bit_identical(plan_setup):
    _, _, _, x, want = plan_setup
    ports = (29543, 29544, 29545)
    plan = make_plan(plan_setup, ports[0], faults=fast_policy(),
                     routing=RoutingPolicy(ports=ports, dead_after_count=1))
    with serving.CloudFleet(plan) as fleet:
        with serving.connect(plan, backend="socket",
                             sleep_fn=lambda s: None) as sess:
            r0 = sess.infer(x)
            np.testing.assert_allclose(r0["logits"], want,
                                       rtol=1e-4, atol=1e-4)
            assert r0["fault"] == {"faults": 0, "retries": 0,
                                   "migrations": 0, "fallback": False}
            victim = sess._client._port
            assert victim == winner(ports)
            fleet.kill(victim)
            r1 = sess.infer(x)               # reroute + replay
            # the survivor runs the SAME deployed split: logits from the
            # rerouted replay are bit-identical to the pre-kill server's
            assert np.array_equal(np.asarray(r1["logits"]),
                                  np.asarray(r0["logits"]))
            assert r1["fault"]["fallback"] is False
            assert r1["fault"]["faults"] >= 1
            assert sess._client._port == winner(ports, exclude=(victim,))
            stats = sess.router.stats()
            assert stats["servers"][victim]["state"] == "dead"
            assert stats["reroutes_count"] >= 1
            # the surviving members keep serving cleanly
            r2 = sess.infer(x)
            assert np.array_equal(np.asarray(r2["logits"]),
                                  np.asarray(r0["logits"]))
            assert r2["fault"]["faults"] == 0


# ---------------------------------------------------------------------------
# e2e: rolling restart (drain every member, zero failed requests)
# ---------------------------------------------------------------------------
def test_rolling_drain_of_whole_fleet_zero_failed_requests(plan_setup):
    _, _, _, x, want = plan_setup
    ports = (29546, 29547, 29548)
    plan = make_plan(plan_setup, ports[0], faults=fast_policy(),
                     routing=RoutingPolicy(ports=ports))
    migrations = 0
    with serving.CloudFleet(plan) as fleet:
        with serving.connect(plan, backend="socket",
                             sleep_fn=lambda s: None) as sess:
            ref = np.asarray(sess.infer(x)["logits"])
            np.testing.assert_allclose(ref, want, rtol=1e-4, atol=1e-4)
            for _ in range(len(ports)):      # one round per member
                victim = sess._client._port
                fleet.drain(victim)
                assert fleet.server(victim).draining
                for _ in range(2):
                    r = sess.infer(x)
                    assert np.array_equal(np.asarray(r["logits"]), ref)
                    # a drain migration is NOT a fault: the request
                    # replays on another member without failing
                    assert r["fault"]["faults"] == 0
                    assert r["fault"]["fallback"] is False
                    migrations += r["fault"]["migrations"]
                assert sess._client._port != victim
                fleet.restart(victim)
                sess.router.revive(victim)
                assert sess.router.state(victim) == "healthy"
    assert migrations == len(ports)          # each round migrated once


# ---------------------------------------------------------------------------
# e2e: whole fleet gone -> edge-only bottom rung
# ---------------------------------------------------------------------------
def test_whole_fleet_down_degrades_to_edge_only_parity(plan_setup):
    _, _, _, x, want = plan_setup
    ports = (29549, 29550)
    plan = make_plan(plan_setup, ports[0], faults=fast_policy(),
                     routing=RoutingPolicy(ports=ports, dead_after_count=1))
    with serving.CloudFleet(plan) as fleet:
        with serving.connect(plan, backend="socket",
                             sleep_fn=lambda s: None) as sess:
            np.testing.assert_allclose(sess.infer(x)["logits"], want,
                                       rtol=1e-4, atol=1e-4)
            for p in ports:
                fleet.kill(p)
            r = sess.infer(x)
            assert r["fault"]["fallback"] is True
            assert r["fault"]["faults"] >= 2     # both members were tried
            assert r["tx_bytes"] == 0            # nothing crossed the wire
            # the bottom rung serves the SAME deployed network (c=N)
            np.testing.assert_allclose(r["logits"], want,
                                       rtol=1e-4, atol=1e-4)
            assert sess.router.healthy_ports() == ()


# ---------------------------------------------------------------------------
# e2e: overload backpressure (BUSY)
# ---------------------------------------------------------------------------
def test_saturated_lane_sheds_busy_instead_of_stalling(plan_setup):
    _, _, _, x, want = plan_setup
    port = 29551
    plan = make_plan(plan_setup, port,
                     batching=BatchingPolicy(max_batch=1, max_wait_ms=1.0,
                                             max_queue=1))
    # a modeled accelerator with a fat per-invocation constant holds the
    # lane long enough that back-to-back raw frames overflow the bound
    molasses = ComputeProfile("molasses", flops_per_s=1e12, mem_bw=1e12,
                              overhead_s=0.4)
    cfg, params, masks, _, _ = plan_setup
    bank = SplitFnBank(params, cfg, masks, True)
    edge_fn, _, _ = bank.get(SPLIT)
    payload = encode_feature(np.asarray(edge_fn(jax.numpy.asarray(x))),
                             codec="fp32")
    srv = serving.CloudServer(plan, max_clients=1, verify=False,
                              simulate_server=molasses)
    try:
        with socket.create_connection(("127.0.0.1", port), timeout=10) as s:
            s.settimeout(10.0)
            for _ in range(3):               # burst: no reads in between
                s.sendall(struct.pack("<Q", len(payload)) + payload)
            replies = []
            for _ in range(3):
                (n,) = struct.unpack("<Q", _read_exact(s, 8))
                replies.append(_read_exact(s, n))
    finally:
        srv.stop()
    busy = [b for b in replies if is_busy(b)]
    served = [np.asarray(decode_tensor(b)[0])
              for b in replies if not is_busy(b)]
    assert len(busy) >= 1                    # the bound shed, no stall
    for b in busy:
        assert decode_busy(b)[0] == "queue"
    for logits in served:                    # the admitted rows still serve
        np.testing.assert_allclose(logits, want, rtol=1e-4, atol=1e-4)
    assert srv.fault_stats.get("busy_shed", 0) == len(busy)


def _read_exact(s: socket.socket, n: int) -> bytes:
    buf = b""
    while len(buf) < n:
        chunk = s.recv(n - len(buf))
        if not chunk:
            raise EOFError("peer closed")
        buf += chunk
    return buf


def test_busy_reply_redirects_to_another_member(plan_setup):
    _, _, _, x, want = plan_setup
    ports = (29552, 29553)
    hot = winner(ports)                      # where the lane hashes first
    cold = next(p for p in ports if p != hot)
    stop = threading.Event()

    def always_busy():
        """A member whose lanes are permanently saturated: every data
        frame is answered with BUSY(redirect)."""
        lst = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        lst.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        lst.bind(("127.0.0.1", hot))
        lst.listen(4)
        lst.settimeout(0.2)
        conns = []
        try:
            while not stop.is_set():
                try:
                    c, _ = lst.accept()
                except socket.timeout:
                    continue
                conns.append(c)
                try:
                    (n,) = struct.unpack("<Q", _read_exact(c, 8))
                    _read_exact(c, n)
                    busy = encode_busy("queue", redirect=True)
                    c.sendall(struct.pack("<Q", len(busy)) + busy)
                except (EOFError, OSError, struct.error):
                    pass
        finally:
            for c in conns:
                c.close()
            lst.close()

    t = threading.Thread(target=always_busy, daemon=True)
    t.start()
    plan = make_plan(plan_setup, cold, faults=fast_policy(),
                     routing=RoutingPolicy(ports=ports))
    sleeps = []
    try:
        with serving.CloudServer(plan, port=cold, max_clients=None,
                                 verify=False):
            with serving.connect(plan, backend="socket", verify=False,
                                 sleep_fn=sleeps.append) as sess:
                assert sess._client._port == hot
                r = sess.infer(x)
                np.testing.assert_allclose(r["logits"], want,
                                           rtol=1e-4, atol=1e-4)
                assert r["fault"]["migrations"] == 1
                assert r["fault"]["faults"] == 0
                assert sess._client._port == cold
                assert sess.router.stats()["reroutes_count"] >= 1
    finally:
        stop.set()
        t.join(timeout=5)
    assert sleeps == []                      # a redirect never backs off


# ---------------------------------------------------------------------------
# recovery plumbing: injectable backoff sleep
# ---------------------------------------------------------------------------
def test_sleep_fn_receives_the_deterministic_backoff(plan_setup):
    _, _, _, x, want = plan_setup
    port = 29554
    plan = make_plan(plan_setup, port,
                     faults=fast_policy(request_deadline_s=1.0))
    inj = serving.FaultInjector(
        serving.FaultSchedule("one_drop", (FaultEvent(0, "drop"),)))
    sleeps = []
    with serving.CloudServer(plan, max_clients=None):
        with serving.connect(plan, backend="socket", faults=inj,
                             sleep_fn=sleeps.append) as sess:
            r = sess.infer(x)
    np.testing.assert_allclose(r["logits"], want, rtol=1e-4, atol=1e-4)
    assert r["fault"]["faults"] == 1 and r["fault"]["retries"] == 1
    # jitter-free policy: the recorded pause IS backoff_s(0), proving
    # the injected sleep replaced time.sleep on the recovery path
    assert sleeps == [pytest.approx(plan.faults.backoff_s(0), abs=1e-9)]


# ---------------------------------------------------------------------------
# congestion-aware adaptive splitting
# ---------------------------------------------------------------------------
def test_note_congestion_waives_dwell_without_collapsing_estimate():
    from repro.core.collab.adaptive import (AdaptivePolicy,
                                            AdaptiveSplitController)
    from repro.core.partition.latency_model import cnn_layer_costs
    from repro.core.partition.profiles import (PAPER_SERVER, LinkProfile,
                                               TwoTierProfile)
    cfg = tiny_cnn_config(num_classes=7, hw=32)
    edge = ComputeProfile("mcu", flops_per_s=50e6, mem_bw=1e9,
                          overhead_s=1e-4)
    prof = TwoTierProfile(edge, PAPER_SERVER,
                          LinkProfile("wifi", bandwidth=50e6 / 8,
                                      rtt_s=1e-3))
    policy = AdaptivePolicy(candidates=(0, 3, 6, 13), ewma_alpha=1.0,
                            min_samples=1, hysteresis=0.05, dwell=2)
    ctl = AdaptiveSplitController.for_deployment(cfg, policy, 0, prof)
    fast, slow = 50e6 / 8, 2e6 / 8
    # at the deployment bandwidth the current split stays optimal (and
    # the dwell counter warms past its threshold)
    assert ctl.step(12_000, 12_000 / fast + 1e-3) is None
    assert ctl.step(12_000, 12_000 / fast + 1e-3) is None
    sw = ctl.step(12_000, 12_000 / slow + 1e-3)      # collapse: offload less
    assert sw is not None and sw.new_split != 0
    # the link heals, but dwell blocks the walk back...
    assert ctl.step(12_000, 12_000 / fast + 1e-3) is None
    # ...until fleet backpressure waives it: re-decide NOW at the
    # current (healthy) estimate — the congestion answer is a re-split,
    # not an outage-style estimator collapse
    sw2 = ctl.note_congestion()
    assert sw2 is not None and sw2.new_split == 0
    assert ctl.estimator.bandwidth == pytest.approx(fast)


# ---------------------------------------------------------------------------
# fleet simulator chaos events
# ---------------------------------------------------------------------------
def test_chaos_event_roundtrip_validation_and_scenario_fold():
    ev = ChaosEvent(t_s=5.0, kind="kill", cloudlet=1)
    assert ChaosEvent.from_json(ev.to_json()) == ev
    with pytest.raises(ValueError, match="kind"):
        ChaosEvent(t_s=1.0, kind="meteor")
    with pytest.raises(ValueError, match="t_s"):
        ChaosEvent(t_s=-1.0, kind="kill")
    calm = FleetScenario(name="calm", seed=3, n_edges=50)
    assert "chaos" not in calm.to_json()     # pre-chaos digests unchanged
    stormy = FleetScenario(name="storm", seed=3, n_edges=50,
                           chaos=(ev, ChaosEvent(t_s=9.0, kind="revive",
                                                 cloudlet=1)))
    assert FleetScenario.from_json(stormy.to_json()).chaos == stormy.chaos
    with pytest.raises(ValueError, match="ChaosEvent"):
        FleetScenario(name="bad", chaos=({"t_s": 1.0},))


def test_fleet_sim_chaos_reroutes_deterministically():
    base = dict(seed=17, n_edges=150, n_cloudlets=3, duration_s=20.0)
    calm = simulate_fleet(FleetScenario(name="calm", **base))
    assert calm["chaos_reroutes_count"] == 0
    chaos = (ChaosEvent(t_s=5.0, kind="kill", cloudlet=0),
             ChaosEvent(t_s=8.0, kind="drain", cloudlet=1),
             ChaosEvent(t_s=14.0, kind="revive", cloudlet=0))
    sc = FleetScenario(name="storm", chaos=chaos, **base)
    r = simulate_fleet(sc)
    assert r["chaos_reroutes_count"] > 0     # orphans + arrivals moved
    assert r == simulate_fleet(sc)           # virtual clock: bit-identical
