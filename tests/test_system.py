"""End-to-end behaviour of the paper's system at reduced scale: the full
two-stage pipeline (train -> DDPG prune -> fine-tune -> greedy split) and
the joint claims the paper makes about it."""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.core.pipeline import (evaluate_topk, run_paper_pipeline,
                                 train_cnn)
from repro.data.synthetic import PlantVillageSynthetic
from repro.models.cnn import cnn_apply, init_cnn_params, tiny_cnn_config


@pytest.fixture(scope="module")
def pipeline_result():
    cfg = tiny_cnn_config(num_classes=38, width=0.2, hw=32)
    data = PlantVillageSynthetic(n_per_class=12, hw=32)
    # adamw at reduced scale (paper's SGD recipe needs many more epochs
    # at tiny width — DESIGN.md §7; the SGD/StepLR recipe itself is
    # validated in test_substrate.py)
    return run_paper_pipeline(cfg, data, train_epochs=6, finetune_epochs=2,
                              episodes=8, warmup=3, flops_budget=0.6,
                              seed=0, optimizer_name="adamw", lr=3e-3)


def test_training_learns(pipeline_result):
    """The original model beats the 1/38 random baseline by a wide margin."""
    assert pipeline_result.acc_original["top1"] > 0.30
    assert pipeline_result.acc_original["top5"] > 0.55


def test_paper_table1_ordering(pipeline_result):
    """Table 1 qualitative claims: pruning costs some accuracy; top-k
    accuracies are monotone in k."""
    r = pipeline_result
    for acc in (r.acc_original, r.acc_pruned, r.acc_finetuned):
        assert acc["top1"] <= acc["top3"] <= acc["top5"]
    # fine-tuning recovers (or beats) the pruned accuracy
    assert r.acc_finetuned["top1"] >= r.acc_pruned["top1"] - 0.02


def test_pruning_reduces_flops(pipeline_result):
    assert pipeline_result.search.best_flops_kept < 0.95
    assert 0 < len(pipeline_result.ratios)
    assert all(0.05 <= a <= 1.0 for a in
               pipeline_result.ratios.values())


def test_split_decision_valid(pipeline_result):
    r = pipeline_result
    n = len(r.cfg.layers)
    assert 0 <= r.split.split_point <= n
    # the split table covers every candidate (Algorithm 1 sweep)
    assert len(r.split.table) == n + 1
    best = min(row["T"] for row in r.split.table)
    assert r.split.latency["T"] == best


def test_deployment_artifacts_compacted(pipeline_result):
    """Stage 6: the pipeline emits physically smaller deployment params
    whose logits match masked execution, plus a split re-priced on the
    compacted shapes."""
    r = pipeline_result
    assert r.compact_cfg is not None and r.deploy_split is not None
    nparams = lambda p: sum(int(np.prod(v.shape))          # noqa: E731
                            for lyr in p.values() for v in lyr.values())
    assert nparams(r.compact_params) < nparams(r.params)
    x = np.random.RandomState(0).rand(4, 32, 32, 3).astype(np.float32)
    masked = np.asarray(cnn_apply(r.params, r.cfg, x, masks=r.masks))
    compact = np.asarray(cnn_apply(r.compact_params, r.compact_cfg, x))
    np.testing.assert_allclose(compact, masked, rtol=1e-4, atol=1e-4)
    n = len(r.cfg.layers)
    assert 0 <= r.deploy_split.split_point <= n


def test_pipeline_emits_deployment_plan(pipeline_result):
    """Stage 6 packages the full deployment contract as a serveable
    DeploymentPlan: same logits as direct masked execution."""
    from repro import serving
    r = pipeline_result
    assert r.plan is not None
    assert r.plan.split == r.deploy_split.split_point
    assert r.plan.compact and r.plan.codec == r.deploy_codec
    assert len(r.plan.digest) == 16
    x = np.random.RandomState(0).rand(2, 32, 32, 3).astype(np.float32)
    masked = np.asarray(cnn_apply(r.params, r.cfg, x, masks=r.masks))
    with serving.connect(r.plan, backend="local") as sess:
        out = sess.infer(x)
    np.testing.assert_allclose(out["logits"], masked, rtol=1e-4, atol=1e-4)


def test_finetune_actually_trains():
    cfg = tiny_cnn_config(num_classes=38, width=0.2, hw=32)
    data = PlantVillageSynthetic(n_per_class=8, hw=32)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    acc0 = evaluate_topk(params, cfg, data, ks=(1,))
    params, hist = train_cnn(params, cfg, data, epochs=3,
                             optimizer_name="adamw", lr=3e-3)
    acc1 = evaluate_topk(params, cfg, data, ks=(1,))
    assert hist[-1] < hist[0]
    assert acc1["top1"] >= acc0["top1"]
