"""Partitioning: latency model, greedy split (Algorithm 1), paper-shape
claims on AlexNet."""
from __future__ import annotations

import jax
import numpy as np

from repro.configs.registry import get_config
from repro.core.partition.latency_model import (cnn_input_bytes,
                                                cnn_layer_costs,
                                                measure_cnn_layer_times,
                                                split_latency,
                                                transformer_layer_costs)
from repro.core.partition.profiles import (PAPER_PROFILE, PROFILES,
                                           TPU_TWO_POD)
from repro.core.partition.splitter import greedy_split, sweep_splits
from repro.models.cnn import alexnet_config, init_cnn_params, tiny_cnn_config


def test_alexnet_layer_costs_shape():
    cfg = alexnet_config()
    costs = cnn_layer_costs(cfg)
    assert len(costs) == len(cfg.layers)
    # Fig. 2 qualitative claims: pooling shrinks activations
    sizes = [c.out_bytes for c in costs]
    pools = [i for i, s in enumerate(cfg.layers) if s.kind == "maxpool"]
    for p in pools:
        assert sizes[p] < sizes[p - 1]
    # total FLOPs ~ 1.4 GFLOPs for AlexNet-ish at 224 (batch 1, 2*MACs)
    total = sum(c.flops for c in costs)
    assert 0.8e9 < total < 3e9


def test_device_only_vs_server_only_endpoints():
    """c=N is device-only (no TX); c=0 is server-only (ships raw input)."""
    cfg = alexnet_config()
    costs = cnn_layer_costs(cfg)
    n = len(costs)
    dev_only = split_latency(costs, n, PAPER_PROFILE, cnn_input_bytes(cfg))
    srv_only = split_latency(costs, 0, PAPER_PROFILE, cnn_input_bytes(cfg))
    assert dev_only["T_TX"] == 0.0 and dev_only["T_S"] == 0.0
    assert srv_only["T_D"] == 0.0
    assert srv_only["tx_bytes"] == cnn_input_bytes(cfg)
    # paper Fig. 5: on the paper's hardware the server GPU is far faster
    assert srv_only["T_S"] < dev_only["T_D"]


PAPER_TABLE2_MS = {1: 99.91, 2: 166.98, 3: 65.89, 4: 85.03, 5: 31.91,
                   6: 20.07, 7: 60.88, 8: 40.98, 9: 55.93, 10: 37.96,
                   11: 57.79, 12: 36.11, 13: 27.96, 14: 26.34, 15: 39.15,
                   16: 34.57, 17: 31.75, 18: 36.04, 19: 36.67, 20: 36.59}


def test_greedy_on_paper_measured_table2_picks_split_6():
    """Algorithm 1 lines 20-27 operate on MEASURED T(G', j); on the paper's
    own Table 2 numbers the argmin must be split 6."""
    c_best, t_best = 1, PAPER_TABLE2_MS[1]
    for j in range(2, 21):                        # the paper's exact loop
        if PAPER_TABLE2_MS[j] < t_best:
            c_best, t_best = j, PAPER_TABLE2_MS[j]
    assert c_best == 6 and t_best == 20.07


def test_alexnet_analytic_optimum_beats_endpoints():
    """The greedy optimum can never lose to device-only / server-only
    (both are candidates); on the analytic paper profile the server-only
    endpoint is strongly transmission-dominated (paper Fig. 5 shape)."""
    cfg = alexnet_config()
    costs = cnn_layer_costs(cfg)
    dec = greedy_split(costs, PAPER_PROFILE, cnn_input_bytes(cfg))
    n = len(costs)
    dev_only = split_latency(costs, n, PAPER_PROFILE, cnn_input_bytes(cfg))
    srv_only = split_latency(costs, 0, PAPER_PROFILE, cnn_input_bytes(cfg))
    assert dec.latency["T"] <= dev_only["T"]
    assert dec.latency["T"] <= srv_only["T"]
    assert srv_only["T_TX"] > 0.5 * srv_only["T"]


def test_pruning_improves_best_latency():
    cfg = alexnet_config()
    dense = greedy_split(cnn_layer_costs(cfg), PAPER_PROFILE,
                         cnn_input_bytes(cfg))
    import jax.numpy as jnp
    masks = {i: jnp.asarray(
        np.r_[np.ones(s.out_channels // 2), np.zeros(s.out_channels -
                                                     s.out_channels // 2)]
        .astype(np.float32))
        for i, s in enumerate(cfg.layers) if s.kind == "conv" and i > 0}
    pruned = greedy_split(cnn_layer_costs(cfg, masks), PAPER_PROFILE,
                          cnn_input_bytes(cfg))
    assert pruned.latency["T"] < dense.latency["T"]


def test_measured_timestamps_drive_split(tmp_path):
    """Algorithm 1 line 22 path: per-layer wall-clock timestamps."""
    cfg = tiny_cnn_config(hw=32)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32, 3))
    times = measure_cnn_layer_times(params, cfg, x, repeats=1)
    assert len(times) == len(cfg.layers)
    assert all(t >= 0 for t in times)
    costs = cnn_layer_costs(cfg)
    dec = greedy_split(costs, PAPER_PROFILE, cnn_input_bytes(cfg),
                       measured_device_s=times)
    assert 0 <= dec.split_point <= len(costs)


def test_sweep_table_covers_all_candidates():
    cfg = tiny_cnn_config()
    costs = cnn_layer_costs(cfg)
    table = sweep_splits(costs, PAPER_PROFILE, cnn_input_bytes(cfg))
    assert [r["split"] for r in table] == list(range(len(costs) + 1))


def test_transformer_costs_all_archs():
    for arch in ["qwen2-7b", "mixtral-8x7b", "mamba2-2.7b",
                 "deepseek-v3-671b"]:
        cfg = get_config(arch)
        costs = transformer_layer_costs(cfg, seq_len=4096)
        assert len(costs) == cfg.num_layers
        assert all(c.flops > 0 and c.out_bytes > 0 for c in costs)
        dec = greedy_split(costs, TPU_TWO_POD,
                           input_bytes=4096 * cfg.d_model * 2)
        assert 0 <= dec.split_point <= cfg.num_layers


def test_profiles_registry():
    assert set(PROFILES) == {"paper", "paper_farm", "tpu_two_pod",
                             "tpu_edge_cloud"}
    p = PROFILES["paper"]
    assert p.link.bandwidth == 50e6 / 8          # 50 Mbps
