"""DRL pruning stack: masks, environment, DDPG agent, policy search."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_smoke_config
from repro.core.pruning.amc_env import (LayerDesc, PruningEnv,
                                        cnn_layer_descs,
                                        transformer_layer_descs)
from repro.core.pruning.ddpg import (ReplayBuffer, actor_apply, agent_update,
                                     critic_apply, init_agent,
                                     truncated_normal_action)
from repro.core.pruning.masks import (cnn_masks_from_ratios, mask_sparsity,
                                      transformer_masks_from_ratios,
                                      transformer_prunable_units)
from repro.core.pruning.policy import search_pruning_policy
from repro.models import transformer as tr
from repro.models.cnn import (cnn_apply, compact_params, init_cnn_params,
                              prunable_layers, tiny_cnn_config)


# ---------------------------------------------------------------------------
# CNN masks + compaction
# ---------------------------------------------------------------------------
def test_masked_equals_compacted():
    """Mask-based execution == physically compacted network (same logits)."""
    cfg = tiny_cnn_config(num_classes=7, hw=32)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    ratios = {i: 0.5 for i in prunable_layers(cfg)}
    masks = cnn_masks_from_ratios(params, cfg, ratios)
    # classifier head stays dense in ratios? prunable_layers excludes head
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3))
    masked = cnn_apply(params, cfg, x, masks=masks)
    cparams, ccfg = compact_params(params, cfg, masks)
    compact = cnn_apply(cparams, ccfg, x)
    np.testing.assert_allclose(np.asarray(masked), np.asarray(compact),
                               rtol=1e-4, atol=1e-4)


def test_cnn_masks_keep_ratio():
    cfg = tiny_cnn_config()
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    masks = cnn_masks_from_ratios(params, cfg, {0: 0.25})
    m = np.asarray(masks[0])
    n = cfg.layers[0].out_channels
    assert int(m.sum()) == max(1, round(0.25 * n))


def test_transformer_masks_structure():
    cfg = get_smoke_config("qwen2-7b").replace(dtype="float32")
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    units = transformer_prunable_units(cfg)
    ratios = [0.5] * len(units)
    masks = transformer_masks_from_ratios(params, cfg, ratios)
    assert len(masks) == len(tr.layer_runs(cfg))
    # GQA group preservation: head mask constant within each kv group
    hm = np.asarray(masks[0]["head_mask"])         # (count, H)
    g = cfg.num_heads // cfg.num_kv_heads
    per_group = hm.reshape(hm.shape[0], cfg.num_kv_heads, g)
    assert (per_group == per_group[..., :1]).all()
    assert 0.0 < mask_sparsity(masks) < 1.0


def test_transformer_masked_forward_runs():
    cfg = get_smoke_config("mixtral-8x7b").replace(dtype="float32")
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    units = transformer_prunable_units(cfg)
    masks = transformer_masks_from_ratios(params, cfg,
                                          [0.6] * len(units))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0,
                             cfg.vocab_size)
    logits, _ = tr.forward(params, cfg, {"tokens": tok}, masks=masks)
    assert bool(jnp.isfinite(logits).all())
    # masked decode path too
    lg, cache = tr.prefill(params, cfg, {"tokens": tok}, max_len=12,
                           masks=masks)
    lg2, _ = tr.decode_step(params, cfg, cache, tok[:, :1], masks=masks)
    assert bool(jnp.isfinite(lg2).all())


def test_ssm_mask_forward():
    cfg = get_smoke_config("mamba2-2.7b").replace(dtype="float32")
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    units = transformer_prunable_units(cfg)
    assert all(u["axis"] == "ssm_head_mask" for u in units)
    masks = transformer_masks_from_ratios(params, cfg, [0.5] * len(units))
    tok = jnp.zeros((1, 8), jnp.int32)
    logits, _ = tr.forward(params, cfg, {"tokens": tok}, masks=masks)
    assert bool(jnp.isfinite(logits).all())


# ---------------------------------------------------------------------------
# layer descriptors
# ---------------------------------------------------------------------------
def test_cnn_layer_descs_match_prunable():
    cfg = tiny_cnn_config()
    descs = cnn_layer_descs(cfg)
    assert [d.index for d in descs] == prunable_layers(cfg)
    assert all(d.flops > 0 for d in descs)


def test_transformer_layer_descs_align_with_units():
    cfg = get_smoke_config("deepseek-v3-671b").replace(dtype="float32")
    units = transformer_prunable_units(cfg)
    descs = transformer_layer_descs(cfg)
    assert len(descs) == len(units)
    assert all(d.flops > 0 for d in descs)


# ---------------------------------------------------------------------------
# DDPG
# ---------------------------------------------------------------------------
def test_ddpg_actor_range():
    agent = init_agent(jax.random.PRNGKey(0), 11)
    s = jax.random.normal(jax.random.PRNGKey(1), (32, 11))
    a = actor_apply(agent.actor, s)
    assert float(a.min()) >= 0.05 and float(a.max()) <= 1.0


def test_truncated_noise_in_bounds():
    key = jax.random.PRNGKey(0)
    a = truncated_normal_action(key, jnp.full((256,), 0.5), 0.5)
    assert float(a.min()) >= 0.05 and float(a.max()) <= 1.0


def test_ddpg_update_learns_reward_signal():
    """Critic learns to predict a reward that prefers high actions; the
    actor follows (mean action increases)."""
    key = jax.random.PRNGKey(0)
    agent = init_agent(key, 11)
    rng = np.random.RandomState(0)
    buf = ReplayBuffer(11, capacity=500)
    for _ in range(300):
        s = rng.rand(11).astype(np.float32)
        a = rng.uniform(0.05, 1.0)
        r = a                                    # reward = action
        buf.add(s, a, r, np.zeros(11, np.float32), 1.0)
    s_test = jnp.asarray(rng.rand(64, 11).astype(np.float32))
    a0 = float(actor_apply(agent.actor, s_test).mean())
    for _ in range(200):
        agent, metrics = agent_update(agent, buf.sample(rng, 64),
                                      baseline=0.5)
    a1 = float(actor_apply(agent.actor, s_test).mean())
    assert a1 > a0 + 0.1, (a0, a1)
    assert np.isfinite(float(metrics["critic_loss"]))


def test_policy_search_finds_flops_heavy_layer():
    """Toy env: accuracy only depends on keeping layer 0 (others free).
    The search should learn to keep layer 0 and prune the rest."""
    descs = [LayerDesc(i, 32, 32, 4, 4, 1, 3, 1e8, in_coupled=False)
             for i in range(4)]

    def evaluate(ratios):
        return float(ratios[0]) - 0.1 * float(np.mean(ratios[1:]))

    env = PruningEnv(descs, evaluate, flops_budget=0.5)
    res = search_pruning_policy(env, episodes=60, warmup=10, seed=0)
    assert res.best_reward > 0.55
    assert res.best_ratios[0] > np.mean(res.best_ratios[1:])
    assert res.best_flops_kept <= 0.75


def test_replay_buffer_ring():
    buf = ReplayBuffer(4, capacity=8)
    for i in range(20):
        buf.add(np.full(4, i, np.float32), i, i, np.zeros(4), 0.0)
    assert buf.n == 8
    sample = buf.sample(np.random.RandomState(0), 16)
    assert float(sample["action"].min()) >= 12      # oldest overwritten
