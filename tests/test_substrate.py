"""Substrate layers: optimizers, schedules, data pipeline, checkpointing."""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.data.synthetic import (NUM_CLASSES, PlantVillageSynthetic,
                                  make_image, stratified_split)
from repro.optim import (adamw, constant, cosine_warmup, make_optimizer,
                         sgd_momentum, step_lr)


# ---------------------------------------------------------------------------
# optimizers / schedules
# ---------------------------------------------------------------------------
def test_step_lr_schedule_paper_recipe():
    """lr0=0.01, x0.1 every 20 epochs (paper §4.1)."""
    sched = step_lr(0.01, 0.1, 20, steps_per_epoch=10)
    np.testing.assert_allclose(float(sched(0)), 0.01, rtol=1e-6)
    np.testing.assert_allclose(float(sched(199)), 0.01, rtol=1e-6)
    np.testing.assert_allclose(float(sched(200)), 0.001, rtol=1e-6)
    np.testing.assert_allclose(float(sched(400)), 0.0001, rtol=1e-6)


def test_cosine_warmup_monotone_then_decay():
    sched = cosine_warmup(1.0, warmup=10, total=100)
    vals = [float(sched(s)) for s in range(100)]
    assert vals[0] < vals[5] < vals[10]
    assert vals[10] >= max(vals[11:])


def _quadratic_losses(opt, steps=120):
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    losses = []
    for _ in range(steps):
        grads = jax.tree_util.tree_map(lambda w: 2 * w, params)
        losses.append(float((params["w"] ** 2).sum()))
        params, state = opt.update(grads, state, params)
    return losses


def test_sgd_momentum_converges_quadratic():
    losses = _quadratic_losses(sgd_momentum(constant(0.05), momentum=0.9))
    assert losses[-1] < 1e-3 * losses[0]


def test_adamw_converges_quadratic():
    losses = _quadratic_losses(adamw(constant(0.1)))
    assert losses[-1] < 1e-2 * losses[0]


def test_adamw_bf16_moments():
    opt = adamw(constant(1e-3), moment_dtype=jnp.bfloat16)
    params = {"w": jnp.ones((4,), jnp.float32)}
    state = opt.init(params)
    assert state["m"]["w"].dtype == jnp.bfloat16
    params2, state = opt.update({"w": jnp.ones((4,))}, state, params)
    assert bool(jnp.isfinite(params2["w"]).all())


def test_make_optimizer_registry():
    assert make_optimizer("sgd", constant(0.1))
    assert make_optimizer("adamw", constant(0.1))


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------
def test_stratified_split_80_20():
    tr, te = stratified_split(n_per_class=20, train_frac=0.8, seed=0)
    assert len(tr) == NUM_CLASSES * 16 and len(te) == NUM_CLASSES * 4
    # disjoint per class
    trs = {(int(c), int(i)) for c, i in tr}
    tes = {(int(c), int(i)) for c, i in te}
    assert not trs & tes
    for c in range(NUM_CLASSES):
        assert sum(1 for cc, _ in tr if cc == c) == 16


def test_images_deterministic_and_class_separable():
    a = make_image(3, 7, seed=0, hw=32)
    b = make_image(3, 7, seed=0, hw=32)
    np.testing.assert_array_equal(a, b)
    c = make_image(4, 7, seed=0, hw=32)
    assert np.abs(a - c).mean() > 0.01
    assert a.shape == (32, 32, 3) and a.dtype == np.float32
    assert a.min() >= 0 and a.max() <= 1


def test_dataset_batches():
    ds = PlantVillageSynthetic(n_per_class=10, hw=16)
    batch = next(ds.iter_train(8))
    assert batch["image"].shape == (8, 16, 16, 3)
    assert batch["label"].dtype == np.int32
    total = sum(len(b["label"]) for b in ds.test_batches(16))
    assert total == len(ds.test_ids)


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------
def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
            "lst": [jnp.zeros((2,)), jnp.full((3,), 7.0)]}
    path = os.path.join(tmp_path, "ck")
    store.save(path, tree, metadata={"step": 42})
    loaded = store.restore(path, like=tree)
    assert store.load_metadata(path)["step"] == 42
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype
