"""Fleet simulator tests: virtual clock, seeded population, admission,
tier batching, scenario serialization, and the determinism regression
the BENCH_fleet record depends on."""
import random

import pytest

from repro.core.collab.batching import BatchingPolicy
from repro.core.collab.faults import FaultPolicy
from repro.core.fleet import (DEFAULT_SLO_CLASSES, ArrivalPattern,
                              EventQueue, FleetScenario, FleetSimulator,
                              SLOClass, TierServer, build_population,
                              percentile, simulate_fleet)
from repro.core.fleet.population import DEVICE_CLASSES
from repro.core.fleet.tiers import CLOUDLET_SERVER
from repro.core.partition.energy_model import (ENERGY_PROFILES,
                                               PHONE_ENERGY,
                                               urgency_scaled_weight)
from repro.core.partition.latency_model import (LayerCost,
                                                batched_segment_time,
                                                batched_server_time)
from repro.core.partition.profiles import PHONE_EDGE, PI_EDGE

pytestmark = pytest.mark.fleet


# ---------------------------------------------------------------------------
# clock
# ---------------------------------------------------------------------------
def test_event_queue_fires_in_time_then_insertion_order():
    q = EventQueue()
    fired = []
    q.push(2.0, lambda: fired.append("late"))
    q.push(1.0, lambda: fired.append("early"))
    q.push(1.0, lambda: fired.append("early2"))   # same t: insertion order
    n = q.run_until()
    assert n == 3
    assert fired == ["early", "early2", "late"]
    assert q.now == 2.0


def test_event_queue_clamps_past_times_and_nests():
    q = EventQueue()
    fired = []

    def first():
        fired.append(q.now)
        q.push(q.now - 5.0, lambda: fired.append(q.now))  # clamped to now

    q.push(1.0, first)
    q.run_until()
    assert fired == [1.0, 1.0]                    # never moves backwards


def test_event_queue_horizon_stops_early():
    q = EventQueue()
    fired = []
    q.push(1.0, lambda: fired.append(1))
    q.push(5.0, lambda: fired.append(5))
    q.run_until(horizon=2.0)
    assert fired == [1] and len(q) == 1


# ---------------------------------------------------------------------------
# profiles satellite
# ---------------------------------------------------------------------------
def test_phone_class_sits_between_pi_and_server():
    assert PI_EDGE.flops_per_s < PHONE_EDGE.flops_per_s
    assert PHONE_EDGE.flops_per_s < CLOUDLET_SERVER.flops_per_s
    assert ENERGY_PROFILES["phone"] is PHONE_ENERGY
    # a phone burns more active power than the Pi-class board's SoC
    assert PHONE_ENERGY.compute_power_w > 0
    assert PHONE_ENERGY.radio.tx_power_w > PHONE_ENERGY.radio.idle_power_w


def test_urgency_scaled_weight_shared_formula():
    w = 0.02
    assert urgency_scaled_weight(w, None) == w
    assert urgency_scaled_weight(w, 1.0) == pytest.approx(w)
    assert urgency_scaled_weight(w, 0.5) == pytest.approx(w * 4)
    # floor keeps a dead battery finite
    assert urgency_scaled_weight(w, 0.0) == pytest.approx(w / 1e-6)


def test_batched_segment_time_generalizes_batched_server_time():
    costs = [LayerCost(i, f"l{i}", 1e9, 1e5) for i in range(5)]
    assert batched_segment_time(costs, 2, 5, CLOUDLET_SERVER, 4) \
        == pytest.approx(batched_server_time(costs, 2, CLOUDLET_SERVER, 4))
    with pytest.raises(ValueError):
        batched_segment_time(costs, 3, 2, CLOUDLET_SERVER, 1)
    with pytest.raises(ValueError):
        batched_segment_time(costs, 0, 5, CLOUDLET_SERVER, 0)


# ---------------------------------------------------------------------------
# scenario + plan section
# ---------------------------------------------------------------------------
def test_scenario_roundtrips_through_json():
    sc = FleetScenario(name="rt", seed=11, n_edges=50, n_cloudlets=3,
                       duration_s=12.0)
    assert FleetScenario.from_json(sc.to_json()) == sc


def test_scenario_validates_mixes_and_batteries():
    with pytest.raises(ValueError, match="shares sum"):
        FleetScenario(name="bad", device_mix=(("mcu", 0.5), ("pi", 0.2)))
    with pytest.raises(ValueError, match="unknown device class"):
        FleetScenario(name="bad", device_mix=(("gpu", 1.0),),
                      battery_j=(("gpu", 10.0),))
    with pytest.raises(ValueError, match="battery_j"):
        FleetScenario(name="bad", battery_j=(("mcu", 0.0),))
    with pytest.raises(ValueError, match="share"):
        SLOClass("x", 0.0, FaultPolicy())


def test_plan_fleet_section_folds_into_digest_only_when_set(tmp_path):
    import jax
    from repro import serving
    from repro.models.cnn import init_cnn_params, tiny_cnn_config
    cfg = tiny_cnn_config(num_classes=5, hw=32)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    bare = serving.DeploymentPlan.from_args(params, cfg, 3)
    sc = FleetScenario(name="study", seed=5, n_edges=100)
    fleet = serving.DeploymentPlan.from_args(params, cfg, 3, fleet=sc)
    assert bare.digest != fleet.digest          # section is contract-level
    assert "fleet" not in bare.contract()       # only-when-set precedent
    assert fleet.contract()["fleet"] == sc.to_json()
    path = fleet.save(str(tmp_path / "deploy"))
    reloaded = serving.DeploymentPlan.load(path)
    assert reloaded.fleet == sc
    assert reloaded.digest == fleet.digest
    assert "fleet=study" in fleet.describe()


# ---------------------------------------------------------------------------
# population
# ---------------------------------------------------------------------------
def test_population_is_seed_deterministic_and_heterogeneous():
    sc = FleetScenario(name="pop", seed=4, n_edges=400)
    a, b = build_population(sc), build_population(sc)
    assert [(e.device_class, e.trace.name, e.slo.name, e.trace_phase,
             e.cloudlet_id) for e in a] \
        == [(e.device_class, e.trace.name, e.slo.name, e.trace_phase,
             e.cloudlet_id) for e in b]
    classes = {e.device_class for e in a}
    assert classes == set(DEVICE_CLASSES)       # all three classes present
    assert len({e.trace.name for e in a}) > 1
    # shares land near the mix (law of large numbers, fixed seed)
    mcu = sum(1 for e in a if e.device_class == "mcu") / len(a)
    assert 0.15 < mcu < 0.35
    # batteries start full, per class
    for e in a:
        assert e.battery_left_j == sc.battery_for(e.device_class)


def test_arrivals_are_seeded_and_diurnal():
    sc = FleetScenario(name="arr", seed=9, n_edges=1)
    edge = build_population(sc)[0]
    ts, t = [], 0.0
    for _ in range(200):
        t = edge.next_arrival(t, sc.arrival)
        ts.append(t)
    assert ts == sorted(ts) and len(set(ts)) == len(ts)
    edge2 = build_population(sc)[0]
    t2 = [edge2.next_arrival(0.0, sc.arrival)]
    for _ in range(199):
        t2.append(edge2.next_arrival(t2[-1], sc.arrival))
    assert ts == t2                             # same seed, same stream
    # long-run mean rate within the diurnal envelope
    rate = len(ts) / ts[-1]
    assert (sc.arrival.base_rate_hz * 0.5 < rate
            < sc.arrival.peak_rate_hz * 1.5)


# ---------------------------------------------------------------------------
# tiers
# ---------------------------------------------------------------------------
def _costs(n=6):
    return [LayerCost(i, f"l{i}", 2e9, 1e5) for i in range(n)]


def test_tier_server_fuses_concurrent_arrivals_into_one_batch():
    q = EventQueue()
    srv = TierServer("t", CLOUDLET_SERVER,
                     BatchingPolicy(max_batch=8, max_wait_ms=5.0),
                     _costs(), q)
    done = []
    for i in range(3):
        assert srv.submit((2, 6), i, lambda p, t: done.append((p, t)))
    q.run_until()
    assert [p for p, _ in done] == [0, 1, 2]
    assert srv.stats.batches == 1 and srv.stats.rows == 3
    # padded up to the policy's bucket (power-of-two default: 4)
    assert srv.stats.padded_rows == 1
    # all three finished together, after the window + one fused service
    t_done = {t for _, t in done}
    assert len(t_done) == 1
    t_serve = batched_segment_time(_costs(), 2, 6, CLOUDLET_SERVER, 4)
    assert t_done.pop() == pytest.approx(5e-3 + t_serve)


def test_tier_server_sheds_at_queue_bound():
    q = EventQueue()
    srv = TierServer("t", CLOUDLET_SERVER,
                     BatchingPolicy(max_batch=2, max_wait_ms=1.0),
                     _costs(), q, max_queue=2)
    assert srv.submit((0, 6), "a", lambda p, t: None)
    assert srv.submit((0, 6), "b", lambda p, t: None)
    assert not srv.submit((0, 6), "c", lambda p, t: None)
    assert srv.stats.shed == 1


def test_tier_server_separates_lanes_by_segment():
    q = EventQueue()
    srv = TierServer("t", CLOUDLET_SERVER,
                     BatchingPolicy(max_batch=8, max_wait_ms=1.0),
                     _costs(), q)
    done = []
    srv.submit((1, 6), "seg16", lambda p, t: done.append(p))
    srv.submit((3, 6), "seg36", lambda p, t: done.append(p))
    q.run_until()
    assert sorted(done) == ["seg16", "seg36"]
    assert srv.stats.batches == 2               # different shapes never fuse


# ---------------------------------------------------------------------------
# end-to-end + determinism regression
# ---------------------------------------------------------------------------
def test_fleet_run_conserves_arrivals_and_uses_every_route():
    sc = FleetScenario(name="e2e", seed=3, n_edges=300, n_cloudlets=2,
                       duration_s=20.0)
    r = simulate_fleet(sc)
    assert r["arrivals"] == r["served"] + r["shed"]
    assert r["served_collab"] > 0 and r["served_edge_only"] > 0
    assert 0.0 < r["deadline_met_frac"] <= 1.0
    assert r["latency_p50_s"] <= r["latency_p99_s"]
    assert r["edge_joules_per_request"] > 0
    assert r["cloudlet_rows"] > 0
    assert r["uplink_mb_total"] > 0


def test_fleet_same_seed_rollups_are_bit_identical():
    # the determinism regression BENCH_fleet.json depends on: same
    # scenario seed -> byte-identical metrics, run to run
    sc = FleetScenario(name="det", seed=21, n_edges=250, n_cloudlets=3,
                       duration_s=15.0)
    assert simulate_fleet(sc) == simulate_fleet(sc)


def test_fleet_seed_actually_matters():
    a = simulate_fleet(FleetScenario(name="s", seed=1, n_edges=200,
                                     duration_s=10.0))
    b = simulate_fleet(FleetScenario(name="s", seed=2, n_edges=200,
                                     duration_s=10.0))
    assert a != b


def test_battery_exhaustion_sheds_and_degrades():
    # microscopic batteries: edges exhaust quickly and later arrivals
    # shed with reason "battery"
    sc = FleetScenario(name="drain", seed=6, n_edges=100, n_cloudlets=2,
                       duration_s=30.0,
                       battery_j=(("mcu", 0.5), ("pi", 0.5),
                                  ("phone", 0.5)))
    sim = FleetSimulator(sc)
    r = sim.run()
    assert r["exhausted_edges"] > 0
    assert r["shed_battery_frac"] > 0
    # exhausted edges stopped paying joules after their budget
    for e in sim.edges:
        assert e.battery_left_j >= 0.0


def test_strict_slo_sheds_more_than_lenient():
    strict = (SLOClass("tight", 1.0,
                       FaultPolicy(request_deadline_s=0.03,
                                   fallback="fail")),)
    lenient = (SLOClass("loose", 1.0,
                        FaultPolicy(request_deadline_s=30.0,
                                    fallback="edge")),)
    base = dict(seed=5, n_edges=150, n_cloudlets=2, duration_s=10.0)
    r_strict = simulate_fleet(FleetScenario(name="st",
                                            slo_classes=strict, **base))
    r_lenient = simulate_fleet(FleetScenario(name="le",
                                             slo_classes=lenient, **base))
    assert r_strict["shed_frac"] > r_lenient["shed_frac"]
    assert r_lenient["deadline_met_frac"] >= r_strict["deadline_met_frac"]


def test_percentile_pure_python():
    assert percentile([], 99) == 0.0
    assert percentile([5.0], 50) == 5.0
    assert percentile([1.0, 2.0, 3.0, 4.0], 50) == pytest.approx(2.5)
    assert percentile([1.0, 2.0, 3.0, 4.0], 100) == 4.0
    xs = [random.Random(0).random() for _ in range(100)]
    assert min(xs) <= percentile(xs, 1) <= percentile(xs, 99) <= max(xs)
