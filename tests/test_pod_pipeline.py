"""Tier-B pod-boundary split inference (core/partition/pod_pipeline):
correctness vs the monolithic forward. Needs >1 fake device for the "pod"
axis, and XLA fixes the device count at first init — so the multi-pod case
runs in a subprocess; the trivial 1-pod case runs in-process."""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from conftest import moe_no_drop
from repro.configs.registry import get_smoke_config
from repro.core.partition import pod_pipeline as pp
from repro.models import transformer as tr


def test_pipeline_supported_table():
    ok = {"qwen2-7b", "gemma-7b", "qwen1.5-4b", "nemotron-4-340b",
          "mamba2-2.7b", "mixtral-8x7b", "hubert-xlarge", "qwen2-vl-7b"}
    no = {"zamba2-1.2b", "deepseek-v3-671b"}
    for a in ok:
        assert pp.pipeline_supported(get_smoke_config(a)), a
    for a in no:
        assert not pp.pipeline_supported(get_smoke_config(a)), a


def test_stack_stage_params_shapes():
    cfg = get_smoke_config("qwen2-7b").replace(dtype="float32")
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    stacked = pp.stack_stage_params(params, cfg, 2)
    for leaf in jax.tree_util.tree_leaves(stacked):
        assert leaf.shape[0] == 2
        assert leaf.shape[1] == cfg.num_layers // 2


_SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, "src")
    import dataclasses
    import jax, jax.numpy as jnp
    from repro.configs.registry import get_smoke_config
    from repro.models import transformer as tr
    from repro.core.partition import pod_pipeline as pp

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    for arch in ["qwen2-7b", "mamba2-2.7b", "mixtral-8x7b"]:
        cfg = get_smoke_config(arch).replace(dtype="float32", remat=False)
        if cfg.moe:
            cfg = cfg.replace(moe=dataclasses.replace(
                cfg.moe,
                capacity_factor=float(cfg.moe.num_experts) / cfg.moe.top_k))
        params = tr.init_params(cfg, jax.random.PRNGKey(0))
        tok = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                                 cfg.vocab_size)
        ref, _ = tr.forward(params, cfg, {"tokens": tok})
        sp = dict(params)
        sp["runs"] = [pp.stack_stage_params(params, cfg, 2)]
        with mesh:
            step = pp.make_split_serve_step(cfg, 2, 2, mesh)
            logits = jax.jit(step)(sp, {"tokens": tok})
        err = float(jnp.max(jnp.abs(logits - ref[:, -1])))
        assert err < 2e-3, (arch, err)
        print(arch, "err", err)
    print("PIPELINE_OK")
""")


@pytest.mark.slow
def test_two_pod_pipeline_matches_forward_subprocess():
    if not hasattr(jax, "shard_map"):
        pytest.skip("multi-pod partial-auto shard_map needs jax >= 0.5 "
                    "(0.4.x lowers axis_index under auto axes to a "
                    "PartitionId op the SPMD partitioner rejects)")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SUBPROC], cwd=os.path.join(
        os.path.dirname(__file__), ".."), env=env,
        capture_output=True, text=True, timeout=540)
    assert "PIPELINE_OK" in r.stdout, (r.stdout[-1000:], r.stderr[-2000:])


def test_single_pod_passthrough():
    """n_pods=1: the pipeline degenerates to the plain layer stack."""
    cfg = moe_no_drop(get_smoke_config("qwen2-7b").replace(
        dtype="float32", remat=False))
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0,
                             cfg.vocab_size)
    ref, _ = tr.forward(params, cfg, {"tokens": tok})
    sp = dict(params)
    sp["runs"] = [pp.stack_stage_params(params, cfg, 1)]
    n = len(jax.devices())
    mesh = jax.sharding.Mesh(
        __import__("numpy").array(jax.devices()).reshape(1, 1, n),
        ("pod", "data", "model"))
    with mesh:
        step = pp.make_split_serve_step(cfg, 1, 2, mesh)
        logits = jax.jit(step)(sp, {"tokens": tok})
    err = float(jnp.max(jnp.abs(logits - ref[:, -1])))
    assert err < 2e-3, err
