"""Unified serving surface: DeploymentPlan artifact (digest, save/load),
serving.connect backends (local / socket / streaming — same plan, same
logits), the HELLO contract handshake, and multi-client serve_cloud."""
from __future__ import annotations

import threading

import jax
import numpy as np
import pytest

from repro import serving
from repro.core.collab.runtime import EdgeClient, deploy_submodels
from repro.core.pruning.masks import cnn_masks_from_ratios
from repro.models.cnn import (cnn_apply, init_cnn_params, prunable_layers,
                              tiny_cnn_config)

SPLIT = 6       # interior split: a real edge + cloud pair


@pytest.fixture(scope="module")
def plan_setup():
    cfg = tiny_cnn_config(num_classes=7, hw=32)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    masks = cnn_masks_from_ratios(
        params, cfg, {i: 0.5 for i in prunable_layers(cfg)})
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3)),
                   np.float32)
    want = np.asarray(cnn_apply(params, cfg, x, masks=masks))
    return cfg, params, masks, x, want


def make_plan(plan_setup, port=29510, **kw):
    cfg, params, masks, _, _ = plan_setup
    kw.setdefault("split", SPLIT)
    kw.setdefault("masks", masks)
    kw.setdefault("compact", True)
    kw.setdefault("codec", "fp32")
    kw.setdefault("shape_link", False)
    return serving.DeploymentPlan.from_args(params, cfg, port=port, **kw)


# ---------------------------------------------------------------------------
# the plan artifact
# ---------------------------------------------------------------------------
def test_plan_digest_stable_and_contract_sensitive(plan_setup):
    a, b = make_plan(plan_setup), make_plan(plan_setup)
    assert a.digest == b.digest                      # deterministic
    assert a.digest != make_plan(plan_setup, split=SPLIT - 1).digest
    assert a.digest != make_plan(plan_setup, codec="int8").digest
    assert a.digest != make_plan(plan_setup, compact=False).digest
    # transport details are NOT part of the contract
    assert a.digest == make_plan(plan_setup, port=31000).digest


def make_plan_with_split(plan_setup, split, **kw):
    cfg, params, masks, _, _ = plan_setup
    return serving.DeploymentPlan.from_args(params, cfg, split, masks=masks,
                                            compact=True, **kw)


def test_plan_validation(plan_setup):
    cfg, params, _, _, _ = plan_setup
    with pytest.raises(ValueError, match="compact"):
        serving.DeploymentPlan.from_args(params, cfg, SPLIT, compact=True)
    with pytest.raises(ValueError, match="codec"):
        make_plan(plan_setup, codec="fp64")
    with pytest.raises(ValueError, match="split"):
        make_plan_with_split(plan_setup, len(cfg.layers) + 1)


def test_plan_auto_split_is_greedy_optimum(plan_setup):
    cfg, params, masks, _, _ = plan_setup
    plan = serving.DeploymentPlan.from_args(params, cfg, None, masks=masks,
                                            compact=True, codec="int8")
    assert 0 <= plan.split <= len(cfg.layers)


def test_plan_save_load_roundtrip_serves_identically(plan_setup, tmp_path):
    """Acceptance: a plan saved to disk and re-loaded serves without the
    original pipeline objects, logits bit-identical to in-memory deploy."""
    _, _, _, x, want = plan_setup
    plan = make_plan(plan_setup)
    in_mem = serving.connect(plan, backend="local").infer(x)
    path = plan.save(str(tmp_path / "deploy"))
    loaded = serving.DeploymentPlan.load(path)
    assert loaded.digest == plan.digest
    assert loaded.host == plan.host and loaded.port == plan.port
    out = serving.connect(loaded, backend="local").infer(x)
    np.testing.assert_array_equal(out["logits"], in_mem["logits"])
    np.testing.assert_allclose(out["logits"], want, rtol=1e-4, atol=1e-4)


def test_plan_load_rejects_tampered_contract(plan_setup, tmp_path):
    import json
    import os
    plan = make_plan(plan_setup)
    path = plan.save(str(tmp_path / "deploy"))
    doc = json.load(open(os.path.join(path, "plan.json")))
    doc["split"] = SPLIT - 1                      # edit the contract
    json.dump(doc, open(os.path.join(path, "plan.json"), "w"))
    with pytest.raises(ValueError, match="digest"):
        serving.DeploymentPlan.load(path)


# ---------------------------------------------------------------------------
# one contract, three backends
# ---------------------------------------------------------------------------
def test_three_backends_bit_identical_logits(plan_setup):
    """Acceptance: local / socket / streaming through serving.connect
    return bit-identical logits for the same plan."""
    _, _, _, x2, want2 = plan_setup
    x, want = x2[:1], want2[:1]        # streaming requests are batch-1
    plan = make_plan(plan_setup, port=29511)
    local = serving.connect(plan, backend="local").infer(x)
    np.testing.assert_allclose(local["logits"], want, rtol=1e-4, atol=1e-4)

    stream_sess = serving.connect(plan, backend="streaming",
                                  realtime_channel=False)
    stream = stream_sess.infer(x)
    np.testing.assert_array_equal(stream["logits"], local["logits"])

    with serving.CloudServer(plan):
        with serving.connect(plan, backend="socket") as sess:
            sock = sess.infer(x)
    np.testing.assert_array_equal(sock["logits"], local["logits"])

    for res in (local, stream, sock):      # uniform result shape
        assert set(res) == {"logits", "t_edge", "t_upstream", "t_total",
                            "tx_bytes", "e_edge_j", "fault"}
        assert res["e_edge_j"] is None     # un-metered plan: no joules
        # uniform fault accounting: all-zero on a clean request
        assert res["fault"] == {"faults": 0, "retries": 0,
                                "migrations": 0, "fallback": False}


def test_streaming_backend_reports_pipeline_stats(plan_setup):
    _, _, _, x, _ = plan_setup
    plan = make_plan(plan_setup)
    sess = serving.connect(plan, backend="streaming",
                           realtime_channel=False)
    out = sess.infer_many([x[:1]] * 4)
    assert len(out) == 4
    rep = sess.last_report
    assert rep.throughput_rps > 0
    assert set(rep.occupancy) == {"edge", "tx", "cloud"}


def test_socket_backend_pipelined_infer_many(plan_setup):
    _, _, _, x, want = plan_setup
    plan = make_plan(plan_setup, port=29512)
    imgs = [x[i % 2:i % 2 + 1] for i in range(5)]
    wants = [want[i % 2:i % 2 + 1] for i in range(5)]
    with serving.CloudServer(plan):
        with serving.connect(plan, backend="socket") as sess:
            out = sess.infer_many(imgs)
    for res, w in zip(out, wants):
        np.testing.assert_allclose(res["logits"], w, rtol=1e-4, atol=1e-4)
        assert res["tx_bytes"] > 0


# ---------------------------------------------------------------------------
# HELLO handshake: contract agreement enforced at connect time
# ---------------------------------------------------------------------------
def test_handshake_digest_mismatch_fails_fast(plan_setup):
    """Acceptance: a deliberate peer plan mismatch errors at connect
    instead of decoding garbage tensors mid-stream."""
    plan = make_plan(plan_setup, port=29513)
    other = make_plan(plan_setup, port=29513, split=SPLIT - 2)
    assert plan.digest != other.digest
    # max_clients=1: a rejected peer must NOT consume the client budget
    with serving.CloudServer(plan, max_clients=1):
        with pytest.raises(serving.PlanMismatchError, match="digest"):
            serving.connect(other, backend="socket")
        # the server survives a rejected peer: a matching edge still works
        with serving.connect(plan, backend="socket") as sess:
            res = sess.infer(plan_setup[3])
            assert res["tx_bytes"] > 0


def test_serve_cloud_survives_connect_and_drop(plan_setup):
    """A probe that connects and closes without a request must not consume
    the bounded server's client budget."""
    import socket as socketlib
    plan = make_plan(plan_setup, port=29516)
    with serving.CloudServer(plan, max_clients=1):
        probe = socketlib.create_connection(("127.0.0.1", 29516))
        probe.close()
        with serving.connect(plan, backend="socket") as sess:
            assert sess.infer(plan_setup[3])["tx_bytes"] > 0


def test_handshake_skipped_for_legacy_edge(plan_setup):
    """An edge that never sends HELLO (verify=False) is served unchecked —
    back-compat with pre-plan clients."""
    _, _, _, x, want = plan_setup
    plan = make_plan(plan_setup, port=29514)
    with serving.CloudServer(plan):
        with serving.connect(plan, backend="socket", verify=False) as sess:
            np.testing.assert_allclose(sess.infer(x)["logits"], want,
                                       rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# multi-client cloud
# ---------------------------------------------------------------------------
def test_serve_cloud_multi_client_concurrent_edges(plan_setup):
    """Acceptance: one cloud process serves two concurrent edges with
    interleaved requests, each getting its own correct results."""
    _, _, _, x, want = plan_setup
    plan = make_plan(plan_setup, port=29515)
    with serving.CloudServer(plan, max_clients=None):
        s1 = serving.connect(plan, backend="socket")
        s2 = serving.connect(plan, backend="socket")
        errs = []

        def hammer(sess, img, w, n=4):
            try:
                for _ in range(n):
                    np.testing.assert_allclose(
                        sess.infer(img)["logits"], w, rtol=1e-4, atol=1e-4)
            except Exception as e:                        # noqa: BLE001
                errs.append(e)

        t1 = threading.Thread(target=hammer, args=(s1, x[:1], want[:1]))
        t2 = threading.Thread(target=hammer, args=(s2, x[1:], want[1:]))
        t1.start(); t2.start(); t1.join(20); t2.join(20)
        s1.close(); s2.close()
        assert not errs, errs


# ---------------------------------------------------------------------------
# wire accounting: payload parity across backends, analytic == measured
# ---------------------------------------------------------------------------
def test_tx_bytes_payload_identical_across_backends(plan_setup):
    """Acceptance: the same plan reports the same tx_bytes on every
    backend — payload bytes only, excluding the socket path's 8-byte
    length prefix (the historical +8 discrepancy)."""
    _, _, _, x2, _ = plan_setup
    x = x2[:1]
    plan = make_plan(plan_setup, port=29517)
    local = serving.connect(plan, backend="local").infer(x)
    stream = serving.connect(plan, backend="streaming",
                             realtime_channel=False).infer(x)
    with serving.CloudServer(plan):
        with serving.connect(plan, backend="socket") as sess:
            sock = sess.infer(x)
    assert local["tx_bytes"] > 0
    assert local["tx_bytes"] == sock["tx_bytes"] == stream["tx_bytes"]


@pytest.mark.parametrize("codec,pack,compact", [
    ("fp32", False, True), ("fp16", False, True), ("int8", False, True),
    ("fp32", True, False), ("int8", True, False), ("fp32", False, False),
])
def test_analytic_tx_bytes_matches_measured_payload(plan_setup, codec,
                                                    pack, compact):
    """The re-priced tx_scale (codec x packing, ``wire_tx_scale``) makes
    the analytic Eq. 5 tx_bytes agree with the measured frame payload —
    including the masked-but-dense unpacked case, which ships the full
    tensor (zeros included)."""
    from repro.core.collab.runtime import CollabRunner
    cfg, params, masks, x, _ = plan_setup
    runner = CollabRunner(params, cfg, SPLIT, serving.DeploymentPlan(
        cfg=cfg, params=params, split=SPLIT).profile, masks=masks,
        compact=compact, codec=codec, pack=pack)
    measured = runner.infer(x[:1])["timing"].tx_bytes
    analytic = runner._analytic["tx_bytes"]
    # frame headers (magic/shape/bitmask/quant params) are not modelled:
    # allow tens of bytes, not the ~KBs a keep-ratio mistake would cause
    assert abs(measured - analytic) <= 160, (codec, pack, compact,
                                             measured, analytic)


# ---------------------------------------------------------------------------
# satellites: deploy_submodels guard, EdgeClient host/timeout
# ---------------------------------------------------------------------------
def test_deploy_submodels_compact_without_masks_raises(plan_setup):
    cfg, params, _, _, _ = plan_setup
    with pytest.raises(ValueError, match="compact"):
        deploy_submodels(params, cfg, masks=None, compact=True)
    with pytest.raises(ValueError, match="compact"):
        deploy_submodels(params, cfg, masks={}, compact=True)


def test_edge_client_accepts_host_and_timeout(plan_setup):
    cfg, params, _, _, _ = plan_setup
    with pytest.raises(OSError):
        # unroutable TEST-NET address: proves host/timeout are honoured
        # (fails fast instead of the old hardwired 127.0.0.1 / 30 s)
        EdgeClient(params, cfg, SPLIT, 29599, host="192.0.2.1",
                   timeout=0.2)
