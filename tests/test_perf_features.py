"""Coverage for the §Perf machinery: gradient accumulation, head-atomic
chunked attention, activation-constraint helper, MoE dispatch pins."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from conftest import moe_no_drop, smoke_batch
from repro.configs.registry import get_smoke_config
from repro.launch.steps import make_train_step
from repro.models import transformer as tr
from repro.models.layers.attention import (chunked_attention,
                                           chunked_attention_ha)
from repro.optim import constant, sgd_momentum
from repro.sharding.constraints import data_axes_spec, maybe_constrain


# ---------------------------------------------------------------------------
# gradient accumulation
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["qwen2-7b", "mamba2-2.7b",
                                  "mixtral-8x7b"])
def test_grad_accum_matches_monolithic(arch):
    cfg = moe_no_drop(get_smoke_config(arch).replace(dtype="float32"))
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    opt = sgd_momentum(constant(0.1))
    batch = smoke_batch(cfg, 4, 8)
    p1, _, _ = jax.jit(make_train_step(cfg, opt))(
        params, opt.init(params), batch)
    p2, _, _ = jax.jit(make_train_step(cfg, opt, grad_accum=2))(
        params, opt.init(params), batch)
    # MoE: the Switch aux loss is nonlinear in batch size, so
    # mean-of-microbatch-aux legitimately differs from full-batch aux by
    # O(1e-4) in the grads — wider tolerance there.
    tol = 2e-3 if cfg.moe is not None else 2e-4
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=tol, atol=tol)


def test_grad_accum_metrics_averaged():
    cfg = get_smoke_config("qwen2-7b").replace(dtype="float32")
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    opt = sgd_momentum(constant(0.0))        # lr 0: params fixed
    batch = smoke_batch(cfg, 4, 8)
    _, _, m1 = jax.jit(make_train_step(cfg, opt))(
        params, opt.init(params), batch)
    _, _, m4 = jax.jit(make_train_step(cfg, opt, grad_accum=4))(
        params, opt.init(params), batch)
    np.testing.assert_allclose(float(m1["xent"]), float(m4["xent"]),
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# head-atomic attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("causal,window", [(True, None), (True, 9),
                                           (False, None)])
def test_head_atomic_equals_grouped(causal, window):
    B, S, H, Hkv, D = 2, 50, 6, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    a = chunked_attention(q, k, v, pos, pos, causal, window, 0.25,
                          block_kv=16)
    b = chunked_attention_ha(q, k, v, pos, pos, causal, window, 0.25,
                             block_kv=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=3e-5, atol=3e-5)


def test_attn_head_atomic_config_end_to_end():
    """forward logits identical with the flag on (CPU: constraints no-op)."""
    cfg = get_smoke_config("qwen2-7b").replace(dtype="float32",
                                               naive_attn_max=0)
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    batch = smoke_batch(cfg, 2, 24, with_labels=False)
    a, _ = tr.forward(params, cfg, batch)
    b, _ = tr.forward(params, cfg.replace(attn_head_atomic=True), batch)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# constraints helper
# ---------------------------------------------------------------------------
def test_maybe_constrain_noop_off_mesh():
    x = jnp.ones((4, 8))
    y = maybe_constrain(x, P("data", "model"))
    assert y is x                       # literally untouched
    assert data_axes_spec() is None


def test_maybe_constrain_applies_on_mesh():
    n = len(jax.devices())
    if n < 2:
        pytest.skip("single-device mesh: XLA folds the trivial sharding "
                    "constraint away at lowering, so there is nothing to "
                    "observe (run under XLA_FLAGS=--xla_force_host_platform_"
                    "device_count=N to exercise)")
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()).reshape(1, n), ("data", "model"))
    seen = {}

    def f(x):
        seen["dspec"] = data_axes_spec()     # captured at trace time
        return maybe_constrain(x, P("data", ("bogus",), "model"))

    with mesh:
        lowered = jax.jit(f).lower(
            jax.ShapeDtypeStruct((2, 3, 4), jnp.float32))
        lowered.compile()
        text = lowered.as_text()
        # constraint present; bogus axis dropped (dim 1 empty), rest kept
        assert "sharding_constraint" in text
        assert '[{"data"}, {}, {"model"}]' in text
    assert seen["dspec"] == "data"


def test_constraints_inside_shard_map_ignore_manual_axes():
    """Inside a pod-manual shard_map, constraints must drop 'pod'."""
    from repro.core.partition import pod_pipeline as pp
    cfg = get_smoke_config("qwen2-7b").replace(dtype="float32",
                                               remat=False)
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    sp = dict(params)
    sp["runs"] = [pp.stack_stage_params(params, cfg, 1)]
    n = len(jax.devices())
    mesh = jax.sharding.Mesh(
        np.array(jax.devices()).reshape(1, 1, n),
        ("pod", "data", "model"))
    tok = jnp.zeros((2, 8), jnp.int32)
    with mesh:
        # mlp_forward inside the stage calls maybe_constrain; 'pod' must
        # be filtered (Manual) or this raises
        logits = jax.jit(pp.make_split_serve_step(cfg, 1, 2, mesh))(
            sp, {"tokens": tok})
    assert bool(jnp.isfinite(logits).all())


# ---------------------------------------------------------------------------
# MoE dispatch pins keep semantics
# ---------------------------------------------------------------------------
def test_moe_pins_preserve_decode_consistency():
    cfg = moe_no_drop(get_smoke_config("mixtral-8x7b").replace(
        dtype="float32"))
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 12), 0,
                             cfg.vocab_size)
    full, _ = tr.forward(params, cfg, {"tokens": tok})
    lg, cache = tr.prefill(params, cfg, {"tokens": tok[:, :11]},
                           max_len=16)
    lg, _ = tr.decode_step(params, cfg, cache, tok[:, 11:12])
    assert float(jnp.max(jnp.abs(lg - full[:, -1]))) < 2e-3
