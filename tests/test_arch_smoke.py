"""Per-architecture smoke tests (assignment deliverable f): a REDUCED
same-family variant runs one forward and one train step on CPU; output
shapes are right and nothing is NaN."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from conftest import smoke_batch
from repro.models import transformer as tr
from repro.optim import adamw, constant


def test_forward_shapes_and_finite(smoke_cfg):
    cfg = smoke_cfg
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = smoke_batch(cfg, B, S)
    logits, aux = tr.forward(params, cfg, batch)
    S_out = S + cfg.vision_tokens
    assert logits.shape == (B, S_out, cfg.padded_vocab)
    assert bool(jnp.isfinite(logits).all())


def test_train_step_updates_and_finite(smoke_cfg):
    cfg = smoke_cfg
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw(constant(1e-3))
    opt_state = opt.init(params)
    batch = smoke_batch(cfg, 2, 16)

    @jax.jit
    def step(p, s, b):
        (loss, metrics), grads = jax.value_and_grad(
            tr.loss_fn, has_aux=True)(p, cfg, b)
        p, s = opt.update(grads, s, p)
        return p, s, loss

    p1, opt_state, loss1 = step(params, opt_state, batch)
    p2, opt_state, loss2 = step(p1, opt_state, batch)
    assert np.isfinite(float(loss1)) and np.isfinite(float(loss2))
    # loss decreases on the same batch after two steps of adamw
    assert float(loss2) < float(loss1)
    # params actually moved
    moved = any(
        not np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(p1)))
    assert moved
