"""Fast deployment path: compaction everywhere, feature codec, pipelined
streaming runtime, async socket client, exact-read framing."""
from __future__ import annotations

import socket
import struct
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.collab.channel import recv_exact
from repro.core.collab.protocol import (CODEC_TX_SCALE, decode_any,
                                        decode_feature, encode_feature,
                                        encode_tensor)
from repro.core.collab.runtime import (CollabRunner, EdgeClient,
                                       deploy_submodels, serve_cloud)
from repro.core.collab.streaming import StreamingCollabRunner
from repro.core.partition.latency_model import (cnn_input_bytes,
                                                cnn_layer_costs,
                                                compacted_cnn_layer_costs)
from repro.core.partition.profiles import PAPER_PROFILE
from repro.core.partition.splitter import greedy_split
from repro.core.pruning.masks import cnn_masks_from_ratios
from repro.models.cnn import (cnn_apply, compact_cnn_config, compact_params,
                              init_cnn_params, prunable_layers,
                              split_keep_indices, tiny_cnn_config)


@pytest.fixture(scope="module")
def pruned_setup():
    cfg = tiny_cnn_config(num_classes=7, hw=32)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    masks = cnn_masks_from_ratios(
        params, cfg, {i: 0.5 for i in prunable_layers(cfg)})
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32, 3)),
                   np.float32)
    want = np.asarray(cnn_apply(params, cfg, x, masks=masks))
    return cfg, params, masks, x, want


# ---------------------------------------------------------------------------
# compaction
# ---------------------------------------------------------------------------
def test_compacted_split_matches_masked_every_split(pruned_setup):
    """Acceptance: compacted split inference == masked logits (1e-4) at
    EVERY split point of tiny_cnn_config."""
    cfg, params, masks, x, want = pruned_setup
    cparams, ccfg = compact_params(params, cfg, masks)
    for c in range(len(cfg.layers) + 1):
        mid = cnn_apply(cparams, ccfg, jnp.asarray(x), stop_layer=c)
        out = np.asarray(cnn_apply(cparams, ccfg, mid, start_layer=c))
        np.testing.assert_allclose(out, want, rtol=1e-4, atol=1e-4,
                                   err_msg=f"split {c}")


def test_compact_cnn_config_matches_materialized(pruned_setup):
    cfg, params, masks, _, _ = pruned_setup
    _, ccfg = compact_params(params, cfg, masks)
    assert compact_cnn_config(cfg, masks) == ccfg


def test_collab_runner_compact(pruned_setup):
    cfg, params, masks, x, want = pruned_setup
    runner = CollabRunner(params, cfg, 6, PAPER_PROFILE, masks=masks,
                          compact=True)
    res = runner.infer(x)
    np.testing.assert_allclose(res["logits"], want, rtol=1e-4, atol=1e-4)
    # compacted deployment ships only surviving channels
    dense = CollabRunner(params, cfg, 6, PAPER_PROFILE, masks=masks)
    assert res["timing"].tx_bytes < dense.infer(x)["timing"].tx_bytes


def test_deploy_submodels_shapes(pruned_setup):
    cfg, params, masks, _, _ = pruned_setup
    dparams, dcfg, dmasks = deploy_submodels(params, cfg, masks,
                                             compact=True)
    assert dmasks is None
    w = dparams["l0"]["w"]
    assert w.shape[-1] == int(np.asarray(masks[0]).sum())
    assert dcfg.layers[0].out_channels == w.shape[-1]


def test_compacted_costs_price_smaller_model(pruned_setup):
    cfg, params, masks, _, _ = pruned_setup
    dense = cnn_layer_costs(cfg)
    compacted = compacted_cnn_layer_costs(cfg, masks)
    assert sum(c.flops for c in compacted) < 0.6 * sum(c.flops
                                                       for c in dense)
    # masked analytic pricing and compacted pricing agree (masks are 0/1)
    masked = cnn_layer_costs(cfg, masks)
    for a, b in zip(masked, compacted):
        assert a.flops == pytest.approx(b.flops, rel=1e-6)
        assert a.out_bytes == pytest.approx(b.out_bytes, rel=1e-6)


def test_greedy_split_tx_scale_discounts_transmission(pruned_setup):
    cfg, params, masks, _, _ = pruned_setup
    costs = compacted_cnn_layer_costs(cfg, masks)
    full = greedy_split(costs, PAPER_PROFILE, cnn_input_bytes(cfg))
    disc = greedy_split(costs, PAPER_PROFILE, cnn_input_bytes(cfg),
                        tx_scale=CODEC_TX_SCALE["int8"])
    for c_full, c_disc in zip(full.table, disc.table):
        assert c_disc["T_TX"] <= c_full["T_TX"] + 1e-12
        assert c_disc["T_D"] == c_full["T_D"]


# ---------------------------------------------------------------------------
# feature codec
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("codec", ["fp32", "fp16", "int8"])
def test_codec_roundtrip(codec):
    rng = np.random.RandomState(0)
    x = rng.randn(2, 4, 4, 24).astype(np.float32)
    buf = encode_feature(x, codec=codec)
    out, used = decode_feature(buf)
    assert used == len(buf)
    assert out.shape == x.shape and out.dtype == np.float32
    if codec == "fp32":
        np.testing.assert_array_equal(out, x)
    elif codec == "fp16":
        np.testing.assert_allclose(out, x, rtol=1e-3, atol=1e-3)
    else:
        scale = (x.max() - x.min()) / 255.0
        assert np.abs(out - x).max() <= scale / 2 + 1e-6


def test_codec_packed_roundtrip_restores_zeros():
    rng = np.random.RandomState(1)
    keep = np.array([1, 5, 6, 10, 23])
    x = np.zeros((2, 3, 3, 24), np.float32)
    x[..., keep] = rng.randn(2, 3, 3, keep.size)
    for codec in ("fp32", "fp16", "int8"):
        buf = encode_feature(x, codec=codec, keep=keep)
        out, _ = decode_feature(buf)
        dead = np.setdiff1d(np.arange(24), keep)
        assert (out[..., dead] == 0).all()
        tol = {"fp32": 1e-7, "fp16": 1e-3, "int8": 0.05}[codec]
        np.testing.assert_allclose(out[..., keep], x[..., keep],
                                   rtol=tol, atol=tol)
    # packed int8 beats raw fp32 by > the keep fraction alone
    raw = len(encode_tensor(x))
    packed = len(encode_feature(x, codec="int8", keep=keep))
    assert packed < 0.25 * raw


def test_decode_any_dispatches_both_frames():
    x = np.arange(12, dtype=np.float32).reshape(3, 4)
    for buf in (encode_tensor(x), encode_feature(x, codec="fp32")):
        out, used = decode_any(buf)
        assert used == len(buf)
        np.testing.assert_array_equal(out, x)


def test_split_keep_indices_marks_only_live_channels(pruned_setup):
    cfg, params, masks, x, _ = pruned_setup
    for c in range(1, len(cfg.layers) + 1):
        keep = split_keep_indices(cfg, masks, c)
        act = np.asarray(cnn_apply(params, cfg, jnp.asarray(x),
                                   masks=masks, stop_layer=c))
        if keep is None:
            continue
        dead = np.setdiff1d(np.arange(act.shape[-1]), keep)
        assert (act[..., dead] == 0).all(), f"split {c}"


def test_collab_runner_packed_codec_lossless_fp32(pruned_setup):
    """fp32 + channel packing is bit-preserving end-to-end."""
    cfg, params, masks, x, want = pruned_setup
    runner = CollabRunner(params, cfg, 4, PAPER_PROFILE, masks=masks,
                          codec="fp32", pack=True)
    res = runner.infer(x)
    np.testing.assert_allclose(res["logits"], want, rtol=1e-5, atol=1e-5)
    dense = CollabRunner(params, cfg, 4, PAPER_PROFILE, masks=masks)
    assert res["timing"].tx_bytes < dense.infer(x)["timing"].tx_bytes


# ---------------------------------------------------------------------------
# pipelined streaming runtime
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kw", [dict(),
                                dict(compact=True),
                                dict(compact=True, microbatch=4),
                                dict(codec="fp32", pack=True)])
def test_streaming_matches_sequential(pruned_setup, kw):
    cfg, params, masks, x, _ = pruned_setup
    imgs = [x[i % 2:i % 2 + 1] for i in range(8)]
    seq = CollabRunner(params, cfg, 6, PAPER_PROFILE, masks=masks,
                       **{k: v for k, v in kw.items() if k != "microbatch"})
    pipe = StreamingCollabRunner(params, cfg, 6, PAPER_PROFILE, masks=masks,
                                 realtime_channel=False, **kw)
    rep = pipe.run(imgs)
    assert len(rep.results) == len(imgs)
    for img, got in zip(imgs, rep.results):
        want = seq.infer(img)["logits"]
        np.testing.assert_allclose(got["logits"], want, rtol=1e-4,
                                   atol=1e-4)
    assert rep.throughput_rps > 0
    assert set(rep.occupancy) == {"edge", "tx", "cloud"}
    assert all(0.0 <= v for v in rep.occupancy.values())


def test_streaming_edge_only_and_cloud_only(pruned_setup):
    cfg, params, masks, x, _ = pruned_setup
    n = len(cfg.layers)
    imgs = [x[:1]] * 3
    for split in (0, n):
        pipe = StreamingCollabRunner(params, cfg, split, PAPER_PROFILE,
                                     masks=masks, realtime_channel=False)
        rep = pipe.run(imgs)
        want = np.asarray(cnn_apply(params, cfg, imgs[0], masks=masks))
        np.testing.assert_allclose(rep.results[0]["logits"], want,
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# socket path: exact reads, compacted service, async pipelining
# ---------------------------------------------------------------------------
def test_recv_exact_reassembles_dribbled_stream():
    a, b = socket.socketpair()
    payload = bytes(range(256)) * 50

    def dribble():
        for i in range(0, len(payload), 97):
            a.sendall(payload[i:i + 97])
        a.close()

    t = threading.Thread(target=dribble)
    t.start()
    got = recv_exact(b, len(payload), chunk=64)
    t.join()
    assert got == payload
    with pytest.raises(EOFError):
        recv_exact(b, 1)
    b.close()


def test_socket_compact_int8_roundtrip(pruned_setup):
    cfg, params, masks, x, want = pruned_setup
    split, port = 6, 29491
    ready = threading.Event()
    srv = threading.Thread(target=serve_cloud,
                           args=(params, cfg, split, port),
                           kwargs=dict(masks=masks, max_requests=2,
                                       ready=ready, compact=True),
                           daemon=True)
    srv.start()
    assert ready.wait(10)
    client = EdgeClient(params, cfg, split, port, masks=masks,
                        compact=True, codec="int8")
    for _ in range(2):
        res = client.infer(x)
        np.testing.assert_allclose(res["logits"], want, rtol=0.05,
                                   atol=0.05)
    client.close()
    srv.join(10)
    assert not srv.is_alive()


def test_edge_client_submit_collect_pipelined(pruned_setup):
    """Async submit/collect returns the same logits as sync infer, in
    submission order."""
    cfg, params, masks, x, want = pruned_setup
    split, port = 6, 29492
    n_req = 6
    ready = threading.Event()
    srv = threading.Thread(target=serve_cloud,
                           args=(params, cfg, split, port),
                           kwargs=dict(masks=masks, max_requests=n_req,
                                       ready=ready, compact=True),
                           daemon=True)
    srv.start()
    assert ready.wait(10)
    client = EdgeClient(params, cfg, split, port, masks=masks,
                        compact=True)
    imgs = [x[i % 2:i % 2 + 1] for i in range(n_req)]
    wants = [np.asarray(cnn_apply(params, cfg, img, masks=masks))
             for img in imgs]
    for img in imgs:
        client.submit(img)
    first = client.collect(2)          # partial collect, then the rest
    results = first + client.collect()
    assert len(results) == n_req
    for res, w in zip(results, wants):
        np.testing.assert_allclose(res["logits"], w, rtol=1e-4, atol=1e-4)
        assert res["tx_bytes"] > 0
    client.close()
    srv.join(10)
    assert not srv.is_alive()
