"""Layer-level unit tests: attention paths, SSM scan, MoE routing, RoPE."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.models.layers.attention import (_band_mask, chunked_attention,
                                           naive_attention)
from repro.models.layers.moe import capacity, init_moe_params, moe_forward
from repro.models.layers.rope import (apply_rope, mrope_angles, rope_angles,
                                      text_mrope_positions)
from repro.models.layers.ssm import ssd_chunked


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def _qkv(key, B, S, H, Hkv, D):
    ks = jax.random.split(key, 3)
    return (jax.random.normal(ks[0], (B, S, H, D), jnp.float32),
            jax.random.normal(ks[1], (B, S, Hkv, D), jnp.float32),
            jax.random.normal(ks[2], (B, S, Hkv, D), jnp.float32))


@pytest.mark.parametrize("causal,window", [(True, None), (True, 7),
                                           (False, None)])
def test_chunked_equals_naive(causal, window):
    B, S, H, Hkv, D = 2, 33, 4, 2, 16
    q, k, v = _qkv(jax.random.PRNGKey(0), B, S, H, Hkv, D)
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    mask = _band_mask(jnp.arange(S), jnp.arange(S), causal, window)
    a = naive_attention(q, k, v, mask, 0.25)
    b = chunked_attention(q, k, v, pos, pos, causal, window, 0.25,
                          block_kv=8)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-5, atol=2e-5)


def test_band_mask_sentinel_excludes_padding():
    k_pos = jnp.array([0, 1, 2 ** 30])
    ok = _band_mask(jnp.arange(3), k_pos, causal=False, window=None)
    assert not bool(ok[:, 2].any())


def test_causal_mask_is_lower_triangular():
    ok = np.asarray(_band_mask(jnp.arange(5), jnp.arange(5), True, None))
    assert (ok == np.tril(np.ones((5, 5), bool))).all()


def test_sliding_window_width():
    ok = np.asarray(_band_mask(jnp.arange(10), jnp.arange(10), True, 3))
    for i in range(10):
        allowed = np.nonzero(ok[i])[0]
        assert allowed.min() == max(0, i - 2) and allowed.max() == i


# ---------------------------------------------------------------------------
# RoPE / M-RoPE
# ---------------------------------------------------------------------------
def test_rope_rotation_preserves_norm():
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 8, 2, 16))
    ang = rope_angles(jnp.arange(8)[None], 16, 1e4)
    y = apply_rope(x, ang)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)


def test_rope_relative_property():
    """<rot(q,m), rot(k,n)> depends only on m-n."""
    D = 16
    q = jax.random.normal(jax.random.PRNGKey(1), (D,))
    k = jax.random.normal(jax.random.PRNGKey(2), (D,))

    def dot_at(m, n):
        am = rope_angles(jnp.array([[m]], jnp.float32), D, 1e4)
        an = rope_angles(jnp.array([[n]], jnp.float32), D, 1e4)
        qr = apply_rope(q[None, None, None], am)[0, 0, 0]
        kr = apply_rope(k[None, None, None], an)[0, 0, 0]
        return float(qr @ kr)

    assert abs(dot_at(3, 1) - dot_at(7, 5)) < 1e-4
    assert abs(dot_at(4, 4) - dot_at(9, 9)) < 1e-4


def test_mrope_text_equals_standard_rope():
    """For text tokens (t == h == w) M-RoPE must reduce to standard RoPE."""
    D, B, S = 32, 2, 6
    sections = (4, 6, 6)              # sums to D//2
    pos3 = text_mrope_positions(B, S)
    am = mrope_angles(pos3, D, 1e4, sections)
    astd = rope_angles(jnp.broadcast_to(jnp.arange(S)[None], (B, S)), D, 1e4)
    np.testing.assert_allclose(np.asarray(am), np.asarray(astd), atol=1e-6)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------
def _moe_setup(E=4, k=2, d=16, de=32, score="softmax", shared=0, cf=4.0):
    moe = MoEConfig(num_experts=E, top_k=k, d_expert=de, num_shared=shared,
                    capacity_factor=cf, score_fn=score)
    params = init_moe_params(jax.random.PRNGKey(0), d, moe, "silu_glu",
                             jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, d), jnp.float32)
    return moe, params, x


def test_moe_output_finite_and_shaped():
    moe, params, x = _moe_setup()
    out, metrics = moe_forward(params, moe, x, "silu_glu")
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all())
    assert float(metrics.drop_frac) == 0.0       # cf=E/k => no drops


def test_moe_expert_mask_reroutes():
    """Masking experts changes routing but output stays finite; fully
    masked-to-one-expert equals dense through that expert."""
    moe, params, x = _moe_setup()
    full, _ = moe_forward(params, moe, x, "silu_glu")
    em = jnp.array([1.0, 0.0, 0.0, 0.0])
    only0, _ = moe_forward(params, moe, x, "silu_glu", expert_mask=em)
    assert bool(jnp.isfinite(only0).all())
    # expert-0-only: equals running expert 0 densely on every token
    h = jax.nn.silu(x @ params["w_up"][0]) * (x @ params["w_gate"][0])
    dense0 = h @ params["w_down"][0]
    np.testing.assert_allclose(np.asarray(only0), np.asarray(dense0),
                               rtol=2e-4, atol=2e-4)


def test_moe_sigmoid_scoring_and_shared():
    moe, params, x = _moe_setup(score="sigmoid", shared=1)
    out, metrics = moe_forward(params, moe, x, "silu_glu")
    assert bool(jnp.isfinite(out).all())
    assert float(metrics.aux_loss) >= 0.0


def test_moe_capacity_droppping_reported():
    moe, params, x = _moe_setup(cf=0.25)         # tiny capacity
    out, metrics = moe_forward(params, moe, x, "silu_glu")
    assert float(metrics.drop_frac) > 0.0
    assert bool(jnp.isfinite(out).all())


def test_capacity_multiple_of_8():
    moe, _, _ = _moe_setup()
    assert capacity(100, moe) % 8 == 0


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------
def _ssd_naive(xh, dt, A, Bm, Cm):
    """O(S) sequential recurrence oracle."""
    B, S, H, P = xh.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    Bh = np.repeat(np.asarray(Bm, np.float64), rep, 2)
    Ch = np.repeat(np.asarray(Cm, np.float64), rep, 2)
    x = np.asarray(xh, np.float64)
    dtn = np.asarray(dt, np.float64)
    An = np.asarray(A, np.float64)
    y = np.zeros((B, S, H, P))
    state = np.zeros((B, H, P, N))
    for t in range(S):
        decay = np.exp(dtn[:, t] * An[None])           # (B, H)
        state = (state * decay[..., None, None]
                 + np.einsum("bh,bhp,bhn->bhpn", dtn[:, t], x[:, t],
                             Bh[:, t]))
        y[:, t] = np.einsum("bhpn,bhn->bhp", state, Ch[:, t])
    return y, state


@pytest.mark.parametrize("chunk", [4, 16, 64])
def test_ssd_chunked_matches_sequential(chunk):
    B, S, H, G, P, N = 1, 32, 4, 2, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    xh = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.5
    y, fs = ssd_chunked(xh, dt, A, Bm, Cm, chunk)
    yn, fsn = _ssd_naive(xh, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y), yn, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(fs), fsn, rtol=1e-3, atol=1e-3)


def test_ssd_chunk_invariance():
    """Different chunk sizes give the same result (state-space duality)."""
    B, S, H, G, P, N = 2, 48, 2, 1, 4, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    xh = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.5
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.5
    y1, s1 = ssd_chunked(xh, dt, A, Bm, Cm, 8)
    y2, s2 = ssd_chunked(xh, dt, A, Bm, Cm, 24)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=1e-4, atol=1e-4)
