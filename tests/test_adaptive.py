"""Adaptive split control: time-varying link traces, the trace-driven
channels, the EWMA bandwidth estimator + hysteresis controller, the
RESPLIT live-switch protocol, and the adaptive serving sessions."""
from __future__ import annotations

import numpy as np
import pytest

import jax

from repro import serving
from repro.core.collab.adaptive import (AdaptivePolicy,
                                        AdaptiveSplitController,
                                        BandwidthEstimator)
from repro.core.collab.channel import SimChannel
from repro.core.collab.protocol import (PROTOCOL_VERSION, decode_resplit,
                                        encode_resplit, is_hello,
                                        is_resplit)
from repro.core.collab.runtime import CollabRunner, SplitFnBank
from repro.core.partition.profiles import (ComputeProfile, LinkProfile,
                                           LinkTrace, PAPER_PROFILE,
                                           TRACES, TraceSegment,
                                           TwoTierProfile)
from repro.core.pruning.masks import cnn_masks_from_ratios
from repro.models.cnn import (cnn_apply, init_cnn_params, prunable_layers,
                              tiny_cnn_config)

#: an edge so weak that the greedy optimum genuinely moves with bandwidth
#: (on the paper's i7 the 32px tiny CNN is device-dominant at any rate)
MCU_EDGE = ComputeProfile("MCU-class edge", flops_per_s=0.15e9,
                          mem_bw=0.5e9, overhead_s=3e-4)


@pytest.fixture(scope="module")
def pruned_setup():
    cfg = tiny_cnn_config(num_classes=7, hw=32)
    params = init_cnn_params(jax.random.PRNGKey(0), cfg)
    masks = cnn_masks_from_ratios(
        params, cfg, {i: 0.5 for i in prunable_layers(cfg)})
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (1, 32, 32, 3)),
                   np.float32)
    return cfg, params, masks, x


def mcu_profile(mbps: float, rtt_s: float = 1e-3) -> TwoTierProfile:
    return TwoTierProfile(MCU_EDGE, PAPER_PROFILE.server,
                          LinkProfile("test", bandwidth=mbps * 1e6 / 8,
                                      rtt_s=rtt_s))


# ---------------------------------------------------------------------------
# link traces
# ---------------------------------------------------------------------------
def test_link_trace_piecewise_lookup():
    tr = LinkTrace.from_mbps("t", [(1.0, 80.0), (2.0, 8.0),
                                   (float("inf"), 2.0)], rtt_ms=5.0)
    assert tr.state_at(0.0) == (80e6 / 8, 5e-3)
    assert tr.state_at(0.999) == (80e6 / 8, 5e-3)
    assert tr.state_at(1.0) == (8e6 / 8, 5e-3)
    assert tr.state_at(2.999)[0] == 8e6 / 8
    assert tr.state_at(100.0)[0] == 2e6 / 8
    bw, _, span = tr.span_at(0.25)
    assert bw == 80e6 / 8 and span == pytest.approx(0.75)
    assert tr.span_at(10.0)[2] == float("inf")     # settled terminal segment


def test_link_trace_loop_wraps_and_rejects_inf():
    tr = LinkTrace.from_mbps("saw", [(1.0, 40.0), (1.0, 4.0)], loop=True)
    assert tr.state_at(0.5)[0] == 40e6 / 8
    assert tr.state_at(1.5)[0] == 4e6 / 8
    assert tr.state_at(2.5)[0] == 40e6 / 8         # wrapped around
    with pytest.raises(ValueError, match="loop"):
        LinkTrace.from_mbps("bad", [(float("inf"), 1.0)], loop=True)
    with pytest.raises(ValueError, match="bandwidth"):
        LinkTrace.from_mbps("dead", [(1.0, 50.0), (float("inf"), 0.0)])
    assert set(TRACES) == {"wifi_steady", "wifi_degrading", "lte_handover",
                           "congested_sawtooth"}


def test_sim_channel_charges_trace_segments_exactly():
    # 1 MB/s for 1 s, then 0.1 MB/s: a 1.5 MB send drains 1 MB from the
    # fast segment and 0.5 MB from the slow one
    tr = LinkTrace("t", (TraceSegment(1.0, 1e6, 0.0),
                         TraceSegment(float("inf"), 1e5, 0.0)))
    ch = SimChannel(LinkProfile("unused", bandwidth=1.0), trace=tr)
    t = ch.send(1_500_000)
    assert t == pytest.approx(1.0 + 0.5e6 / 1e5)
    assert ch.elapsed_s == pytest.approx(t)
    # the next send starts in the slow segment
    assert ch.send(100_000) == pytest.approx(1.0)


def test_sim_channel_advance_moves_the_clock_without_bytes():
    tr = LinkTrace.from_mbps("t", [(1.0, 80.0), (float("inf"), 8.0)],
                             rtt_ms=0.0)
    ch = SimChannel(LinkProfile("unused", bandwidth=1.0), trace=tr)
    fast = ch.send(100_000)
    ch.advance(2.0)                       # compute time: link degrades
    slow = ch.send(100_000)
    assert slow == pytest.approx(10 * fast)
    assert ch.sent_bytes == 200_000


def test_sim_channel_send_spans_many_segments():
    # three finite segments then a terminal one; one send drains all
    # four piecewise: 1 MB @ 1 MB/s + 0.5 MB @ 0.5 MB/s + 0.2 MB @
    # 0.2 MB/s + the remaining 0.3 MB @ 0.1 MB/s
    tr = LinkTrace("t", (TraceSegment(1.0, 1e6, 0.0),
                         TraceSegment(1.0, 5e5, 0.0),
                         TraceSegment(1.0, 2e5, 0.0),
                         TraceSegment(float("inf"), 1e5, 0.0)))
    ch = SimChannel(LinkProfile("unused", bandwidth=1.0), trace=tr)
    t = ch.send(2_000_000)
    assert t == pytest.approx(1.0 + 1.0 + 1.0 + 3e5 / 1e5)
    assert ch.elapsed_s == pytest.approx(t)


def test_sim_channel_looping_trace_wraps_past_the_end():
    # 1 s fast + 1 s slow, looping: a send launched 0.5 s before the
    # trace end pays 0.5 s of fast bandwidth, wraps, and keeps draining
    # from the schedule's start — the wrap must not reset or stall
    tr = LinkTrace("loop", (TraceSegment(1.0, 1e6, 0.0),
                            TraceSegment(1.0, 1e5, 0.0)), loop=True)
    ch = SimChannel(LinkProfile("unused", bandwidth=1.0), trace=tr)
    ch.advance(1.5)            # mid slow segment, 0.5 s before the wrap
    # 0.5 s * 0.1 MB/s = 50 KB in the slow tail, then 150 KB at the
    # wrapped-around fast segment
    t = ch.send(200_000)
    assert t == pytest.approx(0.5 + 150_000 / 1e6)
    # after the wrap the clock sits inside cycle 2's fast segment
    assert ch.send(100_000) == pytest.approx(0.1)


def test_sim_channel_advance_interleaved_with_sends():
    # alternating compute (advance) and tx (send) must walk the same
    # piecewise schedule as one continuous clock
    tr = LinkTrace("t", (TraceSegment(1.0, 1e6, 0.0),
                         TraceSegment(1.0, 2e5, 0.0),
                         TraceSegment(float("inf"), 5e4, 0.0)))
    ch = SimChannel(LinkProfile("unused", bandwidth=1.0), trace=tr)
    assert ch.send(500_000) == pytest.approx(0.5)   # t: 0 -> 0.5, fast
    ch.advance(0.5)                                 # t = 1.0: slow starts
    # 0.1 MB at 0.2 MB/s
    assert ch.send(100_000) == pytest.approx(0.5)   # t -> 1.5
    ch.advance(0.5)                                 # t = 2.0: crawl starts
    assert ch.send(50_000) == pytest.approx(1.0)    # 50 KB at 50 KB/s
    assert ch.elapsed_s == pytest.approx(3.0)
    assert ch.sent_bytes == 650_000


# ---------------------------------------------------------------------------
# estimator + controller
# ---------------------------------------------------------------------------
def test_bandwidth_estimator_ewma_and_rtt_subtraction():
    est = BandwidthEstimator(alpha=0.5, min_samples=2, rtt_s=0.01)
    assert est.bandwidth is None and not est.ready
    est.observe(100_000, 0.11)            # 100 KB in 0.1 s net: 1 MB/s
    assert est.bandwidth == pytest.approx(1e6)
    assert not est.ready
    est.observe(300_000, 0.16)            # 2 MB/s sample
    assert est.ready
    assert est.bandwidth == pytest.approx(1.5e6)   # EWMA midpoint
    est.observe(0, 0.0)                   # edge-only request: no signal
    assert est.bandwidth == pytest.approx(1.5e6)


def test_controller_resweeps_and_guards_with_dwell(pruned_setup):
    cfg, params, masks, _ = pruned_setup
    policy = AdaptivePolicy(candidates=(0, 3, 6, 13), ewma_alpha=1.0,
                            min_samples=1, hysteresis=0.05, dwell=2)
    ctl = AdaptiveSplitController.for_deployment(
        cfg, policy, 0, mcu_profile(50.0), masks=masks, compact=True)
    fast, slow = 50e6 / 8, 2e6 / 8
    # at the deployment bandwidth the current split stays optimal
    assert ctl.step(12_000, 12_000 / fast + 1e-3) is None
    assert ctl.step(12_000, 12_000 / fast + 1e-3) is None
    # the link collapses; dwell already satisfied, so the sweep fires
    sw = ctl.step(12_000, 12_000 / slow + 1e-3)
    assert sw is not None and sw.old_split == 0 and sw.new_split != 0
    assert ctl.split == sw.new_split
    # dwell: the very next observation cannot switch again
    assert ctl.step(12_000, 12_000 / slow + 1e-3) is None
    assert len(ctl.history) == 1


def test_controller_hysteresis_blocks_marginal_wins(pruned_setup):
    cfg, params, masks, _ = pruned_setup
    policy = AdaptivePolicy(candidates=(0, 3, 6, 13), ewma_alpha=1.0,
                            min_samples=1, hysteresis=10.0, dwell=1)
    ctl = AdaptiveSplitController.for_deployment(
        cfg, policy, 0, mcu_profile(50.0), masks=masks, compact=True)
    # impossible hysteresis: even a collapsed link must not trigger
    for _ in range(5):
        assert ctl.step(12_000, 12_000 / (2e6 / 8) + 1e-3) is None
    assert ctl.split == 0


def test_controller_rejects_initial_split_outside_candidates(pruned_setup):
    cfg, _, masks, _ = pruned_setup
    policy = AdaptivePolicy(candidates=(3, 6))
    with pytest.raises(ValueError, match="candidates"):
        AdaptiveSplitController.for_deployment(cfg, policy, 5,
                                               mcu_profile(50.0),
                                               masks=masks, compact=True)


# ---------------------------------------------------------------------------
# RESPLIT protocol + fn bank
# ---------------------------------------------------------------------------
def test_resplit_frame_roundtrip():
    buf = encode_resplit(11)
    assert is_resplit(buf) and not is_hello(buf)
    split, status, version = decode_resplit(buf)
    assert (split, status, version) == (11, 0, PROTOCOL_VERSION)
    split, status, _ = decode_resplit(encode_resplit(3, status=1))
    assert (split, status) == (3, 1)
    with pytest.raises(ValueError, match="magic"):
        decode_resplit(b"\0" * 16)


def test_split_fn_bank_caches_and_validates(pruned_setup):
    cfg, params, masks, x = pruned_setup
    bank = SplitFnBank(params, cfg, masks, compact=True)
    e1, c1, _ = bank.get(6)
    assert bank.get(6)[0] is e1                    # cached
    with pytest.raises(ValueError, match="split"):
        bank.get(99)
    bank.warm((0, 6, 13), x)
    want = np.asarray(c1(e1(x)))
    edge13, cloud13, _ = bank.get(13)
    np.testing.assert_array_equal(np.asarray(edge13(x)), want)


def test_collab_runner_set_split_is_bit_stable(pruned_setup):
    cfg, params, masks, x = pruned_setup
    runner = CollabRunner(params, cfg, 6, PAPER_PROFILE, masks=masks,
                          compact=True, codec="fp32")
    want = runner.infer(x)["logits"]
    for c in (0, 3, 13, 6):
        runner.set_split(c)
        np.testing.assert_array_equal(runner.infer(x)["logits"], want)


# ---------------------------------------------------------------------------
# live socket resplit (no reconnect)
# ---------------------------------------------------------------------------
def make_adaptive_plan(pruned_setup, port, **kw):
    cfg, params, masks, _ = pruned_setup
    kw.setdefault("adaptive",
                  AdaptivePolicy(candidates=(0, 3, 6, 13)))
    return serving.DeploymentPlan.from_args(
        params, cfg, 6, masks=masks, compact=True, codec="fp32",
        shape_link=False, port=port, **kw)


def test_socket_resplit_switches_without_reconnect(pruned_setup):
    _, _, _, x = pruned_setup
    plan = make_adaptive_plan(pruned_setup, port=29530)
    with serving.CloudServer(plan, max_clients=1):
        with serving.connect(plan, backend="socket") as sess:
            want = sess.infer(x)["logits"]
            sock_before = sess._client.sock
            for c in (3, 13, 0, 6):
                sess.resplit(c)
                assert sess.split == c
                np.testing.assert_array_equal(sess.infer(x)["logits"],
                                              want)
            assert sess._client.sock is sock_before    # same connection


def test_shaped_socket_t_tx_is_modeled_link_cost(pruned_setup):
    """On a shaped socket the estimator's t_tx signal is the shaper's
    modeled cost (payload/bandwidth + RTT), not the burst-distorted
    wall-clock — the signal is deterministic and tracks the link."""
    cfg, params, masks, x = pruned_setup
    link = LinkProfile("slow", bandwidth=1e6, rtt_s=5e-3)
    plan = serving.DeploymentPlan.from_args(
        params, cfg, 3, masks=masks, compact=True, codec="fp32",
        port=29535, profile=TwoTierProfile(MCU_EDGE, PAPER_PROFILE.server,
                                           link))
    with serving.CloudServer(plan, max_clients=1):
        with serving.connect(plan, backend="socket") as sess:
            res = sess.infer(x)
            t_tx = sess._client.infer(x)["t_tx"]
    # payload + 8B prefix over 1 MB/s + 5 ms RTT
    assert t_tx == pytest.approx((res["tx_bytes"] + 8) / 1e6 + 5e-3,
                                 rel=0.05)


def test_manual_resplit_restarts_controller_dwell(pruned_setup):
    cfg, _, masks, _ = pruned_setup
    policy = AdaptivePolicy(candidates=(0, 3, 6, 13), ewma_alpha=1.0,
                            min_samples=1, hysteresis=0.0, dwell=3)
    ctl = AdaptiveSplitController.for_deployment(
        cfg, policy, 0, mcu_profile(50.0), masks=masks, compact=True)
    slow = 2e6 / 8
    for _ in range(3):
        ctl.observe(12_000, 12_000 / slow + 1e-3)
    ctl.note_external_switch(13)         # operator override
    assert ctl.split == 13
    # dwell restarted: the controller holds the override for 3 requests
    assert ctl.step(500, 500 / slow + 1e-3) is None
    assert ctl.split == 13


def test_socket_adaptive_infer_many_keeps_control_loop(pruned_setup):
    """infer_many on an adaptive plan falls back to the sequential loop
    (a RESPLIT cannot interleave with in-flight pipelined frames), so the
    controller still observes every request."""
    _, _, _, x = pruned_setup
    plan = make_adaptive_plan(pruned_setup, port=29536)
    with serving.CloudServer(plan, max_clients=1):
        with serving.connect(plan, backend="socket") as sess:
            out = sess.infer_many([x] * 3)
            assert sess._controller.n_requests == 3
    # sequential results carry per-request upstream time (pipelined don't)
    assert all(r["t_upstream"] is not None for r in out)


def test_socket_resplit_outside_candidates_rejected(pruned_setup):
    _, _, _, x = pruned_setup
    plan = make_adaptive_plan(pruned_setup, port=29531)
    with serving.CloudServer(plan, max_clients=1):
        with serving.connect(plan, backend="socket") as sess:
            want = sess.infer(x)["logits"]
            with pytest.raises(serving.PlanMismatchError, match="resplit"):
                sess.resplit(5)            # not in (0, 3, 6, 13)
            # the connection survives a rejected proposal
            np.testing.assert_array_equal(sess.infer(x)["logits"], want)
            assert sess.split == 6


# ---------------------------------------------------------------------------
# adaptive sessions end-to-end on a degrading trace
# ---------------------------------------------------------------------------
def test_adaptive_local_session_resplits_on_degrading_trace(pruned_setup):
    cfg, params, masks, x = pruned_setup
    trace = LinkTrace.from_mbps(
        "degrade", [(0.08, 50.0), (float("inf"), 2.0)], rtt_ms=1.0)
    policy = AdaptivePolicy(candidates=(0, 3, 6, 13), ewma_alpha=0.5,
                            min_samples=2, hysteresis=0.05, dwell=2)
    plan = serving.DeploymentPlan.from_args(
        params, cfg, 0, masks=masks, compact=True, codec="fp32",
        profile=mcu_profile(50.0), adaptive=policy, shape_link=False)
    sess = serving.connect(plan, backend="local", trace=trace)
    fixed = serving.connect(
        serving.DeploymentPlan.from_args(params, cfg, 0, masks=masks,
                                         compact=True, codec="fp32",
                                         profile=mcu_profile(50.0),
                                         shape_link=False),
        backend="local", trace=trace)
    for _ in range(24):
        res, ref = sess.infer(x), fixed.infer(x)
        np.testing.assert_array_equal(res["logits"], ref["logits"])
    assert len(sess.switches) >= 1, "never re-split on a collapsing link"
    assert sess.split != 0
    assert sess.switches[0].old_split == 0


# ---------------------------------------------------------------------------
# plan contract: the adaptive section
# ---------------------------------------------------------------------------
def test_plan_adaptive_section_in_digest(pruned_setup):
    base = make_adaptive_plan(pruned_setup, port=29532, adaptive=None)
    adaptive = make_adaptive_plan(pruned_setup, port=29532)
    assert "adaptive" not in base.contract()
    assert adaptive.contract()["adaptive"]["candidates"] == [0, 3, 6, 13]
    assert base.digest != adaptive.digest
    other = make_adaptive_plan(
        pruned_setup, port=29532,
        adaptive=AdaptivePolicy(candidates=(0, 3, 6, 13), dwell=9))
    assert other.digest != adaptive.digest        # knobs are contractual


def test_plan_adaptive_candidates_normalized_and_validated(pruned_setup):
    plan = make_adaptive_plan(
        pruned_setup, port=29533,
        adaptive=AdaptivePolicy(candidates=(3, 3, 0)))
    assert plan.adaptive.candidates == (0, 3, 6)   # sorted, uniq, + split
    with pytest.raises(ValueError, match="candidates"):
        make_adaptive_plan(pruned_setup, port=29533,
                           adaptive=AdaptivePolicy(candidates=(99,)))


def test_plan_adaptive_save_load_roundtrip(pruned_setup, tmp_path):
    plan = make_adaptive_plan(pruned_setup, port=29534)
    path = plan.save(str(tmp_path / "adeploy"))
    loaded = serving.DeploymentPlan.load(path)
    assert loaded.digest == plan.digest
    assert loaded.adaptive == plan.adaptive
