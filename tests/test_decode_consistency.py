"""Cache-path consistency: forward (full sequence) == prefill + decode_step,
for every causal architecture family. This is the invariant split serving
relies on."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from conftest import moe_no_drop, smoke_batch
from repro.models import transformer as tr

TOL = 2e-3


def _full_and_decoded(cfg, B=2, S=16, n_decode=3, seed=0):
    params = tr.init_params(cfg, jax.random.PRNGKey(seed))
    batch = smoke_batch(cfg, B, S, seed=seed, with_labels=False)
    logits_full, _ = tr.forward(params, cfg, batch)
    max_len = S + cfg.vision_tokens + 4
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :S - n_decode]
    lg, cache = tr.prefill(params, cfg, pre, max_len=max_len)
    outs = []
    for t in range(S - n_decode, S):
        lg, cache = tr.decode_step(params, cfg, cache,
                                   batch["tokens"][:, t:t + 1])
        outs.append(lg)
    got = jnp.stack(outs, axis=1)
    want = logits_full[:, cfg.vision_tokens:][:, -n_decode:]
    return got, want


def test_decode_matches_forward(smoke_cfg):
    cfg = moe_no_drop(smoke_cfg)
    if not cfg.causal:
        pytest.skip("encoder-only: no decode")
    got, want = _full_and_decoded(cfg)
    err = float(jnp.max(jnp.abs(got - want)))
    assert err < TOL, f"{cfg.name}: {err}"


def test_prefill_rejects_short_max_len(smoke_cfg):
    cfg = smoke_cfg
    if (not cfg.causal or cfg.sliding_window is not None
            or cfg.attention != "gqa" or cfg.arch_type in ("ssm", "hybrid")):
        pytest.skip("guard applies to causal GQA KV caches only "
                    "(SSM state is O(1); MLA keeps the full latent)")
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    batch = smoke_batch(cfg, 2, 16, with_labels=False)
    with pytest.raises(ValueError):
        tr.prefill(params, cfg, batch, max_len=4)


def test_sliding_window_rolling_cache():
    """Decode far past the window: rolling buffer must equal full forward."""
    from repro.configs.registry import get_smoke_config
    cfg = get_smoke_config("mixtral-8x7b").replace(dtype="float32")
    cfg = moe_no_drop(cfg).replace(sliding_window=8)
    S = 24                                     # 3x the window
    params = tr.init_params(cfg, jax.random.PRNGKey(1))
    tok = jax.random.randint(jax.random.PRNGKey(2), (1, S), 0,
                             cfg.vocab_size)
    logits_full, _ = tr.forward(params, cfg, {"tokens": tok})
    lg, cache = tr.prefill(params, cfg, {"tokens": tok[:, :4]}, max_len=S)
    for t in range(4, S):
        lg, cache = tr.decode_step(params, cfg, cache, tok[:, t:t + 1])
    err = float(jnp.max(jnp.abs(lg - logits_full[:, -1])))
    assert err < TOL, err


def test_scan_vs_unrolled_stack(smoke_cfg):
    """scan_layers=False (dry-run mode) produces identical logits."""
    cfg = smoke_cfg
    params = tr.init_params(cfg, jax.random.PRNGKey(0))
    batch = smoke_batch(cfg, 2, 8, with_labels=False)
    a, _ = tr.forward(params, cfg, batch)
    b, _ = tr.forward(params, cfg.replace(scan_layers=False), batch)
    assert float(jnp.max(jnp.abs(a - b))) < 1e-5
